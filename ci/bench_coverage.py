#!/usr/bin/env python3
"""CI gate for the perf trajectory: validate a freshly measured perf_probe
summary and diff its probe-name coverage against the committed BENCH_*.json
baselines.

    python3 ci/bench_coverage.py <fresh.json> [--repo-root DIR]

Checks (offline, stdlib only):

1. ``fresh.json`` parses and every entry matches the ``Suite::to_json``
   schema: name -> {ns_per_op, ops_per_s, p10_ns, p90_ns, iters, samples},
   with positive timings, consistent ns/ops inverses, and p10 <= p90.
2. Every probe name appearing in any committed ``BENCH_*.json`` also
   appears in the fresh run — a renamed or dropped probe breaks the
   trajectory's diffability and must be a deliberate baseline update, not
   an accident. Extra fresh probes are fine (they are tomorrow's
   baseline). With no committed baselines yet, the fresh file simply
   seeds the trajectory.

Absolute timings are deliberately NOT compared: shared CI runners are too
noisy to gate on; the committed numbers are quiet-box references (see
README "Kernels & perf trajectory").
"""

import glob
import json
import os
import sys

REQUIRED = ("ns_per_op", "ops_per_s", "p10_ns", "p90_ns", "iters", "samples")

# The transport probes are the acceptance evidence for the binary framed
# transport (ISSUE 7), the sample/partition probes for the query engine
# (ISSUE 8), the cache.*/cluster.gather_* probes for the versioned
# read-path cache (ISSUE 9), and the blob.*/cluster.repair_* probes for
# the zero-copy binary data plane (ISSUE 10): they must be present in
# every fresh run explicitly, not just via the committed-baseline diff
# (which would stop gating them if the baselines were ever pruned).
REQUIRED_PROBES = (
    "frame.encode_request_ns",
    "frame.encode_request_json_ns",
    "frame.decode_request_ns",
    "frame.decode_request_json_ns",
    "frame.encode_response_ns",
    "frame.encode_response_json_ns",
    "frame.decode_response_ns",
    "frame.decode_response_json_ns",
    "transport.sat.framed_ns",
    "transport.sat.framed_p99_ns",
    "transport.sat.json_ns",
    "transport.sat.json_p99_ns",
    "sample.draw32_k256_ns",
    "sample.draw32_k1024_ns",
    "sample.union8_k256_ns",
    "partition.total_weight_k256_ns",
    "partition.total_weight_k1024_ns",
    "cache.merge_keys_hit_ns",
    "cache.merge_keys_miss_ns",
    "cache.topk_hit_ns",
    "cluster.gather_cold_ns",
    "cluster.gather_warm_ns",
    "blob.decode_copy_ns",
    "blob.decode_view_ns",
    "blob.fetch_hex_ns",
    "blob.fetch_binary_ns",
    "cluster.repair_hex_ns",
    "cluster.repair_binary_ns",
)


def fail(msg):
    print(f"bench_coverage: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def validate_schema(path, data):
    if not isinstance(data, dict) or not data:
        fail(f"{path}: expected a non-empty name->stats object")
    for name, stats in data.items():
        if not isinstance(stats, dict):
            fail(f"{path}: probe '{name}' is not an object")
        for key in REQUIRED:
            if key not in stats:
                fail(f"{path}: probe '{name}' missing '{key}'")
            if not isinstance(stats[key], (int, float)):
                fail(f"{path}: probe '{name}' field '{key}' is not numeric")
        ns, ops = stats["ns_per_op"], stats["ops_per_s"]
        if ns <= 0 or ops <= 0:
            fail(f"{path}: probe '{name}' has non-positive timing ({ns} ns, {ops} ops/s)")
        if abs(ns * ops / 1e9 - 1.0) > 1e-6:
            fail(f"{path}: probe '{name}' ns/ops inconsistent ({ns} * {ops} != 1e9)")
        if stats["p10_ns"] > stats["p90_ns"]:
            fail(f"{path}: probe '{name}' p10 > p90")
        if stats["iters"] < 1 or stats["samples"] < 1:
            fail(f"{path}: probe '{name}' has no measurements")


def main():
    args = sys.argv[1:]
    root = "."
    if "--repo-root" in args:
        i = args.index("--repo-root")
        root = args[i + 1]
        del args[i : i + 2]
    if len(args) != 1:
        fail("usage: bench_coverage.py <fresh.json> [--repo-root DIR]")
    fresh_path = args[0]

    with open(fresh_path) as f:
        fresh = json.load(f)
    validate_schema(fresh_path, fresh)
    print(f"bench_coverage: {fresh_path}: {len(fresh)} probes, schema OK")

    missing = sorted(set(REQUIRED_PROBES) - set(fresh))
    if missing:
        fail(f"{fresh_path}: required transport probe(s) not measured: {missing}")
    print(f"bench_coverage: all {len(REQUIRED_PROBES)} required transport probes measured")

    baselines = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if not baselines:
        print("bench_coverage: no committed baselines yet — fresh run seeds the trajectory")
        return
    for bpath in baselines:
        with open(bpath) as f:
            base = json.load(f)
        validate_schema(bpath, base)
        missing = sorted(set(base) - set(fresh))
        if missing:
            fail(
                f"{bpath}: {len(missing)} probe(s) vanished from the fresh run "
                f"(rename/drop must be a deliberate baseline update): {missing[:10]}"
            )
        print(f"bench_coverage: {bpath}: all {len(base)} probes still measured")
    print("bench_coverage: OK")


if __name__ == "__main__":
    main()
