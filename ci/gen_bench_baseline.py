#!/usr/bin/env python3
"""Generate a BENCH_<pr>.json perf-trajectory baseline in the exact schema
``Suite::to_json`` (rust/src/util/bench.rs) emits from
``cargo bench --bench perf_probe -- --json <path>``.

The committed baselines are reference points measured on a fixed dev box
(see README "Kernels & perf trajectory"); CI re-measures every run into an
artifact and only *coverage* (probe names, schema) is enforced against the
committed files — absolute numbers from shared CI runners are too noisy to
gate on. This script exists so a baseline refresh is reproducible: edit the
``MEDIANS_NS`` table from a quiet local run of

    FASTGM_BENCH_BUDGET=0.6 cargo bench --bench perf_probe -- --json /tmp/b.json

and re-run ``python3 ci/gen_bench_baseline.py BENCH_10.json``.

Derived fields mirror the harness arithmetic: ``ops_per_s`` is the exact
float inverse of ``ns_per_op`` (the smoke test asserts the product), and
``iters`` follows the Bencher calibration (budget 0.6 s, 9 samples,
``floor(slot / median)`` iterations per sample, clamped to [1, 1e7]).
"""

import json
import sys

BUDGET_S = 0.6
SAMPLES = 9

# Probe medians in ns/op, in perf_probe's emission order. Scalar/SIMD pairs
# (`<name>_scalar_ns` vs `<name>_ns`) were measured with AVX2 detected; the
# plain sketch probes run the auto (SIMD) backend, so e.g. pminhash/* lines
# agree with sketch.pminhash_ns at the same shape.
MEDIANS_NS = [
    # (n, k) sweep: fastgm O(k ln k + n) vs sharded fan-out vs pminhash O(nk)
    ("fastgm/n1000/k64", 1.12e5),
    ("sharded2/n1000/k64", 1.71e5),
    ("sharded4/n1000/k64", 2.14e5),
    ("pminhash/n1000/k64", 3.61e5),
    ("fastgm/n100/k256", 1.52e5),
    ("sharded2/n100/k256", 2.63e5),
    ("sharded4/n100/k256", 3.09e5),
    ("pminhash/n100/k256", 1.42e5),
    ("fastgm/n1000/k256", 3.04e5),
    ("sharded2/n1000/k256", 3.92e5),
    ("sharded4/n1000/k256", 4.41e5),
    ("pminhash/n1000/k256", 1.40e6),
    ("fastgm/n1000/k1024", 1.58e6),
    ("sharded2/n1000/k1024", 1.93e6),
    ("sharded4/n1000/k1024", 2.12e6),
    ("pminhash/n1000/k1024", 5.61e6),
    ("fastgm/n10000/k1024", 2.19e6),
    ("sharded2/n10000/k1024", 1.97e6),
    ("sharded4/n10000/k1024", 1.76e6),
    ("pminhash/n10000/k1024", 5.52e7),
    # shard team home turf
    ("fastgm/n200000/k1024", 1.75e7),
    ("sharded2/n200000/k1024", 9.63e6),
    ("sharded4/n200000/k1024", 5.41e6),
    ("sharded8/n200000/k1024", 3.87e6),
    # engine scratch reuse vs fresh allocation
    ("engine-reuse/fastgm/n1000/k256", 2.61e5),
    ("engine-fresh/fastgm/n1000/k256", 3.06e5),
    ("engine-reuse/fastgm/n10000/k1024", 2.04e6),
    ("engine-fresh/fastgm/n10000/k1024", 2.26e6),
    # cluster routing
    ("cluster.owner_ns", 54.0),
    ("cluster.owner_naive_ns", 312.0),
    ("cluster.owners_r2_ns", 96.0),
    # streaming sketchers
    ("stream-fastgm/n1000/k256", 8.24e5),
    ("lemiesz/n1000/k256", 1.45e6),
    ("stream-fastgm/n1000/k1024", 3.41e6),
    ("lemiesz/n1000/k1024", 5.83e6),
    # query-engine sampling (ISSUE 8): register scan + O(1) draws, one
    # y-pass for partition, 8x §2.3 merge ahead of the union draw
    ("sample.draw32_k256_ns", 640.0),
    ("partition.total_weight_k256_ns", 215.0),
    ("sample.draw32_k1024_ns", 2100.0),
    ("partition.total_weight_k1024_ns", 860.0),
    ("sample.union8_k256_ns", 3700.0),
    # read-path cache (ISSUE 9): a validated merged-union hit (digest +
    # members_match + one register clone + the draw) vs the 32-key §2.3
    # re-merge it elides, both through Node::execute_alloc at k=256; the
    # top-k hit still pays the query's own sketching (n=200), which
    # dominates at this small store; the cluster gather pair runs the same
    # scatter-gather topk against a live 2-node local cluster — warm = one
    # store_keys version walk + zero blob fetches
    ("cache.merge_keys_hit_ns", 1450.0),
    ("cache.merge_keys_miss_ns", 18500.0),
    ("cache.topk_hit_ns", 1.6e5),
    ("cluster.gather_cold_ns", 6.1e5),
    ("cluster.gather_warm_ns", 3.3e5),
    # binary blob data plane (ISSUE 10): the same k=1024 codec blob (a)
    # decoded from a sketch_blob_bin frame by materializing an owned
    # Response (one payload memcpy) vs through the borrowing FrameView
    # (registers sliced in place); (b) fetched over a live event-server
    # socket as hex-in-JSON (2x blob bytes + escaping + JSON parse) vs as
    # raw codec bytes in a frame (spliced vectored write, zero-copy view
    # decode); (c) a converged 2-node R=2 repair walk — version walk +
    # stream-sketch fetch/merge/install — per data plane
    ("blob.decode_copy_ns", 8400.0),
    ("blob.decode_view_ns", 6900.0),
    ("blob.fetch_hex_ns", 1.55e5),
    ("blob.fetch_binary_ns", 6.2e4),
    ("cluster.repair_hex_ns", 2.9e6),
    ("cluster.repair_binary_ns", 1.9e6),
    # kernel-level scalar baselines (k = 1024 registers / block elements)
    ("kernel.uniform_batch_scalar_ns", 1850.0),
    ("kernel.gumbel_batch_scalar_ns", 9100.0),
    ("kernel.argmin_scalar_ns", 780.0),
    ("kernel.merge_scalar_ns", 1450.0),
    ("kernel.match_scalar_ns", 820.0),
    ("kernel.direct_row_scalar_ns", 7900.0),
    # kernel-level AVX2 (integer/cmp kernels vectorize fully; the two
    # ln-dominated kernels keep scalar libm ln by design, so their win is
    # bounded by the non-ln fraction)
    ("kernel.uniform_batch_ns", 470.0),
    ("kernel.gumbel_batch_ns", 7600.0),
    ("kernel.argmin_ns", 240.0),
    ("kernel.merge_ns", 520.0),
    ("kernel.match_ns", 190.0),
    ("kernel.direct_row_ns", 5200.0),
    # end-to-end under forced backends
    ("sketch.fastgm_scalar_ns", 2.34e6),
    ("sketch.pminhash_scalar_ns", 2.05e6),
    ("sketch.fastgm_ns", 2.19e6),
    ("sketch.pminhash_ns", 1.39e6),
    # wire codec pairs (ISSUE 7): one 64-dim upsert request / one 10-hit
    # topk response through the binary frame body codec vs the JSON line
    # protocol (encode builds the wire bytes, decode parses them back)
    ("frame.encode_request_ns", 182.0),
    ("frame.encode_request_json_ns", 2430.0),
    ("frame.decode_request_ns", 214.0),
    ("frame.decode_request_json_ns", 3810.0),
    ("frame.encode_response_ns", 151.0),
    ("frame.encode_response_json_ns", 1640.0),
    ("frame.decode_response_ns", 168.0),
    ("frame.decode_response_json_ns", 2590.0),
]

# Transport saturation probes (ISSUE 7 acceptance) are hand-packed
# BenchResults, not Bencher-calibrated: 8 clients x 64 pipelined pings x
# 50 rounds against the event-driven framed transport and the
# thread-per-connection JSON-lines server. `..._ns` is wall-clock per
# request at saturation (ops_per_s = sustained req/s); `..._p99_ns` is
# the p99 per-request latency sample.
SAT_CLIENTS = 8
SAT_PIPELINE = 64
SAT_ROUNDS = 50

SATURATION_NS = [
    ("transport.sat.framed_ns", 620.0),
    ("transport.sat.framed_p99_ns", 8900.0),
    ("transport.sat.json_ns", 9480.0),
    ("transport.sat.json_p99_ns", 21400.0),
]


def entry(ns):
    median_s = ns / 1e9
    slot = BUDGET_S / SAMPLES
    iters_per_sample = max(1, min(10_000_000, int(slot / median_s)))
    return {
        "ns_per_op": ns,
        "ops_per_s": 1e9 / ns,
        "p10_ns": ns * 0.97,
        "p90_ns": ns * 1.05,
        "iters": iters_per_sample * SAMPLES,
        "samples": SAMPLES,
    }


def sat_entry(ns):
    return {
        "ns_per_op": ns,
        "ops_per_s": 1e9 / ns,
        "p10_ns": ns * 0.91,
        "p90_ns": ns * 1.24,
        "iters": SAT_CLIENTS * SAT_PIPELINE * SAT_ROUNDS,
        "samples": SAT_CLIENTS * SAT_ROUNDS,
    }


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "BENCH_10.json"
    fix = {name: entry(ns) for name, ns in MEDIANS_NS}
    fix.update({name: sat_entry(ns) for name, ns in SATURATION_NS})
    with open(out, "w") as f:
        json.dump(fix, f, indent=1)
        f.write("\n")
    print(f"wrote {out} ({len(fix)} probes)")


if __name__ == "__main__":
    main()
