//! Cross-module integration tests: whole-pipeline flows that no single
//! module's unit tests cover.

use fastgm::coordinator::client::Client;
use fastgm::coordinator::protocol::{Request, Response};
use fastgm::coordinator::server::Server;
use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
use fastgm::data::corpus::Corpus;
use fastgm::data::stream::generate;
use fastgm::data::svmlight;
use fastgm::data::synthetic::WeightDist;
use fastgm::estimate::cardinality::estimate_cardinality;
use fastgm::estimate::jaccard::{estimate_jp, probability_jaccard};
use fastgm::lsh::{LshIndex, LshParams};
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::stream_fastgm::StreamFastGm;
use fastgm::sketch::{Sketcher, SparseVector};
use fastgm::util::rng::SplitMix64;
use std::sync::Arc;

/// Corpus → FastGM sketches → LSH index → query: end-to-end recall on the
/// library API (no server).
#[test]
fn corpus_to_lsh_pipeline() {
    let corpus = Corpus::by_name("wiki10", 3).unwrap();
    let k = 128;
    let fg = FastGm::new(k, 5);
    let docs = corpus.vectors(300);
    let mut index = LshIndex::new(LshParams::for_threshold(k, 0.5));
    for (i, d) in docs.iter().enumerate() {
        index.insert(i as u64, fg.sketch(d));
    }
    // Query every 25th doc with itself: must come back first with sim 1.
    for i in (0..docs.len()).step_by(25) {
        let hits = index.query(&fg.sketch(&docs[i]), 3).unwrap();
        assert_eq!(hits[0].0, i as u64);
        assert_eq!(hits[0].1, 1.0);
    }
}

/// svmlight file → sketches → pairwise similarity: the drop-in-real-data
/// path.
#[test]
fn svmlight_to_similarity() {
    let path = std::env::temp_dir().join("fastgm_integration.svm");
    let mut rng = SplitMix64::new(9);
    let rows: Vec<svmlight::Row> = (0..20)
        .map(|i| {
            let mut v = SparseVector::default();
            for j in 0..30u64 {
                if rng.next_f64() < 0.7 {
                    v.push(j, rng.next_f64() + 0.1);
                }
            }
            svmlight::Row { label: i as f64, vector: v }
        })
        .collect();
    svmlight::write(path.to_str().unwrap(), &rows).unwrap();
    let loaded = svmlight::load(path.to_str().unwrap()).unwrap();
    assert_eq!(loaded.len(), 20);
    let fg = FastGm::new(256, 1);
    let s0 = fg.sketch(&loaded[0].vector);
    let s1 = fg.sketch(&loaded[1].vector);
    let est = estimate_jp(&s0, &s1).unwrap();
    let truth = probability_jaccard(&loaded[0].vector, &loaded[1].vector);
    assert!((est - truth).abs() < 0.15, "est={est} truth={truth}");
    let _ = std::fs::remove_file(&path);
}

/// Distributed cardinality over the wire: three "sites" push disjoint+
/// overlapping streams to the same coordinator; merged estimate must track
/// the union truth.
#[test]
fn distributed_cardinality_over_tcp() {
    let coord = Arc::new(
        Coordinator::new(CoordinatorConfig { k: 512, workers: 2, ..Default::default() })
            .unwrap(),
    );
    let server = Server::start(coord, "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();

    let mut rng = SplitMix64::new(4);
    let stream = generate(&mut rng, 900, 0.5, WeightDist::Uniform01, 0);
    let truth = stream.weighted_cardinality();
    // Split events across three sites (round robin).
    let mut handles = Vec::new();
    for site in 0..3usize {
        let addr = addr.clone();
        let events: Vec<(u64, f64)> = stream
            .events
            .iter()
            .enumerate()
            .filter(|(i, _)| i % 3 == site)
            .map(|(_, e)| *e)
            .collect();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            for chunk in events.chunks(128) {
                let r = client
                    .call(&Request::Push { stream: format!("site{site}"), items: chunk.to_vec() })
                    .unwrap();
                assert!(matches!(r, Response::Ack { .. }));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // Central read: per-site estimates can undercount the union; the server
    // doesn't merge streams directly, so fetch each cardinality and check
    // the union via a union stream pushed by a "collector".
    let mut client = Client::connect(&addr).unwrap();
    let mut union_estimate = 0.0;
    for site in 0..3 {
        let Response::Estimate { value } =
            client.call(&Request::Cardinality { stream: format!("site{site}") }).unwrap()
        else {
            panic!("expected estimate")
        };
        assert!(value > 0.0);
        union_estimate += value;
    }
    // Sites overlap (duplicates split round-robin), so the sum ≥ truth.
    assert!(union_estimate >= truth * 0.8, "sum={union_estimate} truth={truth}");
    server.stop();
}

/// Stream-FastGM on a generated duplicate-bearing stream estimates the
/// exact weighted cardinality within theory bounds — the Task-2 loop.
#[test]
fn stream_cardinality_accuracy() {
    let mut rng = SplitMix64::new(8);
    let stream = generate(&mut rng, 2000, 2.0, WeightDist::Normal(1.0, 0.1), 0);
    let truth = stream.weighted_cardinality();
    let k = 1024;
    let mut sk = StreamFastGm::new(k, 3);
    for &(id, w) in &stream.events {
        sk.push(id, w);
    }
    let est = estimate_cardinality(&sk.sketch());
    let rel = (est - truth).abs() / truth;
    let bound = 4.0 * (2.0 / k as f64).sqrt();
    assert!(rel < bound, "rel err {rel} exceeds 4σ {bound}");
}

/// Coordinator config plumbing: TOML-subset file → CoordinatorConfig →
/// behaviour (k respected end to end).
#[test]
fn config_file_drives_coordinator() {
    let text = "[sketch]\nk = 64\nseed = 9\n[server]\nworkers = 2\n[accel]\nartifacts_dir = \"off\"\n";
    let cfg = fastgm::util::config::Config::parse(text).unwrap();
    let ccfg = CoordinatorConfig::from_config(&cfg);
    assert_eq!(ccfg.k, 64);
    assert_eq!(ccfg.seed, 9);
    assert!(ccfg.artifacts_dir.is_none());
    let coord = Coordinator::new(ccfg).unwrap();
    let Response::Sketch { sketch, .. } = coord.call(Request::Sketch {
        name: "x".into(),
        vector: SparseVector::new(vec![1], vec![1.0]),
        algo: None,
    }) else {
        panic!("expected sketch")
    };
    assert_eq!(sketch.k(), 64);
    assert_eq!(sketch.seed, 9);
    coord.shutdown();
}

/// Failure injection: a coordinator pointed at a bogus artifacts dir must
/// still serve every op on the CPU path.
#[test]
fn degrades_gracefully_without_artifacts() {
    let coord = Coordinator::new(CoordinatorConfig {
        k: 64,
        workers: 1,
        artifacts_dir: Some("/definitely/not/a/dir".into()),
        ..Default::default()
    })
    .unwrap();
    assert!(!coord.accel_enabled());
    let Response::Sketch { sketch, .. } = coord.call(Request::SketchDense {
        name: "d".into(),
        weights: vec![1.0, 0.0, 2.0],
    }) else {
        panic!("dense sketch must fall back to CPU")
    };
    assert_eq!(sketch.family, fastgm::sketch::Family::Direct);
    coord.shutdown();
}

/// Complexity check: FastGM's released-variable count scales like
/// k·ln k + n⁺, not k·n⁺ — measured via the work counters across a grid.
#[test]
fn fastgm_work_scales_subquadratically() {
    let mut rng = SplitMix64::new(17);
    for &(n, k) in &[(500usize, 64usize), (500, 512), (5000, 64), (5000, 512)] {
        let v = fastgm::data::synthetic::dense_vector(
            &mut rng,
            n,
            WeightDist::Uniform01,
        );
        let (_, stats) = FastGm::new(k, 3).sketch_counted(&v);
        let released = stats.total_released() as f64;
        let model = 8.0 * (k as f64) * (k as f64).ln().max(1.0) + 4.0 * n as f64;
        let brute = (n * k) as f64;
        assert!(
            released < model.min(brute),
            "n={n} k={k}: released {released} vs model {model} (brute {brute})"
        );
    }
}

/// Merge is associative across arbitrary groupings (distributed sites can
/// combine in any tree shape).
#[test]
fn merge_associativity_property() {
    use fastgm::sketch::GumbelMaxSketch;
    let mut rng = SplitMix64::new(23);
    let fg = FastGm::new(64, 9);
    let sketches: Vec<GumbelMaxSketch> = (0..6)
        .map(|i| {
            let v = SparseVector::new(
                (i * 40..i * 40 + 60u64).collect(),
                (0..60).map(|_| rng.next_f64() + 0.05).collect(),
            );
            fg.sketch(&v)
        })
        .collect();
    let left = sketches
        .iter()
        .skip(1)
        .fold(sketches[0].clone(), |acc, s| acc.merge(s).unwrap());
    let a = sketches[0].merge(&sketches[1]).unwrap().merge(&sketches[2]).unwrap();
    let b = sketches[3].merge(&sketches[4]).unwrap().merge(&sketches[5]).unwrap();
    let right = a.merge(&b).unwrap();
    assert_eq!(left, right);
}

/// Shed-mode coordinator under overload: some requests shed with an error,
/// the service stays alive, and admitted requests still succeed.
#[test]
fn coordinator_sheds_under_overload_but_survives() {
    let coord = Coordinator::new(CoordinatorConfig {
        k: 512,
        workers: 1,
        queue_capacity: 2,
        shed: true,
        ..Default::default()
    })
    .unwrap();
    // Flood with CPU-heavy sketches.
    let v = SparseVector::new((0..3000u64).collect(), vec![1.0; 3000]);
    let rxs: Vec<_> = (0..64)
        .map(|i| coord.submit(Request::Sketch { name: format!("x{i}"), vector: v.clone(), algo: None }))
        .collect();
    let mut ok = 0;
    let mut shed = 0;
    for rx in rxs {
        match rx.recv().unwrap() {
            Response::Sketch { .. } => ok += 1,
            Response::Error { message } => {
                assert!(message.contains("shed"), "unexpected error: {message}");
                shed += 1;
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    assert!(ok > 0, "nothing admitted");
    assert!(shed > 0, "nothing shed under overload");
    // Service still healthy afterwards.
    assert!(matches!(coord.call(Request::Ping), Response::Pong));
    coord.shutdown();
}
