//! Golden-file conformance tests for `coordinator::protocol`.
//!
//! The JSON lines under `rust/tests/golden/` are the wire format, frozen.
//! Every line must decode, and re-encoding the decoded value must reproduce
//! the line byte for byte — so neither the encoder nor the decoder can
//! drift without this test (and the checked-in goldens) changing too. The
//! exhaustiveness checks force a new golden line whenever a request op or
//! response type is added.

use fastgm::coordinator::protocol::{
    decode_request, decode_response, encode_line, QueryTarget, Request, Response,
};
use fastgm::sketch::{SparseVector, EMPTY_REGISTER};
use std::collections::BTreeSet;

const REQUESTS: &str = include_str!("golden/requests.jsonl");
const RESPONSES: &str = include_str!("golden/responses.jsonl");

/// Every request op the protocol knows. Adding a `Request` variant must
/// extend this list AND `golden/requests.jsonl` in the same change.
const ALL_REQUEST_OPS: &[&str] = &[
    "sketch",
    "sketch_dense",
    "get_sketch",
    "push",
    "cardinality",
    "jaccard",
    "weighted_jaccard",
    "merge",
    "lsh_insert",
    "lsh_query",
    "upsert",
    "delete",
    "topk",
    "store_stats",
    "snapshot",
    "restore",
    "hello",
    "sketch_fetch",
    "store_keys",
    "store_put",
    "stream_merge",
    "sample",
    "partition",
    "metrics",
    "ping",
    "store_put_bin",
    "stream_merge_bin",
    "sketch_fetch_bin",
];

/// Every response type. Same rule as [`ALL_REQUEST_OPS`].
const ALL_RESPONSE_TYPES: &[&str] = &[
    "sketch",
    "ack",
    "estimate",
    "topk",
    "metrics",
    "stats",
    "keys",
    "hello",
    "sketch_blob",
    "sketch_blob_bin",
    "samples",
    "error",
    "pong",
];

fn golden_lines(text: &str) -> Vec<&str> {
    text.lines().map(str::trim).filter(|l| !l.is_empty()).collect()
}

#[test]
fn every_golden_request_roundtrips_byte_for_byte() {
    for line in golden_lines(REQUESTS) {
        let req = decode_request(line)
            .unwrap_or_else(|e| panic!("golden request no longer decodes: {line}\n{e}"));
        let encoded = encode_line(&req.to_json());
        assert_eq!(
            encoded.trim(),
            line,
            "wire format drifted for op '{}'",
            req.op()
        );
    }
}

#[test]
fn every_golden_response_roundtrips_byte_for_byte() {
    for line in golden_lines(RESPONSES) {
        let resp = decode_response(line)
            .unwrap_or_else(|e| panic!("golden response no longer decodes: {line}\n{e}"));
        let encoded = encode_line(&resp.to_json());
        assert_eq!(encoded.trim(), line, "wire format drifted for: {line}");
    }
}

#[test]
fn golden_requests_cover_every_op() {
    let seen: BTreeSet<&str> = golden_lines(REQUESTS)
        .iter()
        .map(|l| decode_request(l).unwrap().op())
        .collect();
    let want: BTreeSet<&str> = ALL_REQUEST_OPS.iter().copied().collect();
    assert_eq!(seen, want, "golden file op coverage drifted");
    // And the protocol rejects anything outside the frozen set.
    assert!(decode_request(r#"{"op":"explode"}"#).is_err());
}

#[test]
fn golden_responses_cover_every_type() {
    let seen: BTreeSet<String> = golden_lines(RESPONSES)
        .iter()
        .map(|l| {
            let v = fastgm::util::json::parse(l).unwrap();
            v.req_str("type").unwrap().to_string()
        })
        .collect();
    let want: BTreeSet<String> =
        ALL_RESPONSE_TYPES.iter().map(|s| s.to_string()).collect();
    assert_eq!(seen, want, "golden file response-type coverage drifted");
    assert!(decode_response(r#"{"ok":true,"type":"warp"}"#).is_err());
}

/// The values inside the goldens decode to exactly the structures we think
/// they do — in particular the lossless >2^53 id/seed path and the negative
/// empty-register encoding.
#[test]
fn golden_values_decode_losslessly() {
    let lines = golden_lines(REQUESTS);
    let Request::Sketch { name, vector, algo } = decode_request(lines[0]).unwrap() else {
        panic!("first golden line must be a sketch request")
    };
    assert_eq!(name, "doc1");
    assert_eq!(vector, SparseVector::new(vec![1, 5, u64::MAX], vec![0.5, 2.0, 1.25]));
    assert_eq!(algo, None, "algo-less golden must decode to the default");

    // The last golden line carries an explicit engine-registry algo.
    let Request::Sketch { algo, .. } = decode_request(lines[lines.len() - 1]).unwrap() else {
        panic!("last golden line must be the algo-bearing sketch request")
    };
    assert_eq!(algo.as_deref(), Some("pminhash"));

    let Request::Push { stream, items } = decode_request(lines[3]).unwrap() else {
        panic!("fourth golden line must be a push request")
    };
    assert_eq!(stream, "s");
    assert_eq!(items, vec![(3, 0.5), ((1u64 << 53) + 1, 1.0)]);

    // The keyed-store ops sit between ping and the algo-bearing sketch.
    let Request::Upsert { key, vector, version } = decode_request(lines[12]).unwrap() else {
        panic!("golden line 12 must be an upsert request")
    };
    assert_eq!(key, "doc1");
    assert_eq!(vector, SparseVector::new(vec![1, 5], vec![0.5, 2.0]));
    assert_eq!(version, None, "version-less golden upsert must decode to None");
    let Request::TopK { limit, .. } = decode_request(lines[14]).unwrap() else {
        panic!("golden line 14 must be a topk request")
    };
    assert_eq!(limit, 5);
    let Request::Snapshot { path } = decode_request(lines[16]).unwrap() else {
        panic!("golden line 16 must be a snapshot request")
    };
    assert_eq!(path, "/tmp/fgm.fgms");

    // The cluster handshake/gather ops sit just before the trailing
    // algo-bearing sketch line.
    assert_eq!(decode_request(lines[18]).unwrap(), Request::Hello);
    let Request::SketchFetch { name, source } = decode_request(lines[19]).unwrap() else {
        panic!("golden line 19 must be a sketch_fetch request")
    };
    assert_eq!(name, "doc1");
    assert_eq!(source, fastgm::coordinator::protocol::SketchSource::Store);

    // The replication ops (versioned upsert + anti-entropy walk/install).
    let Request::Upsert { version, .. } = decode_request(lines[20]).unwrap() else {
        panic!("golden line 20 must be the versioned upsert request")
    };
    assert_eq!(version, Some(7));
    assert_eq!(
        decode_request(lines[21]).unwrap(),
        Request::StoreKeys { after: None, limit: 100 }
    );
    assert_eq!(
        decode_request(lines[22]).unwrap(),
        Request::StoreKeys { after: Some("doc1".into()), limit: 64 }
    );
    let Request::StorePut { data } = decode_request(lines[23]).unwrap() else {
        panic!("golden line 23 must be a store_put request")
    };
    assert_eq!(data, "46474d53");
    let Request::StreamMerge { stream, data } = decode_request(lines[24]).unwrap() else {
        panic!("golden line 24 must be a stream_merge request")
    };
    assert_eq!((stream.as_str(), data.as_str()), ("s", "46474d53"));

    // The query-engine ops: the key|keys|stream target trio for sample and
    // partition, including the lossless >2^53 seed path.
    assert_eq!(
        decode_request(lines[25]).unwrap(),
        Request::Sample { target: QueryTarget::key("doc1"), n: 8, seed: 7 }
    );
    let Request::Sample { target, n, seed } = decode_request(lines[26]).unwrap() else {
        panic!("golden line 26 must be the multi-key sample request")
    };
    assert_eq!(target, QueryTarget::Keys(vec!["doc1".into(), "doc2".into()]));
    assert_eq!((n, seed), (3, u64::MAX));
    assert_eq!(
        decode_request(lines[27]).unwrap(),
        Request::Sample { target: QueryTarget::Stream("s".into()), n: 4, seed: 1 }
    );
    assert_eq!(
        decode_request(lines[28]).unwrap(),
        Request::Partition {
            target: QueryTarget::Keys(vec!["doc1".into(), "doc2".into()])
        }
    );
    assert_eq!(
        decode_request(lines[29]).unwrap(),
        Request::Partition { target: QueryTarget::Stream("s".into()) }
    );

    // The binary blob ops (ISSUE 10): on the JSON wire their payload is
    // hex (the compatibility form); the decoded value is the RAW bytes —
    // so "46474d53" decodes to the literal codec magic, not the hex text.
    assert_eq!(
        decode_request(lines[30]).unwrap(),
        Request::StorePutBin { data: b"FGMS".to_vec() }
    );
    assert_eq!(
        decode_request(lines[31]).unwrap(),
        Request::StreamMergeBin { stream: "s".into(), data: b"FGMS".to_vec() }
    );
    assert_eq!(
        decode_request(lines[32]).unwrap(),
        Request::SketchFetchBin {
            name: "doc1".into(),
            source: fastgm::coordinator::protocol::SketchSource::Store,
        }
    );

    let resp_lines = golden_lines(RESPONSES);
    let Response::Sketch { sketch, .. } = decode_response(resp_lines[0]).unwrap() else {
        panic!("first golden response must be a sketch")
    };
    assert!(sketch.y[0].is_infinite());
    assert_eq!(sketch.s[0], EMPTY_REGISTER);
    assert_eq!(sketch.y[1], 0.25);
    assert_eq!(sketch.s[1], 77);

    let Response::Sketch { sketch, .. } = decode_response(resp_lines[1]).unwrap() else {
        panic!("second golden response must be a sketch")
    };
    assert_eq!(sketch.seed, u64::MAX);
    assert_eq!(sketch.s[0], (1u64 << 53) + 1);

    // The binary blob reply decodes its hex compatibility form to raw
    // bytes, exactly like the request side.
    let Response::SketchBlobBin { name, data } = decode_response(resp_lines[12]).unwrap()
    else {
        panic!("golden response 12 must be the binary blob reply")
    };
    assert_eq!((name.as_str(), data), ("doc1", b"FGMS".to_vec()));

    // Sampled register ids survive the >2^53 string encoding round trip.
    let Response::Samples { ids } =
        decode_response(resp_lines[resp_lines.len() - 2]).unwrap()
    else {
        panic!("second-to-last golden response must be a samples reply")
    };
    assert_eq!(ids, vec![3, 17, 3, u64::MAX]);

    // The store_keys page reply carries (key, version) pairs.
    let Response::Keys { keys } =
        decode_response(resp_lines[resp_lines.len() - 1]).unwrap()
    else {
        panic!("last golden response must be a keys page")
    };
    assert_eq!(keys, vec![("doc1".to_string(), 7), ("doc2".to_string(), 1)]);

    // The extended stats reply (ISSUE 9) carries write generations and the
    // read-path cache object inside the opaque stats payload; the plain
    // stats reply right above it keeps decoding unchanged (the payload is
    // opaque JSON — no codec change was needed).
    let Response::Stats { stats } = decode_response(resp_lines[9]).unwrap() else {
        panic!("golden response 9 must be the cache-bearing stats reply")
    };
    assert_eq!(stats.get("generation").and_then(|v| v.as_f64()), Some(9.0));
    assert_eq!(stats.get("delete_generation").and_then(|v| v.as_f64()), Some(1.0));
    let cache = stats.get("cache").expect("extended stats carry a cache object");
    assert_eq!(cache.get("enabled").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(cache.get("hits").and_then(|v| v.as_f64()), Some(3.0));
    assert_eq!(cache.get("max_bytes").and_then(|v| v.as_f64()), Some(8388608.0));
}
