//! Integration tests for the parallel shard-merge path (§2.3): the sharded
//! sketcher must be indistinguishable — bit for bit — from single-threaded
//! FastGM, standalone and through the whole coordinator stack.

use fastgm::coordinator::protocol::{Request, Response};
use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
use fastgm::estimate::jaccard::estimate_jp;
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::sharded::ShardedSketcher;
use fastgm::sketch::{Sketcher, SparseVector};
use fastgm::util::proptest::forall_explain;
use fastgm::util::rng::SplitMix64;

fn skewed_vector(r: &mut SplitMix64, n: usize) -> SparseVector {
    // Zipf-ish weights: the worst case for naive count-based sharding.
    SparseVector::new(
        (0..n as u64).map(|i| i.wrapping_mul(0x9E37_79B9) + 1).collect(),
        (0..n).map(|i| (1.0 / (i as f64 + 1.0)) * (r.next_f64() + 0.5)).collect(),
    )
}

/// Acceptance property: ShardedSketcher == FastGm for random vectors, over
/// shard counts, sketch lengths and seeds.
#[test]
fn sharded_sketcher_equals_fastgm_property() {
    forall_explain(
        30,
        |r| {
            let k = [4usize, 16, 64, 128][r.next_range(0, 3)];
            let shards = r.next_range(2, 12);
            let n = r.next_range(1, 400);
            (r.next_u64(), k, shards, skewed_vector(r, n))
        },
        |(seed, k, shards, v)| {
            let single = FastGm::new(*k, *seed).sketch(v);
            let sharded = ShardedSketcher::new(*k, *seed, *shards).sketch(v);
            if single == sharded {
                Ok(())
            } else {
                Err(format!("P={shards}, k={k}: sharded != single-threaded"))
            }
        },
    );
}

/// Sharded sketches interoperate with everything downstream: estimators see
/// the exact same registers, so estimates match exactly.
#[test]
fn sharded_sketches_interoperate_with_estimators() {
    let mut r = SplitMix64::new(5);
    let u = skewed_vector(&mut r, 300);
    let v = skewed_vector(&mut r, 300);
    let fg = FastGm::new(128, 7);
    let sh = ShardedSketcher::new(128, 7, 5);
    let jp_single = estimate_jp(&fg.sketch(&u), &fg.sketch(&v)).unwrap();
    let jp_mixed = estimate_jp(&sh.sketch(&u), &fg.sketch(&v)).unwrap();
    assert_eq!(jp_single, jp_mixed);
}

/// End to end through the coordinator: the same vector sketched below and
/// above the shard threshold stores identical registers, so a client can
/// never observe which path served it.
#[test]
fn coordinator_shard_routing_is_transparent() {
    let v = SparseVector::new(
        (0..800u64).map(|i| i * 3 + 11).collect(),
        (0..800).map(|i| 0.05 + (i % 17) as f64).collect(),
    );
    let mk = |shards: usize, min_nplus: usize| {
        Coordinator::new(CoordinatorConfig {
            k: 64,
            workers: 2,
            shards,
            shard_min_nplus: min_nplus,
            ..CoordinatorConfig::default()
        })
        .unwrap()
    };
    // Forced sharded vs forced single-threaded.
    let sharded_coord = mk(6, 1);
    let single_coord = mk(1, usize::MAX);
    let get = |c: &Coordinator| -> fastgm::sketch::GumbelMaxSketch {
        let Response::Sketch { sketch, .. } =
            c.call(Request::Sketch { name: "v".into(), vector: v.clone(), algo: None })
        else {
            panic!("expected sketch response")
        };
        sketch
    };
    let a = get(&sharded_coord);
    let b = get(&single_coord);
    assert_eq!(a, b, "shard routing changed the stored sketch");
    sharded_coord.shutdown();
    single_coord.shutdown();
}

/// Concurrency smoke: many large sharded sketch requests in flight at once
/// (worker pool × shard teams) all complete and all agree with the oracle.
#[test]
fn concurrent_sharded_requests_are_correct() {
    let c = Coordinator::new(CoordinatorConfig {
        k: 32,
        workers: 4,
        shards: 4,
        shard_min_nplus: 50,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let mut r = SplitMix64::new(77);
    let vectors: Vec<SparseVector> = (0..16).map(|_| skewed_vector(&mut r, 200)).collect();
    let rxs: Vec<_> = vectors
        .iter()
        .enumerate()
        .map(|(i, v)| {
            c.submit(Request::Sketch { name: format!("v{i}"), vector: v.clone(), algo: None })
        })
        .collect();
    let fg = FastGm::new(32, 42); // coordinator default seed
    for (v, rx) in vectors.iter().zip(rxs) {
        let Response::Sketch { sketch, .. } = rx.recv().unwrap() else {
            panic!("expected sketch response")
        };
        assert_eq!(sketch, fg.sketch(v));
    }
    c.shutdown();
}
