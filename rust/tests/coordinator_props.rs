//! Property tests on coordinator invariants (routing, batching, protocol,
//! registry state) using the in-crate proptest helper.

use fastgm::coordinator::batcher::{BatcherConfig, DenseBatcher};
use fastgm::coordinator::protocol::{decode_request, encode_line, Request};
use fastgm::coordinator::router::{Path, Router, RouterConfig};
use fastgm::coordinator::registry::Registry;
use fastgm::sketch::{pminhash::PMinHash, Sketcher, SparseVector};
use fastgm::util::proptest::{forall, forall_explain};
use fastgm::util::rng::SplitMix64;
use std::time::Duration;

fn random_vector(r: &mut SplitMix64) -> SparseVector {
    let n = r.next_range(0, 40);
    SparseVector::new(
        (0..n).map(|_| r.next_u64() >> r.next_range(0, 50)).collect(),
        (0..n).map(|_| r.next_f64() * 3.0 - 0.5).collect(), // incl. ≤0 weights
    )
}

/// Routing is total and consistent: every vector gets exactly one path;
/// vectors that exceed the bucket span or density floor go to CPU; the
/// accelerator is never chosen when disabled.
#[test]
fn routing_invariants() {
    forall_explain(
        300,
        |r| {
            let max_len = [0usize, 256, 1024, 4096][r.next_range(0, 3)];
            let density = r.next_f64();
            (max_len, density, random_vector(r))
        },
        |(max_len, density, v)| {
            let router = Router::new(RouterConfig {
                accel_max_len: *max_len,
                min_density: *density,
                ..RouterConfig::default()
            });
            let path = router.route_sparse(v);
            if *max_len == 0 && path != Path::CpuFastGm {
                return Err("accelerator chosen while disabled".into());
            }
            if let Some(max_id) = v.positive().map(|(id, _)| id).max() {
                let span = max_id as usize + 1;
                if span > *max_len && path != Path::CpuFastGm {
                    return Err(format!("span {span} exceeds bucket {max_len} but routed accel"));
                }
                if path == Path::Accelerator {
                    let d = v.n_plus() as f64 / span as f64;
                    if d < *density {
                        return Err(format!("density {d} below floor {density}"));
                    }
                }
            } else if path != Path::CpuFastGm {
                return Err("empty vector must go to CPU".into());
            }
            Ok(())
        },
    );
}

/// Protocol: encode → decode is the identity over randomized requests.
#[test]
fn protocol_roundtrip_property() {
    forall(
        200,
        |r| {
            let ids: Vec<u64> = (0..r.next_range(0, 10)).map(|_| r.next_u64()).collect();
            let weights: Vec<f64> =
                ids.iter().map(|_| (r.next_f64() * 8.0).round() / 8.0).collect();
            let v = SparseVector::new(ids, weights);
            match r.next_range(0, 4) {
                0 => Request::Sketch { name: format!("n{}", r.next_u32()), vector: v, algo: None },
                1 => Request::Push {
                    stream: format!("s{}", r.next_range(0, 5)),
                    items: (0..r.next_range(0, 6))
                        .map(|_| (r.next_u64() >> 12, (r.next_f64() * 4.0).round() / 4.0))
                        .collect(),
                },
                2 => Request::Merge {
                    names: (0..r.next_range(1, 4)).map(|i| format!("m{i}")).collect(),
                    out: "out".into(),
                },
                3 => Request::LshQuery { vector: v, limit: r.next_range(1, 100) },
                _ => Request::Jaccard { a: "a".into(), b: "b".into() },
            }
        },
        |req| {
            let line = encode_line(&req.to_json());
            decode_request(&line).map(|back| back == *req).unwrap_or(false)
        },
    );
}

/// Batcher: N submissions yield exactly N replies, each equal to the
/// direct CPU P-MinHash sketch of its own row, regardless of batch/deadline
/// interleaving.
#[test]
fn batcher_preserves_request_response_pairing() {
    forall_explain(
        15,
        |r| {
            let rows: Vec<Vec<f64>> = (0..r.next_range(1, 12))
                .map(|_| {
                    (0..r.next_range(1, 60))
                        .map(|_| if r.next_f64() < 0.3 { 0.0 } else { r.next_f64() })
                        .collect()
                })
                .collect();
            let max_batch = r.next_range(1, 6);
            let deadline_us = r.next_range(100, 3000) as u64;
            (rows, max_batch, deadline_us)
        },
        |(rows, max_batch, deadline_us)| {
            let b = DenseBatcher::new(
                BatcherConfig {
                    max_batch: *max_batch,
                    deadline: Duration::from_micros(*deadline_us),
                    k: 32,
                    seed: 5,
                },
                None,
            );
            let rxs: Vec<_> = rows.iter().map(|row| b.submit(row.clone())).collect();
            let cpu = PMinHash::new(32, 5);
            for (row, rx) in rows.iter().zip(rxs) {
                let got = rx
                    .recv_timeout(Duration::from_secs(5))
                    .map_err(|_| "batcher timed out".to_string())?
                    .map_err(|e| e.to_string())?;
                let want = cpu.sketch(&SparseVector::from_dense(row));
                if got != want {
                    return Err("reply does not match its own row".into());
                }
            }
            Ok(())
        },
    );
}

/// Registry stream state: pushes from any interleaving of duplicate-bearing
/// batches produce the same sketch as one combined pass (idempotent,
/// order-insensitive state).
#[test]
fn registry_stream_state_is_order_insensitive() {
    forall_explain(
        40,
        |r| {
            let items: Vec<(u64, f64)> = (0..r.next_range(1, 30))
                .map(|_| (r.next_range(0, 12) as u64, 0.0))
                .map(|(id, _)| (id, 0.25 + (id as f64) * 0.125)) // weight fixed per id
                .collect();
            let mut shuffled = items.clone();
            r.shuffle(&mut shuffled);
            let cut = r.next_range(0, items.len() - 1);
            (items, shuffled, cut)
        },
        |(items, shuffled, cut)| {
            let a = Registry::new();
            a.stream_push("s", 16, 3, items);
            let b = Registry::new();
            b.stream_push("s", 16, 3, &shuffled[..*cut]);
            b.stream_push("s", 16, 3, &shuffled[*cut..]);
            if a.stream_sketch("s") == b.stream_sketch("s") {
                Ok(())
            } else {
                Err("stream state depends on push order".into())
            }
        },
    );
}
