//! End-to-end acceptance + partition-correctness property tests for the
//! cluster layer (ISSUE 4 + the ISSUE 5 replication refactor):
//!
//! * a 3-node local cluster ingests 200+ keys through the cluster client,
//!   scatter-gather `topk` ranks exactly like a brute-force single-store
//!   `estimate_jp` scan, cluster-wide cardinality lands within the
//!   single-node estimator's error bound, and killing one node leaves
//!   `topk` serving (degraded, non-panicking) while `upsert` to the dead
//!   partition returns a typed error (the R=1 topology);
//! * property (a): scatter-gather `topk` over an M-node cluster equals
//!   single-node `topk` on the union store, for several M;
//! * property (b): cluster-wide cardinality sketches — per-site stream
//!   sketches moved through `sketch::codec` and merged — are bit-identical
//!   to sketching the concatenated stream (§2.3 across the wire);
//! * replica-set properties: `owners(key, r)` prefix-stable in r, node
//!   removal only promotes standbys;
//! * the ISSUE 5 acceptance: at R=2 on 3 nodes, killing ANY single node
//!   leaves `topk`, `card` and quorum-`upsert` fully available with
//!   rankings/estimates identical to the healthy cluster, and `cluster
//!   repair` after a cold restart converges every key's version and
//!   registers bit-identically across its replica set;
//! * under-quorum writes are typed `QuorumLost` errors naming the down
//!   nodes, and mid-rebalance version skew resolves to the
//!   highest-version blob in the `topk` gather (regression).

use fastgm::coordinator::client::Client;
use fastgm::coordinator::cluster::{
    ClusterClient, ClusterError, LocalCluster, Partitioner, ReplicaConfig,
};
use fastgm::coordinator::protocol::{Request, Response, SketchSource};
use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
use fastgm::estimate::cardinality::cardinality_rel_std;
use fastgm::estimate::jaccard::estimate_jp;
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::stream_fastgm::StreamFastGm;
use fastgm::sketch::{Sketcher, SparseVector};
use fastgm::util::rng::SplitMix64;

const K: usize = 128;
const SEED: u64 = 42;
const N: usize = 210;
const LIMIT: usize = 5;

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        k: K,
        seed: SEED,
        workers: 2,
        node_id: "acc".into(),
        // Exact per-partition answers: every node brute-scans its shard, so
        // the gather's claim ("equals a brute-force scan of the union") is
        // deterministic. The probe path's recall is covered by
        // store_serving.rs; this suite pins the *distribution* logic.
        topk_scan_max: 100_000,
        ..Default::default()
    }
}

fn random_vec(r: &mut SplitMix64, n: usize, span: u64) -> SparseVector {
    SparseVector::new(
        (0..n).map(|_| r.next_u64() % span).collect(),
        (0..n).map(|_| r.next_f64() + 0.1).collect(),
    )
}

/// `base` + near-duplicates + unrelated docs (disjoint id spaces), so the
/// brute-force top-5 is the near-duplicate family with strictly positive
/// scores.
fn corpus(n: usize) -> (SparseVector, Vec<SparseVector>) {
    let mut r = SplitMix64::new(31);
    let base = SparseVector::new(
        (0..40u64).map(|i| i * 31 + 5).collect(),
        (0..40).map(|_| r.next_f64() + 0.1).collect(),
    );
    let mut docs = Vec::with_capacity(n);
    docs.push(base.clone());
    for j in 1..5u64 {
        let swapped = [j - 1, j + 9, j + 19];
        let mut near = SparseVector::default();
        for (idx, (id, w)) in base.positive().enumerate() {
            if swapped.contains(&(idx as u64)) {
                near.push(r.next_u64() | (1 << 63), w);
            } else {
                near.push(id, w);
            }
        }
        docs.push(near);
    }
    for i in 5..n {
        docs.push(SparseVector::new(
            (0..40u64).map(|j| (i as u64) * 100_000 + j).collect(),
            (0..40).map(|_| r.next_f64() + 0.1).collect(),
        ));
    }
    (base, docs)
}

fn brute_force_topk(
    query: &SparseVector,
    docs: &[SparseVector],
    limit: usize,
) -> Vec<(String, f64)> {
    let f = FastGm::new(K, SEED);
    let qsk = f.sketch(query);
    let mut scored: Vec<(String, f64)> = docs
        .iter()
        .enumerate()
        .map(|(i, d)| (format!("doc{i:03}"), estimate_jp(&qsk, &f.sketch(d)).unwrap()))
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    scored.truncate(limit);
    scored
}

#[test]
fn three_node_cluster_serves_degrades_and_errors_typed() {
    let (query, docs) = corpus(N);

    // ---- 3 nodes, 200+ keys ingested via the cluster client. ------------
    let mut cluster = LocalCluster::start(3, &cfg()).unwrap();
    let mut cc = ClusterClient::connect(&cluster.addrs()).unwrap();
    assert_eq!(cc.nodes(), 3);
    for (i, d) in docs.iter().enumerate() {
        cc.upsert(&format!("doc{i:03}"), d.clone()).unwrap();
    }
    // Every key landed on its rendezvous owner and nowhere else.
    let sizes = cc.store_sizes();
    let total: f64 = sizes.iter().map(|(_, s)| s.unwrap()).sum();
    assert_eq!(total, N as f64, "partition sizes must sum to the corpus: {sizes:?}");
    assert!(
        sizes.iter().all(|(_, s)| s.unwrap() > 0.0),
        "every node should own part of the corpus: {sizes:?}"
    );

    // ---- scatter-gather == brute-force single-store scan. ---------------
    let brute = brute_force_topk(&query, &docs, LIMIT);
    let (hits, stats) = cc.topk(&query, LIMIT).unwrap();
    assert_eq!(hits, brute, "scatter-gather must rank exactly like a brute scan");
    assert_eq!(hits[0].0, "doc000");
    assert!((hits[0].1 - 1.0).abs() < 1e-12, "self-similarity must be 1: {hits:?}");
    assert_eq!(stats.nodes, 3);
    assert_eq!(stats.live, 3);
    assert!(stats.candidates >= LIMIT && stats.reranked >= LIMIT, "{stats:?}");

    // ---- cluster cardinality within the estimator's error bound. --------
    let truth = 1500.0;
    let items: Vec<(u64, f64)> = (0..truth as u64).map(|i| (i * 977 + 13, 1.0)).collect();
    cc.push("pkts", &items).unwrap();
    let est = cc.cardinality("pkts").unwrap();
    // 5σ of the k-register estimator — generous but still meaningful.
    let bound = 5.0 * cardinality_rel_std(K);
    assert!(
        (est - truth).abs() / truth < bound,
        "cluster cardinality {est} vs truth {truth} (bound {bound})"
    );

    // ---- kill one node: typed write errors, degraded (non-panicking)
    // ---- reads.
    const VICTIM: usize = 2;
    let victim_id = cc.node_id(VICTIM).to_string();
    cluster.kill(VICTIM);
    // A write routed to the dead partition is a typed NodeDown, naming it.
    let dead_key = (0..)
        .map(|i| format!("probe{i}"))
        .find(|k| cc.owner(k) == VICTIM)
        .unwrap();
    match cc.upsert(&dead_key, docs[0].clone()) {
        Err(ClusterError::NodeDown { node, .. }) => assert_eq!(node, victim_id),
        other => panic!("expected NodeDown, got {other:?}"),
    }
    // Reads keep serving with degraded coverage: the surviving partitions'
    // brute ranking, which is the full ranking minus the dead node's keys.
    let (degraded, stats) = cc.topk(&query, LIMIT).unwrap();
    assert_eq!(stats.live, 2, "{stats:?}");
    let survivors: Vec<(String, f64)> = {
        let f = FastGm::new(K, SEED);
        let qsk = f.sketch(&query);
        let mut scored: Vec<(String, f64)> = docs
            .iter()
            .enumerate()
            .map(|(i, d)| (format!("doc{i:03}"), estimate_jp(&qsk, &f.sketch(d)).unwrap()))
            .filter(|(key, _)| cc.owner(key) != VICTIM)
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        scored.truncate(LIMIT);
        scored
    };
    assert_eq!(degraded, survivors, "degraded gather must equal the surviving union");
    // Writes to live partitions still work.
    let live_key = (0..)
        .map(|i| format!("alive{i}"))
        .find(|k| cc.owner(k) != VICTIM)
        .unwrap();
    cc.upsert(&live_key, docs[0].clone()).unwrap();
    // Cardinality degrades (some partitions dark) but still answers.
    let est = cc.cardinality("pkts").unwrap();
    assert!(est > 0.0 && est < truth, "degraded estimate should undercount: {est}");

    cluster.stop();
}

/// Property (a): scatter-gather over M nodes == single-node topk on the
/// union store, hit-for-hit and score-for-score (both f64-exact — the
/// central re-rank recomputes the identical deterministic estimator).
#[test]
fn scatter_gather_equals_single_node_union_topk() {
    let mut r = SplitMix64::new(7);
    let docs: Vec<SparseVector> = (0..60).map(|_| random_vec(&mut r, 25, 4000)).collect();
    let queries: Vec<SparseVector> = (0..6).map(|_| random_vec(&mut r, 25, 4000)).collect();

    // Reference: one node holding the whole corpus.
    let single = Coordinator::new(cfg()).unwrap();
    for (i, d) in docs.iter().enumerate() {
        let resp = single.call(Request::Upsert {
            key: format!("doc{i:03}"),
            vector: d.clone(),
            version: None,
        });
        assert!(matches!(resp, Response::Ack { .. }), "{resp:?}");
    }

    for m in [1usize, 2, 3, 5] {
        let cluster = LocalCluster::start(m, &cfg()).unwrap();
        let mut cc = ClusterClient::connect(&cluster.addrs()).unwrap();
        for (i, d) in docs.iter().enumerate() {
            cc.upsert(&format!("doc{i:03}"), d.clone()).unwrap();
        }
        for (qi, q) in queries.iter().enumerate() {
            let Response::TopK { hits: want } =
                single.call(Request::TopK { vector: q.clone(), limit: 8 })
            else {
                panic!("expected topk")
            };
            let (got, stats) = cc.topk(q, 8).unwrap();
            assert_eq!(
                got, want,
                "query {qi} over {m} nodes diverged from the union store ({stats:?})"
            );
        }
        cluster.stop();
    }
    single.shutdown();
}

/// Property (b): the merged cluster sketch — per-site stream sketches
/// fetched as codec blobs and merge_tree'd — is bit-identical to one
/// Stream-FastGM run over the concatenated stream (§2.3 across the wire).
#[test]
fn merged_cluster_stream_sketch_is_bit_identical_to_concatenated_stream() {
    let mut r = SplitMix64::new(99);
    for m in [1usize, 2, 4] {
        let cluster = LocalCluster::start(m, &cfg()).unwrap();
        let mut cc = ClusterClient::connect(&cluster.addrs()).unwrap();
        // Unique element ids with varied weights; pushed in chunks so
        // per-site streams interleave arbitrarily.
        let items: Vec<(u64, f64)> =
            (0..800u64).map(|i| (i * 6_364_136 + 11, r.next_f64() + 0.05)).collect();
        for chunk in items.chunks(97) {
            cc.push("s", chunk).unwrap();
        }
        let merged = cc.merged_stream_sketch("s").unwrap();
        let mut reference = StreamFastGm::new(K, SEED);
        for &(id, w) in &items {
            reference.push(id, w);
        }
        assert_eq!(
            merged,
            reference.sketch(),
            "merge over {m} sites must be bit-identical to the concatenated stream"
        );
        cluster.stop();
    }
}

/// A typo'd stream on a healthy cluster is a gather error naming the
/// stream — not a spurious "no live nodes" outage report.
#[test]
fn unknown_stream_on_healthy_cluster_is_not_an_outage() {
    let cluster = LocalCluster::start(2, &cfg()).unwrap();
    let mut cc = ClusterClient::connect(&cluster.addrs()).unwrap();
    let err = cc.cardinality("nope").unwrap_err();
    assert!(matches!(err, ClusterError::Gather(_)), "got {err:?}");
    assert!(err.to_string().contains("'nope' not found"), "{err}");
    cluster.stop();
}

/// The handshake refuses to form a cluster out of incompatible nodes.
#[test]
fn connect_rejects_mismatched_node_configs() {
    let a = LocalCluster::start(1, &cfg()).unwrap();
    let b = LocalCluster::start(
        1,
        &CoordinatorConfig { k: 64, node_id: "other".into(), ..cfg() },
    )
    .unwrap();
    let addrs: Vec<String> = a.addrs().into_iter().chain(b.addrs()).collect();
    let err = ClusterClient::connect(&addrs).unwrap_err().to_string();
    assert!(err.contains("config mismatch"), "{err}");
    // And duplicate identities are rejected even with matching configs.
    let c = LocalCluster::start(1, &cfg()).unwrap();
    let dup: Vec<String> = a.addrs().into_iter().chain(c.addrs()).collect();
    let err = ClusterClient::connect(&dup).unwrap_err().to_string();
    assert!(err.contains("duplicate node id"), "{err}");

    // reconnect() re-checks the formation config: a node rejoining under
    // the same identity but a changed sketch config is refused up front,
    // not discovered query-by-query as gather errors.
    let mut cc = ClusterClient::connect(&a.addrs()).unwrap();
    let imposter = LocalCluster::start(
        1,
        &CoordinatorConfig { k: 64, ..cfg() }, // same "acc-0" id, different k
    )
    .unwrap();
    let err = cc.reconnect(0, imposter.addr(0)).unwrap_err().to_string();
    assert!(err.contains("rejoined with"), "{err}");
    // A same-config rejoin is accepted (here: the original node itself).
    cc.reconnect(0, a.addr(0)).unwrap();
    imposter.stop();
    a.stop();
    b.stop();
    c.stop();
}

/// Replication shapes the membership cannot carry are refused at connect.
#[test]
fn connect_rejects_impossible_replication_shapes() {
    let cluster = LocalCluster::start(2, &cfg()).unwrap();
    let addrs = cluster.addrs();
    for (r, w) in [(3, 1), (0, 0), (2, 3), (1, 0)] {
        let err = ClusterClient::connect_with(
            &addrs,
            ReplicaConfig { replication: r, write_quorum: w, ..Default::default() },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("replication") || err.contains("quorum"), "R={r} W={w}: {err}");
    }
    let mut cc = ClusterClient::connect_with(
        &addrs,
        ReplicaConfig { replication: 2, write_quorum: 2, ..Default::default() },
    )
    .unwrap();
    assert!(cc.set_write_quorum(3).is_err());
    cc.set_write_quorum(1).unwrap();
    cluster.stop();
}

/// Replica-set properties of the HRW partitioner, via the public API:
/// prefix stability in r, and node removal only promoting standbys.
#[test]
fn replica_sets_prefix_stable_and_standby_promoting() {
    let ids: Vec<String> = (0..5).map(|i| format!("site-{i}")).collect();
    let p = Partitioner::new(&ids).unwrap();
    for i in 0..400 {
        let key = format!("doc{i:04}");
        // Prefix stability: owners(key, r) is the first r of one ranking.
        let full = p.owners(&key, 5);
        assert_eq!(full[0], p.owner(&key));
        for r in 1..5 {
            assert_eq!(p.owners(&key, r), full[..r], "'{key}' not prefix-stable at r={r}");
        }
    }
    // Removing a node: keys without it in their replica set keep it
    // verbatim; keys with it only promote their standby (rank R+1).
    const R: usize = 2;
    let survivors: Vec<String> = ids.iter().filter(|s| *s != "site-3").cloned().collect();
    let q = Partitioner::new(&survivors).unwrap();
    let mut affected = 0usize;
    for i in 0..400 {
        let key = format!("doc{i:04}");
        let before: Vec<&String> = p.owners(&key, R).into_iter().map(|o| &ids[o]).collect();
        let after: Vec<&String> = q.owners(&key, R).into_iter().map(|o| &survivors[o]).collect();
        if before.iter().all(|id| *id != "site-3") {
            assert_eq!(before, after, "'{key}' reshuffled though site-3 did not own it");
        } else {
            affected += 1;
            let want: Vec<&String> = p
                .owners(&key, R + 1)
                .into_iter()
                .map(|o| &ids[o])
                .filter(|id| *id != "site-3")
                .collect();
            assert_eq!(after, want[..R], "'{key}' promoted the wrong standby");
        }
    }
    // ~2/5 of keys have site-3 in their 2-owner set; sanity-check spread.
    assert!(affected > 80 && affected < 240, "affected={affected}");
}

/// The ISSUE 5 acceptance: a 3-node cluster at R=2, W=1. Killing ANY
/// single node leaves `topk` rankings and the merged cardinality sketch
/// **identical** to the healthy cluster (not merely degraded), and
/// quorum-upserts keep landing. After a cold restart, `repair` converges
/// every key's version and registers bit-identically across its replica
/// set — including the writes made while the node was dead.
#[test]
fn replicated_cluster_survives_any_single_kill_and_repairs() {
    const M: usize = 3;
    let (query, docs) = corpus(80);
    let mut cluster = LocalCluster::start(M, &cfg()).unwrap();
    let mut cc = ClusterClient::connect_with(
        &cluster.addrs(),
        ReplicaConfig { replication: 2, write_quorum: 1, ..Default::default() },
    )
    .unwrap();
    for (i, d) in docs.iter().enumerate() {
        let info = cc.upsert(&format!("doc{i:03}"), d.clone()).unwrap();
        assert!(info.contains("(2/2 replicas)"), "healthy writes hit both owners: {info}");
    }
    // Every key lives on exactly its 2 owners: sizes sum to 2N.
    let total: f64 = cc.store_sizes().iter().map(|(_, s)| s.unwrap()).sum();
    assert_eq!(total, 2.0 * docs.len() as f64);
    let items: Vec<(u64, f64)> = (0..900u64).map(|i| (i * 977 + 13, 1.0)).collect();
    cc.push("pkts", &items).unwrap();

    let (healthy_hits, healthy_stats) = cc.topk(&query, LIMIT).unwrap();
    assert_eq!(healthy_stats.live, M);
    assert_eq!(healthy_hits, brute_force_topk(&query, &docs, LIMIT));
    let healthy_sketch = cc.merged_stream_sketch("pkts").unwrap();
    // Replicated pushes merge to EXACTLY the concatenated-stream sketch
    // (§2.3: duplicates across replicas are idempotent).
    let mut reference = StreamFastGm::new(K, SEED);
    for &(id, w) in &items {
        reference.push(id, w);
    }
    assert_eq!(healthy_sketch, reference.sketch());

    let mut heal_seq = 0u64;
    for victim in 0..M {
        let victim_id = cc.node_id(victim).to_string();
        cluster.kill(victim);

        // Reads are IDENTICAL, not degraded: every partition still has a
        // live replica, and §2.3 merges make the stream sketch exact.
        let (hits, stats) = cc.topk(&query, LIMIT).unwrap();
        assert_eq!(stats.live, M - 1, "{stats:?}");
        assert_eq!(hits, healthy_hits, "victim {victim_id}: rankings drifted");
        assert_eq!(
            cc.merged_stream_sketch("pkts").unwrap(),
            healthy_sketch,
            "victim {victim_id}: merged stream sketch not bit-identical"
        );

        // Quorum writes stay available at W=1 — including to keys whose
        // PRIMARY owner is the victim (the standby replica absorbs them).
        let heal_key = (heal_seq..)
            .map(|i| format!("heal{i}"))
            .find(|k| cc.owners(k).contains(&victim))
            .unwrap();
        heal_seq += 1;
        // Disjoint id space: scores 0 against every query, so the
        // baseline rankings stay untouched.
        let filler = SparseVector::new(
            (0..10u64).map(|j| (victim as u64 + 7) * 1_000_000_000 + j).collect(),
            (0..10).map(|_| 1.0).collect(),
        );
        let info = cc.upsert(&heal_key, filler).unwrap();
        assert!(info.contains("(1/2 replicas)"), "{info}");
        // Stream pushes replicate too (each element still has a live
        // owner), and stay exact.
        cc.push("pkts", &items[..100]).unwrap(); // idempotent replays
        assert_eq!(cc.merged_stream_sketch("pkts").unwrap(), healthy_sketch);

        // Cold restart: the node comes back EMPTY. Repair rebuilds it
        // from its peers — store blobs by version, streams by §2.3 merge.
        cluster.restart(victim).unwrap();
        cc.reconnect(victim, cluster.addr(victim)).unwrap();
        let report = cc.repair(&["pkts".to_string()]).unwrap();
        assert!(report.keys_scanned >= docs.len(), "{report:?}");
        assert!(report.keys_healed > 0, "cold node must be healed: {report:?}");
        assert_eq!(report.stream_merges, M, "every live node absorbs the union");

        // Convergence witness: every key's replica set agrees on version
        // AND registers, bit for bit.
        let mut union_keys: std::collections::BTreeMap<String, u64> =
            std::collections::BTreeMap::new();
        for i in 0..M {
            for (k, v) in cc.node_keys(i).unwrap() {
                union_keys.insert(k, v);
            }
        }
        assert!(union_keys.len() >= docs.len());
        let mut direct: Vec<Client> = (0..M)
            .map(|i| Client::connect(cluster.addr(i)).unwrap())
            .collect();
        for (key, _) in union_keys {
            let owners = cc.owners(&key);
            let copies: Vec<(u64, fastgm::sketch::GumbelMaxSketch)> = owners
                .iter()
                .map(|&o| {
                    direct[o]
                        .sketch_fetch_versioned(&key, SketchSource::Store)
                        .unwrap_or_else(|e| panic!("'{key}' missing on owner {o}: {e}"))
                })
                .collect();
            for copy in &copies[1..] {
                assert_eq!(copy, &copies[0], "'{key}' replicas diverged after repair");
            }
        }
        // Stream states converged to the union sketch on every node.
        for d in direct.iter_mut() {
            assert_eq!(
                d.sketch_fetch("pkts", SketchSource::Stream).unwrap(),
                healthy_sketch,
                "stream state did not converge"
            );
        }
        // Repair is idempotent: a second pass heals nothing new.
        let again = cc.repair(&["pkts".to_string()]).unwrap();
        assert_eq!(again.keys_healed, 0, "{again:?}");
        // And the healthy-cluster answers are back (heal keys score 0).
        let (hits, stats) = cc.topk(&query, LIMIT).unwrap();
        assert_eq!(stats.live, M);
        assert_eq!(hits, healthy_hits);
    }
    cluster.stop();
}

/// ISSUE 8 satellite (S2): cluster `sample`/`partition` on a key whose
/// primary owner is down must FAIL OVER to the next live owner — not
/// return `NodeDown` — and, because the draw happens centrally on the
/// merged registers, the samples and estimates must be bit-identical to
/// the healthy cluster's. Union (multi-key) targets and stream targets
/// stay exact too (§2.3: every partition has a surviving replica).
#[test]
fn cluster_sample_fails_over_to_live_replica() {
    use fastgm::coordinator::protocol::QueryTarget;
    const M: usize = 3;
    let mut cluster = LocalCluster::start(M, &cfg()).unwrap();
    let mut cc = ClusterClient::connect_with(
        &cluster.addrs(),
        ReplicaConfig { replication: 2, write_quorum: 1, ..Default::default() },
    )
    .unwrap();
    let mut r = SplitMix64::new(13);
    let keys: Vec<String> = (0..12).map(|i| format!("doc{i:02}")).collect();
    for key in &keys {
        cc.upsert(key, random_vec(&mut r, 20, 5000)).unwrap();
    }
    let items: Vec<(u64, f64)> = (0..300u64).map(|i| (i * 31 + 7, 1.0)).collect();
    cc.push("pkts", &items).unwrap();

    // Healthy answers, for every single-key target plus a union target.
    let healthy: Vec<(Vec<u64>, f64)> = keys
        .iter()
        .map(|k| {
            let t = QueryTarget::key(k.clone());
            (cc.sample(&t, 16, 9).unwrap(), cc.partition(&t).unwrap())
        })
        .collect();
    let union_target = QueryTarget::Keys(keys.clone());
    let healthy_union = cc.sample(&union_target, 32, 5).unwrap();
    let healthy_stream = cc.sample(&QueryTarget::Stream("pkts".into()), 16, 2).unwrap();

    const VICTIM: usize = 1;
    cluster.kill(VICTIM);
    // Keys whose PRIMARY owner is the victim are the regression surface:
    // the fetch must fail over to the standby, not error NodeDown.
    assert!(
        keys.iter().any(|k| cc.owner(k) == VICTIM),
        "corpus must cover the victim's partitions"
    );
    for (key, (want_ids, want_z)) in keys.iter().zip(&healthy) {
        let t = QueryTarget::key(key.clone());
        let ids = cc
            .sample(&t, 16, 9)
            .unwrap_or_else(|e| panic!("sample '{key}' (owner {}): {e}", cc.owner(key)));
        assert_eq!(&ids, want_ids, "'{key}': failover changed the sample");
        assert_eq!(cc.partition(&t).unwrap(), *want_z, "'{key}': estimate drifted");
    }
    assert_eq!(cc.sample(&union_target, 32, 5).unwrap(), healthy_union);
    assert_eq!(
        cc.sample(&QueryTarget::Stream("pkts".into()), 16, 2).unwrap(),
        healthy_stream
    );
    // A key that exists nowhere is a gather error naming it, not an outage.
    let err = cc.sample(&QueryTarget::key("ghost"), 4, 0).unwrap_err();
    assert!(matches!(err, ClusterError::Gather(_)), "{err:?}");
    assert!(err.to_string().contains("'ghost'"), "{err}");
    cluster.stop();
}

/// Under-quorum writes are typed `QuorumLost` errors naming the down
/// owners — for keyed writes and stream pushes alike — and lowering the
/// quorum restores availability.
#[test]
fn under_quorum_writes_are_typed_quorum_lost() {
    let mut cluster = LocalCluster::start(3, &cfg()).unwrap();
    let mut cc = ClusterClient::connect_with(
        &cluster.addrs(),
        ReplicaConfig { replication: 2, write_quorum: 2, ..Default::default() },
    )
    .unwrap();
    const VICTIM: usize = 0;
    let victim_id = cc.node_id(VICTIM).to_string();
    cluster.kill(VICTIM);
    let key = (0..)
        .map(|i| format!("k{i}"))
        .find(|k| cc.owners(k).contains(&VICTIM))
        .unwrap();
    let v = SparseVector::new(vec![1, 2], vec![1.0, 1.0]);
    match cc.upsert(&key, v.clone()) {
        Err(ClusterError::QuorumLost { want, acked, replication, down, .. }) => {
            assert_eq!((want, acked, replication), (2, 1, 2));
            assert_eq!(down, vec![victim_id.clone()], "must name the down owner");
        }
        other => panic!("expected QuorumLost, got {other:?}"),
    }
    // A key whose replica set avoids the victim still writes at W=2.
    let safe = (0..)
        .map(|i| format!("safe{i}"))
        .find(|k| !cc.owners(k).contains(&VICTIM))
        .unwrap();
    assert!(cc.upsert(&safe, v.clone()).unwrap().contains("(2/2 replicas)"));
    // Pushes: find items owned by the victim.
    let items: Vec<(u64, f64)> = (0..200u64).map(|i| (i, 1.0)).collect();
    match cc.push("s", &items) {
        Err(ClusterError::QuorumLost { down, .. }) => {
            assert_eq!(down, vec![victim_id.clone()]);
        }
        other => panic!("expected QuorumLost, got {other:?}"),
    }
    // W=1 restores availability for both.
    cc.set_write_quorum(1).unwrap();
    assert!(cc.upsert(&key, v).unwrap().contains("(1/2 replicas)"));
    assert_eq!(cc.push("s", &items).unwrap(), items.len());
    cluster.stop();
}

/// Regression (ISSUE 5 bugfix): when two nodes both hold a key — e.g. a
/// mid-rebalance overlap — the gather must serve the HIGHEST-version
/// copy, not whichever node happened to answer first. Here the stale
/// copy sits on slot 0 (the old first-reporter-wins winner) and the live
/// copy on slot 1; the query must score 1.0 against the NEW vector.
#[test]
fn topk_dedup_keeps_the_highest_version_copy() {
    let cluster = LocalCluster::start(2, &cfg()).unwrap();
    let mut cc = ClusterClient::connect(&cluster.addrs()).unwrap();
    // A key whose rendezvous owner is slot 1 — slot 0 holding it is
    // ownership drift (exactly what a rebalance leaves behind).
    let key = (0..)
        .map(|i| format!("doc{i}"))
        .find(|k| cc.owner(k) == 1)
        .unwrap();
    let old_vec = SparseVector::new(vec![1, 2, 3], vec![1.0, 1.0, 1.0]);
    let new_vec = SparseVector::new(vec![10, 11, 12], vec![1.0, 1.0, 1.0]);
    // Slot 0: the stale residue at version 1 (written directly, behind
    // the partitioner's back).
    let mut direct0 = Client::connect(cluster.addr(0)).unwrap();
    assert!(direct0.upsert(&key, old_vec.clone()).unwrap().contains("@v1"));
    // Slot 1 (the real owner): two writes → version 2, new content.
    let mut direct1 = Client::connect(cluster.addr(1)).unwrap();
    direct1.upsert(&key, old_vec).unwrap();
    assert!(direct1.upsert(&key, new_vec.clone()).unwrap().contains("@v2"));
    // Both nodes report the key; the v2 blob must win the dedup, so the
    // new vector scores a perfect self-similarity.
    let (hits, stats) = cc.topk(&new_vec, 1).unwrap();
    assert_eq!(stats.candidates, 1, "{stats:?}");
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].0, key);
    assert!((hits[0].1 - 1.0).abs() < 1e-12, "stale v1 blob won the dedup: score {}", hits[0].1);
    // The single-key read applies the same rule: highest version wins.
    let (version, sk) = cc.fetch_key(&key).unwrap().expect("key is held");
    assert_eq!(version, 2);
    assert_eq!(sk, FastGm::new(K, SEED).sketch(&new_vec));
    assert_eq!(cc.fetch_key("ghost").unwrap(), None);
    cluster.stop();
}

/// ISSUE 7 satellite: the per-node I/O timeout is configurable through
/// `ReplicaConfig::io_timeout` — a node that accepts the handshake and
/// then goes silent (full receive buffer, stop-the-world pause) is
/// marked down after the configured timeout, not the 10s default.
#[test]
fn tiny_io_timeout_marks_a_stuffed_node_down() {
    use std::io::{BufRead, BufReader, Write};
    // A "stuffed" node: answers the hello handshake, then never replies
    // to anything again (reads and discards forever).
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stub = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap(); // the hello request
        let mut w = stream;
        w.write_all(
            concat!(
                r#"{"ok":true,"type":"hello","protocol":4,"node":"stuffed","epoch":0,"#,
                r#""k":8,"seed":1,"algo":"fastgm","algos":["fastgm"]}"#,
                "\n"
            )
            .as_bytes(),
        )
        .unwrap();
        // Swallow everything else until the client hangs up.
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => return,
                Ok(_) => {}
            }
        }
    });
    let mut cc = ClusterClient::connect_with(
        &[addr],
        ReplicaConfig {
            io_timeout: std::time::Duration::from_millis(100),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(cc.live_nodes(), 1);
    let t0 = std::time::Instant::now();
    let err = cc.upsert("doc", SparseVector::new(vec![1], vec![1.0])).unwrap_err();
    assert!(matches!(err, ClusterError::NodeDown { .. }), "{err}");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(5),
        "io_timeout did not bound the stall: {:?}",
        t0.elapsed()
    );
    assert_eq!(cc.live_nodes(), 0);
    drop(cc); // closes the socket; the stub sees EOF and exits
    stub.join().unwrap();
}

/// ISSUE 9: the client-side `(key, version)` gather-blob cache. Warm
/// gathers — `topk`, `sample`, `partition` — must be bit-identical to
/// cold ones AND to an uncached client's; a version advance must
/// invalidate exactly the changed key; deletes drop out of the version
/// view; and `cache_bytes == 0` keeps the whole path off.
#[test]
fn gather_blob_cache_is_bit_identical_and_version_invalidated() {
    use fastgm::coordinator::protocol::QueryTarget;
    let cluster = LocalCluster::start(2, &cfg()).unwrap();
    let mut cached = ClusterClient::connect_with(
        &cluster.addrs(),
        ReplicaConfig { cache_bytes: 1 << 20, ..Default::default() },
    )
    .unwrap();
    let mut fresh = ClusterClient::connect(&cluster.addrs()).unwrap();
    assert!(fresh.gather_cache_stats().is_none(), "cache_bytes=0 must disable the cache");

    let mut r = SplitMix64::new(21);
    let keys: Vec<String> = (0..10).map(|i| format!("doc{i:02}")).collect();
    for key in &keys {
        cached.upsert(key, random_vec(&mut r, 20, 5000)).unwrap();
    }
    let query = random_vec(&mut r, 20, 5000);
    let union_target = QueryTarget::Keys(keys.clone());

    // Cold pass fills the cache; warm pass must serve hits and stay
    // bit-identical to both the cold answers and the uncached client's.
    let (cold_hits, _) = cached.topk(&query, LIMIT).unwrap();
    let cold_sample = cached.sample(&union_target, 16, 3).unwrap();
    let cold_z = cached.partition(&union_target).unwrap();
    let after_cold = cached.gather_cache_stats().unwrap();
    assert!(after_cold.entries > 0 && after_cold.bytes > 0, "{after_cold:?}");
    let (warm_hits, _) = cached.topk(&query, LIMIT).unwrap();
    assert_eq!(warm_hits, cold_hits, "warm topk drifted from the cold gather");
    assert_eq!(cached.sample(&union_target, 16, 3).unwrap(), cold_sample);
    assert_eq!(cached.partition(&union_target).unwrap(), cold_z);
    let after_warm = cached.gather_cache_stats().unwrap();
    assert!(after_warm.hits > after_cold.hits, "warm gathers must hit: {after_warm:?}");
    let (want_hits, _) = fresh.topk(&query, LIMIT).unwrap();
    assert_eq!(warm_hits, want_hits, "cached topk diverged from the uncached client");
    assert_eq!(fresh.sample(&union_target, 16, 3).unwrap(), cold_sample);

    // A version advance on one key invalidates exactly that entry: the
    // next gathers re-fetch it and track the uncached client bit for bit.
    cached.upsert(&keys[3], random_vec(&mut r, 20, 5000)).unwrap();
    let (new_hits, _) = cached.topk(&query, LIMIT).unwrap();
    let (new_want, _) = fresh.topk(&query, LIMIT).unwrap();
    assert_eq!(new_hits, new_want, "post-write cached topk diverged");
    assert_eq!(
        cached.sample(&union_target, 16, 3).unwrap(),
        fresh.sample(&union_target, 16, 3).unwrap(),
        "post-write cached sample diverged"
    );
    assert_eq!(
        cached.partition(&union_target).unwrap(),
        fresh.partition(&union_target).unwrap(),
        "post-write cached partition estimate diverged"
    );
    let after_write = cached.gather_cache_stats().unwrap();
    assert!(
        after_write.stale_drops > after_warm.stale_drops,
        "the version advance must drop the stale entry: {after_write:?}"
    );

    // A deleted key drops out of the version view: the union target now
    // fails identically on both clients, and the surviving keys keep
    // serving (still bit-identical).
    cached.delete(&keys[7]).unwrap();
    let e_cached = cached.sample(&union_target, 16, 3).unwrap_err().to_string();
    let e_fresh = fresh.sample(&union_target, 16, 3).unwrap_err().to_string();
    assert_eq!(e_cached, e_fresh, "cached error shape drifted");
    let survivors = QueryTarget::Keys(
        keys.iter().filter(|k| *k != &keys[7]).cloned().collect(),
    );
    assert_eq!(
        cached.sample(&survivors, 16, 3).unwrap(),
        fresh.sample(&survivors, 16, 3).unwrap(),
        "post-delete cached sample diverged"
    );
    let s = cached.gather_cache_stats().unwrap();
    assert!(s.hits > 0 && s.misses > 0, "{s:?}");
    cluster.stop();
}

/// ISSUE 10 acceptance: a framed cluster client moves every blob —
/// gather fetches, single-key reads, stream merges, repair installs — as
/// raw codec bytes in binary frames, and its answers are BIT-IDENTICAL
/// to a hex-in-JSON client's against the SAME nodes: healthy, with a
/// node down at R=2 (failover fetches ride the binary path too), and
/// after a binary-plane repair of a cold-restarted node.
#[cfg(unix)]
#[test]
fn binary_and_hex_gathers_are_bit_identical_with_a_node_down() {
    const M: usize = 3;
    let (query, docs) = corpus(60);
    let mut cluster = LocalCluster::start_event(M, &cfg()).unwrap();
    let repl = || ReplicaConfig { replication: 2, write_quorum: 1, ..Default::default() };
    let mut hex = ClusterClient::connect_with(&cluster.addrs(), repl()).unwrap();
    let mut bin =
        ClusterClient::connect_with(&cluster.addrs(), ReplicaConfig { framed: true, ..repl() })
            .unwrap();

    // Ingest through the BINARY client; read back through both planes.
    for (i, d) in docs.iter().enumerate() {
        let info = bin.upsert(&format!("doc{i:03}"), d.clone()).unwrap();
        assert!(info.contains("(2/2 replicas)"), "{info}");
    }
    let items: Vec<(u64, f64)> = (0..700u64).map(|i| (i * 977 + 13, 1.0)).collect();
    bin.push("pkts", &items).unwrap();

    let brute = brute_force_topk(&query, &docs, LIMIT);
    let (bin_hits, bin_stats) = bin.topk(&query, LIMIT).unwrap();
    let (hex_hits, _) = hex.topk(&query, LIMIT).unwrap();
    assert_eq!(bin_hits, brute, "binary gather drifted from the brute scan");
    assert_eq!(bin_hits, hex_hits, "binary and hex gathers disagree");
    assert_eq!(bin_stats.live, M);
    let healthy_sketch = hex.merged_stream_sketch("pkts").unwrap();
    assert_eq!(bin.merged_stream_sketch("pkts").unwrap(), healthy_sketch);
    // Single-key reads: same (version, registers) through both planes,
    // and the same None for a key nobody holds.
    for i in 0..docs.len() {
        let key = format!("doc{i:03}");
        assert_eq!(bin.fetch_key(&key).unwrap(), hex.fetch_key(&key).unwrap(), "'{key}'");
    }
    assert_eq!(bin.fetch_key("ghost").unwrap(), None);

    // One node down at R=2: every partition keeps a live replica, and
    // BOTH planes keep their exact healthy answers.
    const VICTIM: usize = 1;
    cluster.kill(VICTIM);
    let (bin_down, stats) = bin.topk(&query, LIMIT).unwrap();
    assert_eq!(stats.live, M - 1, "{stats:?}");
    assert_eq!(bin_down, brute, "degraded binary gather drifted");
    assert_eq!(hex.topk(&query, LIMIT).unwrap().0, brute, "degraded hex gather drifted");
    assert_eq!(bin.merged_stream_sketch("pkts").unwrap(), healthy_sketch);
    assert_eq!(hex.merged_stream_sketch("pkts").unwrap(), healthy_sketch);
    for i in 0..docs.len() {
        let key = format!("doc{i:03}");
        assert_eq!(
            bin.fetch_key(&key).unwrap(),
            hex.fetch_key(&key).unwrap(),
            "'{key}' diverged with a node down"
        );
    }

    // Cold restart + repair THROUGH THE BINARY PLANE: the phase-2 blob
    // installs ride `store_put_bin`, phase-3 stream convergence rides
    // `stream_merge_bin` — and the hex client sees the same converged
    // cluster afterwards.
    cluster.restart(VICTIM).unwrap();
    bin.reconnect(VICTIM, cluster.addr(VICTIM)).unwrap();
    hex.reconnect(VICTIM, cluster.addr(VICTIM)).unwrap();
    let report = bin.repair(&["pkts".to_string()]).unwrap();
    assert!(report.keys_healed > 0, "cold node must be healed: {report:?}");
    assert_eq!(report.stream_merges, M, "every live node absorbs the union");
    assert_eq!(bin.repair(&["pkts".to_string()]).unwrap().keys_healed, 0, "repair idempotent");
    assert_eq!(bin.topk(&query, LIMIT).unwrap().0, brute);
    assert_eq!(hex.topk(&query, LIMIT).unwrap().0, brute);
    assert_eq!(hex.merged_stream_sketch("pkts").unwrap(), healthy_sketch);
    cluster.stop();
}
