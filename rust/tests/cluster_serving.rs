//! End-to-end acceptance + partition-correctness property tests for the
//! cluster layer (ISSUE 4):
//!
//! * a 3-node local cluster ingests 200+ keys through the cluster client,
//!   scatter-gather `topk` ranks exactly like a brute-force single-store
//!   `estimate_jp` scan, cluster-wide cardinality lands within the
//!   single-node estimator's error bound, and killing one node leaves
//!   `topk` serving (degraded, non-panicking) while `upsert` to the dead
//!   partition returns a typed error;
//! * property (a): scatter-gather `topk` over an M-node cluster equals
//!   single-node `topk` on the union store, for several M;
//! * property (b): cluster-wide cardinality sketches — per-site stream
//!   sketches moved through `sketch::codec` and merged — are bit-identical
//!   to sketching the concatenated stream (§2.3 across the wire).

use fastgm::coordinator::cluster::{ClusterClient, ClusterError, LocalCluster};
use fastgm::coordinator::protocol::{Request, Response};
use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
use fastgm::estimate::cardinality::cardinality_rel_std;
use fastgm::estimate::jaccard::estimate_jp;
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::stream_fastgm::StreamFastGm;
use fastgm::sketch::{Sketcher, SparseVector};
use fastgm::util::rng::SplitMix64;

const K: usize = 128;
const SEED: u64 = 42;
const N: usize = 210;
const LIMIT: usize = 5;

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        k: K,
        seed: SEED,
        workers: 2,
        node_id: "acc".into(),
        // Exact per-partition answers: every node brute-scans its shard, so
        // the gather's claim ("equals a brute-force scan of the union") is
        // deterministic. The probe path's recall is covered by
        // store_serving.rs; this suite pins the *distribution* logic.
        topk_scan_max: 100_000,
        ..Default::default()
    }
}

fn random_vec(r: &mut SplitMix64, n: usize, span: u64) -> SparseVector {
    SparseVector::new(
        (0..n).map(|_| r.next_u64() % span).collect(),
        (0..n).map(|_| r.next_f64() + 0.1).collect(),
    )
}

/// `base` + near-duplicates + unrelated docs (disjoint id spaces), so the
/// brute-force top-5 is the near-duplicate family with strictly positive
/// scores.
fn corpus(n: usize) -> (SparseVector, Vec<SparseVector>) {
    let mut r = SplitMix64::new(31);
    let base = SparseVector::new(
        (0..40u64).map(|i| i * 31 + 5).collect(),
        (0..40).map(|_| r.next_f64() + 0.1).collect(),
    );
    let mut docs = Vec::with_capacity(n);
    docs.push(base.clone());
    for j in 1..5u64 {
        let swapped = [j - 1, j + 9, j + 19];
        let mut near = SparseVector::default();
        for (idx, (id, w)) in base.positive().enumerate() {
            if swapped.contains(&(idx as u64)) {
                near.push(r.next_u64() | (1 << 63), w);
            } else {
                near.push(id, w);
            }
        }
        docs.push(near);
    }
    for i in 5..n {
        docs.push(SparseVector::new(
            (0..40u64).map(|j| (i as u64) * 100_000 + j).collect(),
            (0..40).map(|_| r.next_f64() + 0.1).collect(),
        ));
    }
    (base, docs)
}

fn brute_force_topk(
    query: &SparseVector,
    docs: &[SparseVector],
    limit: usize,
) -> Vec<(String, f64)> {
    let f = FastGm::new(K, SEED);
    let qsk = f.sketch(query);
    let mut scored: Vec<(String, f64)> = docs
        .iter()
        .enumerate()
        .map(|(i, d)| (format!("doc{i:03}"), estimate_jp(&qsk, &f.sketch(d)).unwrap()))
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    scored.truncate(limit);
    scored
}

#[test]
fn three_node_cluster_serves_degrades_and_errors_typed() {
    let (query, docs) = corpus(N);

    // ---- 3 nodes, 200+ keys ingested via the cluster client. ------------
    let mut cluster = LocalCluster::start(3, &cfg()).unwrap();
    let mut cc = ClusterClient::connect(&cluster.addrs()).unwrap();
    assert_eq!(cc.nodes(), 3);
    for (i, d) in docs.iter().enumerate() {
        cc.upsert(&format!("doc{i:03}"), d.clone()).unwrap();
    }
    // Every key landed on its rendezvous owner and nowhere else.
    let sizes = cc.store_sizes();
    let total: f64 = sizes.iter().map(|(_, s)| s.unwrap()).sum();
    assert_eq!(total, N as f64, "partition sizes must sum to the corpus: {sizes:?}");
    assert!(
        sizes.iter().all(|(_, s)| s.unwrap() > 0.0),
        "every node should own part of the corpus: {sizes:?}"
    );

    // ---- scatter-gather == brute-force single-store scan. ---------------
    let brute = brute_force_topk(&query, &docs, LIMIT);
    let (hits, stats) = cc.topk(&query, LIMIT).unwrap();
    assert_eq!(hits, brute, "scatter-gather must rank exactly like a brute scan");
    assert_eq!(hits[0].0, "doc000");
    assert!((hits[0].1 - 1.0).abs() < 1e-12, "self-similarity must be 1: {hits:?}");
    assert_eq!(stats.nodes, 3);
    assert_eq!(stats.live, 3);
    assert!(stats.candidates >= LIMIT && stats.reranked >= LIMIT, "{stats:?}");

    // ---- cluster cardinality within the estimator's error bound. --------
    let truth = 1500.0;
    let items: Vec<(u64, f64)> = (0..truth as u64).map(|i| (i * 977 + 13, 1.0)).collect();
    cc.push("pkts", &items).unwrap();
    let est = cc.cardinality("pkts").unwrap();
    // 5σ of the k-register estimator — generous but still meaningful.
    let bound = 5.0 * cardinality_rel_std(K);
    assert!(
        (est - truth).abs() / truth < bound,
        "cluster cardinality {est} vs truth {truth} (bound {bound})"
    );

    // ---- kill one node: typed write errors, degraded (non-panicking)
    // ---- reads.
    const VICTIM: usize = 2;
    let victim_id = cc.node_id(VICTIM).to_string();
    cluster.kill(VICTIM);
    // A write routed to the dead partition is a typed NodeDown, naming it.
    let dead_key = (0..)
        .map(|i| format!("probe{i}"))
        .find(|k| cc.owner(k) == VICTIM)
        .unwrap();
    match cc.upsert(&dead_key, docs[0].clone()) {
        Err(ClusterError::NodeDown { node, .. }) => assert_eq!(node, victim_id),
        other => panic!("expected NodeDown, got {other:?}"),
    }
    // Reads keep serving with degraded coverage: the surviving partitions'
    // brute ranking, which is the full ranking minus the dead node's keys.
    let (degraded, stats) = cc.topk(&query, LIMIT).unwrap();
    assert_eq!(stats.live, 2, "{stats:?}");
    let survivors: Vec<(String, f64)> = {
        let f = FastGm::new(K, SEED);
        let qsk = f.sketch(&query);
        let mut scored: Vec<(String, f64)> = docs
            .iter()
            .enumerate()
            .map(|(i, d)| (format!("doc{i:03}"), estimate_jp(&qsk, &f.sketch(d)).unwrap()))
            .filter(|(key, _)| cc.owner(key) != VICTIM)
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        scored.truncate(LIMIT);
        scored
    };
    assert_eq!(degraded, survivors, "degraded gather must equal the surviving union");
    // Writes to live partitions still work.
    let live_key = (0..)
        .map(|i| format!("alive{i}"))
        .find(|k| cc.owner(k) != VICTIM)
        .unwrap();
    cc.upsert(&live_key, docs[0].clone()).unwrap();
    // Cardinality degrades (some partitions dark) but still answers.
    let est = cc.cardinality("pkts").unwrap();
    assert!(est > 0.0 && est < truth, "degraded estimate should undercount: {est}");

    cluster.stop();
}

/// Property (a): scatter-gather over M nodes == single-node topk on the
/// union store, hit-for-hit and score-for-score (both f64-exact — the
/// central re-rank recomputes the identical deterministic estimator).
#[test]
fn scatter_gather_equals_single_node_union_topk() {
    let mut r = SplitMix64::new(7);
    let docs: Vec<SparseVector> = (0..60).map(|_| random_vec(&mut r, 25, 4000)).collect();
    let queries: Vec<SparseVector> = (0..6).map(|_| random_vec(&mut r, 25, 4000)).collect();

    // Reference: one node holding the whole corpus.
    let single = Coordinator::new(cfg()).unwrap();
    for (i, d) in docs.iter().enumerate() {
        let resp = single.call(Request::Upsert {
            key: format!("doc{i:03}"),
            vector: d.clone(),
        });
        assert!(matches!(resp, Response::Ack { .. }), "{resp:?}");
    }

    for m in [1usize, 2, 3, 5] {
        let cluster = LocalCluster::start(m, &cfg()).unwrap();
        let mut cc = ClusterClient::connect(&cluster.addrs()).unwrap();
        for (i, d) in docs.iter().enumerate() {
            cc.upsert(&format!("doc{i:03}"), d.clone()).unwrap();
        }
        for (qi, q) in queries.iter().enumerate() {
            let Response::TopK { hits: want } =
                single.call(Request::TopK { vector: q.clone(), limit: 8 })
            else {
                panic!("expected topk")
            };
            let (got, stats) = cc.topk(q, 8).unwrap();
            assert_eq!(
                got, want,
                "query {qi} over {m} nodes diverged from the union store ({stats:?})"
            );
        }
        cluster.stop();
    }
    single.shutdown();
}

/// Property (b): the merged cluster sketch — per-site stream sketches
/// fetched as codec blobs and merge_tree'd — is bit-identical to one
/// Stream-FastGM run over the concatenated stream (§2.3 across the wire).
#[test]
fn merged_cluster_stream_sketch_is_bit_identical_to_concatenated_stream() {
    let mut r = SplitMix64::new(99);
    for m in [1usize, 2, 4] {
        let cluster = LocalCluster::start(m, &cfg()).unwrap();
        let mut cc = ClusterClient::connect(&cluster.addrs()).unwrap();
        // Unique element ids with varied weights; pushed in chunks so
        // per-site streams interleave arbitrarily.
        let items: Vec<(u64, f64)> =
            (0..800u64).map(|i| (i * 6_364_136 + 11, r.next_f64() + 0.05)).collect();
        for chunk in items.chunks(97) {
            cc.push("s", chunk).unwrap();
        }
        let merged = cc.merged_stream_sketch("s").unwrap();
        let mut reference = StreamFastGm::new(K, SEED);
        for &(id, w) in &items {
            reference.push(id, w);
        }
        assert_eq!(
            merged,
            reference.sketch(),
            "merge over {m} sites must be bit-identical to the concatenated stream"
        );
        cluster.stop();
    }
}

/// A typo'd stream on a healthy cluster is a gather error naming the
/// stream — not a spurious "no live nodes" outage report.
#[test]
fn unknown_stream_on_healthy_cluster_is_not_an_outage() {
    let cluster = LocalCluster::start(2, &cfg()).unwrap();
    let mut cc = ClusterClient::connect(&cluster.addrs()).unwrap();
    let err = cc.cardinality("nope").unwrap_err();
    assert!(matches!(err, ClusterError::Gather(_)), "got {err:?}");
    assert!(err.to_string().contains("'nope' not found"), "{err}");
    cluster.stop();
}

/// The handshake refuses to form a cluster out of incompatible nodes.
#[test]
fn connect_rejects_mismatched_node_configs() {
    let a = LocalCluster::start(1, &cfg()).unwrap();
    let b = LocalCluster::start(
        1,
        &CoordinatorConfig { k: 64, node_id: "other".into(), ..cfg() },
    )
    .unwrap();
    let addrs: Vec<String> = a.addrs().into_iter().chain(b.addrs()).collect();
    let err = ClusterClient::connect(&addrs).unwrap_err().to_string();
    assert!(err.contains("config mismatch"), "{err}");
    // And duplicate identities are rejected even with matching configs.
    let c = LocalCluster::start(1, &cfg()).unwrap();
    let dup: Vec<String> = a.addrs().into_iter().chain(c.addrs()).collect();
    let err = ClusterClient::connect(&dup).unwrap_err().to_string();
    assert!(err.contains("duplicate node id"), "{err}");

    // reconnect() re-checks the formation config: a node rejoining under
    // the same identity but a changed sketch config is refused up front,
    // not discovered query-by-query as gather errors.
    let mut cc = ClusterClient::connect(&a.addrs()).unwrap();
    let imposter = LocalCluster::start(
        1,
        &CoordinatorConfig { k: 64, ..cfg() }, // same "acc-0" id, different k
    )
    .unwrap();
    let err = cc.reconnect(0, imposter.addr(0)).unwrap_err().to_string();
    assert!(err.contains("rejoined with"), "{err}");
    // A same-config rejoin is accepted (here: the original node itself).
    cc.reconnect(0, a.addr(0)).unwrap();
    imposter.stop();
    a.stop();
    b.stop();
    c.stop();
}
