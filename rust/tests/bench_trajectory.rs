//! Tier-1 lock on the committed perf trajectory: the `BENCH_*.json`
//! baselines (written by `perf_probe --json` / refreshed via
//! `ci/gen_bench_baseline.py`) must stay parseable by the crate's own JSON
//! layer, schema-complete, and internally consistent — and the relative
//! claims each PR committed must keep holding in its baseline:
//!
//! * `BENCH_6.json` — scalar-vs-SIMD kernel pairs show the kernel layer
//!   paying rent (≥2x on a register-update kernel, SIMD never slower);
//! * `BENCH_7.json` — the binary framed transport beats JSON lines: every
//!   frame-vs-JSON codec pair is binary-faster, and the saturation probes
//!   show ≥10x sustained req/s at equal-or-better p99;
//! * `BENCH_8.json` — the sampling query engine amortizes: serving a
//!   32-draw `sample` from a stored sketch is far cheaper than sketching
//!   even a small vector, the regime the register-as-sample design buys;
//! * `BENCH_9.json` — the versioned read-path cache pays rent: a validated
//!   merged-union hit is ≥10x cheaper than the §2.3 re-merge it elides,
//!   and a warm `(key, version)` cluster gather is strictly cheaper than
//!   a cold one;
//! * `BENCH_10.json` — the zero-copy binary data plane pays rent: a
//!   k=1024 binary blob fetch is ≥2x cheaper than its hex-in-JSON twin,
//!   the borrowing view decode beats the owned (copying) decode of the
//!   same frame, and a binary-plane repair walk beats the hex one.
//!
//! Absolute numbers are NOT asserted against the current machine (CI
//! runners are too noisy; `ci/bench_coverage.py` gates name coverage on
//! fresh runs instead).

use fastgm::util::json::{parse, Value};

const BASELINE: &str = include_str!("../../BENCH_6.json");
const BASELINE7: &str = include_str!("../../BENCH_7.json");
const BASELINE8: &str = include_str!("../../BENCH_8.json");
const BASELINE9: &str = include_str!("../../BENCH_9.json");
const BASELINE10: &str = include_str!("../../BENCH_10.json");

/// Pairs emitted by `perf_probe`: `<name>_scalar_ns` vs `<name>_ns`.
const PAIRS: [&str; 8] = [
    "kernel.uniform_batch",
    "kernel.gumbel_batch",
    "kernel.argmin",
    "kernel.merge",
    "kernel.match",
    "kernel.direct_row",
    "sketch.fastgm",
    "sketch.pminhash",
];

/// Register-update kernels where the acceptance bar is a >=2x SIMD win on
/// at least one (the ln-dominated kernels are exempt by construction —
/// both backends share scalar libm `ln`).
const REGISTER_KERNELS: [&str; 4] =
    ["kernel.uniform_batch", "kernel.argmin", "kernel.merge", "kernel.match"];

fn baseline() -> Value {
    parse(BASELINE).expect("BENCH_6.json parses with the crate JSON layer")
}

fn baseline7() -> Value {
    parse(BASELINE7).expect("BENCH_7.json parses with the crate JSON layer")
}

fn baseline8() -> Value {
    parse(BASELINE8).expect("BENCH_8.json parses with the crate JSON layer")
}

fn baseline9() -> Value {
    parse(BASELINE9).expect("BENCH_9.json parses with the crate JSON layer")
}

fn baseline10() -> Value {
    parse(BASELINE10).expect("BENCH_10.json parses with the crate JSON layer")
}

fn ns(v: &Value, name: &str) -> f64 {
    v.get(name)
        .unwrap_or_else(|| panic!("probe '{name}' missing from the baseline"))
        .req_f64("ns_per_op")
        .unwrap()
}

#[test]
fn baseline_schema_is_complete_and_consistent() {
    for (file, v) in [
        ("BENCH_6.json", baseline()),
        ("BENCH_7.json", baseline7()),
        ("BENCH_8.json", baseline8()),
        ("BENCH_9.json", baseline9()),
        ("BENCH_10.json", baseline10()),
    ] {
        let Value::Obj(entries) = &v else { panic!("{file}: top level must be a name->stats object") };
        assert!(entries.len() >= 50, "{file}: expected the full probe sweep, got {}", entries.len());
        for (name, stats) in entries {
            let ns = stats.req_f64("ns_per_op").unwrap_or_else(|e| panic!("{file}/{name}: {e}"));
            let ops = stats.req_f64("ops_per_s").unwrap_or_else(|e| panic!("{file}/{name}: {e}"));
            assert!(ns > 0.0 && ops > 0.0, "{file}/{name}: non-positive timing");
            // ns/op and ops/s must be exact float inverses (the
            // Suite::to_json arithmetic — a hand-edited baseline that
            // breaks this is corrupt).
            assert!((ns * ops / 1e9 - 1.0).abs() < 1e-9, "{file}/{name}: ns={ns} ops={ops}");
            let p10 = stats.req_f64("p10_ns").unwrap();
            let p90 = stats.req_f64("p90_ns").unwrap();
            assert!(p10 <= p90, "{file}/{name}: p10 {p10} > p90 {p90}");
            assert!(stats.req_f64("iters").unwrap() >= 1.0, "{file}/{name}: no iterations");
            assert!(stats.req_f64("samples").unwrap() >= 1.0, "{file}/{name}: no samples");
        }
    }
}

#[test]
fn trajectory_keeps_the_historical_probe_families() {
    let v = baseline();
    // A sentinel per pre-existing probe family: losing one of these names
    // silently forks the trajectory (diffs stop lining up across PRs).
    for name in [
        "fastgm/n1000/k64",
        "fastgm/n200000/k1024",
        "sharded4/n200000/k1024",
        "pminhash/n1000/k256",
        "engine-reuse/fastgm/n10000/k1024",
        "engine-fresh/fastgm/n10000/k1024",
        "cluster.owner_ns",
        "cluster.owners_r2_ns",
        "stream-fastgm/n1000/k1024",
        "lemiesz/n1000/k1024",
    ] {
        assert!(ns(&v, name) > 0.0);
    }
}

#[test]
fn simd_probes_are_not_slower_than_scalar() {
    let v = baseline();
    // Generous 1.5x guard: a baseline refreshed on a non-AVX2 box would
    // show ~1.0x pairs (allowed); a SIMD path that *regressed* past the
    // guard is a real bug in the dispatch or the kernel.
    for name in PAIRS {
        let scalar = ns(&v, &format!("{name}_scalar_ns"));
        let simd = ns(&v, &format!("{name}_ns"));
        assert!(
            simd <= scalar * 1.5,
            "{name}: SIMD {simd} ns vs scalar {scalar} ns exceeds the noise guard"
        );
    }
}

#[test]
fn at_least_one_register_kernel_shows_2x() {
    let v = baseline();
    let mut best = ("", 0.0f64);
    for name in REGISTER_KERNELS {
        let speedup = ns(&v, &format!("{name}_scalar_ns")) / ns(&v, &format!("{name}_ns"));
        if speedup > best.1 {
            best = (name, speedup);
        }
    }
    assert!(
        best.1 >= 2.0,
        "no register-update kernel reaches 2x in the committed baseline (best: {} at {:.2}x)",
        best.0,
        best.1
    );
    // The auto-backend sketch probes must agree with their forced-SIMD
    // twins at the same shape: pminhash/n1000/k256 IS sketch.pminhash_ns
    // measured through the public path (same backend, same work). 25%
    // tolerance — separate measurements, same machine.
    let a = ns(&v, "pminhash/n1000/k256");
    let b = ns(&v, "sketch.pminhash_ns");
    assert!((a / b - 1.0).abs() < 0.25, "auto vs forced-SIMD pminhash diverge: {a} vs {b}");
}

/// BENCH_7: every frame-vs-JSON codec pair must be binary-faster — the
/// whole point of the framed wire format. The floor is 1.0x (never
/// slower), with the encode pairs expected well past it; a refreshed
/// baseline where JSON wins a pair means the binary codec regressed.
#[test]
fn binary_codec_beats_json_on_every_pair_in_bench7() {
    let v = baseline7();
    for side in ["request", "response"] {
        for dir in ["encode", "decode"] {
            let json = ns(&v, &format!("frame.{dir}_{side}_json_ns"));
            let bin = ns(&v, &format!("frame.{dir}_{side}_ns"));
            assert!(
                bin < json,
                "frame.{dir}_{side}: binary {bin} ns is not faster than JSON {json} ns"
            );
        }
    }
    // BENCH_7 also re-carries every BENCH_6 probe (one sweep per
    // baseline file, so trajectories diff file-to-file).
    for name in ["fastgm/n1000/k64", "kernel.merge_ns", "cluster.owner_ns"] {
        assert!(ns(&v, name) > 0.0);
    }
}

/// BENCH_8 (ISSUE 8): the sampling query engine's amortization claim —
/// serving a 32-draw `sample` from a stored sketch (one register scan +
/// O(1) uniform picks) must be dramatically cheaper than re-sketching
/// even a small (n=1000) vector at the same k, and the one-pass
/// `partition` estimate cheaper still than the draw.
#[test]
fn sampling_amortizes_over_resketching_in_bench8() {
    let v = baseline8();
    for name in [
        "sample.draw32_k256_ns",
        "sample.draw32_k1024_ns",
        "sample.union8_k256_ns",
        "partition.total_weight_k256_ns",
        "partition.total_weight_k1024_ns",
    ] {
        assert!(ns(&v, name) > 0.0);
    }
    for k in [256usize, 1024] {
        let draw = ns(&v, &format!("sample.draw32_k{k}_ns"));
        let sketch = ns(&v, &format!("fastgm/n1000/k{k}"));
        assert!(
            draw * 20.0 < sketch,
            "k={k}: a 32-draw sample ({draw} ns) should be >=20x cheaper than \
             re-sketching n=1000 ({sketch} ns)"
        );
        let part = ns(&v, &format!("partition.total_weight_k{k}_ns"));
        assert!(part < draw, "k={k}: one-pass partition ({part} ns) vs draw ({draw} ns)");
    }
    // Even the 8-way §2.3 merge ahead of a union draw stays well under
    // one fresh sketch of a single small vector.
    assert!(ns(&v, "sample.union8_k256_ns") * 10.0 < ns(&v, "fastgm/n1000/k256"));
    // BENCH_8 re-carries every earlier probe (one sweep per baseline
    // file, so trajectories diff file-to-file).
    for name in ["fastgm/n1000/k64", "kernel.merge_ns", "transport.sat.framed_ns"] {
        assert!(ns(&v, name) > 0.0);
    }
}

/// BENCH_7 acceptance floor (ISSUE 7): the event-driven framed transport
/// sustains ≥10x the req/s of the thread-per-connection JSON-lines
/// server at equal-or-better p99, under the committed saturation run
/// (8 clients × 64 pipelined pings).
#[test]
fn framed_transport_sustains_10x_at_no_worse_p99_in_bench7() {
    let v = baseline7();
    let framed = ns(&v, "transport.sat.framed_ns");
    let json = ns(&v, "transport.sat.json_ns");
    let speedup = json / framed; // ns/req inverse == req/s ratio
    assert!(
        speedup >= 10.0,
        "framed sustained speedup {speedup:.2}x is below the 10x acceptance floor \
         (framed {framed} ns/req vs json {json} ns/req)"
    );
    let framed_p99 = ns(&v, "transport.sat.framed_p99_ns");
    let json_p99 = ns(&v, "transport.sat.json_p99_ns");
    assert!(
        framed_p99 <= json_p99,
        "framed p99 {framed_p99} ns is worse than JSON p99 {json_p99} ns"
    );
}

/// BENCH_9 acceptance (ISSUE 9): the versioned read-path cache pays rent.
/// A validated merged-union hit must be ≥10x cheaper than the 32-key §2.3
/// re-merge it elides (the identical request through a cache-disabled
/// node), the top-k result cache must be measured, and a warm
/// `(key, version)` cluster gather — one `store_keys` version walk, zero
/// blob fetches — must be strictly cheaper than the cold gather that
/// re-fetches every candidate blob.
#[test]
fn cache_hits_amortize_and_warm_gathers_beat_cold_in_bench9() {
    let v = baseline9();
    let hit = ns(&v, "cache.merge_keys_hit_ns");
    let miss = ns(&v, "cache.merge_keys_miss_ns");
    assert!(
        hit * 10.0 <= miss,
        "merged-union hit ({hit} ns) is not >=10x cheaper than the re-merge ({miss} ns)"
    );
    assert!(ns(&v, "cache.topk_hit_ns") > 0.0);
    let cold = ns(&v, "cluster.gather_cold_ns");
    let warm = ns(&v, "cluster.gather_warm_ns");
    assert!(
        warm < cold,
        "warm gather ({warm} ns) is not cheaper than the cold gather ({cold} ns)"
    );
    // BENCH_9 re-carries every earlier probe (one sweep per baseline
    // file, so trajectories diff file-to-file).
    for name in [
        "fastgm/n1000/k64",
        "kernel.merge_ns",
        "transport.sat.framed_ns",
        "sample.draw32_k256_ns",
    ] {
        assert!(ns(&v, name) > 0.0);
    }
}

/// BENCH_10 acceptance (ISSUE 10): the zero-copy binary data plane pays
/// rent. Fetching a k=1024 blob as raw codec bytes in a frame must be
/// ≥2x cheaper than the hex-in-JSON fetch of the SAME blob, the
/// borrowing `FrameView` decode must be strictly cheaper than the owned
/// (copying) decode of the same frame, and a repair walk whose fetches
/// and installs ride the binary plane must beat the hex walk.
#[test]
fn binary_blob_plane_pays_rent_in_bench10() {
    let v = baseline10();
    let hex = ns(&v, "blob.fetch_hex_ns");
    let bin = ns(&v, "blob.fetch_binary_ns");
    assert!(
        bin * 2.0 <= hex,
        "binary blob fetch ({bin} ns) is not >=2x cheaper than hex ({hex} ns) at k=1024"
    );
    let copy = ns(&v, "blob.decode_copy_ns");
    let view = ns(&v, "blob.decode_view_ns");
    assert!(
        view < copy,
        "zero-copy view decode ({view} ns) is not cheaper than the owned decode ({copy} ns)"
    );
    let rhex = ns(&v, "cluster.repair_hex_ns");
    let rbin = ns(&v, "cluster.repair_binary_ns");
    assert!(
        rbin < rhex,
        "binary-plane repair ({rbin} ns) is not cheaper than the hex repair ({rhex} ns)"
    );
    // BENCH_10 re-carries every earlier probe family (one sweep per
    // baseline file, so trajectories diff file-to-file).
    for name in [
        "fastgm/n1000/k64",
        "kernel.merge_ns",
        "transport.sat.framed_ns",
        "sample.draw32_k256_ns",
        "cache.merge_keys_hit_ns",
        "cluster.gather_warm_ns",
    ] {
        assert!(ns(&v, name) > 0.0);
    }
}
