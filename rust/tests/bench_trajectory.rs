//! Tier-1 lock on the committed perf trajectory: `BENCH_6.json` (the first
//! tracked baseline, written by `perf_probe --json` / refreshed via
//! `ci/gen_bench_baseline.py`) must stay parseable by the crate's own JSON
//! layer, schema-complete, and internally consistent — and its
//! scalar-vs-SIMD pairs must actually show the kernel layer paying rent.
//!
//! Absolute numbers are NOT asserted against the current machine (CI
//! runners are too noisy; `ci/bench_coverage.py` gates name coverage on
//! fresh runs instead). What IS asserted: the baseline's own arithmetic,
//! and the relative claims the PR makes — SIMD never slower than scalar
//! beyond a generous noise guard, and ≥2x on at least one register-update
//! kernel.

use fastgm::util::json::{parse, Value};

const BASELINE: &str = include_str!("../../BENCH_6.json");

/// Pairs emitted by `perf_probe`: `<name>_scalar_ns` vs `<name>_ns`.
const PAIRS: [&str; 8] = [
    "kernel.uniform_batch",
    "kernel.gumbel_batch",
    "kernel.argmin",
    "kernel.merge",
    "kernel.match",
    "kernel.direct_row",
    "sketch.fastgm",
    "sketch.pminhash",
];

/// Register-update kernels where the acceptance bar is a >=2x SIMD win on
/// at least one (the ln-dominated kernels are exempt by construction —
/// both backends share scalar libm `ln`).
const REGISTER_KERNELS: [&str; 4] =
    ["kernel.uniform_batch", "kernel.argmin", "kernel.merge", "kernel.match"];

fn baseline() -> Value {
    parse(BASELINE).expect("BENCH_6.json parses with the crate JSON layer")
}

fn ns(v: &Value, name: &str) -> f64 {
    v.get(name)
        .unwrap_or_else(|| panic!("probe '{name}' missing from BENCH_6.json"))
        .req_f64("ns_per_op")
        .unwrap()
}

#[test]
fn baseline_schema_is_complete_and_consistent() {
    let v = baseline();
    let Value::Obj(entries) = &v else { panic!("top level must be a name->stats object") };
    assert!(entries.len() >= 50, "expected the full probe sweep, got {}", entries.len());
    for (name, stats) in entries {
        let ns = stats.req_f64("ns_per_op").unwrap_or_else(|e| panic!("{name}: {e}"));
        let ops = stats.req_f64("ops_per_s").unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(ns > 0.0 && ops > 0.0, "{name}: non-positive timing");
        // ns/op and ops/s must be exact float inverses (the Suite::to_json
        // arithmetic — a hand-edited baseline that breaks this is corrupt).
        assert!((ns * ops / 1e9 - 1.0).abs() < 1e-9, "{name}: ns={ns} ops={ops}");
        let p10 = stats.req_f64("p10_ns").unwrap();
        let p90 = stats.req_f64("p90_ns").unwrap();
        assert!(p10 <= p90, "{name}: p10 {p10} > p90 {p90}");
        assert!(stats.req_f64("iters").unwrap() >= 1.0, "{name}: no iterations");
        assert!(stats.req_f64("samples").unwrap() >= 1.0, "{name}: no samples");
    }
}

#[test]
fn trajectory_keeps_the_historical_probe_families() {
    let v = baseline();
    // A sentinel per pre-existing probe family: losing one of these names
    // silently forks the trajectory (diffs stop lining up across PRs).
    for name in [
        "fastgm/n1000/k64",
        "fastgm/n200000/k1024",
        "sharded4/n200000/k1024",
        "pminhash/n1000/k256",
        "engine-reuse/fastgm/n10000/k1024",
        "engine-fresh/fastgm/n10000/k1024",
        "cluster.owner_ns",
        "cluster.owners_r2_ns",
        "stream-fastgm/n1000/k1024",
        "lemiesz/n1000/k1024",
    ] {
        assert!(ns(&v, name) > 0.0);
    }
}

#[test]
fn simd_probes_are_not_slower_than_scalar() {
    let v = baseline();
    // Generous 1.5x guard: a baseline refreshed on a non-AVX2 box would
    // show ~1.0x pairs (allowed); a SIMD path that *regressed* past the
    // guard is a real bug in the dispatch or the kernel.
    for name in PAIRS {
        let scalar = ns(&v, &format!("{name}_scalar_ns"));
        let simd = ns(&v, &format!("{name}_ns"));
        assert!(
            simd <= scalar * 1.5,
            "{name}: SIMD {simd} ns vs scalar {scalar} ns exceeds the noise guard"
        );
    }
}

#[test]
fn at_least_one_register_kernel_shows_2x() {
    let v = baseline();
    let mut best = ("", 0.0f64);
    for name in REGISTER_KERNELS {
        let speedup = ns(&v, &format!("{name}_scalar_ns")) / ns(&v, &format!("{name}_ns"));
        if speedup > best.1 {
            best = (name, speedup);
        }
    }
    assert!(
        best.1 >= 2.0,
        "no register-update kernel reaches 2x in the committed baseline (best: {} at {:.2}x)",
        best.0,
        best.1
    );
    // The auto-backend sketch probes must agree with their forced-SIMD
    // twins at the same shape: pminhash/n1000/k256 IS sketch.pminhash_ns
    // measured through the public path (same backend, same work). 25%
    // tolerance — separate measurements, same machine.
    let a = ns(&v, "pminhash/n1000/k256");
    let b = ns(&v, "sketch.pminhash_ns");
    assert!((a / b - 1.0).abs() < 0.25, "auto vs forced-SIMD pminhash diverge: {a} vs {b}");
}
