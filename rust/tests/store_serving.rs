//! End-to-end acceptance test for the keyed similarity store: a client
//! `upsert`s N vectors over TCP, snapshots, the server fully restarts
//! (stop + coordinator teardown), restores, and a `topk` query returns
//! exactly the neighbors a brute-force `estimate_jp` scan ranks first —
//! with the LSH probe touching fewer than N candidates (verified through
//! the server's own metrics).

use fastgm::coordinator::client::Client;
use fastgm::coordinator::protocol::{Request, Response};
use fastgm::coordinator::server::Server;
use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
use fastgm::estimate::jaccard::estimate_jp;
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::{Sketcher, SparseVector};
use fastgm::util::rng::SplitMix64;
use std::sync::Arc;

const K: usize = 128;
const SEED: u64 = 42;
/// Above the default `topk_scan_max` (64), so `topk` takes the band probe.
const N: usize = 120;
const LIMIT: usize = 5;

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig { k: K, seed: SEED, workers: 2, ..Default::default() }
}

/// doc000 = the query itself, doc001..doc004 near-duplicates (exactly 3 of
/// 40 entries replaced each, so J_P ≈ 0.9 deterministically — far above the
/// 0.5 banding threshold), the rest unrelated with disjoint id spaces — so
/// the brute-force top-5 is exactly {doc000..doc004} with strictly positive
/// scores, and everything else scores exactly 0 (no ambiguous tail).
fn corpus() -> (SparseVector, Vec<SparseVector>) {
    let mut r = SplitMix64::new(31);
    let base = SparseVector::new(
        (0..40u64).map(|i| i * 31 + 5).collect(),
        (0..40).map(|_| r.next_f64() + 0.1).collect(),
    );
    let mut docs = Vec::with_capacity(N);
    docs.push(base.clone());
    for j in 1..5u64 {
        // Replace a fixed, per-duplicate set of 3 entries with fresh ids.
        let swapped = [j - 1, j + 9, j + 19];
        let mut near = SparseVector::default();
        for (idx, (id, w)) in base.positive().enumerate() {
            if swapped.contains(&(idx as u64)) {
                near.push(r.next_u64() | (1 << 63), w);
            } else {
                near.push(id, w);
            }
        }
        docs.push(near);
    }
    for i in 5..N {
        docs.push(SparseVector::new(
            (0..40u64).map(|j| (i as u64) * 100_000 + j).collect(),
            (0..40).map(|_| r.next_f64() + 0.1).collect(),
        ));
    }
    (base, docs)
}

#[test]
fn upsert_snapshot_restart_restore_topk_matches_bruteforce() {
    let (query, docs) = corpus();

    // ---- Serve + pipelined ingest over TCP. -----------------------------
    let coordinator = Arc::new(Coordinator::new(cfg()).unwrap());
    let server = Server::start(coordinator.clone(), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let reqs: Vec<Request> = docs
        .iter()
        .enumerate()
        .map(|(i, d)| Request::Upsert {
            key: format!("doc{i:03}"),
            vector: d.clone(),
            version: None,
        })
        .collect();
    for chunk in reqs.chunks(32) {
        for r in client.call_pipelined(chunk).unwrap() {
            assert!(matches!(r, Response::Ack { .. }), "upsert failed: {r:?}");
        }
    }

    // ---- Snapshot, then a REAL restart: stop + tear down everything. ----
    let path =
        std::env::temp_dir().join(format!("fastgm-store-serving-{}.fgms", std::process::id()));
    let path_str = path.to_string_lossy().to_string();
    client.snapshot(&path_str).unwrap();
    drop(client);
    server.stop();
    let Ok(coord) = Arc::try_unwrap(coordinator) else {
        panic!("Server::stop must join every connection thread");
    };
    coord.shutdown();

    // ---- Fresh server, cold store: restore from the snapshot. -----------
    let coordinator = Arc::new(Coordinator::new(cfg()).unwrap());
    let server = Server::start(coordinator.clone(), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let info = client.restore(&path_str).unwrap();
    assert!(info.contains(&format!("restored {N} entries")), "{info}");

    // ---- topk over the wire vs a local brute-force estimate_jp scan. ----
    let hits = client.topk(query.clone(), LIMIT).unwrap();
    let f = FastGm::new(K, SEED);
    let qsk = f.sketch(&query);
    let mut brute: Vec<(String, f64)> = docs
        .iter()
        .enumerate()
        .map(|(i, d)| (format!("doc{i:03}"), estimate_jp(&qsk, &f.sketch(d)).unwrap()))
        .collect();
    brute.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    brute.truncate(LIMIT);
    assert_eq!(hits, brute, "band-probe top-k must equal the brute-force ranking");
    assert_eq!(hits[0].0, "doc000");
    assert!((hits[0].1 - 1.0).abs() < 1e-12, "self-similarity must be 1: {hits:?}");
    assert!(
        hits.iter().all(|h| h.1 > 0.4),
        "near-duplicates should fill the whole top set: {hits:?}"
    );

    // ---- Probe cost is sub-linear and reported via metrics. -------------
    let Response::MetricsDump { snapshot } = client.call(&Request::Metrics).unwrap() else {
        panic!("expected metrics")
    };
    let counter = |name: &str| {
        snapshot
            .get("counters")
            .and_then(|c| c.get(name))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
    };
    let candidates = counter("topk.candidates");
    assert!(candidates >= LIMIT as f64, "probe missed expected hits: {snapshot}");
    assert!(
        candidates < N as f64,
        "probe candidate count must be sub-linear in the store size: {snapshot}"
    );
    assert!(counter("path.topk.probe") >= 1.0, "topk did not take the probe path: {snapshot}");
    assert_eq!(counter("store.restore"), 1.0, "{snapshot}");
    let store_size = snapshot
        .get("gauges")
        .and_then(|g| g.get("store.size"))
        .and_then(|v| v.as_f64());
    assert_eq!(store_size, Some(N as f64), "{snapshot}");

    server.stop();
    std::fs::remove_file(&path).ok();
}

/// The snapshot file itself is the versioned binary format — a corrupted
/// file is refused over the wire with a clean error and the store keeps
/// its current contents.
#[test]
fn corrupt_snapshot_is_refused_over_the_wire() {
    let (query, docs) = corpus();
    let coordinator = Arc::new(Coordinator::new(cfg()).unwrap());
    let server = Server::start(coordinator, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    for (i, d) in docs.iter().take(8).enumerate() {
        client.upsert(&format!("doc{i:03}"), d.clone()).unwrap();
    }
    let path =
        std::env::temp_dir().join(format!("fastgm-store-corrupt-{}.fgms", std::process::id()));
    let path_str = path.to_string_lossy().to_string();
    client.snapshot(&path_str).unwrap();
    // Flip one byte mid-file: restore must refuse and leave the store be.
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    let err = client.restore(&path_str).unwrap_err().to_string();
    assert!(
        err.contains("checksum") || err.contains("truncated") || err.contains("snapshot"),
        "unexpected error: {err}"
    );
    let stats = client.store_stats().unwrap();
    assert_eq!(stats.get("size").and_then(|v| v.as_f64()), Some(8.0), "{stats}");
    // And the store still serves.
    let hits = client.topk(query, 1).unwrap();
    assert_eq!(hits[0].0, "doc000");
    server.stop();
    std::fs::remove_file(&path).ok();
}
