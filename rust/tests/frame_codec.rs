//! Black-box property tests for the binary frame codec
//! (`fastgm::coordinator::frame`), in the style of `store_codec.rs`:
//! every-byte corruption, every-prefix truncation (including mid
//! length-prefix), version mismatch, and the mixed-protocol contract —
//! a JSON line and a binary frame interleaved on ONE event-server
//! connection, proving old line-protocol clients coexist with framed
//! ones on the same port. The in-module unit tests cover per-message
//! round-trips; these lock the wire-level failure contract the event
//! loop's tear-down-on-corruption rule relies on.

use fastgm::coordinator::frame::{
    decode_frame, encode_request_frame, encode_response_frame, FrameMsg, FrameStatus,
    FRAME_MAGIC, FRAME_VERSION, HEADER_LEN,
};
use fastgm::coordinator::protocol::{Request, Response, SketchSource};
use fastgm::sketch::codec;
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::{Sketcher, SparseVector};
use fastgm::util::hash::fnv1a64;
use fastgm::util::rng::SplitMix64;

/// A frame per message shape class: fixed (ping), stringy, vector-heavy,
/// sketch-register and blob payloads — both the hex-in-JSON blob arm and
/// the binary blob kinds (`store_put_bin` / `stream_merge_bin` /
/// `sketch_fetch_bin` / `sketch_blob_bin`) whose bodies carry raw codec
/// bytes, including `0xFB` (the frame magic) and newlines — so the
/// byte-level properties are exercised against every field primitive the
/// codec has.
fn sample_frames() -> Vec<(u64, Vec<u8>)> {
    let v = SparseVector::new(vec![3, 1 << 60, 7], vec![0.25, 1.5, 9.0]);
    let sk = FastGm::new(16, 11).sketch(&v);
    // A real codec blob for a key containing a raw newline — the byte
    // that would tear a line protocol apart but must ride frames
    // untouched (the register bytes themselves are arbitrary binary).
    let blob = codec::encode_sketch_bytes("βlob\nkey", 9, &sk);
    let reqs: Vec<(u64, Request)> = vec![
        (1, Request::Ping),
        (u64::MAX, Request::Sketch { name: "βeta-doc".into(), vector: v.clone(), algo: None }),
        (7, Request::TopK { vector: v, limit: 5 }),
        (
            8,
            Request::StorePut { data: "fb01aa".into() }, // raw-byte blob arm
        ),
        (9, Request::SketchFetch { name: "s".into(), source: SketchSource::Stream }),
        (10, Request::StorePutBin { data: blob.clone() }),
        (11, Request::StreamMergeBin { stream: "clicks".into(), data: blob.clone() }),
        (12, Request::SketchFetchBin { name: "s".into(), source: SketchSource::Stream }),
    ];
    let mut frames = Vec::new();
    for (id, req) in &reqs {
        let mut out = Vec::new();
        encode_request_frame(*id, req, &mut out);
        frames.push((*id, out));
    }
    let resps: Vec<(u64, Response)> = vec![
        (2, Response::Pong),
        (13, Response::SketchBlobBin { name: "βlob\nkey".into(), data: blob }),
        (3, Response::Sketch { name: "doc".into(), sketch: sk }),
        (4, Response::Error { message: "no sketch named 'ghost'".into() }),
    ];
    for (id, resp) in &resps {
        let mut out = Vec::new();
        encode_response_frame(*id, resp, &mut out);
        frames.push((*id, out));
    }
    frames
}

fn refresh_checksum(mut bytes: Vec<u8>) -> Vec<u8> {
    let n = bytes.len();
    let sum = fnv1a64(&bytes[..n - 8]);
    bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
    bytes
}

/// Every sample decodes to a frame consuming exactly its own bytes, with
/// the client-assigned id intact — also when another frame is queued
/// right behind it (the event loop decodes off the front of a stream).
#[test]
fn frames_decode_exactly_and_keep_their_ids() {
    for (id, bytes) in sample_frames() {
        let status = decode_frame(&bytes).unwrap();
        let FrameStatus::Frame { consumed, id: got, .. } = status else {
            panic!("complete frame reported incomplete")
        };
        assert_eq!(consumed, bytes.len());
        assert_eq!(got, id);
        // With a second frame concatenated, the first still consumes only
        // its own bytes.
        let mut two = bytes.clone();
        two.extend_from_slice(&bytes);
        let FrameStatus::Frame { consumed, .. } = decode_frame(&two).unwrap() else {
            panic!("concatenated frame reported incomplete")
        };
        assert_eq!(consumed, bytes.len());
    }
}

/// Every strict prefix of a valid frame — including cuts INSIDE the
/// 4-byte length prefix — is `Incomplete`: a clean "need more bytes",
/// never an error, never a bogus decode, never a panic. This is what
/// lets the event loop buffer partial reads without special cases.
#[test]
fn every_truncation_asks_for_more_bytes() {
    for (_, bytes) in sample_frames() {
        for len in 0..bytes.len() {
            match decode_frame(&bytes[..len]) {
                Ok(FrameStatus::Incomplete) => {}
                Ok(FrameStatus::Frame { .. }) => {
                    panic!("prefix {len}/{} decoded as a whole frame", bytes.len())
                }
                Err(e) => panic!("prefix {len}/{} errored: {e}", bytes.len()),
            }
        }
    }
}

/// Flipping any single bit of any byte must never yield a decoded frame:
/// header flips are refused outright, length flips either fail the
/// (relocated) checksum or ask for bytes that will never come, payload
/// and trailer flips fail the checksum. `Incomplete` is acceptable —
/// the connection then stalls and is torn down — but a silent wrong
/// decode is not.
#[test]
fn every_byte_corruption_is_caught() {
    for (_, bytes) in sample_frames() {
        for at in 0..bytes.len() {
            for bit in 0..8 {
                let mut bad = bytes.clone();
                bad[at] ^= 1 << bit;
                match decode_frame(&bad) {
                    Ok(FrameStatus::Frame { .. }) => {
                        panic!("flip of bit {bit} at byte {at} went unnoticed")
                    }
                    Ok(FrameStatus::Incomplete) | Err(_) => {}
                }
            }
        }
    }
    // Random multi-byte corruption too (store_codec idiom).
    let mut r = SplitMix64::new(5);
    for (_, bytes) in sample_frames() {
        for _ in 0..50 {
            let mut bad = bytes.clone();
            for _ in 0..3 {
                let at = r.next_range(0, bad.len() - 1);
                bad[at] ^= 1 << r.next_range(0, 7);
            }
            assert!(
                !matches!(decode_frame(&bad), Ok(FrameStatus::Frame { .. })),
                "3-byte corruption went unnoticed"
            );
        }
    }
}

/// A future frame version is refused as soon as the version byte is seen
/// — even with a valid checksum — and the error names both versions, so
/// a mixed-build cluster fails loudly at the first frame, not with a
/// checksum mystery. Bad magic likewise names the byte.
#[test]
fn version_mismatch_is_a_named_clean_error() {
    let (_, bytes) = &sample_frames()[0];
    assert_eq!(bytes[0], FRAME_MAGIC, "layout assumption: magic first");
    assert_eq!(bytes[1], FRAME_VERSION, "layout assumption: version second");
    let mut future = bytes.clone();
    future[1] = FRAME_VERSION + 1;
    let err = decode_frame(&refresh_checksum(future.clone())).unwrap_err().to_string();
    assert!(
        err.contains(&format!("version {}", FRAME_VERSION + 1))
            && err.contains(&format!("v{FRAME_VERSION}")),
        "version mismatch must name both versions: {err}"
    );
    // Refused from the first two bytes — no length/checksum needed.
    assert!(decode_frame(&future[..2]).is_err());
    // Bad magic: refused from byte one. Every JSON first byte ('{',
    // whitespace) falls here, which is exactly how the event loop
    // dispatches between the two protocols.
    for first in [b'{', b' ', b'\t', 0x00, 0xFF] {
        let mut alien = bytes.clone();
        alien[0] = first;
        let err = decode_frame(&refresh_checksum(alien)).unwrap_err().to_string();
        assert!(err.contains("not a binary frame"), "{err}");
        assert!(decode_frame(&[first]).is_err(), "single byte 0x{first:02x} accepted");
    }
}

/// Oversized / undersized length prefixes are refused before any
/// allocation: a hostile 4 GiB length must not reserve memory.
#[test]
fn hostile_length_prefixes_are_refused() {
    let (_, bytes) = &sample_frames()[0];
    for len in [0u32, 1, 8, u32::MAX, (fastgm::coordinator::frame::MAX_PAYLOAD + 1) as u32] {
        let mut bad = bytes.clone();
        bad[2..HEADER_LEN].copy_from_slice(&len.to_le_bytes());
        assert!(
            decode_frame(&refresh_checksum(bad)).is_err(),
            "payload length {len} accepted"
        );
    }
}

/// The bulk-blob kinds at transfer scale: a k=1024 codec blob rides a
/// `sketch_blob_bin` frame bit-exactly, the borrowing [`FrameView`]
/// slices the SAME bytes the owned decoder parses (no copy between the
/// socket buffer and `decode_sketch_bytes`), every strict prefix is
/// `Incomplete`, sampled single-bit flips never yield a frame on either
/// decode path, and hostile length prefixes are refused before any
/// allocation — the full wire contract at the size the data plane
/// actually moves.
#[test]
fn bulk_blob_frames_hold_the_wire_properties_at_k1024() {
    use fastgm::coordinator::frame::{decode_frame_view, FrameViewStatus, MAX_PAYLOAD};

    let dims: Vec<u64> = (0..1024u64).map(|i| i * 37 + 5).collect();
    let weights: Vec<f64> = (0..1024).map(|i| 0.5 + (i % 97) as f64).collect();
    let sk = FastGm::new(1024, 7).sketch(&SparseVector::new(dims, weights));
    let blob = codec::encode_sketch_bytes("bulk", 41, &sk);
    let mut frame_bytes = Vec::new();
    encode_response_frame(
        99,
        &Response::SketchBlobBin { name: "bulk".into(), data: blob.clone() },
        &mut frame_bytes,
    );
    assert!(frame_bytes.len() > 4 * 1024, "k=1024 blob should be kilobytes of payload");

    // Owned and borrowing decodes agree; the view hands back the exact
    // blob bytes, which the codec parses straight into the sketch.
    let FrameStatus::Frame { consumed, id, msg } = decode_frame(&frame_bytes).unwrap() else {
        panic!("complete bulk frame reported incomplete")
    };
    assert_eq!((consumed, id), (frame_bytes.len(), 99));
    let FrameMsg::Response(Response::SketchBlobBin { name, data }) = msg else {
        panic!("bulk frame decoded to the wrong message")
    };
    assert_eq!((name.as_str(), data), ("bulk", blob.clone()));
    let FrameViewStatus::Frame(view) = decode_frame_view(&frame_bytes).unwrap() else {
        panic!("complete bulk frame reported incomplete by the view decoder")
    };
    assert_eq!((view.consumed, view.id), (frame_bytes.len(), 99));
    let (vname, vblob) = view.sketch_blob_bin().unwrap().expect("blob frame");
    assert_eq!((vname.as_str(), vblob), ("bulk", blob.as_slice()));
    let (key, version, decoded) = codec::decode_sketch_bytes(vblob).unwrap();
    assert_eq!((key.as_str(), version), ("bulk", 41));
    assert_eq!(decoded, sk);

    // Every strict prefix — all ~17k of them — is a clean Incomplete.
    for len in 0..frame_bytes.len() {
        assert!(
            matches!(decode_frame_view(&frame_bytes[..len]).unwrap(), FrameViewStatus::Incomplete),
            "bulk prefix {len}/{} not Incomplete",
            frame_bytes.len()
        );
    }

    // Sampled single-bit corruption across the whole frame: neither
    // decode path may ever hand back a frame.
    let mut r = SplitMix64::new(17);
    for _ in 0..400 {
        let mut bad = frame_bytes.clone();
        let at = r.next_range(0, bad.len() - 1);
        bad[at] ^= 1 << r.next_range(0, 7);
        assert!(
            !matches!(decode_frame(&bad), Ok(FrameStatus::Frame { .. })),
            "bit flip at byte {at} went unnoticed by decode_frame"
        );
        assert!(
            !matches!(decode_frame_view(&bad), Ok(FrameViewStatus::Frame(_))),
            "bit flip at byte {at} went unnoticed by decode_frame_view"
        );
    }

    // Hostile length prefixes on a bulk frame are refused up front —
    // a 4 GiB length must never reserve memory.
    for len in [0u32, 1, 8, u32::MAX, (MAX_PAYLOAD + 1) as u32] {
        let mut bad = frame_bytes.clone();
        bad[2..HEADER_LEN].copy_from_slice(&len.to_le_bytes());
        let bad = refresh_checksum(bad);
        assert!(decode_frame(&bad).is_err(), "payload length {len} accepted");
        assert!(decode_frame_view(&bad).is_err(), "payload length {len} accepted by the view");
    }
}

/// The mixed-protocol contract, end to end: ONE event-server connection
/// serves a JSON line, then a binary frame, then JSON again — each
/// answered in its own protocol — while a plain `Client` (the golden
/// line-protocol path) works unchanged on the same port.
#[cfg(unix)]
#[test]
fn json_and_frames_interleave_on_one_connection() {
    use fastgm::coordinator::client::Client;
    use fastgm::coordinator::event_server::EventServer;
    use fastgm::coordinator::protocol;
    use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
    use std::io::{BufRead, BufReader, Read, Write};
    use std::sync::Arc;

    let coord = Arc::new(
        Coordinator::new(CoordinatorConfig { k: 32, workers: 2, ..Default::default() }).unwrap(),
    );
    let server = EventServer::start(coord.clone(), "127.0.0.1:0").unwrap();

    // Raw socket: JSON, frame, JSON on the same connection.
    let stream = std::net::TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(protocol::decode_response(&line).unwrap(), Response::Pong);

    let mut fbuf = Vec::new();
    encode_request_frame(42, &Request::Hello, &mut fbuf);
    writer.write_all(&fbuf).unwrap();
    let mut acc: Vec<u8> = reader.buffer().to_vec();
    reader.consume(acc.len());
    let (id, msg) = loop {
        match decode_frame(&acc).unwrap() {
            FrameStatus::Frame { id, msg, .. } => break (id, msg),
            FrameStatus::Incomplete => {
                let mut chunk = [0u8; 4096];
                let got = reader.read(&mut chunk).unwrap();
                assert!(got > 0, "server closed mid-frame");
                acc.extend_from_slice(&chunk[..got]);
            }
        }
    };
    assert_eq!(id, 42);
    let FrameMsg::Response(Response::Hello { info }) = msg else {
        panic!("expected hello response")
    };
    assert_eq!(info.k, 32);

    writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(protocol::decode_response(&line).unwrap(), Response::Pong);
    drop((writer, reader));

    // The golden line-protocol client path, same port, untouched.
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let hello = client.hello().unwrap();
    assert_eq!(hello.k, 32);
    drop(client);

    server.stop();
    Arc::try_unwrap(coord).ok().expect("server kept a coordinator reference").shutdown();
}
