//! Black-box property tests for the binary frame codec
//! (`fastgm::coordinator::frame`), in the style of `store_codec.rs`:
//! every-byte corruption, every-prefix truncation (including mid
//! length-prefix), version mismatch, and the mixed-protocol contract —
//! a JSON line and a binary frame interleaved on ONE event-server
//! connection, proving old line-protocol clients coexist with framed
//! ones on the same port. The in-module unit tests cover per-message
//! round-trips; these lock the wire-level failure contract the event
//! loop's tear-down-on-corruption rule relies on.

use fastgm::coordinator::frame::{
    decode_frame, encode_request_frame, encode_response_frame, FrameMsg, FrameStatus,
    FRAME_MAGIC, FRAME_VERSION, HEADER_LEN,
};
use fastgm::coordinator::protocol::{Request, Response, SketchSource};
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::{Sketcher, SparseVector};
use fastgm::util::hash::fnv1a64;
use fastgm::util::rng::SplitMix64;

/// A frame per message shape class: fixed (ping), stringy, vector-heavy,
/// sketch-register and blob payloads — so the byte-level properties are
/// exercised against every field primitive the codec has.
fn sample_frames() -> Vec<(u64, Vec<u8>)> {
    let v = SparseVector::new(vec![3, 1 << 60, 7], vec![0.25, 1.5, 9.0]);
    let sk = FastGm::new(16, 11).sketch(&v);
    let reqs: Vec<(u64, Request)> = vec![
        (1, Request::Ping),
        (u64::MAX, Request::Sketch { name: "βeta-doc".into(), vector: v.clone(), algo: None }),
        (7, Request::TopK { vector: v, limit: 5 }),
        (
            8,
            Request::StorePut { data: "fb01aa".into() }, // raw-byte blob arm
        ),
        (9, Request::SketchFetch { name: "s".into(), source: SketchSource::Stream }),
    ];
    let mut frames = Vec::new();
    for (id, req) in &reqs {
        let mut out = Vec::new();
        encode_request_frame(*id, req, &mut out);
        frames.push((*id, out));
    }
    let resps: Vec<(u64, Response)> = vec![
        (2, Response::Pong),
        (3, Response::Sketch { name: "doc".into(), sketch: sk }),
        (4, Response::Error { message: "no sketch named 'ghost'".into() }),
    ];
    for (id, resp) in &resps {
        let mut out = Vec::new();
        encode_response_frame(*id, resp, &mut out);
        frames.push((*id, out));
    }
    frames
}

fn refresh_checksum(mut bytes: Vec<u8>) -> Vec<u8> {
    let n = bytes.len();
    let sum = fnv1a64(&bytes[..n - 8]);
    bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
    bytes
}

/// Every sample decodes to a frame consuming exactly its own bytes, with
/// the client-assigned id intact — also when another frame is queued
/// right behind it (the event loop decodes off the front of a stream).
#[test]
fn frames_decode_exactly_and_keep_their_ids() {
    for (id, bytes) in sample_frames() {
        let status = decode_frame(&bytes).unwrap();
        let FrameStatus::Frame { consumed, id: got, .. } = status else {
            panic!("complete frame reported incomplete")
        };
        assert_eq!(consumed, bytes.len());
        assert_eq!(got, id);
        // With a second frame concatenated, the first still consumes only
        // its own bytes.
        let mut two = bytes.clone();
        two.extend_from_slice(&bytes);
        let FrameStatus::Frame { consumed, .. } = decode_frame(&two).unwrap() else {
            panic!("concatenated frame reported incomplete")
        };
        assert_eq!(consumed, bytes.len());
    }
}

/// Every strict prefix of a valid frame — including cuts INSIDE the
/// 4-byte length prefix — is `Incomplete`: a clean "need more bytes",
/// never an error, never a bogus decode, never a panic. This is what
/// lets the event loop buffer partial reads without special cases.
#[test]
fn every_truncation_asks_for_more_bytes() {
    for (_, bytes) in sample_frames() {
        for len in 0..bytes.len() {
            match decode_frame(&bytes[..len]) {
                Ok(FrameStatus::Incomplete) => {}
                Ok(FrameStatus::Frame { .. }) => {
                    panic!("prefix {len}/{} decoded as a whole frame", bytes.len())
                }
                Err(e) => panic!("prefix {len}/{} errored: {e}", bytes.len()),
            }
        }
    }
}

/// Flipping any single bit of any byte must never yield a decoded frame:
/// header flips are refused outright, length flips either fail the
/// (relocated) checksum or ask for bytes that will never come, payload
/// and trailer flips fail the checksum. `Incomplete` is acceptable —
/// the connection then stalls and is torn down — but a silent wrong
/// decode is not.
#[test]
fn every_byte_corruption_is_caught() {
    for (_, bytes) in sample_frames() {
        for at in 0..bytes.len() {
            for bit in 0..8 {
                let mut bad = bytes.clone();
                bad[at] ^= 1 << bit;
                match decode_frame(&bad) {
                    Ok(FrameStatus::Frame { .. }) => {
                        panic!("flip of bit {bit} at byte {at} went unnoticed")
                    }
                    Ok(FrameStatus::Incomplete) | Err(_) => {}
                }
            }
        }
    }
    // Random multi-byte corruption too (store_codec idiom).
    let mut r = SplitMix64::new(5);
    for (_, bytes) in sample_frames() {
        for _ in 0..50 {
            let mut bad = bytes.clone();
            for _ in 0..3 {
                let at = r.next_range(0, bad.len() - 1);
                bad[at] ^= 1 << r.next_range(0, 7);
            }
            assert!(
                !matches!(decode_frame(&bad), Ok(FrameStatus::Frame { .. })),
                "3-byte corruption went unnoticed"
            );
        }
    }
}

/// A future frame version is refused as soon as the version byte is seen
/// — even with a valid checksum — and the error names both versions, so
/// a mixed-build cluster fails loudly at the first frame, not with a
/// checksum mystery. Bad magic likewise names the byte.
#[test]
fn version_mismatch_is_a_named_clean_error() {
    let (_, bytes) = &sample_frames()[0];
    assert_eq!(bytes[0], FRAME_MAGIC, "layout assumption: magic first");
    assert_eq!(bytes[1], FRAME_VERSION, "layout assumption: version second");
    let mut future = bytes.clone();
    future[1] = FRAME_VERSION + 1;
    let err = decode_frame(&refresh_checksum(future.clone())).unwrap_err().to_string();
    assert!(
        err.contains(&format!("version {}", FRAME_VERSION + 1))
            && err.contains(&format!("v{FRAME_VERSION}")),
        "version mismatch must name both versions: {err}"
    );
    // Refused from the first two bytes — no length/checksum needed.
    assert!(decode_frame(&future[..2]).is_err());
    // Bad magic: refused from byte one. Every JSON first byte ('{',
    // whitespace) falls here, which is exactly how the event loop
    // dispatches between the two protocols.
    for first in [b'{', b' ', b'\t', 0x00, 0xFF] {
        let mut alien = bytes.clone();
        alien[0] = first;
        let err = decode_frame(&refresh_checksum(alien)).unwrap_err().to_string();
        assert!(err.contains("not a binary frame"), "{err}");
        assert!(decode_frame(&[first]).is_err(), "single byte 0x{first:02x} accepted");
    }
}

/// Oversized / undersized length prefixes are refused before any
/// allocation: a hostile 4 GiB length must not reserve memory.
#[test]
fn hostile_length_prefixes_are_refused() {
    let (_, bytes) = &sample_frames()[0];
    for len in [0u32, 1, 8, u32::MAX, (fastgm::coordinator::frame::MAX_PAYLOAD + 1) as u32] {
        let mut bad = bytes.clone();
        bad[2..HEADER_LEN].copy_from_slice(&len.to_le_bytes());
        assert!(
            decode_frame(&refresh_checksum(bad)).is_err(),
            "payload length {len} accepted"
        );
    }
}

/// The mixed-protocol contract, end to end: ONE event-server connection
/// serves a JSON line, then a binary frame, then JSON again — each
/// answered in its own protocol — while a plain `Client` (the golden
/// line-protocol path) works unchanged on the same port.
#[cfg(unix)]
#[test]
fn json_and_frames_interleave_on_one_connection() {
    use fastgm::coordinator::client::Client;
    use fastgm::coordinator::event_server::EventServer;
    use fastgm::coordinator::protocol;
    use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
    use std::io::{BufRead, BufReader, Read, Write};
    use std::sync::Arc;

    let coord = Arc::new(
        Coordinator::new(CoordinatorConfig { k: 32, workers: 2, ..Default::default() }).unwrap(),
    );
    let server = EventServer::start(coord.clone(), "127.0.0.1:0").unwrap();

    // Raw socket: JSON, frame, JSON on the same connection.
    let stream = std::net::TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(protocol::decode_response(&line).unwrap(), Response::Pong);

    let mut fbuf = Vec::new();
    encode_request_frame(42, &Request::Hello, &mut fbuf);
    writer.write_all(&fbuf).unwrap();
    let mut acc: Vec<u8> = reader.buffer().to_vec();
    reader.consume(acc.len());
    let (id, msg) = loop {
        match decode_frame(&acc).unwrap() {
            FrameStatus::Frame { id, msg, .. } => break (id, msg),
            FrameStatus::Incomplete => {
                let mut chunk = [0u8; 4096];
                let got = reader.read(&mut chunk).unwrap();
                assert!(got > 0, "server closed mid-frame");
                acc.extend_from_slice(&chunk[..got]);
            }
        }
    };
    assert_eq!(id, 42);
    let FrameMsg::Response(Response::Hello { info }) = msg else {
        panic!("expected hello response")
    };
    assert_eq!(info.k, 32);

    writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(protocol::decode_response(&line).unwrap(), Response::Pong);
    drop((writer, reader));

    // The golden line-protocol client path, same port, untouched.
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let hello = client.hello().unwrap();
    assert_eq!(hello.k, 32);
    drop(client);

    server.stop();
    Arc::try_unwrap(coord).ok().expect("server kept a coordinator reference").shutdown();
}
