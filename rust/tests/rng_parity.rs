//! Cross-language RNG parity: `rust/tests/fixtures/rng_parity.json` is
//! generated (and independently asserted) by the pure-Python reference in
//! `python/tests/rng_reference.py` / `test_rng_parity.py`. This test pins
//! `util::rng` and `sketch::order_stats` to the same outputs, so the two
//! language layers can never silently diverge — the same lock
//! `test_rng.py` provides for the Direct-family kernel constants.
//!
//! Integer outputs (hashes, counter RNG, SplitMix64 streams, register
//! assignments, and `next_f64`, which is pure dyadic arithmetic) must match
//! **exactly**. Exponential arrival times go through `ln` and are compared
//! to 1e-12 relative — libm rounding is the only divergence allowed.

use fastgm::sketch::kernels::{self, Backend};
use fastgm::sketch::order_stats::ElementRace;
use fastgm::util::json::{parse, Value};
use fastgm::util::rng::{direct_bits, fmix32, fmix64, SplitMix64};

const FIXTURE: &str = include_str!("fixtures/rng_parity.json");

fn fixture() -> Value {
    parse(FIXTURE).expect("rng_parity.json parses")
}

/// Fixture u64s are decimal strings (JSON numbers are f64 and would
/// truncate above 2^53).
fn u(v: &Value) -> u64 {
    v.as_str().expect("string-encoded integer").parse().expect("valid u64")
}

fn f(v: &Value) -> f64 {
    v.as_str().expect("string-encoded float").parse().expect("valid f64")
}

fn arr<'a>(v: &'a Value, key: &str) -> &'a [Value] {
    v.req(key).unwrap().as_arr().unwrap()
}

#[test]
fn fmix_finalizers_match_reference() {
    let fx = fixture();
    let cases32 = arr(&fx, "fmix32");
    assert!(cases32.len() >= 5);
    for case in cases32 {
        let (input, want) = (u(case.idx(0).unwrap()) as u32, u(case.idx(1).unwrap()) as u32);
        assert_eq!(fmix32(input), want, "fmix32({input})");
    }
    for case in arr(&fx, "fmix64") {
        let (input, want) = (u(case.idx(0).unwrap()), u(case.idx(1).unwrap()));
        assert_eq!(fmix64(input), want, "fmix64({input})");
    }
}

#[test]
fn direct_bits_matches_reference() {
    let fx = fixture();
    for case in arr(&fx, "direct_bits") {
        let seed = u(case.idx(0).unwrap()) as u32;
        let i = u(case.idx(1).unwrap()) as u32;
        let j = u(case.idx(2).unwrap()) as u32;
        let want = u(case.idx(3).unwrap()) as u32;
        assert_eq!(direct_bits(seed, i, j), want, "direct_bits({seed},{i},{j})");
    }
}

#[test]
fn splitmix_streams_match_reference_exactly() {
    let fx = fixture();
    let cases = arr(&fx, "splitmix64");
    assert!(cases.len() >= 3);
    for case in cases {
        let seed = u(case.req("seed").unwrap());
        let mut r = SplitMix64::new(seed);
        for (i, want) in arr(case, "u64").iter().enumerate() {
            assert_eq!(r.next_u64(), u(want), "seed {seed}, u64 #{i}");
        }
        // next_f64 is dyadic arithmetic on the u64 stream: bit-exact.
        let mut r = SplitMix64::new(seed);
        for (i, want) in arr(case, "f64").iter().enumerate() {
            let got = r.next_f64();
            assert_eq!(got.to_bits(), f(want).to_bits(), "seed {seed}, f64 #{i}: {got}");
        }
    }
}

/// The batched kernel layer (`sketch::kernels`) against the Python
/// reference: `fill_u64_block` / `fill_uniform_block` must reproduce the
/// scalar SplitMix64 stream bit-exactly on BOTH backends (the blocks are
/// pure integer + dyadic arithmetic), `fill_exp_block` to 1e-12 relative
/// cross-language and bit-exactly scalar-vs-SIMD (`ln` is scalar libm in
/// both backends by design). Afterwards the RNG must sit at the same
/// stream position as if the draws had been made one at a time.
#[test]
fn batched_blocks_match_reference_on_both_backends() {
    let fx = fixture();
    let cases = arr(&fx, "batched_blocks");
    assert!(cases.len() >= 3);
    for case in cases {
        let seed = u(case.req("seed").unwrap());
        let uniforms = arr(case, "uniform");
        let exps = arr(case, "exp");
        let n = uniforms.len();
        for backend in [Backend::Scalar, Backend::Simd] {
            // u64 block == the splitmix64 stream drawn one at a time.
            let mut r = SplitMix64::new(seed);
            let mut block = vec![0u64; n];
            kernels::fill_u64_block_with(backend, &mut r, &mut block);
            let mut one = SplitMix64::new(seed);
            for (i, got) in block.iter().enumerate() {
                assert_eq!(*got, one.next_u64(), "seed {seed} {backend:?} u64 #{i}");
            }
            // Stream continuation: block fill left the state where the
            // one-at-a-time draws did.
            assert_eq!(r.next_u64(), one.next_u64(), "seed {seed} {backend:?} continuation");

            let mut r = SplitMix64::new(seed);
            let mut uni = vec![0.0f64; n];
            kernels::fill_uniform_block_with(backend, &mut r, &mut uni);
            for (i, (got, want)) in uni.iter().zip(uniforms).enumerate() {
                assert_eq!(
                    got.to_bits(),
                    f(want).to_bits(),
                    "seed {seed} {backend:?} uniform #{i}: {got}"
                );
            }
        }
        // Exponentials: scalar-vs-SIMD bitwise, cross-language 1e-12.
        let mut rs = SplitMix64::new(seed);
        let mut scalar = vec![0.0f64; n];
        kernels::fill_exp_block_with(Backend::Scalar, &mut rs, &mut scalar);
        let mut rv = SplitMix64::new(seed);
        let mut simd = vec![0.0f64; n];
        kernels::fill_exp_block_with(Backend::Simd, &mut rv, &mut simd);
        for (i, ((s, v), want)) in scalar.iter().zip(&simd).zip(exps).enumerate() {
            assert_eq!(s.to_bits(), v.to_bits(), "seed {seed} exp #{i} backend divergence");
            let want = f(want);
            let rel = (s - want).abs() / want.abs().max(f64::MIN_POSITIVE);
            assert!(rel < 1e-12, "seed {seed} exp #{i}: {s} vs {want} (rel {rel:.3e})");
        }
    }
}

#[test]
fn element_stream_keying_matches_reference() {
    let fx = fixture();
    for case in arr(&fx, "for_element") {
        let seed = u(case.req("seed").unwrap());
        let element = u(case.req("element").unwrap());
        let want = u(case.req("first_u64").unwrap());
        assert_eq!(
            SplitMix64::for_element(seed, element).next_u64(),
            want,
            "for_element({seed}, {element})"
        );
    }
}

#[test]
fn element_race_matches_reference() {
    let fx = fixture();
    let cases = arr(&fx, "element_race");
    assert!(cases.len() >= 3);
    for case in cases {
        let seed = u(case.req("seed").unwrap());
        let element = u(case.req("element").unwrap());
        let w = f(case.req("w").unwrap());
        let k = case.req("k").unwrap().as_usize().unwrap();
        let pairs = ElementRace::new(seed, element, w, k).drain();
        let registers = arr(case, "registers");
        let arrivals = arr(case, "arrivals");
        assert_eq!(pairs.len(), k);
        assert_eq!(registers.len(), k);
        for (z, ((b, c), (want_reg, want_b))) in pairs
            .iter()
            .zip(registers.iter().zip(arrivals))
            .enumerate()
        {
            // Register choice: integers all the way down — exact.
            assert_eq!(
                *c as usize,
                want_reg.as_usize().unwrap(),
                "race({seed},{element},{w},{k}) register #{z}"
            );
            // Arrival time: one ln per step, so allow libm ulp noise only.
            let want_b = f(want_b);
            let rel = (b - want_b).abs() / want_b.abs().max(f64::MIN_POSITIVE);
            assert!(
                rel < 1e-12,
                "race({seed},{element},{w},{k}) arrival #{z}: {b} vs {want_b} (rel {rel:.3e})"
            );
        }
    }
}
