//! Engine-layer conformance: scratch reuse is lossless for EVERY registered
//! algorithm, the registry round-trips names, and unknown `algo` values are
//! clean error paths at the protocol/service boundary.
//!
//! The core suite iterates [`AlgorithmId::ALL`], so registering a new
//! algorithm automatically subjects it to the bit-identical-reuse property —
//! no test edit required (and an algorithm that misses the registry shows up
//! as a name-coverage failure below).

use fastgm::coordinator::protocol::{decode_request, Request, Response};
use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
use fastgm::sketch::engine::{self, AlgorithmId, EngineParams, SketchScratch};
use fastgm::sketch::{GumbelMaxSketch, Sketcher, SparseVector};
use fastgm::util::rng::SplitMix64;

fn random_vector(r: &mut SplitMix64, max_n: usize) -> SparseVector {
    let n = r.next_range(1, max_n);
    let mut v = SparseVector::default();
    for _ in 0..n {
        // Mix in non-positive weights: every sketcher must skip them.
        let w = if r.next_f64() < 0.1 {
            -r.next_f64()
        } else {
            r.next_exp() * 10f64.powi(r.next_range(0, 3) as i32 - 1)
        };
        v.push(r.next_u64(), w);
    }
    v
}

/// THE engine property: `sketch_into` with a dirty, shared, reused scratch
/// is bit-identical to a fresh `sketch()` for every registered algorithm.
/// One scratch is shared across all algorithms, k values, seeds and rounds —
/// the worst-case cross-contamination a coordinator worker can see.
#[test]
fn scratch_reuse_is_bit_identical_for_every_algorithm() {
    let mut r = SplitMix64::new(0xE2612E);
    let mut scratch = SketchScratch::new();
    let mut out = GumbelMaxSketch::empty(fastgm::sketch::Family::Ordered, 0, 1);
    for round in 0..12 {
        let k = [1usize, 2, 8, 33, 64][r.next_range(0, 4)];
        let seed = r.next_u64();
        let shards = r.next_range(1, 6);
        let v = random_vector(&mut r, 60);
        for id in AlgorithmId::ALL {
            let s = engine::build(id, EngineParams::new(k, seed).with_shards(shards));
            let fresh = s.sketch(&v);
            assert_eq!(fresh.family, id.family());
            assert_eq!(fresh.seed, seed);
            assert_eq!(fresh.k(), k);
            s.sketch_into(&v, &mut scratch, &mut out);
            assert_eq!(
                out,
                fresh,
                "algo '{}' diverged under scratch reuse (round {round}, k={k})",
                s.name()
            );
        }
    }
    // The scratch really was used, not silently replaced by per-call
    // allocations: the race pool (top level or inside shard sub-scratches)
    // must have accumulated state from the FastGM-family rounds above.
    assert!(
        scratch.pooled_races() > 0,
        "sketch_into never touched the shared scratch's race pool"
    );
}

/// Same property under repeated reuse of ONE algorithm (the steady-state
/// serving pattern), including empty and all-nonpositive vectors.
#[test]
fn steady_state_reuse_matches_fresh_for_edge_vectors() {
    for id in AlgorithmId::ALL {
        let s = engine::build(id, EngineParams::new(16, 7).with_shards(3));
        let mut scratch = SketchScratch::new();
        let mut out = GumbelMaxSketch::empty(s.family(), s.seed(), s.k());
        let vectors = [
            SparseVector::new((0..50).collect(), (0..50).map(|i| 0.1 + i as f64).collect()),
            SparseVector::default(),
            SparseVector::new(vec![1, 2], vec![0.0, -3.0]),
            SparseVector::new(vec![9], vec![2.5]),
            SparseVector::new((0..200).collect(), vec![0.5; 200]),
        ];
        for v in &vectors {
            s.sketch_into(v, &mut scratch, &mut out);
            assert_eq!(out, s.sketch(v), "algo '{}' diverged on edge vector", s.name());
        }
    }
}

#[test]
fn registry_covers_every_algorithm_name() {
    for id in AlgorithmId::ALL {
        assert_eq!(AlgorithmId::from_name(id.name()).unwrap(), id);
        let built = engine::build_named(id.name(), EngineParams::new(4, 1)).unwrap();
        assert_eq!(built.name(), id.name());
        assert_eq!(built.family(), id.family());
    }
    assert!(engine::build_named("not-an-algo", EngineParams::new(4, 1)).is_err());
}

/// Unknown `algo` at the protocol layer: the wire accepts the string (no
/// schema validation on decode), the service resolves it through the
/// registry and answers with an error response naming the bad algorithm.
#[test]
fn unknown_algo_is_a_protocol_error_response() {
    let line = r#"{"op":"sketch","name":"d","vector":{"ids":[1,2],"weights":[1,0.5]},"algo":"quantum"}"#;
    let req = decode_request(line).expect("decode must not validate algo names");
    let c = Coordinator::new(CoordinatorConfig {
        k: 16,
        workers: 1,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let resp = c.call(req);
    let Response::Error { message } = resp else {
        panic!("unknown algo must yield an error response, got {resp:?}")
    };
    assert!(message.contains("unknown sketch algorithm 'quantum'"), "{message}");
    // Known names on the same wire shape succeed.
    let ok = decode_request(
        r#"{"op":"sketch","name":"d","vector":{"ids":[1,2],"weights":[1,0.5]},"algo":"icws"}"#,
    )
    .unwrap();
    assert!(matches!(c.call(ok), Response::Sketch { .. }));
    c.shutdown();
}

/// The per-request `algo` field makes non-race families storable, so the
/// estimators those sketches cannot serve must fail loudly (not return
/// silently biased numbers), and the LSH index must reject sketches its
/// default-algo query path could never match.
#[test]
fn estimators_and_lsh_fail_loudly_for_incompatible_families() {
    let c = Coordinator::new(CoordinatorConfig {
        k: 16,
        workers: 1,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let v = SparseVector::new(vec![1, 2, 3], vec![1.0, 0.5, 2.0]);
    for name in ["icws", "bagminhash"] {
        for reg in ["a", "b"] {
            c.call(Request::Sketch {
                name: format!("{name}-{reg}"),
                vector: v.clone(),
                algo: Some(name.to_string()),
            });
        }
        let wj =
            c.call(Request::WeightedJaccard { a: format!("{name}-a"), b: format!("{name}-b") });
        let Response::Error { message } = wj else { panic!("J_W on {name} must error: {wj:?}") };
        assert!(message.contains("cardinality"), "{message}");
        let jp = c.call(Request::Jaccard { a: format!("{name}-a"), b: format!("{name}-b") });
        assert!(matches!(jp, Response::Error { .. }), "J_P on {name} must error: {jp:?}");
        // Default-algo LshQuery could never match these — reject at insert.
        let ins = c.call(Request::LshInsert { name: format!("{name}-a") });
        assert!(matches!(ins, Response::Error { .. }), "LshInsert of {name} must error: {ins:?}");
    }
    c.shutdown();

    // A coordinator whose DEFAULT algo is a non-race family cannot serve
    // LSH at all (the query scorer is J_P): both ends refuse up front with
    // one clear message instead of erroring candidate-by-candidate.
    let mh = Coordinator::new(CoordinatorConfig {
        k: 16,
        workers: 1,
        algo: "minhash".into(),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    mh.call(Request::Sketch { name: "m".into(), vector: v.clone(), algo: None });
    let ins = mh.call(Request::LshInsert { name: "m".into() });
    let Response::Error { message } = ins else { panic!("minhash LshInsert must error: {ins:?}") };
    assert!(message.contains("requires an EXP-register default algo"), "{message}");
    let q = mh.call(Request::LshQuery { vector: v, limit: 1 });
    assert!(matches!(q, Response::Error { .. }), "minhash LshQuery must error: {q:?}");
    mh.shutdown();
}

/// Requests may pick any registry algorithm per call; the stored sketch
/// matches a direct registry build at the coordinator's (k, seed).
#[test]
fn every_algorithm_is_reachable_through_the_coordinator() {
    let c = Coordinator::new(CoordinatorConfig {
        k: 32,
        workers: 2,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let v = SparseVector::new(vec![3, 5, 8, 13], vec![1.0, 0.25, 2.0, 0.5]);
    for id in AlgorithmId::ALL {
        let Response::Sketch { sketch, .. } = c.call(Request::Sketch {
            name: id.name().to_string(),
            vector: v.clone(),
            algo: Some(id.name().to_string()),
        }) else {
            panic!("algo '{}' unreachable through the coordinator", id.name())
        };
        let want = engine::build(id, EngineParams::new(32, 42).with_shards(4)).sketch(&v);
        assert_eq!(sketch, want, "coordinator result diverged for '{}'", id.name());
    }
    c.shutdown();
}
