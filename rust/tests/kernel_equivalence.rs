//! SIMD/scalar equivalence harness — the enforcement half of the kernel
//! layer's "bit-identical by construction" contract.
//!
//! The flagship test forces each backend in turn (`kernels::set_forced`)
//! and proves every registered algorithm family produces bit-identical
//! registers across adversarial shapes: k not a multiple of the SIMD lane
//! width, n⁺ straddling the lane count, denormal-adjacent weights, and
//! dirty scratch reuse interleaved across algorithms. All `set_forced`
//! usage lives in that ONE test — the knob is process-global, and although
//! a concurrent flip cannot change any result (that is the very property
//! under test), it could silently make a comparison vacuous (both sides on
//! the same backend). Every other test uses the explicit `_with(backend)`
//! kernel APIs, which are race-free.
//!
//! Also home of the batched-estimator property (satellite of the same PR):
//! `estimate_jp_batch` must equal the historical per-pair loop in
//! estimates, ordering, and error semantics — including the family
//! rejection paths introduced in PR 2.

use fastgm::estimate::jaccard::{estimate_jp, estimate_jp_batch};
use fastgm::sketch::engine::{self, AlgorithmId, EngineParams, SketchScratch};
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::kernels::{self, Backend};
use fastgm::sketch::pminhash::PMinHash;
use fastgm::sketch::{Family, GumbelMaxSketch, MergeError, Sketcher, SparseVector};
use fastgm::util::rng::SplitMix64;

/// Positive weights drawn from a pool deliberately stacked with
/// denormal-adjacent magnitudes: tiny weights stress the `1/w` scaling in
/// the Direct-family fused update, huge ones stress the normalization in
/// FastSearch. Non-positive entries are mixed in — every sketcher must
/// skip them identically on both backends.
fn adversarial_vector(r: &mut SplitMix64, nplus: usize) -> SparseVector {
    let mut v = SparseVector::default();
    for _ in 0..nplus {
        let w = match r.next_range(0, 7) {
            0 => 1e-308,
            1 => f64::MIN_POSITIVE,
            2 => 1e300,
            3 => r.next_exp() * 1e-9,
            _ => r.next_exp(),
        };
        v.push(r.next_u64(), w);
        if r.next_f64() < 0.2 {
            v.push(r.next_u64(), -r.next_f64());
        }
    }
    if r.next_f64() < 0.3 {
        v.push(r.next_u64(), 0.0);
    }
    v
}

/// Bit-level sketch comparison: `s` registers are integers (exact), `y`
/// registers are compared via `to_bits` so `-0.0 != 0.0` and any payload
/// drift would be caught (plain `==` on f64 is too forgiving).
fn assert_bit_identical(a: &GumbelMaxSketch, b: &GumbelMaxSketch, ctx: &str) {
    assert_eq!(a.family, b.family, "{ctx}: family");
    assert_eq!(a.seed, b.seed, "{ctx}: seed");
    assert_eq!(a.s, b.s, "{ctx}: argmin ids diverged");
    assert_eq!(a.y.len(), b.y.len(), "{ctx}: k");
    for (j, (x, y)) in a.y.iter().zip(&b.y).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: y[{j}] = {x} vs {y}");
    }
}

/// THE equivalence property: for every `AlgorithmId`, forcing the scalar
/// backend and forcing the SIMD backend produce bit-identical sketches.
/// One dirty scratch per backend is shared across every (algorithm, shape)
/// combination, so scratch-reuse contamination is part of the adversary.
/// On hosts without AVX2 the forced-SIMD side falls back to scalar and the
/// comparison degenerates to a (still valid) self-check.
#[test]
fn every_algorithm_is_bit_identical_across_backends() {
    let mut r = SplitMix64::new(0x51D_E9);
    let mut scratch_scalar = SketchScratch::new();
    let mut scratch_simd = SketchScratch::new();
    let mut out_scalar = GumbelMaxSketch::empty(Family::Ordered, 0, 1);
    let mut out_simd = GumbelMaxSketch::empty(Family::Ordered, 0, 1);
    // k straddles the f64 lane width (4) and the f32 row width (8);
    // n⁺ straddles the lane count including 0 and 1.
    let ks = [1usize, 2, 7, 8, 9, 33, 64, 65];
    let nplus = [0usize, 1, 3, 4, 5, 37];
    for &k in &ks {
        for &n in &nplus {
            let seed = r.next_u64();
            let v = adversarial_vector(&mut r, n);
            for id in AlgorithmId::ALL {
                let s = engine::build(id, EngineParams::new(k, seed).with_shards(3));
                kernels::set_forced(Some(Backend::Scalar));
                s.sketch_into(&v, &mut scratch_scalar, &mut out_scalar);
                kernels::set_forced(Some(Backend::Simd));
                s.sketch_into(&v, &mut scratch_simd, &mut out_simd);
                kernels::set_forced(None);
                assert_bit_identical(
                    &out_scalar,
                    &out_simd,
                    &format!("algo '{}' k={k} n⁺={n}", s.name()),
                );
            }
        }
    }
}

/// The public kernel wrappers themselves, via the race-free `_with` APIs,
/// on lengths that exercise every tail-handling branch (0, sub-lane, exact
/// multiples, one-past).
#[test]
fn public_kernels_agree_on_awkward_lengths() {
    let mut r = SplitMix64::new(99);
    for len in [0usize, 1, 3, 4, 5, 7, 8, 9, 31, 33] {
        // Block fills must agree bitwise AND leave the RNG stream at the
        // same point (checked by drawing one more value).
        let mut ra = SplitMix64::new(len as u64);
        let mut rb = SplitMix64::new(len as u64);
        let mut ua = vec![0u64; len];
        let mut ub = vec![0u64; len];
        kernels::fill_u64_block_with(Backend::Scalar, &mut ra, &mut ua);
        kernels::fill_u64_block_with(Backend::Simd, &mut rb, &mut ub);
        assert_eq!(ua, ub, "u64 block len={len}");
        assert_eq!(ra.next_u64(), rb.next_u64(), "stream continuation len={len}");
        let mut fa = vec![0.0f64; len];
        let mut fb = vec![0.0f64; len];
        kernels::fill_exp_block_with(Backend::Scalar, &mut ra, &mut fa);
        kernels::fill_exp_block_with(Backend::Simd, &mut rb, &mut fb);
        for (x, y) in fa.iter().zip(&fb) {
            assert_eq!(x.to_bits(), y.to_bits(), "exp block len={len}");
        }
        // Scans and pairwise register kernels.
        let ys: Vec<f64> = (0..len).map(|_| r.next_exp()).collect();
        assert_eq!(
            kernels::argmin_f64_with(Backend::Scalar, &ys),
            kernels::argmin_f64_with(Backend::Simd, &ys),
            "argmin len={len}"
        );
        assert_eq!(
            kernels::argmax_f64_with(Backend::Scalar, &ys),
            kernels::argmax_f64_with(Backend::Simd, &ys),
            "argmax len={len}"
        );
        let oy: Vec<f64> = (0..len).map(|_| r.next_exp()).collect();
        let os: Vec<u64> = (0..len).map(|_| r.next_u64()).collect();
        let (mut ya, mut sa) = (ys.clone(), os.clone());
        let (mut yb, mut sb) = (ys.clone(), os.clone());
        kernels::merge_min_into_with(Backend::Scalar, &mut ya, &mut sa, &oy, &os);
        kernels::merge_min_into_with(Backend::Simd, &mut yb, &mut sb, &oy, &os);
        assert_eq!(sa, sb, "merge ids len={len}");
        for (x, y) in ya.iter().zip(&yb) {
            assert_eq!(x.to_bits(), y.to_bits(), "merge y len={len}");
        }
        let a: Vec<u64> = (0..len).map(|_| r.next_range(0, 4) as u64).collect();
        let b: Vec<u64> = (0..len).map(|_| r.next_range(0, 4) as u64).collect();
        assert_eq!(
            kernels::match_count_with(Backend::Scalar, &a, &b),
            kernels::match_count_with(Backend::Simd, &a, &b),
            "match len={len}"
        );
        assert_eq!(
            kernels::count_empty_with(Backend::Scalar, &a),
            kernels::count_empty_with(Backend::Simd, &a),
            "count_empty len={len}"
        );
    }
}

fn random_vector(r: &mut SplitMix64, max_n: usize) -> SparseVector {
    let n = r.next_range(1, max_n);
    let mut v = SparseVector::default();
    for _ in 0..n {
        v.push(r.next_range(0, 40) as u64, r.next_exp());
    }
    v
}

/// `estimate_jp_batch` == the per-pair loop it replaced: same estimates
/// (exact f64 equality), same candidate ordering (input order preserved —
/// what keeps downstream (score desc, key asc) ranking stable across the
/// refactor), across both EXP-register families.
#[test]
fn batched_estimate_matches_per_pair_exactly() {
    let mut r = SplitMix64::new(0xBA7C4);
    for round in 0..12 {
        let k = [8usize, 16, 33, 64][r.next_range(0, 3)];
        let seed = r.next_u64();
        let q = random_vector(&mut r, 30);
        let cands: Vec<SparseVector> = (0..6).map(|_| random_vector(&mut r, 30)).collect();
        for family in ["ordered", "direct"] {
            let sk = |v: &SparseVector| -> GumbelMaxSketch {
                match family {
                    "ordered" => FastGm::new(k, seed).sketch(v),
                    _ => PMinHash::new(k, seed).sketch(v),
                }
            };
            let query = sk(&q);
            let sketches: Vec<(String, GumbelMaxSketch)> =
                cands.iter().enumerate().map(|(i, v)| (format!("c{i}"), sk(v))).collect();
            let batch = estimate_jp_batch(
                &query,
                sketches.iter().map(|(name, s)| (name.clone(), s)),
            )
            .unwrap();
            assert_eq!(batch.len(), sketches.len());
            for ((bname, bscore), (name, s)) in batch.iter().zip(&sketches) {
                assert_eq!(bname, name, "round {round}: batch reordered candidates");
                let want = estimate_jp(&query, s).unwrap();
                assert_eq!(*bscore, want, "round {round} {family} {name}");
            }
        }
    }
}

/// Error semantics: the first failing candidate aborts the batch with
/// exactly the error the per-pair loop would have hit — mismatched seeds
/// mid-list, and the PR 2 family-rejection paths (ICWS/BagMinHash/MinHash
/// must refuse J_P loudly, batched or not).
#[test]
fn batched_estimate_preserves_error_semantics() {
    let v = SparseVector::new(vec![1, 2, 3], vec![1.0, 0.5, 2.0]);
    let query = FastGm::new(16, 1).sketch(&v);
    let good = FastGm::new(16, 1).sketch(&v);
    let bad_seed = FastGm::new(16, 2).sketch(&v);
    let cands = [("a", &good), ("b", &bad_seed), ("c", &good)];
    let err = estimate_jp_batch(&query, cands.iter().copied()).unwrap_err();
    assert_eq!(err, estimate_jp(&query, &bad_seed).unwrap_err());
    assert!(matches!(err, MergeError::SeedMismatch(1, 2)), "{err}");

    // Family gates: a non-race query fails against its own family exactly
    // as estimate_jp does, and a race query fails against a non-race
    // candidate with the per-pair error.
    for id in [AlgorithmId::Icws, AlgorithmId::BagMinHash, AlgorithmId::MinHash] {
        let nk = engine::build(id, EngineParams::new(16, 1)).sketch(&v);
        let batch_err = estimate_jp_batch(&nk, [("x", &nk)]).unwrap_err();
        assert_eq!(batch_err, estimate_jp(&nk, &nk).unwrap_err(), "{id:?}");
        assert!(
            matches!(batch_err, MergeError::EstimatorUnsupported { .. }),
            "{id:?}: {batch_err}"
        );
        let cross = estimate_jp_batch(&query, [("x", &nk)]).unwrap_err();
        assert_eq!(cross, estimate_jp(&query, &nk).unwrap_err(), "{id:?}");
    }

    // An empty candidate list is a successful empty batch, not an error.
    let empty: Vec<(&str, &GumbelMaxSketch)> = Vec::new();
    assert!(estimate_jp_batch(&query, empty).unwrap().is_empty());
}
