//! Black-box round-trip property tests for the snapshot codec
//! (`fastgm::sketch::codec`) across **every** registered algorithm family,
//! plus clean-error coverage for corrupt, truncated and version-mismatched
//! inputs, and v1 (pre-per-key-version) decode compatibility. The
//! in-module unit tests cover byte-level details; these lock the public
//! contract the coordinator's snapshot/restore and the cluster's
//! repair/gather paths rely on.

use fastgm::sketch::codec::{decode_store, encode_store, MAGIC, MIN_VERSION, VERSION};
use fastgm::sketch::engine::{build, AlgorithmId, EngineParams};
use fastgm::sketch::{Family, GumbelMaxSketch, Sketcher, SparseVector, EMPTY_REGISTER};
use fastgm::util::hash::fnv1a64;
use fastgm::util::rng::SplitMix64;

fn random_vec(r: &mut SplitMix64, n: usize) -> SparseVector {
    SparseVector::new(
        (0..n).map(|_| r.next_u64()).collect(),
        (0..n).map(|_| r.next_f64() + 0.05).collect(),
    )
}

/// One sketch per registered algorithm — iterating the registry keeps a
/// newly added algorithm covered automatically. Entry versions span the
/// interesting range (0 = pre-versioning, huge = >2^53 exactness).
fn entries_across_all_families() -> Vec<(String, u64, GumbelMaxSketch)> {
    let mut r = SplitMix64::new(11);
    let mut entries: Vec<(String, u64, GumbelMaxSketch)> = AlgorithmId::ALL
        .into_iter()
        .enumerate()
        .map(|(i, id)| {
            let sk = build(id, EngineParams::new(32, 7)).sketch(&random_vec(&mut r, 20));
            let version = match i {
                0 => 0,
                1 => u64::MAX - 9,
                i => i as u64,
            };
            (format!("doc-{}", id.name()), version, sk)
        })
        .collect();
    // Plus a mostly-empty sketch: +inf / EMPTY_REGISTER sentinels and a
    // >2^53 id must survive bit-for-bit.
    let mut sparse = GumbelMaxSketch::empty(Family::Ordered, 7, 32);
    sparse.y[3] = 0.5;
    sparse.s[3] = u64::MAX - 7;
    entries.push(("nearly-empty".into(), 1, sparse));
    entries
}

fn refresh_checksum(mut bytes: Vec<u8>) -> Vec<u8> {
    let n = bytes.len();
    let sum = fnv1a64(&bytes[..n - 8]);
    bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
    bytes
}

#[test]
fn every_algorithm_family_roundtrips_bit_identically() {
    let entries = entries_across_all_families();
    let bytes = encode_store(&entries);
    let back = decode_store(&bytes).unwrap();
    assert_eq!(back.len(), entries.len());
    for ((ka, va, a), (kb, vb, b)) in entries.iter().zip(&back) {
        assert_eq!(ka, kb);
        assert_eq!(va, vb, "{ka}: entry version drifted");
        assert_eq!(a.family, b.family, "{ka}");
        assert_eq!(a.seed, b.seed, "{ka}");
        assert_eq!(a.s, b.s, "{ka}");
        // Bit-level equality, stricter than f64 PartialEq.
        for (x, y) in a.y.iter().zip(&b.y) {
            assert_eq!(x.to_bits(), y.to_bits(), "{ka}: y register drifted");
        }
    }
    // Sentinels survived.
    let (_, _, sparse) = back.last().unwrap();
    assert!(sparse.y[0].is_infinite());
    assert_eq!(sparse.s[0], EMPTY_REGISTER);
    assert_eq!(sparse.s[3], u64::MAX - 7);
    // Deterministic encoding: re-encoding the decode is byte-identical.
    assert_eq!(encode_store(&back), bytes);
}

#[test]
fn random_stores_roundtrip() {
    let mut r = SplitMix64::new(99);
    for round in 0..20 {
        let n = r.next_range(0, 12);
        let entries: Vec<(String, u64, GumbelMaxSketch)> = (0..n)
            .map(|i| {
                let f = fastgm::sketch::fastgm::FastGm::new(16, round as u64);
                (format!("k{i}"), r.next_u64(), f.sketch(&random_vec(&mut r, 1 + i)))
            })
            .collect();
        let bytes = encode_store(&entries);
        assert_eq!(decode_store(&bytes).unwrap(), entries, "round {round}");
    }
}

/// The v1 layout (no per-entry version field, container version 1) still
/// decodes — registers bit-identical, every entry surfacing as version 0
/// so any post-upgrade write supersedes it. Built by hand here so the
/// compatibility contract is against the frozen v1 bytes, not against
/// whatever this build's encoder writes.
#[test]
fn v1_snapshots_decode_as_version_zero() {
    assert_eq!(MIN_VERSION, 1, "v1 must stay decodable");
    let entries = entries_across_all_families();
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&MAGIC);
    bytes.extend_from_slice(&1u16.to_le_bytes()); // container v1
    bytes.extend_from_slice(&0u16.to_le_bytes()); // flags
    bytes.extend_from_slice(&(entries.len() as u64).to_le_bytes());
    for (key, _, sk) in &entries {
        bytes.extend_from_slice(&(key.len() as u32).to_le_bytes());
        bytes.extend_from_slice(key.as_bytes());
        // v1 entry: family tag directly after the key (no version field).
        let tag = match sk.family {
            Family::Ordered => 0u8,
            Family::Direct => 1,
            Family::Icws => 2,
            Family::Bag => 3,
            Family::MinHash => 4,
        };
        bytes.push(tag);
        bytes.extend_from_slice(&sk.seed.to_le_bytes());
        bytes.extend_from_slice(&(sk.k() as u64).to_le_bytes());
        for &y in &sk.y {
            bytes.extend_from_slice(&y.to_bits().to_le_bytes());
        }
        for &s in &sk.s {
            bytes.extend_from_slice(&s.to_le_bytes());
        }
    }
    let sum = fnv1a64(&bytes);
    bytes.extend_from_slice(&sum.to_le_bytes());

    let back = decode_store(&bytes).unwrap();
    assert_eq!(back.len(), entries.len());
    for ((ka, _, a), (kb, vb, b)) in entries.iter().zip(&back) {
        assert_eq!(ka, kb);
        assert_eq!(*vb, 0, "{ka}: v1 entries must decode as version 0");
        assert_eq!(a, b, "{ka}: v1 registers must round-trip bit-identically");
    }
    // Re-encoding a v1 decode upgrades it to the current container
    // version (still decodable, versions preserved at 0).
    let upgraded = encode_store(&back);
    assert_eq!(upgraded[4], VERSION as u8);
    assert_eq!(decode_store(&upgraded).unwrap(), back);
    // v1 is as strictly checked as v2.
    for len in (0..bytes.len()).step_by(9) {
        assert!(decode_store(&bytes[..len]).is_err(), "v1 prefix {len} decoded");
    }
}

#[test]
fn truncated_inputs_are_clean_errors() {
    let bytes = encode_store(&entries_across_all_families());
    // Every strict prefix must fail to decode — never panic, never succeed.
    for len in (0..bytes.len()).step_by(7).chain([bytes.len() - 1]) {
        let err = decode_store(&bytes[..len]);
        assert!(err.is_err(), "prefix of {len}/{} bytes decoded", bytes.len());
    }
}

#[test]
fn corrupt_inputs_are_clean_errors() {
    let bytes = encode_store(&entries_across_all_families());
    let mut r = SplitMix64::new(5);
    for _ in 0..200 {
        let mut bad = bytes.clone();
        let at = r.next_range(0, bad.len() - 1);
        bad[at] ^= 1 << r.next_range(0, 7);
        assert!(decode_store(&bad).is_err(), "flip at byte {at} went unnoticed");
    }
    assert!(decode_store(b"").is_err());
    assert!(decode_store(b"FGMS").is_err());
    assert!(decode_store(&[0u8; 64]).is_err());
}

#[test]
fn version_mismatch_is_a_named_clean_error() {
    let bytes = encode_store(&entries_across_all_families());
    assert_eq!(&bytes[..4], &MAGIC, "layout assumption: magic first");
    let mut future = bytes.clone();
    let next = VERSION + 1;
    future[4..6].copy_from_slice(&next.to_le_bytes());
    let err = decode_store(&refresh_checksum(future)).unwrap_err().to_string();
    assert!(
        err.contains(&format!("version {next}")),
        "version mismatch must name the version: {err}"
    );
    // Below MIN_VERSION is refused too (v0 never existed).
    let mut ancient = bytes.clone();
    ancient[4..6].copy_from_slice(&0u16.to_le_bytes());
    assert!(decode_store(&refresh_checksum(ancient)).is_err());
    // And the magic check still guards non-snapshots with valid length.
    let mut not_ours = bytes;
    not_ours[..4].copy_from_slice(b"ELFY");
    let err = decode_store(&refresh_checksum(not_ours)).unwrap_err().to_string();
    assert!(err.contains("bad magic"), "{err}");
}
