//! `fastgm` — launcher CLI for the FastGM sketching service.
//!
//! ```text
//! fastgm serve    [--config cfg.toml] [--addr host:port] [--transport lines|event] [--set k=v ...]
//! fastgm client   [--addr host:port] (--ping | --metrics | --json '{...}')
//! fastgm store    [--addr host:port] (--upsert KEY --vec "id:w,..." | --delete KEY | --stats)
//! fastgm topk     [--addr host:port] --vec "id:w,..." [--limit N]
//! fastgm sample   [--addr host:port] (--key K | --keys K1,K2,... | --stream S) [--n N] [--seed S]
//! fastgm partition [--addr host:port] (--key K | --keys K1,K2,... | --stream S)
//! fastgm snapshot [--addr host:port] (--save PATH | --restore PATH)
//! fastgm cluster  serve  [--nodes N] [--host H] [--base-port P] [--config cfg] [--set k=v ...]
//! fastgm cluster  info   --addrs a:p,b:p,... [--replication R] [--write-quorum W] [--io-timeout S] [--framed]
//! fastgm cluster  upsert --addrs ... --key K --vec "id:w,..." [--replication R] [--write-quorum W]
//! fastgm cluster  delete --addrs ... --key K [--replication R] [--write-quorum W]
//! fastgm cluster  topk   --addrs ... --vec "id:w,..." [--limit N] [--replication R]
//! fastgm cluster  get    --addrs ... --key K [--replication R]
//! fastgm cluster  push   --addrs ... --stream S --items "id:w,..." [--replication R] [--write-quorum W]
//! fastgm cluster  sample --addrs ... (--key K | --keys K1,... | --stream S) [--n N] [--seed S] [--replication R]
//! fastgm cluster  partition --addrs ... (--key K | --keys K1,... | --stream S) [--replication R]
//! fastgm cluster  card   --addrs ... --stream S
//! fastgm cluster  repair --addrs ... [--streams S1,S2] [--replication R]
//! fastgm sketch   [--dataset NAME|path:FILE|synthetic] [--k K] [--algo A] [--count N]
//! fastgm exp      <table1|fig4|...|ablation-delta|ablation-accel|all> [--out DIR] [--full]
//! fastgm simnet   [--depth D] [--packets N] [--k K]
//! fastgm info
//! ```

// Same clippy baseline as the library crate (see rust/src/lib.rs).
#![allow(clippy::needless_range_loop)]

use fastgm::coordinator::client::Client;
use fastgm::coordinator::cluster::{ClusterClient, LocalCluster, ReplicaConfig};
#[cfg(unix)]
use fastgm::coordinator::event_server::EventServer;
use fastgm::coordinator::protocol::{decode_request, encode_line, QueryTarget, Request};
use fastgm::coordinator::server::Server;
use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
use fastgm::data::corpus::{Corpus, CORPORA};
use fastgm::data::svmlight;
use fastgm::data::synthetic::{dense_vector, WeightDist};
use fastgm::exp::{self, ExpOptions};
use fastgm::sketch::engine::{self, EngineParams};
use fastgm::sketch::{GumbelMaxSketch, SketchScratch, Sketcher, SparseVector};
use fastgm::simnet::{NodeSketcher, SimNet, SimParams};
use fastgm::util::argparse::ArgSpec;
use fastgm::util::config::Config;
use fastgm::util::rng::SplitMix64;
use fastgm::util::stats::fmt_duration;
use std::sync::Arc;

fn main() {
    fastgm::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(argv: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = argv.first() else {
        anyhow::bail!(top_help());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "serve" => cmd_serve(rest),
        "client" => cmd_client(rest),
        "store" => cmd_store(rest),
        "topk" => cmd_topk(rest),
        "sample" => cmd_sample(rest),
        "partition" => cmd_partition(rest),
        "snapshot" => cmd_snapshot(rest),
        "cluster" => cmd_cluster(rest),
        "sketch" => cmd_sketch(rest),
        "exp" => cmd_exp(rest),
        "simnet" => cmd_simnet(rest),
        "info" => cmd_info(),
        "--help" | "-h" | "help" => {
            println!("{}", top_help());
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n\n{}", top_help()),
    }
}

fn top_help() -> String {
    "fastgm — Fast Gumbel-Max Sketch service (paper reproduction)\n\n\
     USAGE: fastgm <COMMAND> [OPTIONS]\n\n\
     COMMANDS:\n\
       serve     run the sketching coordinator (TCP JSON-lines)\n\
       client    talk to a running coordinator\n\
       store     upsert/delete keys in the server's similarity store\n\
       topk      top-k similarity query against the server's store\n\
       sample    draw weighted samples from a key, key union or stream\n\
       partition sum-of-weights estimate for a key, key union or stream\n\
       snapshot  save/restore the server's store (binary snapshot)\n\
       cluster   run/drive an N-node replicated cluster (scatter-gather)\n\
       sketch    sketch a dataset locally and report timing\n\
       exp       regenerate a paper table/figure (or 'all')\n\
       simnet    run the braided-chain sensor network simulation\n\
       info      environment, corpora and artifact status\n\n\
     Each command accepts --help."
        .to_string()
}

fn cmd_serve(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new("serve", "run the sketching coordinator")
        .opt("config", "", "TOML config file")
        .opt("addr", "127.0.0.1:7878", "listen address")
        .opt(
            "transport",
            "lines",
            "'lines' (thread-per-connection JSON) or 'event' (poll loop: \
             binary frames + JSON lines on one port; unix only)",
        )
        .opt(
            "cache-bytes",
            "",
            "read-path cache budget in bytes (0 disables; shorthand for \
             --set cache.max_bytes=N)",
        )
        .multi("set", "config override key=value");
    let args = spec.parse(argv)?;
    let mut cfg = if args.str("config").is_empty() {
        Config::new()
    } else {
        Config::from_file(&args.str("config"))?
    };
    // --cache-bytes is sugar applied before --set, so an explicit
    // --set cache.max_bytes=N still wins.
    if !args.str("cache-bytes").is_empty() {
        cfg.set_override(&format!("cache.max_bytes={}", args.str("cache-bytes")))?;
    }
    for s in args.all("set") {
        cfg.set_override(&s)?;
    }
    let ccfg = CoordinatorConfig::from_config(&cfg);
    log::info!(
        "starting coordinator: k={} workers={} accel={:?}",
        ccfg.k,
        ccfg.workers,
        ccfg.artifacts_dir
    );
    let coordinator = Arc::new(Coordinator::new(ccfg)?);
    match args.str("transport").as_str() {
        "lines" => {
            let server = Server::start(coordinator, &args.str("addr"))?;
            println!("fastgm serving on {}", server.addr);
            // Serve until killed.
            loop {
                std::thread::park();
            }
        }
        #[cfg(unix)]
        "event" => {
            let server = EventServer::start(coordinator, &args.str("addr"))?;
            println!("fastgm serving on {} (event transport)", server.addr);
            loop {
                std::thread::park();
            }
        }
        other => anyhow::bail!(
            "unknown transport '{other}' (want 'lines'{})",
            if cfg!(unix) { " or 'event'" } else { "; 'event' needs unix" },
        ),
    }
}

fn cmd_client(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new("client", "talk to a running coordinator")
        .opt("addr", "127.0.0.1:7878", "server address")
        .flag("ping", "send a ping")
        .flag("metrics", "fetch metrics")
        .opt("json", "", "raw request JSON (one object)");
    let args = spec.parse(argv)?;
    let mut client = Client::connect(&args.str("addr"))?;
    let req = if args.flag("ping") {
        Request::Ping
    } else if args.flag("metrics") {
        Request::Metrics
    } else if !args.str("json").is_empty() {
        decode_request(&args.str("json"))?
    } else {
        anyhow::bail!("one of --ping | --metrics | --json required");
    };
    let resp = client.call(&req)?;
    println!("{}", encode_line(&resp.to_json()).trim());
    Ok(())
}

/// Parse a sparse vector spec of the form `id:weight,id:weight,...`.
fn parse_vec(spec: &str) -> anyhow::Result<SparseVector> {
    let mut v = SparseVector::default();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (id, w) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("bad vector entry '{part}' (want id:weight)"))?;
        v.push(
            id.trim().parse().map_err(|e| anyhow::anyhow!("bad id '{id}': {e}"))?,
            w.trim().parse().map_err(|e| anyhow::anyhow!("bad weight '{w}': {e}"))?,
        );
    }
    anyhow::ensure!(!v.ids.is_empty(), "empty vector spec (want id:weight,id:weight,...)");
    Ok(v)
}

fn cmd_store(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new("store", "upsert/delete keys in the server's similarity store")
        .opt("addr", "127.0.0.1:7878", "server address")
        .opt("upsert", "", "key to upsert (requires --vec)")
        .opt("vec", "", "sparse vector as id:w,id:w,...")
        .opt("delete", "", "key to delete")
        .flag("stats", "fetch store statistics");
    let args = spec.parse(argv)?;
    let mut client = Client::connect(&args.str("addr"))?;
    if !args.str("upsert").is_empty() {
        let v = parse_vec(&args.str("vec"))?;
        println!("{}", client.upsert(&args.str("upsert"), v)?);
    } else if !args.str("delete").is_empty() {
        println!("{}", client.delete(&args.str("delete"))?);
    } else if args.flag("stats") {
        println!("{}", client.store_stats()?);
    } else {
        anyhow::bail!(
            "one of --upsert KEY --vec ... | --delete KEY | --stats required\n\n{}",
            spec.help_text()
        );
    }
    Ok(())
}

fn cmd_topk(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new("topk", "top-k similarity query against the server's store")
        .opt("addr", "127.0.0.1:7878", "server address")
        .opt("vec", "", "query vector as id:w,id:w,...")
        .opt("limit", "10", "number of neighbors");
    let args = spec.parse(argv)?;
    let v = parse_vec(&args.str("vec"))?;
    let mut client = Client::connect(&args.str("addr"))?;
    let hits = client.topk(v, args.usize("limit")?)?;
    if hits.is_empty() {
        println!("(no hits)");
    }
    for (rank, (key, score)) in hits.iter().enumerate() {
        println!("{:>3}. {key}  J_P≈{score:.4}", rank + 1);
    }
    Ok(())
}

/// The query-target trio every sampling op shares: exactly one of a single
/// key, a comma-separated key union, or a stream.
fn target_spec(spec: ArgSpec) -> ArgSpec {
    spec.opt("key", "", "single store key")
        .opt("keys", "", "comma-separated store keys (queried as their union)")
        .opt("stream", "", "stream name")
}

fn parse_target(args: &fastgm::util::argparse::Args) -> anyhow::Result<QueryTarget> {
    let (key, keys, stream) = (args.str("key"), args.str("keys"), args.str("stream"));
    match (key.is_empty(), keys.is_empty(), stream.is_empty()) {
        (false, true, true) => Ok(QueryTarget::key(key)),
        (true, false, true) => {
            let keys: Vec<String> = keys
                .split(',')
                .map(str::trim)
                .filter(|k| !k.is_empty())
                .map(str::to_string)
                .collect();
            anyhow::ensure!(!keys.is_empty(), "--keys needs at least one key");
            Ok(QueryTarget::Keys(keys))
        }
        (true, true, false) => Ok(QueryTarget::Stream(stream)),
        _ => anyhow::bail!("exactly one of --key K | --keys K1,K2,... | --stream S required"),
    }
}

fn cmd_sample(argv: &[String]) -> anyhow::Result<()> {
    let spec = target_spec(
        ArgSpec::new("sample", "draw weighted samples from a key, key union or stream"),
    )
    .opt("addr", "127.0.0.1:7878", "server address")
    .opt("n", "10", "number of draws")
    .opt("seed", "1", "draw seed (same seed => same ids)");
    let args = spec.parse(argv)?;
    let target = parse_target(&args)?;
    let mut client = Client::connect(&args.str("addr"))?;
    let ids = client.sample(target, args.usize("n")?, args.u64("seed")?)?;
    for id in ids {
        println!("{id}");
    }
    Ok(())
}

fn cmd_partition(argv: &[String]) -> anyhow::Result<()> {
    let spec = target_spec(
        ArgSpec::new("partition", "sum-of-weights estimate for a key, key union or stream"),
    )
    .opt("addr", "127.0.0.1:7878", "server address");
    let args = spec.parse(argv)?;
    let target = parse_target(&args)?;
    let mut client = Client::connect(&args.str("addr"))?;
    println!("{:.6}", client.partition(target)?);
    Ok(())
}

fn cmd_snapshot(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new("snapshot", "save/restore the server's store (binary snapshot)")
        .opt("addr", "127.0.0.1:7878", "server address")
        .opt("save", "", "write the store to this server-side path")
        .opt("restore", "", "replace the store from this server-side path");
    let args = spec.parse(argv)?;
    let mut client = Client::connect(&args.str("addr"))?;
    if !args.str("save").is_empty() {
        println!("{}", client.snapshot(&args.str("save"))?);
    } else if !args.str("restore").is_empty() {
        println!("{}", client.restore(&args.str("restore"))?);
    } else {
        anyhow::bail!("one of --save PATH | --restore PATH required\n\n{}", spec.help_text());
    }
    Ok(())
}

fn cluster_help() -> String {
    "fastgm cluster — run/drive an N-node replicated serving cluster\n\n\
     USAGE: fastgm cluster <ACTION> [OPTIONS]\n\n\
     ACTIONS:\n\
       serve   spawn N local nodes (one port each) and serve until killed\n\
       info    hello + store occupancy for every node\n\
       upsert  fan an upsert out to the key's replica set (W-quorum)\n\
       delete  fan a delete out to the key's replica set (W-quorum)\n\
       topk    scatter-gather top-k across all live nodes\n\
       get     read one key from its replica set (highest version wins)\n\
       push    push stream items to each element's replica set\n\
       sample  weighted samples from a key, key union or stream (replica failover)\n\
       partition  sum-of-weights estimate for a key, key union or stream\n\
       card    cluster-wide weighted cardinality (merged §2.3 sketches)\n\
       repair  anti-entropy: converge replica versions + merge streams\n\n\
     Every driving action takes --addrs host:port,host:port,... and the\n\
     replication shape --replication R (default 1) --write-quorum W\n\
     (default 1). Each action accepts --help."
        .to_string()
}

fn cmd_cluster(argv: &[String]) -> anyhow::Result<()> {
    let Some(action) = argv.first() else {
        anyhow::bail!(cluster_help());
    };
    let rest = &argv[1..];
    match action.as_str() {
        "serve" => cluster_serve(rest),
        "info" => cluster_info(rest),
        "upsert" => cluster_upsert(rest),
        "delete" => cluster_delete(rest),
        "topk" => cluster_topk(rest),
        "get" => cluster_get(rest),
        "push" => cluster_push(rest),
        "sample" => cluster_sample(rest),
        "partition" => cluster_partition(rest),
        "card" => cluster_card(rest),
        "repair" => cluster_repair(rest),
        "--help" | "-h" | "help" => {
            println!("{}", cluster_help());
            Ok(())
        }
        other => anyhow::bail!("unknown cluster action '{other}'\n\n{}", cluster_help()),
    }
}

fn cluster_serve(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new("cluster serve", "spawn N local nodes and serve")
        .opt("nodes", "3", "number of nodes")
        .opt("host", "127.0.0.1", "bind host")
        .opt("base-port", "7900", "first node's port (node i gets port+i)")
        .opt("config", "", "TOML config file (shared by every node)")
        .multi("set", "config override key=value");
    let args = spec.parse(argv)?;
    let n = args.usize("nodes")?;
    anyhow::ensure!(n >= 1, "--nodes must be at least 1");
    let mut cfg = if args.str("config").is_empty() {
        Config::new()
    } else {
        Config::from_file(&args.str("config"))?
    };
    for s in args.all("set") {
        cfg.set_override(&s)?;
    }
    let base = CoordinatorConfig::from_config(&cfg);
    let host = args.str("host");
    let base_port = args.usize("base-port")?;
    let addrs: Vec<String> = (0..n).map(|i| format!("{host}:{}", base_port + i)).collect();
    let cluster = LocalCluster::start_on(&addrs, &base)?;
    println!("fastgm cluster: {n} nodes (k={}, seed={}, algo={})", base.k, base.seed, base.algo);
    for i in 0..cluster.len() {
        println!("  {}  {}", cluster.node_id(i), cluster.addr(i));
    }
    println!("drive it with: fastgm cluster topk --addrs {}", cluster.addrs().join(","));
    // Serve until killed.
    loop {
        std::thread::park();
    }
}

fn parse_addrs(spec: &str) -> anyhow::Result<Vec<String>> {
    let addrs: Vec<String> = spec
        .split(',')
        .map(str::trim)
        .filter(|a| !a.is_empty())
        .map(str::to_string)
        .collect();
    anyhow::ensure!(!addrs.is_empty(), "--addrs needs at least one host:port");
    Ok(addrs)
}

/// Parse stream items `id:w,id:w,...` (numeric ids, unlike store vectors).
fn parse_items(spec: &str) -> anyhow::Result<Vec<(u64, f64)>> {
    let v = parse_vec(spec)?;
    Ok(v.ids.into_iter().zip(v.weights).collect())
}

/// The options every cluster-driving action shares: membership + the
/// replication shape the client routes and quorum-checks with.
fn cluster_spec(name: &'static str, about: &'static str) -> ArgSpec {
    ArgSpec::new(name, about)
        .opt("addrs", "", "comma-separated node addresses")
        .opt("replication", "1", "replica set size R (HRW top-R owners per key)")
        .opt("write-quorum", "1", "owner acks required per write (1..=R)")
        .opt("io-timeout", "10", "per-node I/O timeout in seconds (expiry marks the node down)")
        .flag(
            "framed",
            "speak the binary framed protocol to the nodes (event transport only); \
             blob transfers (gathers, repair, stream merges) ride raw codec bytes \
             instead of hex-in-JSON",
        )
        .opt(
            "cache-bytes",
            "0",
            "client-side (key,version) gather-blob cache budget in bytes (0 disables)",
        )
}

fn cluster_connect(args: &fastgm::util::argparse::Args) -> anyhow::Result<ClusterClient> {
    let secs = args.f64("io-timeout")?;
    anyhow::ensure!(secs > 0.0, "--io-timeout must be positive (got {secs})");
    ClusterClient::connect_with(
        &parse_addrs(&args.str("addrs"))?,
        ReplicaConfig {
            replication: args.usize("replication")?,
            write_quorum: args.usize("write-quorum")?,
            io_timeout: std::time::Duration::from_secs_f64(secs),
            framed: args.flag("framed"),
            cache_bytes: args.usize("cache-bytes")?,
        },
    )
}

fn cluster_info(argv: &[String]) -> anyhow::Result<()> {
    let spec = cluster_spec("cluster info", "hello + occupancy for every node");
    let args = spec.parse(argv)?;
    let mut cc = cluster_connect(&args)?;
    let sizes = cc.store_sizes();
    println!("{} nodes, {} live", cc.nodes(), cc.live_nodes());
    for (i, (id, size)) in sizes.iter().enumerate() {
        let h = cc.hello(i);
        println!(
            "  {id:<12} {}  protocol v{}  epoch {}  k={} seed={} algo={}  store={}",
            cc.addr(i),
            h.protocol,
            h.epoch,
            h.k,
            h.seed,
            h.algo,
            size.map(|s| s.to_string()).unwrap_or_else(|| "?".into()),
        );
    }
    Ok(())
}

fn cluster_upsert(argv: &[String]) -> anyhow::Result<()> {
    let spec = cluster_spec("cluster upsert", "fan an upsert out to the key's replica set")
        .opt("key", "", "store key")
        .opt("vec", "", "sparse vector as id:w,id:w,...");
    let args = spec.parse(argv)?;
    anyhow::ensure!(!args.str("key").is_empty(), "--key required");
    let v = parse_vec(&args.str("vec"))?;
    let mut cc = cluster_connect(&args)?;
    let key = args.str("key");
    let owner = cc.owner(&key);
    println!("{} (owner: {})", cc.upsert(&key, v)?, cc.node_id(owner));
    Ok(())
}

fn cluster_delete(argv: &[String]) -> anyhow::Result<()> {
    let spec = cluster_spec("cluster delete", "fan a delete out to the key's replica set")
        .opt("key", "", "store key");
    let args = spec.parse(argv)?;
    anyhow::ensure!(!args.str("key").is_empty(), "--key required");
    let mut cc = cluster_connect(&args)?;
    println!("{}", cc.delete(&args.str("key"))?);
    Ok(())
}

fn cluster_topk(argv: &[String]) -> anyhow::Result<()> {
    let spec = cluster_spec("cluster topk", "scatter-gather top-k across live nodes")
        .opt("vec", "", "query vector as id:w,id:w,...")
        .opt("limit", "10", "number of neighbors");
    let args = spec.parse(argv)?;
    let v = parse_vec(&args.str("vec"))?;
    let mut cc = cluster_connect(&args)?;
    let (hits, stats) = cc.topk(&v, args.usize("limit")?)?;
    if hits.is_empty() {
        println!("(no hits)");
    }
    for (rank, (key, score)) in hits.iter().enumerate() {
        println!("{:>3}. {key}  J_P≈{score:.4}", rank + 1);
    }
    println!(
        "({}/{} nodes answered, {} candidates, {} re-ranked)",
        stats.live, stats.nodes, stats.candidates, stats.reranked
    );
    Ok(())
}

fn cluster_get(argv: &[String]) -> anyhow::Result<()> {
    let spec = cluster_spec("cluster get", "read one key from its replica set")
        .opt("key", "", "store key");
    let args = spec.parse(argv)?;
    anyhow::ensure!(!args.str("key").is_empty(), "--key required");
    let mut cc = cluster_connect(&args)?;
    let key = args.str("key");
    match cc.fetch_key(&key)? {
        Some((version, sk)) => println!(
            "'{key}' @v{version}: family {}, k={}, seed={}",
            sk.family.name(),
            sk.k(),
            sk.seed
        ),
        None => println!("'{key}' not held by any live owner"),
    }
    Ok(())
}

fn cluster_push(argv: &[String]) -> anyhow::Result<()> {
    let spec = cluster_spec("cluster push", "push stream items to each element's replica set")
        .opt("stream", "s", "stream name")
        .opt("items", "", "items as id:w,id:w,...");
    let args = spec.parse(argv)?;
    let items = parse_items(&args.str("items"))?;
    let mut cc = cluster_connect(&args)?;
    let n = cc.push(&args.str("stream"), &items)?;
    println!("routed {n} items into stream '{}'", args.str("stream"));
    Ok(())
}

fn cluster_sample(argv: &[String]) -> anyhow::Result<()> {
    let spec = target_spec(cluster_spec(
        "cluster sample",
        "weighted samples from a key, key union or stream (replica failover)",
    ))
    .opt("n", "10", "number of draws")
    .opt("seed", "1", "draw seed (same seed => same ids)");
    let args = spec.parse(argv)?;
    let target = parse_target(&args)?;
    let mut cc = cluster_connect(&args)?;
    for id in cc.sample(&target, args.usize("n")?, args.u64("seed")?)? {
        println!("{id}");
    }
    Ok(())
}

fn cluster_partition(argv: &[String]) -> anyhow::Result<()> {
    let spec = target_spec(cluster_spec(
        "cluster partition",
        "sum-of-weights estimate for a key, key union or stream",
    ));
    let args = spec.parse(argv)?;
    let target = parse_target(&args)?;
    let mut cc = cluster_connect(&args)?;
    println!("{:.6}", cc.partition(&target)?);
    Ok(())
}

fn cluster_card(argv: &[String]) -> anyhow::Result<()> {
    let spec = cluster_spec("cluster card", "cluster-wide weighted cardinality")
        .opt("stream", "s", "stream name");
    let args = spec.parse(argv)?;
    let mut cc = cluster_connect(&args)?;
    let est = cc.cardinality(&args.str("stream"))?;
    println!("cluster cardinality of '{}': {est:.1}", args.str("stream"));
    Ok(())
}

fn cluster_repair(argv: &[String]) -> anyhow::Result<()> {
    let spec = cluster_spec(
        "cluster repair",
        "anti-entropy: diff replica versions, stream blobs onto stale owners, merge streams",
    )
    .opt("streams", "", "comma-separated stream names to converge (optional)");
    let args = spec.parse(argv)?;
    let streams: Vec<String> = args
        .str("streams")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    let mut cc = cluster_connect(&args)?;
    let report = cc.repair(&streams)?;
    println!(
        "repair: {} keys scanned, {} replica installs, {} skipped, {} stream merges",
        report.keys_scanned, report.keys_healed, report.keys_skipped, report.stream_merges
    );
    Ok(())
}

fn load_dataset(name: &str, count: usize) -> anyhow::Result<Vec<SparseVector>> {
    if let Some(path) = name.strip_prefix("path:") {
        return Ok(svmlight::load(path)?.into_iter().take(count).map(|r| r.vector).collect());
    }
    if name == "synthetic" {
        let mut rng = SplitMix64::new(1);
        return Ok((0..count)
            .map(|_| dense_vector(&mut rng, 1000, WeightDist::Uniform01))
            .collect());
    }
    let corpus = Corpus::by_name(name, 7)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}' (see `fastgm info`)"))?;
    Ok(corpus.vectors(count))
}

fn cmd_sketch(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new("sketch", "sketch a dataset locally, report timing")
        .opt("dataset", "synthetic", "synthetic | corpus name | path:FILE (svmlight)")
        .opt("k", "1024", "sketch length")
        .opt("algo", "fastgm", "any engine-registry name (fastgm | fastgm-c | sharded | stream | pminhash | lemiesz | icws | bagminhash | minhash)")
        .opt("count", "100", "number of vectors")
        .opt("seed", "1", "sketch seed");
    let args = spec.parse(argv)?;
    let k = args.usize("k")?;
    let seed = args.u64("seed")?;
    let vectors = load_dataset(&args.str("dataset"), args.usize("count")?)?;
    anyhow::ensure!(!vectors.is_empty(), "dataset is empty");
    // Any registered algorithm by name, timed through the zero-allocation
    // engine exactly like the coordinator's hot path runs it.
    let sketcher = engine::build_named(&args.str("algo"), EngineParams::new(k, seed))?;
    let mut scratch = SketchScratch::new();
    let mut out = GumbelMaxSketch::empty(sketcher.family(), sketcher.seed(), k);
    let t0 = std::time::Instant::now();
    for v in &vectors {
        sketcher.sketch_into(v, &mut scratch, &mut out);
        std::hint::black_box(&out);
    }
    let dt = t0.elapsed().as_secs_f64();
    let mean_np =
        vectors.iter().map(|v| v.n_plus()).sum::<usize>() as f64 / vectors.len() as f64;
    println!(
        "{} vectors (mean n+ {:.1}), k={k}, algo={}: total {}, per-vector {}",
        vectors.len(),
        mean_np,
        args.str("algo"),
        fmt_duration(dt),
        fmt_duration(dt / vectors.len() as f64)
    );
    Ok(())
}

fn cmd_exp(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new("exp", "regenerate a paper table/figure")
        .positional("name", "table1|fig4|fig5|fig6|fig7|fig8|fig10|fig11|ablation-*|all")
        .opt("out", "results", "output directory")
        .flag("full", "paper-scale parameters (slow)");
    let args = spec.parse(argv)?;
    let name = args
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("experiment name required\n\n{}", spec.help_text()))?
        .to_string();
    let opts = ExpOptions { out_dir: args.str("out"), full: args.flag("full") };
    exp::run(&name, &opts)
}

fn cmd_simnet(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new("simnet", "run the braided-chain sensor network")
        .opt("depth", "30", "number of layers")
        .opt("packets", "10000", "packets per source")
        .opt("k", "200", "sketch length")
        .opt("p1", "0.9", "same-chain delivery probability")
        .opt("p2", "0.1", "cross-chain delivery probability")
        .opt("sketcher", "stream-fastgm", "stream-fastgm | lemiesz");
    let args = spec.parse(argv)?;
    let params = SimParams {
        depth: args.usize("depth")?,
        packets_per_source: args.usize("packets")?,
        k: args.usize("k")?,
        p1: args.f64("p1")?,
        p2: args.f64("p2")?,
        seed: 42,
    };
    let sketcher = match args.str("sketcher").as_str() {
        "stream-fastgm" => NodeSketcher::StreamFastGm,
        "lemiesz" => NodeSketcher::Lemiesz,
        other => anyhow::bail!("unknown sketcher '{other}'"),
    };
    let net = SimNet::run(params, sketcher);
    println!(
        "simnet: d={} n={} k={} sketching took {}",
        params.depth,
        params.packets_per_source,
        params.k,
        fmt_duration(net.sketch_seconds)
    );
    println!("layer  lost-truth  lost-est  J_W-truth  J_W-est");
    let c = net.fig10c();
    let d = net.fig10d();
    for l in 0..params.depth {
        println!(
            "{l:>5}  {:>10.1}  {:>8.1}  {:>9.3}  {:>7.3}",
            c[l].0, c[l].1, d[l].0, d[l].1
        );
    }
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    println!("fastgm {} — Fast Gumbel-Max Sketch reproduction", env!("CARGO_PKG_VERSION"));
    println!("\ncorpora analogs:");
    for c in CORPORA {
        println!(
            "  {:<10} {:>8} vectors  {:>9} features  mean n+ ~{}",
            c.name, c.vectors, c.features, c.mean_nplus
        );
    }
    match fastgm::runtime::read_manifest("artifacts") {
        Ok(specs) => {
            println!("\nartifacts ({}):", specs.len());
            for s in specs {
                println!(
                    "  {:<32} {:?} -> {:?}",
                    s.name,
                    s.inputs.iter().map(|t| &t.shape).collect::<Vec<_>>(),
                    s.outputs.iter().map(|t| &t.shape).collect::<Vec<_>>()
                );
            }
        }
        Err(e) => println!("\nartifacts: not built ({e})"),
    }
    Ok(())
}
