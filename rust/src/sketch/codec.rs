//! Versioned binary snapshot codec for keyed sketch collections — the
//! persistence format behind [`crate::coordinator::store::SketchStore`]'s
//! `snapshot` / `restore` ops, so a server warm-restarts without
//! recomputing a single sketch, and the cross-node transfer format of the
//! cluster gather / anti-entropy repair paths.
//!
//! Format v2, little-endian, with a trailing integrity checksum:
//!
//! ```text
//! magic "FGMS" | version u16 | flags u16 (0) | count u64
//! per entry:
//!   key_len u32 | key (UTF-8) | entry_version u64 |
//!   family u8 | seed u64 | k u64 | y[k] (f64 bit patterns) | s[k] u64
//! fnv1a64(checksum of every preceding byte) u64
//! ```
//!
//! v2 added the per-entry `entry_version` — the keyed store's monotonic
//! per-key write version, what makes last-writer-wins deterministic when
//! replicas of a key diff their states during `cluster repair`. v1 (no
//! per-entry version field) still decodes: its entries surface with
//! version 0, which any post-upgrade write (version ≥ 1) supersedes.
//!
//! Register values round-trip via raw bit patterns, so restore is
//! **bit-identical** for every family — including `+inf` / EMPTY_REGISTER
//! sentinels in untouched registers.
//!
//! Versioning rules: the container version is bumped on any layout change;
//! decoders read exactly the versions they know and refuse the rest loudly
//! (no best-effort parsing of future layouts). Encoders always write the
//! newest version. Decoding is strict — bad magic, unknown version or
//! family tag, truncation anywhere, trailing garbage and checksum
//! mismatches are all clean `Err`s, never panics and never partial state.

use super::{Family, GumbelMaxSketch};
use crate::util::hash::fnv1a64;

pub const MAGIC: [u8; 4] = *b"FGMS";
/// Container version encoders write.
pub const VERSION: u16 = 2;
/// Oldest container version decoders still read (entry versions = 0).
pub const MIN_VERSION: u16 = 1;

/// Largest key the snapshot format accepts. Public so writers (the
/// coordinator's `upsert` op) can refuse oversized keys up front — an
/// acked upsert must never produce a snapshot that cannot be restored.
/// Also the decode-side allocation guard: a corrupt length field must not
/// ask the allocator for gigabytes before the inevitable truncation error.
pub const MAX_KEY_LEN: usize = 1 << 20;
pub(crate) const MAX_K: u64 = 1 << 28;

pub(crate) fn family_tag(f: Family) -> u8 {
    match f {
        Family::Ordered => 0,
        Family::Direct => 1,
        Family::Icws => 2,
        Family::Bag => 3,
        Family::MinHash => 4,
    }
}

pub(crate) fn family_from_tag(t: u8) -> anyhow::Result<Family> {
    Ok(match t {
        0 => Family::Ordered,
        1 => Family::Direct,
        2 => Family::Icws,
        3 => Family::Bag,
        4 => Family::MinHash,
        other => anyhow::bail!("snapshot has unknown family tag {other}"),
    })
}

pub(crate) fn push_u16(out: &mut Vec<u8>, x: u16) {
    out.extend_from_slice(&x.to_le_bytes());
}

pub(crate) fn push_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

pub(crate) fn push_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Encode `(key, entry version, sketch)` entries (already in the order the
/// caller wants frozen — the store sorts by key so snapshots of equal
/// state are byte-identical).
pub fn encode_store(entries: &[(String, u64, GumbelMaxSketch)]) -> Vec<u8> {
    encode_entries(entries.iter().map(|(k, v, sk)| (k.as_str(), *v, sk)))
}

/// Borrow-based encoding core shared by [`encode_store`] and the
/// single-sketch wire path — no key/register clones required.
fn encode_entries<'a>(
    entries: impl Iterator<Item = (&'a str, u64, &'a GumbelMaxSketch)> + Clone,
) -> Vec<u8> {
    let (count, payload) = entries
        .clone()
        .fold((0u64, 0usize), |(n, bytes), (key, _, sk)| {
            (n + 1, bytes + 4 + key.len() + 8 + 1 + 8 + 8 + 16 * sk.k())
        });
    let mut out = Vec::with_capacity(16 + payload + 8);
    out.extend_from_slice(&MAGIC);
    push_u16(&mut out, VERSION);
    push_u16(&mut out, 0); // flags, reserved
    push_u64(&mut out, count);
    for (key, version, sk) in entries {
        push_u32(&mut out, key.len() as u32);
        out.extend_from_slice(key.as_bytes());
        push_u64(&mut out, version);
        out.push(family_tag(sk.family));
        push_u64(&mut out, sk.seed);
        push_u64(&mut out, sk.k() as u64);
        for &y in &sk.y {
            push_u64(&mut out, y.to_bits());
        }
        for &s in &sk.s {
            push_u64(&mut out, s);
        }
    }
    let checksum = fnv1a64(&out);
    push_u64(&mut out, checksum);
    out
}

/// Strict little-endian reader over the snapshot body. Crate-visible so
/// the binary frame codec ([`crate::coordinator::frame`]) decodes with the
/// exact same truncation-safe primitives.
pub(crate) struct Reader<'a> {
    pub(crate) bytes: &'a [u8],
    pub(crate) pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.bytes.len());
        let Some(end) = end else {
            anyhow::bail!(
                "snapshot truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.bytes.len() - self.pos
            );
        };
        let out = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    pub(crate) fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")))
    }

    pub(crate) fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    pub(crate) fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    pub(crate) fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }
}

/// Decode a snapshot produced by [`encode_store`] (v2) or by a pre-version
/// build (v1 — entries surface with version 0).
pub fn decode_store(bytes: &[u8]) -> anyhow::Result<Vec<(String, u64, GumbelMaxSketch)>> {
    anyhow::ensure!(
        bytes.len() >= MAGIC.len() + 2 + 2 + 8 + 8,
        "snapshot too short ({} bytes) to be a FastGM snapshot",
        bytes.len()
    );
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
    anyhow::ensure!(
        fnv1a64(body) == want,
        "snapshot checksum mismatch (file is corrupt or truncated)"
    );
    let mut r = Reader { bytes: body, pos: 0 };
    anyhow::ensure!(r.take(4)? == MAGIC, "not a FastGM snapshot (bad magic)");
    let version = r.u16()?;
    anyhow::ensure!(
        (MIN_VERSION..=VERSION).contains(&version),
        "unsupported snapshot version {version} (this build reads v{MIN_VERSION}..v{VERSION})"
    );
    let _flags = r.u16()?;
    let count = r.u64()?;
    let mut out = Vec::new();
    for i in 0..count {
        let key_len = r.u32()? as usize;
        anyhow::ensure!(key_len <= MAX_KEY_LEN, "entry {i}: key length {key_len} too large");
        let key = std::str::from_utf8(r.take(key_len)?)
            .map_err(|e| anyhow::anyhow!("entry {i}: key is not UTF-8: {e}"))?
            .to_string();
        // v1 predates per-entry versions: everything decodes as version 0,
        // which any post-upgrade write (version >= 1) supersedes.
        let entry_version = if version >= 2 { r.u64()? } else { 0 };
        let family = family_from_tag(r.u8()?)?;
        let seed = r.u64()?;
        let k = r.u64()?;
        anyhow::ensure!(k <= MAX_K, "entry '{key}': register count {k} too large");
        // Checked in u64 so `16 * k` cannot wrap on 32-bit targets and
        // bypass the allocation guard.
        anyhow::ensure!(
            r.remaining() as u64 >= 16 * k,
            "entry '{key}': truncated register arrays (k={k})"
        );
        let k = k as usize;
        let mut y = Vec::with_capacity(k);
        for j in 0..k {
            let v = f64::from_bits(r.u64()?);
            anyhow::ensure!(!v.is_nan(), "entry '{key}': register y[{j}] is NaN");
            y.push(v);
        }
        let mut s = Vec::with_capacity(k);
        for _ in 0..k {
            s.push(r.u64()?);
        }
        out.push((key, entry_version, GumbelMaxSketch { family, seed, y, s }));
    }
    anyhow::ensure!(
        r.remaining() == 0,
        "snapshot has {} trailing bytes after {count} entries",
        r.remaining()
    );
    Ok(out)
}

// -- single-sketch wire transfer (cluster gather + repair paths) -----------
//
// `sketch_fetch` responses and `store_put` requests carry one
// codec-encoded sketch, so the binary snapshot format — per-key version,
// checksum, strict decode and all — is also the cross-node transfer
// format (§2.3 sketches move between sites exactly as they are
// persisted). The JSON-lines protocol wraps the bytes in hex (dependency-
// free, string-safe); the framed transport's `*_bin` ops carry them raw —
// same bytes, half the wire size, zero re-encoding (the frame layer
// splices this module's output into the frame verbatim).

/// Lowercase hex of `bytes`.
pub fn to_hex(bytes: &[u8]) -> String {
    const DIGITS: &[u8; 16] = b"0123456789abcdef";
    let mut out = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        out.push(DIGITS[(b >> 4) as usize] as char);
        out.push(DIGITS[(b & 0xF) as usize] as char);
    }
    out
}

/// Strict inverse of [`to_hex`] (accepts upper/lower case, rejects odd
/// length and non-hex bytes).
pub fn from_hex(text: &str) -> anyhow::Result<Vec<u8>> {
    let bytes = text.as_bytes();
    anyhow::ensure!(bytes.len() % 2 == 0, "hex text has odd length {}", bytes.len());
    let nibble = |c: u8| -> anyhow::Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            other => anyhow::bail!("invalid hex byte 0x{other:02x}"),
        }
    };
    let mut out = Vec::with_capacity(bytes.len() / 2);
    for pair in bytes.chunks_exact(2) {
        out.push((nibble(pair[0])? << 4) | nibble(pair[1])?);
    }
    Ok(out)
}

/// Encode one `(key, version, sketch)` triple as raw codec bytes (a
/// one-entry store snapshot — checksum and versioning included for free).
/// Borrow-based: this sits on the per-candidate path of every cluster
/// gather, so it must not clone k registers just to encode them. Sources
/// without a write version (registry, stream sketches) pass 0. The binary
/// frame transport ships these bytes directly; the JSON-lines protocol
/// wraps them in hex via [`encode_sketch_hex`].
pub fn encode_sketch_bytes(key: &str, version: u64, sk: &GumbelMaxSketch) -> Vec<u8> {
    encode_entries(std::iter::once((key, version, sk)))
}

/// Decode a blob produced by [`encode_sketch_bytes`]; refuses blobs that
/// do not hold exactly one entry.
pub fn decode_sketch_bytes(bytes: &[u8]) -> anyhow::Result<(String, u64, GumbelMaxSketch)> {
    let mut entries = decode_store(bytes)?;
    anyhow::ensure!(
        entries.len() == 1,
        "expected exactly one sketch in the blob, got {}",
        entries.len()
    );
    Ok(entries.pop().expect("one entry"))
}

/// [`encode_sketch_bytes`] wrapped in lowercase hex — the JSON-lines wire
/// form of a codec blob.
pub fn encode_sketch_hex(key: &str, version: u64, sk: &GumbelMaxSketch) -> String {
    to_hex(&encode_sketch_bytes(key, version, sk))
}

/// Decode a blob produced by [`encode_sketch_hex`]; refuses blobs that do
/// not hold exactly one entry.
pub fn decode_sketch_hex(text: &str) -> anyhow::Result<(String, u64, GumbelMaxSketch)> {
    decode_sketch_bytes(&from_hex(text)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{SparseVector, EMPTY_REGISTER};

    fn sample() -> Vec<(String, u64, GumbelMaxSketch)> {
        let mut a = GumbelMaxSketch::empty(Family::Ordered, 42, 4);
        a.y[1] = 0.125;
        a.s[1] = u64::MAX - 1; // above 2^53: binary stays exact
        let b = crate::sketch::fastgm::FastGm::new(8, 7)
            .sketch(&SparseVector::new(vec![1, 2, 3], vec![1.0, 0.5, 2.0]));
        // One pre-versioning entry (0) and one with a large write version.
        vec![("alpha".into(), 0, a), ("βeta".into(), u64::MAX - 3, b)]
    }

    /// Patch bytes and keep the trailing checksum consistent, so structural
    /// errors (not the checksum) are what the decoder reports.
    fn with_checksum_refreshed(mut bytes: Vec<u8>) -> Vec<u8> {
        let n = bytes.len();
        let sum = fnv1a64(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        bytes
    }

    /// Hand-rolled v1 layout (no per-entry version field) — what pre-v2
    /// builds wrote. Kept here so v1 decode compatibility is tested against
    /// the real byte layout, not against this build's encoder.
    fn encode_v1(entries: &[(String, GumbelMaxSketch)]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        push_u16(&mut out, 1);
        push_u16(&mut out, 0);
        push_u64(&mut out, entries.len() as u64);
        for (key, sk) in entries {
            push_u32(&mut out, key.len() as u32);
            out.extend_from_slice(key.as_bytes());
            out.push(family_tag(sk.family));
            push_u64(&mut out, sk.seed);
            push_u64(&mut out, sk.k() as u64);
            for &y in &sk.y {
                push_u64(&mut out, y.to_bits());
            }
            for &s in &sk.s {
                push_u64(&mut out, s);
            }
        }
        let checksum = fnv1a64(&out);
        push_u64(&mut out, checksum);
        out
    }

    #[test]
    fn roundtrip_is_bit_identical() {
        let entries = sample();
        let bytes = encode_store(&entries);
        let back = decode_store(&bytes).unwrap();
        assert_eq!(back, entries);
        // Untouched registers survive exactly; versions too.
        assert!(back[0].2.y[0].is_infinite());
        assert_eq!(back[0].2.s[0], EMPTY_REGISTER);
        assert_eq!(back[1].1, u64::MAX - 3);
        // Deterministic encoding.
        assert_eq!(bytes, encode_store(&back));
    }

    #[test]
    fn empty_store_roundtrips() {
        let bytes = encode_store(&[]);
        assert_eq!(decode_store(&bytes).unwrap(), vec![]);
    }

    /// A v1 snapshot (pre-versioning layout) still decodes; entries come
    /// back with version 0, superseded by any v2-era write.
    #[test]
    fn v1_snapshots_decode_with_version_zero() {
        let v1_entries: Vec<(String, GumbelMaxSketch)> =
            sample().into_iter().map(|(k, _, sk)| (k, sk)).collect();
        let bytes = encode_v1(&v1_entries);
        let back = decode_store(&bytes).unwrap();
        assert_eq!(back.len(), v1_entries.len());
        for ((k1, sk1), (k2, v2, sk2)) in v1_entries.iter().zip(&back) {
            assert_eq!(k1, k2);
            assert_eq!(*v2, 0, "v1 entries must surface as version 0");
            assert_eq!(sk1, sk2, "v1 registers must round-trip bit-identically");
        }
        // v1 is as strictly checked as v2: every truncation fails clean.
        for len in 0..bytes.len() {
            assert!(decode_store(&bytes[..len]).is_err(), "v1 prefix {len} decoded");
        }
    }

    #[test]
    fn every_truncation_is_a_clean_error() {
        let bytes = encode_store(&sample());
        for len in 0..bytes.len() {
            assert!(
                decode_store(&bytes[..len]).is_err(),
                "prefix of {len}/{} bytes decoded",
                bytes.len()
            );
        }
    }

    #[test]
    fn every_single_byte_corruption_is_detected() {
        let bytes = encode_store(&sample());
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x01;
            assert!(decode_store(&bad).is_err(), "flip at byte {i} went unnoticed");
        }
    }

    #[test]
    fn version_and_magic_mismatches_are_named() {
        let bytes = encode_store(&sample());
        let mut wrong_version = bytes.clone();
        wrong_version[4] = 99; // version lives after the 4-byte magic
        let err = decode_store(&with_checksum_refreshed(wrong_version)).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");

        let mut too_old = bytes.clone();
        too_old[4] = 0; // v0 never existed; below MIN_VERSION
        assert!(decode_store(&with_checksum_refreshed(too_old)).is_err());

        let mut wrong_magic = bytes.clone();
        wrong_magic[0] = b'X';
        let err = decode_store(&with_checksum_refreshed(wrong_magic)).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");

        let mut bad_family = bytes;
        // First entry: 16 header bytes, 4-byte key length, "alpha"
        // (5 bytes), 8-byte entry version.
        let fam_off = 16 + 4 + 5 + 8;
        bad_family[fam_off] = 42;
        let err = decode_store(&with_checksum_refreshed(bad_family)).unwrap_err();
        assert!(err.to_string().contains("family tag 42"), "{err}");
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut bytes = encode_store(&sample());
        let tail_at = bytes.len() - 8;
        bytes.splice(tail_at..tail_at, [0u8; 3]);
        let err = decode_store(&with_checksum_refreshed(bytes)).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn sketch_hex_roundtrips_bit_identically() {
        for (key, version, sk) in sample() {
            let blob = encode_sketch_hex(&key, version, &sk);
            assert!(blob.bytes().all(|b| b.is_ascii_hexdigit()));
            assert!(blob.starts_with(&to_hex(&MAGIC)), "blob must open with the magic");
            let (back_key, back_version, back) = decode_sketch_hex(&blob).unwrap();
            assert_eq!(back_key, key);
            assert_eq!(back_version, version);
            assert_eq!(back, sk);
        }
    }

    #[test]
    fn sketch_hex_rejects_garbage_and_multi_entry_blobs() {
        assert!(decode_sketch_hex("zz").is_err()); // non-hex
        assert!(decode_sketch_hex("abc").is_err()); // odd length
        assert!(decode_sketch_hex("deadbeef").is_err()); // not a snapshot
        // A two-entry store snapshot is valid codec but not a single-sketch
        // blob.
        let blob = to_hex(&encode_store(&sample()));
        let err = decode_sketch_hex(&blob).unwrap_err().to_string();
        assert!(err.contains("exactly one sketch"), "{err}");
        // A corrupted blob fails the checksum, not the hex layer.
        let mut bad = encode_sketch_hex("a", 3, &sample()[0].2);
        let flip = bad.len() / 2;
        let orig = bad.as_bytes()[flip];
        bad.replace_range(flip..flip + 1, if orig == b'0' { "1" } else { "0" });
        assert!(decode_sketch_hex(&bad).is_err());
    }

    #[test]
    fn sketch_bytes_roundtrip_and_match_the_hex_form() {
        for (key, version, sk) in sample() {
            let bytes = encode_sketch_bytes(&key, version, &sk);
            assert_eq!(to_hex(&bytes), encode_sketch_hex(&key, version, &sk));
            let (bk, bv, bsk) = decode_sketch_bytes(&bytes).unwrap();
            assert_eq!((bk, bv, bsk), (key, version, sk));
        }
        // Multi-entry blobs are refused at the byte level too.
        assert!(decode_sketch_bytes(&encode_store(&sample())).is_err());
    }

    #[test]
    fn hex_roundtrip_and_case_insensitivity() {
        let bytes: Vec<u8> = (0..=255u8).collect();
        let hex = to_hex(&bytes);
        assert_eq!(from_hex(&hex).unwrap(), bytes);
        assert_eq!(from_hex(&hex.to_uppercase()).unwrap(), bytes);
        assert_eq!(to_hex(&[]), "");
        assert_eq!(from_hex("").unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn oversized_length_fields_do_not_allocate() {
        // count claims entries the buffer cannot hold → truncation error,
        // not an attempted huge allocation.
        let mut bytes = encode_store(&[]);
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_store(&with_checksum_refreshed(bytes)).is_err());
    }
}
