//! Ascending exponential generation — the building block of FastGM.
//!
//! For an element `i` with weight `v_i`, the k race variables
//! `b_{i,1..k} ~ EXP(v_i)` are produced **in ascending order** via Rényi's
//! order-statistics recurrence (Eq. 7/8 of the paper):
//!
//! ```text
//!   b_(z) = b_(z-1) + ( -ln u_z ) / ( v_i · (k - z + 1) ),   b_(0) = 0
//! ```
//!
//! paired with a *streamed* Fisher–Yates shuffle that assigns each arrival a
//! distinct register ("server") uniformly at random. The resulting stream of
//! `(arrival_time, register)` tuples is the queue `Q_i` of the paper's
//! k-server/n-queue model. Draws come from a [`SplitMix64`] stream keyed by
//! `(seed, element)`, so every vector containing element `i` sees the same
//! queue — the consistency property Gumbel-Max sketches require.
//!
//! The permutation is held *lazily* ([`LazyPerm`]): only the entries touched
//! by a swap are stored, so an element that releases `R_i ≪ k` customers
//! costs `O(R_i)` memory instead of the `O(k)` of a materialized array (an
//! improvement over the paper's `n⁺·k·log k`-bit bookkeeping; the §Perf
//! comments below record the measurements that drove it).

use crate::util::rng::SplitMix64;

/// Tiny open-addressing u32→u32 map (linear probing, power-of-two
/// capacity). `std::collections::HashMap`'s SipHash dominated the race's
/// per-release cost (§Perf log: ~2× whole-sketch speedup from this swap);
/// a multiply-shift hash over u32 keys is all the permutation override
/// table needs.
#[derive(Debug, Clone)]
struct U32Map {
    // keys[i] == u32::MAX means empty (k < 2^32-1 always holds here).
    keys: Vec<u32>,
    vals: Vec<u32>,
    len: usize,
}

const EMPTY_KEY: u32 = u32::MAX;

impl U32Map {
    fn new() -> Self {
        // A map is only built once the inline slots spill, i.e. the queue
        // is releasing many customers — start at 64 to avoid regrow churn
        // (grow() was 9% of the stream profile at capacity 8).
        U32Map { keys: vec![EMPTY_KEY; 64], vals: vec![0; 64], len: 0 }
    }

    #[inline(always)]
    fn slot(&self, key: u32) -> usize {
        // Fibonacci multiply-shift; table length is a power of two.
        let h = key.wrapping_mul(0x9E37_79B1);
        (h as usize) & (self.keys.len() - 1)
    }

    #[inline]
    fn get(&self, key: u32) -> Option<u32> {
        let mask = self.keys.len() - 1;
        let mut i = self.slot(key);
        loop {
            let k = self.keys[i];
            if k == key {
                return Some(self.vals[i]);
            }
            if k == EMPTY_KEY {
                return None;
            }
            i = (i + 1) & mask;
        }
    }

    #[inline]
    fn insert(&mut self, key: u32, val: u32) {
        if self.len * 4 >= self.keys.len() * 3 {
            self.grow();
        }
        let mask = self.keys.len() - 1;
        let mut i = self.slot(key);
        loop {
            let k = self.keys[i];
            if k == key {
                self.vals[i] = val;
                return;
            }
            if k == EMPTY_KEY {
                self.keys[i] = key;
                self.vals[i] = val;
                self.len += 1;
                return;
            }
            i = (i + 1) & mask;
        }
    }

    fn grow(&mut self) {
        let old_keys = std::mem::replace(&mut self.keys, vec![EMPTY_KEY; 0]);
        let old_vals = std::mem::take(&mut self.vals);
        self.keys = vec![EMPTY_KEY; old_keys.len() * 2];
        self.vals = vec![0; old_keys.len() * 2];
        self.len = 0;
        for (k, v) in old_keys.into_iter().zip(old_vals) {
            if k != EMPTY_KEY {
                self.insert(k, v);
            }
        }
    }

}

/// Inline capacity before spilling to the heap map. Under FastPrune most
/// queues release only a handful of customers (one override each), so the
/// common case allocates nothing (§Perf log).
const INLINE_CAP: usize = 12;

/// A lazily materialized Fisher–Yates permutation of `0..k`.
///
/// Conceptually `perm` starts as the identity; `swap_take(z, j)` performs
/// `swap(perm[z], perm[j])` and returns the new `perm[z]`. Position `z` is
/// never revisited (the stream only advances), so only the override at `j`
/// is recorded — inline for the first [`INLINE_CAP`] overrides, then in a
/// [`U32Map`].
#[derive(Debug, Clone)]
pub struct LazyPerm {
    inline: [(u32, u32); INLINE_CAP],
    inline_len: usize,
    spill: Option<Box<U32Map>>,
}

impl LazyPerm {
    pub fn new() -> Self {
        LazyPerm { inline: [(EMPTY_KEY, 0); INLINE_CAP], inline_len: 0, spill: None }
    }

    #[inline]
    fn get(&self, i: u32) -> u32 {
        for &(k, v) in &self.inline[..self.inline_len] {
            if k == i {
                return v;
            }
        }
        if let Some(m) = &self.spill {
            if let Some(v) = m.get(i) {
                return v;
            }
        }
        i
    }

    #[inline]
    fn set(&mut self, key: u32, val: u32) {
        for slot in &mut self.inline[..self.inline_len] {
            if slot.0 == key {
                slot.1 = val;
                return;
            }
        }
        if self.spill.is_none() && self.inline_len < INLINE_CAP {
            self.inline[self.inline_len] = (key, val);
            self.inline_len += 1;
            return;
        }
        self.spill.get_or_insert_with(|| Box::new(U32Map::new())).insert(key, val);
    }

    /// Swap positions `z` and `j` (`z <= j`) and return the value landing
    /// at `z`.
    #[inline]
    pub fn swap_take(&mut self, z: u32, j: u32) -> u32 {
        let vj = self.get(j);
        if z != j {
            let vz = self.get(z);
            self.set(j, vz);
        }
        // Position z is consumed and never read again; stale entries at z
        // are harmless (future probes only touch indices > z).
        vj
    }

    pub fn touched(&self) -> usize {
        self.inline_len + self.spill.as_ref().map(|m| m.len).unwrap_or(0)
    }

    /// Back to the identity permutation. A spill map is *dropped*, not
    /// kept: retaining it would disable the inline fast path for the rest
    /// of the slot's lifetime (`set` only uses the inline array while no
    /// spill exists) and cost an `EMPTY_KEY` fill across the grown
    /// capacity on every reset. Spilling is the rare case (> INLINE_CAP
    /// overrides in one race), so re-allocating on the next spill is
    /// cheaper than poisoning every small reuse. A cleared [`LazyPerm`] is
    /// indistinguishable from a new one.
    pub fn clear(&mut self) {
        self.inline_len = 0;
        self.spill = None;
    }
}

impl Default for LazyPerm {
    fn default() -> Self {
        Self::new()
    }
}

/// The ascending race stream (queue `Q_i`) for one positive element.
#[derive(Debug, Clone)]
pub struct ElementRace {
    rng: SplitMix64,
    inv_w: f64,
    k: u32,
    /// Customers released so far (`z_i` in Algorithm 1).
    pub z: u32,
    /// Current arrival time (`b_i` in Algorithm 1).
    pub b: f64,
    perm: LazyPerm,
}

impl ElementRace {
    /// Queue for element `id` with weight `w > 0` under sketch `seed`.
    pub fn new(seed: u64, id: u64, w: f64, k: usize) -> Self {
        debug_assert!(w > 0.0 && w.is_finite());
        ElementRace {
            rng: SplitMix64::for_element(seed, id),
            inv_w: 1.0 / w,
            k: k as u32,
            z: 0,
            b: 0.0,
            perm: LazyPerm::new(),
        }
    }

    /// Re-arm this race for a new `(seed, id, w, k)` in place, reusing the
    /// permutation's buffers. After `reset` the race is bit-identical to
    /// `ElementRace::new(seed, id, w, k)` — the engine property suite
    /// (`rust/tests/engine_props.rs`) locks that in across every sketcher.
    pub fn reset(&mut self, seed: u64, id: u64, w: f64, k: usize) {
        debug_assert!(w > 0.0 && w.is_finite());
        self.rng = SplitMix64::for_element(seed, id);
        self.inv_w = 1.0 / w;
        self.k = k as u32;
        self.z = 0;
        self.b = 0.0;
        self.perm.clear();
    }

    pub fn exhausted(&self) -> bool {
        self.z >= self.k
    }

    /// Release the next customer: `(arrival_time, register)`.
    /// Returns `None` once all k customers have been released.
    #[inline]
    pub fn next(&mut self) -> Option<(f64, u32)> {
        if self.z >= self.k {
            return None;
        }
        let remaining = (self.k - self.z) as f64;
        self.z += 1;
        let u = self.rng.next_f64();
        self.b += self.inv_w * (-u.ln()) / remaining;
        let z0 = self.z - 1;
        let j = self.rng.next_range(z0 as usize, (self.k - 1) as usize) as u32;
        let c = self.perm.swap_take(z0, j);
        Some((self.b, c))
    }

    /// Peek memory used by the lazy permutation (diagnostics).
    pub fn perm_entries(&self) -> usize {
        self.perm.touched()
    }

    /// Drain the remaining stream into `(time, register)` tuples (testing
    /// and the brute-force oracle).
    pub fn drain(mut self) -> Vec<(f64, u32)> {
        let mut out = Vec::with_capacity((self.k - self.z) as usize);
        while let Some(t) = self.next() {
            out.push(t);
        }
        out
    }
}

/// Brute-force oracle: the exact Ordered-family sketch registers obtained by
/// fully draining every element's queue. `O(n⁺·k)` — used by tests and as
/// the reference implementation FastGM must match bit-for-bit.
pub fn oracle_registers(
    seed: u64,
    elements: &[(u64, f64)],
    k: usize,
) -> (Vec<f64>, Vec<u64>) {
    let mut y = vec![f64::INFINITY; k];
    let mut s = vec![super::EMPTY_REGISTER; k];
    for &(id, w) in elements {
        if w <= 0.0 {
            continue;
        }
        for (t, c) in ElementRace::new(seed, id, w, k).drain() {
            let c = c as usize;
            if t < y[c] {
                y[c] = t;
                s[c] = id;
            }
        }
    }
    (y, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall_explain;
    use crate::util::stats::OnlineStats;

    #[test]
    fn race_emits_k_ascending_arrivals() {
        let race = ElementRace::new(7, 42, 0.5, 64);
        let ts = race.drain();
        assert_eq!(ts.len(), 64);
        for w in ts.windows(2) {
            assert!(w[0].0 < w[1].0, "arrivals must be strictly ascending");
        }
    }

    #[test]
    fn race_registers_form_permutation() {
        forall_explain(
            50,
            |r| (r.next_u64(), r.next_u64(), r.next_f64() + 0.01, r.next_range(1, 128)),
            |&(seed, id, w, k)| {
                let race = ElementRace::new(seed, id, w, k);
                let mut regs: Vec<u32> = race.drain().into_iter().map(|(_, c)| c).collect();
                regs.sort_unstable();
                let want: Vec<u32> = (0..k as u32).collect();
                if regs == want {
                    Ok(())
                } else {
                    Err(format!("registers not a permutation of 0..{k}: {regs:?}"))
                }
            },
        );
    }

    #[test]
    fn race_is_deterministic_per_element() {
        let a = ElementRace::new(9, 5, 0.3, 32).drain();
        let b = ElementRace::new(9, 5, 0.3, 32).drain();
        assert_eq!(a, b);
        let c = ElementRace::new(10, 5, 0.3, 32).drain();
        assert_ne!(a, c);
    }

    /// Each register's value across the stream is an EXP(w) variable: check
    /// the distribution of per-register values (register j's arrival is one
    /// of the k iid EXP(w) draws, shuffled).
    #[test]
    fn register_values_are_exp_w() {
        let w = 2.5;
        let k = 16;
        let mut stats = OnlineStats::new();
        for id in 0..4000u64 {
            for (t, _) in ElementRace::new(1, id, w, k).drain() {
                stats.push(t);
            }
        }
        // Mean of EXP(w) is 1/w; the pooled per-register values are exactly
        // the k iid draws.
        assert!((stats.mean() - 1.0 / w).abs() < 0.01, "mean={}", stats.mean());
        assert!((stats.var() - 1.0 / (w * w)).abs() < 0.02, "var={}", stats.var());
    }

    /// First arrival of the queue is the min of k EXP(w) = EXP(k·w).
    #[test]
    fn first_arrival_is_exp_kw() {
        let w = 0.7;
        let k = 32;
        let mut stats = OnlineStats::new();
        for id in 0..20_000u64 {
            let mut race = ElementRace::new(2, id, w, k);
            stats.push(race.next().unwrap().0);
        }
        let want = 1.0 / (k as f64 * w);
        assert!(
            (stats.mean() - want).abs() < want * 0.05,
            "mean={} want={want}",
            stats.mean()
        );
    }

    #[test]
    fn lazy_perm_matches_dense_fisher_yates() {
        forall_explain(
            100,
            |r| (r.next_u64(), r.next_range(1, 64)),
            |&(seed, k)| {
                // Dense reference.
                let mut rng = SplitMix64::new(seed);
                let mut dense: Vec<u32> = (0..k as u32).collect();
                let mut picks_dense = Vec::new();
                for z in 0..k {
                    let _u = rng.next_f64(); // mirror the race's draw order
                    let j = rng.next_range(z, k - 1);
                    dense.swap(z, j);
                    picks_dense.push(dense[z]);
                }
                // Lazy version with the same RNG stream.
                let mut rng = SplitMix64::new(seed);
                let mut lazy = LazyPerm::new();
                let mut picks_lazy = Vec::new();
                for z in 0..k {
                    let _u = rng.next_f64();
                    let j = rng.next_range(z, k - 1);
                    picks_lazy.push(lazy.swap_take(z as u32, j as u32));
                }
                if picks_dense == picks_lazy {
                    Ok(())
                } else {
                    Err(format!("dense {picks_dense:?} != lazy {picks_lazy:?}"))
                }
            },
        );
    }

    /// A reset race must replay exactly the stream of a fresh one, even
    /// after the previous use spilled the permutation to the heap map.
    #[test]
    fn reset_race_equals_fresh_race() {
        forall_explain(
            50,
            |r| {
                (
                    r.next_u64(),
                    r.next_u64(),
                    r.next_f64() + 0.01,
                    r.next_range(1, 96),
                    r.next_u64(),
                    r.next_range(1, 96),
                )
            },
            |&(seed, id, w, k, id2, k2)| {
                // Dirty the race on (id2, k2) first — fully drained so the
                // lazy permutation accumulates overrides (and may spill).
                let mut race = ElementRace::new(seed ^ 1, id2, 0.5, k2);
                while race.next().is_some() {}
                race.reset(seed, id, w, k);
                let mut reused = Vec::new();
                while let Some(t) = race.next() {
                    reused.push(t);
                }
                let fresh = ElementRace::new(seed, id, w, k).drain();
                if reused == fresh {
                    Ok(())
                } else {
                    Err(format!("reset race diverged from fresh at k={k}"))
                }
            },
        );
    }

    #[test]
    fn oracle_monotone_under_more_elements() {
        // Adding elements can only lower register values.
        let a = oracle_registers(3, &[(1, 0.5), (2, 0.1)], 32);
        let b = oracle_registers(3, &[(1, 0.5), (2, 0.1), (3, 1.0)], 32);
        for j in 0..32 {
            assert!(b.0[j] <= a.0[j]);
        }
    }

    #[test]
    fn oracle_ignores_nonpositive_weights() {
        let a = oracle_registers(3, &[(1, 0.5), (9, 0.0), (10, -2.0)], 16);
        let b = oracle_registers(3, &[(1, 0.5)], 16);
        assert_eq!(a, b);
    }
}
