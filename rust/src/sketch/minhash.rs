//! Classic binary MinHash (Broder et al.) — the unweighted ancestor of the
//! whole sketch family, kept as a substrate/related-work baseline and used
//! by the LSH tests as the binary-vector special case.

use crate::util::rng::{fmix64, SplitMix64};
use super::engine::SketchScratch;
use super::{Family, GumbelMaxSketch, Sketcher, SparseVector};

const MINHASH_SALT: u64 = 0x3141_5926_5358_9793;

#[derive(Debug, Clone, PartialEq)]
pub struct MinHashSketch {
    pub seed: u64,
    /// Per-register minimal hash values.
    pub h: Vec<u64>,
    /// Per-register argmin element ids.
    pub s: Vec<u64>,
}

impl MinHashSketch {
    /// Estimate set resemblance (binary Jaccard) by match fraction.
    pub fn resemblance(&self, other: &MinHashSketch) -> f64 {
        assert_eq!(self.seed, other.seed);
        assert_eq!(self.h.len(), other.h.len());
        let m = self.h.iter().zip(&other.h).filter(|(a, b)| a == b).count();
        m as f64 / self.h.len() as f64
    }

    pub fn merge(&self, other: &MinHashSketch) -> MinHashSketch {
        assert_eq!(self.seed, other.seed);
        let mut out = self.clone();
        for j in 0..out.h.len() {
            if other.h[j] < out.h[j] {
                out.h[j] = other.h[j];
                out.s[j] = other.s[j];
            }
        }
        out
    }
}

#[derive(Debug, Clone)]
pub struct MinHash {
    pub k: usize,
    pub seed: u64,
}

impl MinHash {
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 1);
        MinHash { k, seed }
    }

    pub fn sketch_ids<'a>(&self, ids: impl IntoIterator<Item = &'a u64>) -> MinHashSketch {
        let mut h = vec![u64::MAX; self.k];
        let mut s = vec![u64::MAX; self.k];
        for &id in ids {
            // k register hashes from one SplitMix64 stream per element.
            let mut rng = SplitMix64::new(fmix64(id ^ MINHASH_SALT) ^ self.seed);
            for j in 0..self.k {
                let v = rng.next_u64();
                if v < h[j] {
                    h[j] = v;
                    s[j] = id;
                }
            }
        }
        MinHashSketch { seed: self.seed, h, s }
    }
}

impl Sketcher for MinHash {
    fn name(&self) -> &'static str {
        "minhash"
    }

    fn family(&self) -> Family {
        Family::MinHash
    }

    fn k(&self) -> usize {
        self.k
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    /// Binary MinHash over the *support set* of `v` (positive-weight ids;
    /// weights are otherwise ignored). Register hashes land in `y` projected
    /// to `[0, 1)` via their top 53 bits, so match-fraction estimation over
    /// the common registers behaves exactly like [`MinHashSketch`] (ties in
    /// the low 11 bits are the only — astronomically rare — divergence).
    fn sketch_into(&self, v: &SparseVector, _scratch: &mut SketchScratch, out: &mut GumbelMaxSketch) {
        out.reset(Family::MinHash, self.seed, self.k);
        for (id, _w) in v.positive() {
            let mut rng = SplitMix64::new(fmix64(id ^ MINHASH_SALT) ^ self.seed);
            for j in 0..self.k {
                let y = (rng.next_u64() >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0);
                if y < out.y[j] {
                    out.y[j] = y;
                    out.s[j] = id;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::OnlineStats;

    #[test]
    fn resemblance_estimator_unbiased() {
        // |A∩B| = 2, |A∪B| = 4 → J = 0.5.
        let a = vec![1u64, 2, 3];
        let b = vec![2u64, 3, 4];
        let mut stats = OnlineStats::new();
        for seed in 0..100u64 {
            let mh = MinHash::new(64, seed);
            stats.push(mh.sketch_ids(&a).resemblance(&mh.sketch_ids(&b)));
        }
        assert!((stats.mean() - 0.5).abs() < 0.02, "mean={}", stats.mean());
    }

    #[test]
    fn merge_is_union() {
        let mh = MinHash::new(32, 9);
        let a = vec![1u64, 2];
        let b = vec![3u64, 4];
        let ab = vec![1u64, 2, 3, 4];
        assert_eq!(mh.sketch_ids(&a).merge(&mh.sketch_ids(&b)), mh.sketch_ids(&ab));
    }

    #[test]
    fn disjoint_sets_rarely_match() {
        let mh = MinHash::new(256, 1);
        let a: Vec<u64> = (0..50).collect();
        let b: Vec<u64> = (100..150).collect();
        assert!(mh.sketch_ids(&a).resemblance(&mh.sketch_ids(&b)) < 0.05);
    }
}
