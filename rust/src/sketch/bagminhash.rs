//! BagMinHash — weighted minwise hashing (Ertl, KDD'18), the paper's
//! Task-1 efficiency baseline for weighted Jaccard similarity `J_W`.
//!
//! The construction views each element `d` as a region `(0, w_d)` on a
//! weight axis and runs a Poisson point process of intensity `k` per unit
//! (weight × time), each point carrying a uniform register mark. Register
//! `j`'s value for `(d, w)` is the earliest point of `d` with height `< w`
//! and mark `j` — an `EXP(w)` variable that is *monotonically coupled
//! across weights*, which is exactly what the minwise property
//! `P(signature match) = J_W` requires (our first simplified version
//! dropped that coupling and the unbiasedness test caught it).
//!
//! As in Ertl's algorithm the weight axis is cut into dyadic strips
//! `[2^L, 2^{L+1})` so point generation is weight-independent: each strip
//! has its own deterministic point stream per element, emitted in ascending
//! time, and a query weight `w` simply *thins* points with height `≥ w`.
//! A segment-tree max tracker (Ertl's "binary tree of maxima") provides the
//! stop bound; strips are processed top-down and abandoned once their
//! residual point probability is negligible (rate halves per level).

use crate::util::rng::SplitMix64;
use super::engine::SketchScratch;
use super::{Family, GumbelMaxSketch, Sketcher, SparseVector};

/// Domain separation from the Ordered family streams.
const BAG_SALT: u64 = 0xBA61_14A5_11D5_0B1E;

/// How many dyadic strips below the top strip to visit. Strip L's expected
/// useful points decay as `k·2^L·y*`; 48 halvings puts the residual below
/// 2^-48·k·y* — negligible for every workload here.
const STRIP_DEPTH: i32 = 48;

/// Segment tree over register values supporting point update + global max —
/// the "binary tree of maxima" of the original algorithm.
#[derive(Debug, Clone)]
pub struct MaxTracker {
    n: usize,
    tree: Vec<f64>,
}

impl MaxTracker {
    pub fn new(n: usize, init: f64) -> Self {
        MaxTracker { n, tree: vec![init; 2 * n] }
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: f64) {
        let mut idx = self.n + i;
        self.tree[idx] = v;
        while idx > 1 {
            idx /= 2;
            let m = self.tree[2 * idx].max(self.tree[2 * idx + 1]);
            if self.tree[idx] == m {
                break;
            }
            self.tree[idx] = m;
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        self.tree[self.n + i]
    }

    #[inline]
    pub fn max(&self) -> f64 {
        self.tree[1]
    }

    /// Number of leaves.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Reset every leaf (and internal max) to `init`, keeping the
    /// allocation — indistinguishable from `MaxTracker::new(self.n, init)`.
    pub fn reset(&mut self, init: f64) {
        self.tree.fill(init);
    }
}

/// A BagMinHash signature: a view over the common Gumbel-Max registers
/// tagged [`Family::Bag`]. It estimates `J_W`, not `J_P`, and its race
/// values are consistent only with other BagMinHash sketches — the family
/// tag makes cross-family estimation a loud error instead of a silent bias.
#[derive(Debug, Clone, PartialEq)]
pub struct BagSketch {
    pub base: GumbelMaxSketch,
}

impl BagSketch {
    pub fn seed(&self) -> u64 {
        self.base.seed
    }

    /// Estimate weighted Jaccard `J_W` by register match fraction.
    pub fn estimate_jw(&self, other: &BagSketch) -> f64 {
        assert_eq!(self.base.seed, other.base.seed, "BagMinHash seeds must match");
        assert_eq!(self.base.k(), other.base.k());
        let k = self.base.k();
        let m = (0..k)
            .filter(|&j| {
                self.base.s[j] == other.base.s[j] && self.base.y[j] == other.base.y[j]
            })
            .count();
        m as f64 / k as f64
    }
}

#[derive(Debug, Clone)]
pub struct BagMinHash {
    pub k: usize,
    pub seed: u64,
}

impl BagMinHash {
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 1);
        BagMinHash { k, seed }
    }

    /// Sketch and return the number of Poisson points generated (the work
    /// counter the Fig. 4/5 efficiency comparison reports).
    pub fn sketch_counted(&self, v: &SparseVector) -> (BagSketch, u64) {
        let mut scratch = SketchScratch::new();
        let mut base = GumbelMaxSketch::empty(Family::Bag, self.seed, self.k);
        let points = self.sketch_counted_into(v, &mut scratch, &mut base);
        (BagSketch { base }, points)
    }

    /// The signature without the work counter.
    pub fn sketch_bag(&self, v: &SparseVector) -> BagSketch {
        self.sketch_counted(v).0
    }

    /// Allocation-free core: registers into `out`, stop bounds through the
    /// scratch's reusable [`MaxTracker`]. Returns the points generated.
    pub fn sketch_counted_into(
        &self,
        v: &SparseVector,
        scratch: &mut SketchScratch,
        out: &mut GumbelMaxSketch,
    ) -> u64 {
        let k = self.k;
        out.reset(Family::Bag, self.seed, k);
        let y = &mut out.y;
        let s = &mut out.s;
        let tracker = scratch.bag_tracker_mut(k, f64::INFINITY);
        let mut points = 0u64;

        for (id, w) in v.positive() {
            // Top strip: the dyadic strip containing w.
            let top = w.log2().floor() as i32;
            for l in (top - STRIP_DEPTH..=top).rev() {
                let lo = 2f64.powi(l);
                let hi = 2f64.powi(l + 1);
                if lo >= w {
                    continue; // strip entirely above the weight
                }
                // Skip strips whose first point is virtually certain to
                // exceed the stop bound: P ≈ k·(hi−lo)·y* (points ascend and
                // rates halve per level, so all lower strips are smaller).
                let bound = tracker.max();
                if bound.is_finite() && k as f64 * (hi - lo) * bound < 1e-6 {
                    break; // safe: lower strips have halving widths
                }
                // Deterministic per (element, strip): thinning by `h < w`
                // reads a prefix of the same stream for every query weight.
                let mut rng =
                    SplitMix64::for_element(self.seed ^ BAG_SALT, id ^ ((l as u64) << 40));
                let rate = k as f64 * (hi - lo);
                let mut t = 0.0f64;
                loop {
                    t += rng.next_exp() / rate;
                    points += 1;
                    if t > tracker.max() {
                        break;
                    }
                    let h = lo + rng.next_f64() * (hi - lo);
                    let j = rng.next_range(0, k - 1);
                    if h >= w {
                        continue; // thinned: point above this vector's weight
                    }
                    if t < y[j] {
                        y[j] = t;
                        s[j] = id;
                        tracker.set(j, t);
                    }
                }
            }
        }
        points
    }
}

impl Sketcher for BagMinHash {
    fn name(&self) -> &'static str {
        "bagminhash"
    }

    fn family(&self) -> Family {
        Family::Bag
    }

    fn k(&self) -> usize {
        self.k
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn sketch_into(&self, v: &SparseVector, scratch: &mut SketchScratch, out: &mut GumbelMaxSketch) {
        self.sketch_counted_into(v, scratch, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimate::jaccard::weighted_jaccard;
    use crate::sketch::EMPTY_REGISTER;
    use crate::util::rng::SplitMix64;
    use crate::util::stats::OnlineStats;

    #[test]
    fn max_tracker_matches_naive() {
        let mut t = MaxTracker::new(7, f64::INFINITY);
        let mut naive = vec![f64::INFINITY; 7];
        let mut r = SplitMix64::new(1);
        for _ in 0..500 {
            let i = r.next_range(0, 6);
            let v = r.next_f64();
            if v < naive[i] {
                naive[i] = v;
                t.set(i, v);
            }
            let want = naive.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(t.max(), want);
        }
    }

    #[test]
    fn registers_fill_and_are_deterministic() {
        let v = SparseVector::new(vec![1, 2, 3], vec![1.0, 0.5, 2.0]);
        let a = BagMinHash::new(64, 9).sketch(&v);
        let b = BagMinHash::new(64, 9).sketch(&v);
        assert_eq!(a, b);
        assert!(a.y.iter().all(|y| y.is_finite()));
        assert!(a.s.iter().all(|&s| s != EMPTY_REGISTER));
    }

    #[test]
    fn self_similarity_is_one() {
        let v = SparseVector::new(vec![5, 6], vec![0.3, 0.9]);
        let a = BagMinHash::new(32, 2).sketch_bag(&v);
        assert_eq!(a.estimate_jw(&a), 1.0);
        // The view exposes exactly the trait's common registers.
        assert_eq!(a.base, BagMinHash::new(32, 2).sketch(&v));
        assert_eq!(a.base.family, Family::Bag);
    }

    /// The monotone weight coupling: raising one element's weight can only
    /// lower (or keep) each register value, never change others' values.
    #[test]
    fn weight_coupling_is_monotone() {
        let u = SparseVector::new(vec![5, 6], vec![0.3, 0.9]);
        let v = SparseVector::new(vec![5, 6], vec![0.3, 1.7]);
        let bm = BagMinHash::new(64, 11);
        let su = bm.sketch(&u);
        let sv = bm.sketch(&v);
        for j in 0..64 {
            assert!(sv.y[j] <= su.y[j], "register {j} not monotone");
            if su.s[j] == 5 && sv.s[j] == 5 {
                assert_eq!(su.y[j], sv.y[j], "untouched element's value changed");
            }
        }
    }

    /// Unbiasedness of the J_W estimator — including shared elements whose
    /// weights DIFFER across the two vectors (the case that requires the
    /// strip construction).
    #[test]
    fn jw_estimator_is_unbiased() {
        let u = SparseVector::new(vec![1, 2, 3, 4], vec![1.0, 2.0, 0.0, 1.0]);
        let v = SparseVector::new(vec![1, 2, 3, 4], vec![2.0, 2.0, 1.0, 0.0]);
        let truth = weighted_jaccard(&u, &v); // (1+2)/(2+2+1+1) = 0.5
        let mut stats = OnlineStats::new();
        for seed in 0..120u64 {
            let bm = BagMinHash::new(64, seed);
            stats.push(bm.sketch_bag(&u).estimate_jw(&bm.sketch_bag(&v)));
        }
        assert!(
            (stats.mean() - truth).abs() < 0.03,
            "mean={} truth={truth}",
            stats.mean()
        );
    }

    /// Work: subquadratic in n·k thanks to the stop bound.
    #[test]
    fn work_counter_subquadratic() {
        let mut r = SplitMix64::new(3);
        let n = 1000;
        let k = 128;
        let v = SparseVector::new(
            (0..n as u64).collect(),
            (0..n).map(|_| r.next_f64() + 0.01).collect(),
        );
        let (_, points) = BagMinHash::new(k, 1).sketch_counted(&v);
        assert!(points < (n * k) as u64 / 4, "points={points}");
    }
}
