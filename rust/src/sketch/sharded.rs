//! Parallel shard-merge sketching — multi-core FastGM via §2.3 mergeability.
//!
//! The union property makes Gumbel-Max sketches exactly combinable: for any
//! partition of a vector's positive entries into shards, the register-wise
//! `merge_all` of the per-shard sketches equals the sketch of the whole
//! vector, **bit for bit** (each element's race stream depends only on
//! `(seed, id)`, and every register value is the min over element arrivals —
//! a min over shard minima). [`ShardedSketcher`] exploits that: it splits a
//! [`SparseVector`] into `P` weight-balanced contiguous shards, sketches
//! them concurrently with [`FastGm`], and merges.
//!
//! Balance: one pass accumulates weight and cuts a shard whenever the
//! running load reaches `total/P`, so each shard's load overshoots the ideal
//! by at most one element's weight. Weight balance (not just count balance)
//! matters because FastSearch's budget schedule releases work in proportion
//! to normalized weight — a shard holding most of the mass would dominate
//! the wall clock.
//!
//! Threading: shards run on a scoped thread team spawned per call, NOT on
//! the coordinator's request [`WorkerPool`](crate::coordinator::worker) —
//! a request handler already executes *on* a pool worker, and fan-out back
//! into the same bounded pool can deadlock once every worker blocks waiting
//! for shard jobs that sit behind it in the queue. Scoped threads keep the
//! fan-out strictly nested and deadlock-free; the coordinator routes only
//! large requests here (see `coordinator::router::Router::plan_sketch`),
//! where the per-shard `O(k ln k)` FastSearch overhead amortizes.
//!
//! The shard merges go through `GumbelMaxSketch::merge_in_place`, i.e. the
//! `sketch::kernels::merge_min_into` lane-wise min kernel — sharding and
//! vectorization compose, and both are bit-preserving.

use super::engine::SketchScratch;
use super::fastgm::FastGm;
use super::{Family, GumbelMaxSketch, Sketcher, SparseVector};

/// FastGM fanned out over `shards` threads and merged (§2.3).
#[derive(Debug, Clone)]
pub struct ShardedSketcher {
    inner: FastGm,
    shards: usize,
}

impl ShardedSketcher {
    pub fn new(k: usize, seed: u64, shards: usize) -> Self {
        assert!(shards >= 1, "shard count must be >= 1");
        ShardedSketcher { inner: FastGm::new(k, seed), shards }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Split `v`'s positive entries into at most `shards` contiguous,
    /// weight-balanced parts (empty parts are dropped; non-positive entries
    /// are ignored, exactly as every sketcher does).
    pub fn partition(v: &SparseVector, shards: usize) -> Vec<SparseVector> {
        let mut parts = Vec::new();
        let used = Self::partition_into(v, shards, &mut parts);
        parts.truncate(used);
        parts
    }

    /// Allocation-reusing partition: writes the parts into `parts[..n]`
    /// (clearing and reusing existing buffers, growing the pool on demand)
    /// and returns `n`. Placement is identical to [`Self::partition`].
    pub fn partition_into(
        v: &SparseVector,
        shards: usize,
        parts: &mut Vec<SparseVector>,
    ) -> usize {
        assert!(shards >= 1);
        let total: f64 = v.total_weight();
        if total <= 0.0 {
            return 0;
        }
        let target = total / shards as f64;
        let mut used = 0usize; // 1-based index of the part being filled
        let mut load = 0.0f64;
        for (id, w) in v.positive() {
            if used == 0 {
                used = 1;
                clear_part(parts, 0);
            }
            parts[used - 1].push(id, w);
            load += w;
            if load >= target && used < shards {
                used += 1;
                clear_part(parts, used - 1);
                load = 0.0;
            }
        }
        // A part opened after the final element stays empty — drop it.
        if used > 0 && parts[used - 1].ids.is_empty() {
            used -= 1;
        }
        used
    }

    /// Sketch `v` across the shard team. Bit-identical to
    /// `FastGm::new(k, seed).sketch(v)` (the property test and
    /// `rust/tests/sharding.rs` lock this).
    pub fn sketch_sharded(&self, v: &SparseVector) -> GumbelMaxSketch {
        self.sketch(v)
    }
}

fn clear_part(parts: &mut Vec<SparseVector>, idx: usize) {
    if parts.len() <= idx {
        parts.push(SparseVector::default());
    } else {
        parts[idx].ids.clear();
        parts[idx].weights.clear();
    }
}

impl Sketcher for ShardedSketcher {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn family(&self) -> Family {
        Family::Ordered
    }

    fn k(&self) -> usize {
        self.inner.k
    }

    fn seed(&self) -> u64 {
        self.inner.seed
    }

    /// Partition into `scratch.parts`, sketch each shard with its own
    /// per-shard sub-scratch (reused across requests), and merge. The shard
    /// team runs on scoped threads exactly as before; only the allocations
    /// are pooled.
    fn sketch_into(&self, v: &SparseVector, scratch: &mut SketchScratch, out: &mut GumbelMaxSketch) {
        let (k, seed) = (self.inner.k, self.inner.seed);
        // Disjoint field borrows: parts (read by shard threads), per-shard
        // scratches and outputs (one &mut each per thread).
        let SketchScratch { parts, shard_scratches, shard_outs, .. } = scratch;
        let nparts = Self::partition_into(v, self.shards, parts);
        match nparts {
            0 => out.reset(Family::Ordered, seed, k),
            1 => {
                if shard_scratches.is_empty() {
                    shard_scratches.push(SketchScratch::new());
                }
                self.inner.sketch_counted_into(&parts[0], &mut shard_scratches[0], out);
            }
            _ => {
                while shard_scratches.len() < nparts {
                    shard_scratches.push(SketchScratch::new());
                }
                while shard_outs.len() < nparts - 1 {
                    shard_outs.push(GumbelMaxSketch::empty(Family::Ordered, seed, k));
                }
                let (first_scratch, rest_scratches) = shard_scratches.split_at_mut(1);
                std::thread::scope(|scope| {
                    for ((p, sc), o) in parts[1..nparts]
                        .iter()
                        .zip(rest_scratches[..nparts - 1].iter_mut())
                        .zip(shard_outs[..nparts - 1].iter_mut())
                    {
                        scope.spawn(move || self.inner.sketch_counted_into(p, sc, o));
                    }
                    // The calling thread takes the first shard itself.
                    self.inner.sketch_counted_into(&parts[0], &mut first_scratch[0], out);
                });
                for o in &shard_outs[..nparts - 1] {
                    out.merge_in_place(o).expect("shard sketches share family/seed/k");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall_explain;
    use crate::util::rng::SplitMix64;

    fn random_vector(r: &mut SplitMix64, max_n: usize) -> SparseVector {
        let n = r.next_range(1, max_n);
        let mut v = SparseVector::default();
        for _ in 0..n {
            // Mix in non-positive weights: partition must skip them too.
            let w = if r.next_f64() < 0.1 {
                -r.next_f64()
            } else {
                r.next_exp() * 10f64.powi(r.next_range(0, 3) as i32 - 1)
            };
            v.push(r.next_u64(), w);
        }
        v
    }

    /// THE tentpole property: sharded == single-threaded FastGM, exactly,
    /// for every shard count.
    #[test]
    fn sharded_equals_fastgm_bit_for_bit() {
        forall_explain(
            40,
            |r| {
                let k = [1, 8, 33, 64][r.next_range(0, 3)];
                let shards = r.next_range(1, 9);
                (r.next_u64(), k, shards, random_vector(r, 120))
            },
            |(seed, k, shards, v)| {
                let single = FastGm::new(*k, *seed).sketch(v);
                let sharded = ShardedSketcher::new(*k, *seed, *shards).sketch(v);
                if single == sharded {
                    Ok(())
                } else {
                    Err(format!("sharded (P={shards}) != single for k={k}"))
                }
            },
        );
    }

    #[test]
    fn partition_is_weight_balanced_and_lossless() {
        forall_explain(
            60,
            |r| (r.next_range(1, 8), random_vector(r, 200)),
            |(shards, v)| {
                let parts = ShardedSketcher::partition(v, *shards);
                // Lossless: the concatenation is exactly the positive entries
                // in order.
                let got: Vec<(u64, f64)> =
                    parts.iter().flat_map(|p| p.positive()).collect();
                let want: Vec<(u64, f64)> = v.positive().collect();
                if got != want {
                    return Err("partition lost or reordered entries".into());
                }
                if parts.len() > *shards {
                    return Err(format!("{} parts for P={shards}", parts.len()));
                }
                // Balance: every shard's load ≤ ideal + its heaviest element.
                let total = v.total_weight();
                if total > 0.0 {
                    let target = total / *shards as f64;
                    for p in &parts {
                        let load = p.total_weight();
                        let heaviest =
                            p.positive().map(|(_, w)| w).fold(0.0f64, f64::max);
                        if load > target + heaviest + 1e-9 {
                            return Err(format!(
                                "shard load {load} exceeds target {target} + max {heaviest}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_and_nonpositive_vectors_yield_empty_sketch() {
        let s = ShardedSketcher::new(16, 3, 4);
        let sk = s.sketch(&SparseVector::default());
        assert!(sk.y.iter().all(|y| y.is_infinite()));
        let sk2 = s.sketch(&SparseVector::new(vec![1, 2], vec![0.0, -1.0]));
        assert_eq!(sk, sk2);
        assert_eq!(sk.family, Family::Ordered);
    }

    #[test]
    fn single_shard_is_plain_fastgm() {
        let mut r = SplitMix64::new(9);
        let v = random_vector(&mut r, 50);
        assert_eq!(
            ShardedSketcher::new(32, 7, 1).sketch(&v),
            FastGm::new(32, 7).sketch(&v)
        );
    }

    #[test]
    fn fewer_entries_than_shards_still_works() {
        let v = SparseVector::new(vec![5], vec![2.0]);
        let sharded = ShardedSketcher::new(8, 1, 16).sketch(&v);
        assert_eq!(sharded, FastGm::new(8, 1).sketch(&v));
    }
}
