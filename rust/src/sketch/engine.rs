//! The zero-allocation sketch engine: a reusable scratch arena plus the
//! algorithm registry every layer above (coordinator, experiments, benches)
//! constructs sketchers through.
//!
//! At serving scale the `O(k ln k + n⁺)` bound makes the constant factor
//! the remaining lever, and the dominant constant was per-request heap
//! churn: `FastGm::sketch` rebuilt its element-race queues, the prune
//! worklists, and the register arrays on every call. [`SketchScratch`]
//! owns all of those buffers; [`Sketcher::sketch_into`] threads one
//! through every algorithm, so a long-lived caller (a coordinator worker,
//! a benchmark loop, an experiment sweep) pays allocation cost once and
//! amortizes it to zero.
//!
//! Scratch reuse is **provably lossless**: `sketch_into` with an
//! arbitrarily dirty scratch is bit-identical to a fresh `sketch()` call.
//! `rust/tests/engine_props.rs` asserts that property for every
//! [`AlgorithmId`] by iterating the registry, so a newly registered
//! algorithm is covered automatically.

use super::bagminhash::{BagMinHash, MaxTracker};
use super::fastgm::FastGm;
use super::fastgm_c::FastGmConference;
use super::icws::Icws;
use super::lemiesz::Lemiesz;
use super::minhash::MinHash;
use super::order_stats::ElementRace;
use super::pminhash::PMinHash;
use super::sharded::ShardedSketcher;
use super::stream_fastgm::{StreamFastGm, StreamSketcher};
use super::{Family, GumbelMaxSketch, Sketcher, SparseVector};

/// Reusable working memory for [`Sketcher::sketch_into`]: element-race
/// queues, budget worklists, shard partitions with per-shard sub-scratches,
/// a streaming state, and the BagMinHash register-max tracker. One scratch
/// serves *every* algorithm — the coordinator keeps one per worker thread
/// and routes all requests through it regardless of the requested `algo`.
#[derive(Debug, Default)]
pub struct SketchScratch {
    /// Positive `(id, weight)` entries of the vector being sketched.
    pub(crate) elements: Vec<(u64, f64)>,
    /// Element race queues (FastGM); reset in place per call.
    pub(crate) races: Vec<ElementRace>,
    /// FastPrune worklists (indices of still-open queues), swapped per round.
    pub(crate) alive: Vec<usize>,
    pub(crate) next_alive: Vec<usize>,
    /// Shard partitions and their sub-scratches / outputs (sharded path).
    pub(crate) parts: Vec<SparseVector>,
    pub(crate) shard_scratches: Vec<SketchScratch>,
    pub(crate) shard_outs: Vec<GumbelMaxSketch>,
    /// Streaming state reused by the `stream` / `fastgm-c` batch adapters.
    pub(crate) stream: Option<StreamFastGm>,
    /// BagMinHash "binary tree of maxima" stop-bound tracker.
    pub(crate) bag_tracker: Option<MaxTracker>,
    /// Direct-family EXP(1) row staging buffer (`kernels::direct_exp_row`
    /// output for one element across all k registers), pooled so the
    /// P-MinHash hot loop stays allocation-free under scratch reuse.
    pub(crate) direct_row: Vec<f32>,
    /// Times [`SketchScratch::begin_use`] was called (coordinator metric).
    pub(crate) uses: u64,
}

impl SketchScratch {
    pub fn new() -> SketchScratch {
        SketchScratch::default()
    }

    /// Record one use; returns `true` when the scratch is being *reused*
    /// (i.e. this is not its first sketch). The coordinator feeds this into
    /// its `scratch.reuse` / `scratch.alloc` counters.
    pub fn begin_use(&mut self) -> bool {
        let reused = self.uses > 0;
        self.uses += 1;
        reused
    }

    /// Total sketches computed through this scratch.
    pub fn uses(&self) -> u64 {
        self.uses
    }

    /// Diagnostic: element-race slots currently pooled (including inside
    /// per-shard sub-scratches). Non-zero after a FastGM-family sketch ran
    /// through this scratch — the observable proof that `sketch_into`
    /// actually used the passed arena instead of allocating its own.
    pub fn pooled_races(&self) -> usize {
        self.races.len() + self.shard_scratches.iter().map(|s| s.pooled_races()).sum::<usize>()
    }

    /// The streaming state, reset to `(k, seed)` (created on first use).
    pub(crate) fn stream_mut(&mut self, k: usize, seed: u64) -> &mut StreamFastGm {
        if let Some(st) = self.stream.as_mut() {
            st.reset(k, seed);
        } else {
            self.stream = Some(StreamFastGm::new(k, seed));
        }
        self.stream.as_mut().expect("stream state just ensured")
    }

    /// The pooled Direct-family row buffer, sized to `k` (contents are
    /// overwritten by `kernels::direct_exp_row` before every read).
    pub(crate) fn direct_row_mut(&mut self, k: usize) -> &mut [f32] {
        self.direct_row.clear();
        self.direct_row.resize(k, 0.0);
        &mut self.direct_row
    }

    /// The BagMinHash max tracker, reset to `n` leaves of `init` (recreated
    /// only when the register count changes).
    pub(crate) fn bag_tracker_mut(&mut self, n: usize, init: f64) -> &mut MaxTracker {
        let reusable = matches!(&self.bag_tracker, Some(t) if t.len() == n);
        if reusable {
            let t = self.bag_tracker.as_mut().expect("tracker checked above");
            t.reset(init);
        } else {
            self.bag_tracker = Some(MaxTracker::new(n, init));
        }
        self.bag_tracker.as_mut().expect("tracker just ensured")
    }
}

/// Every sketch algorithm constructible by name through the registry.
///
/// These are the names accepted by the coordinator's config key
/// `sketch.algo`, the wire protocol's optional `algo` request field, and
/// the `fastgm sketch --algo` CLI flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgorithmId {
    /// FastGM, the paper's Algorithm 1 (`fastgm`).
    FastGm,
    /// WWW'20 conference baseline, prune-only (`fastgm-c`).
    FastGmC,
    /// FastGM over weight-balanced shards, §2.3 merge (`sharded`).
    Sharded,
    /// One-pass Stream-FastGM driven in batch mode (`stream`).
    Stream,
    /// O(k·n⁺) P-MinHash, Direct family (`pminhash`).
    PMinHash,
    /// Lemiesz's weighted-cardinality sketch, Direct family (`lemiesz`).
    Lemiesz,
    /// Improved Consistent Weighted Sampling (`icws`).
    Icws,
    /// BagMinHash weighted-Jaccard baseline (`bagminhash`).
    BagMinHash,
    /// Classic binary MinHash over the support set (`minhash`).
    MinHash,
}

impl AlgorithmId {
    /// Every registered algorithm — tests iterate this so new entries are
    /// covered automatically.
    pub const ALL: [AlgorithmId; 9] = [
        AlgorithmId::FastGm,
        AlgorithmId::FastGmC,
        AlgorithmId::Sharded,
        AlgorithmId::Stream,
        AlgorithmId::PMinHash,
        AlgorithmId::Lemiesz,
        AlgorithmId::Icws,
        AlgorithmId::BagMinHash,
        AlgorithmId::MinHash,
    ];

    pub fn name(self) -> &'static str {
        match self {
            AlgorithmId::FastGm => "fastgm",
            AlgorithmId::FastGmC => "fastgm-c",
            AlgorithmId::Sharded => "sharded",
            AlgorithmId::Stream => "stream",
            AlgorithmId::PMinHash => "pminhash",
            AlgorithmId::Lemiesz => "lemiesz",
            AlgorithmId::Icws => "icws",
            AlgorithmId::BagMinHash => "bagminhash",
            AlgorithmId::MinHash => "minhash",
        }
    }

    pub fn from_name(s: &str) -> anyhow::Result<AlgorithmId> {
        AlgorithmId::ALL
            .into_iter()
            .find(|id| id.name() == s)
            .ok_or_else(|| {
                let known: Vec<&str> = AlgorithmId::ALL.iter().map(|id| id.name()).collect();
                anyhow::anyhow!("unknown sketch algorithm '{s}' (known: {})", known.join(", "))
            })
    }

    /// RNG family the algorithm's sketches belong to.
    pub fn family(self) -> Family {
        match self {
            AlgorithmId::FastGm
            | AlgorithmId::FastGmC
            | AlgorithmId::Sharded
            | AlgorithmId::Stream => Family::Ordered,
            AlgorithmId::PMinHash | AlgorithmId::Lemiesz => Family::Direct,
            AlgorithmId::Icws => Family::Icws,
            AlgorithmId::BagMinHash => Family::Bag,
            AlgorithmId::MinHash => Family::MinHash,
        }
    }
}

/// Construction parameters shared by every registry entry.
#[derive(Debug, Clone, Copy)]
pub struct EngineParams {
    pub k: usize,
    pub seed: u64,
    /// Shard team size for [`AlgorithmId::Sharded`] (ignored elsewhere).
    pub shards: usize,
    /// FastSearch budget step override for [`AlgorithmId::FastGm`].
    pub delta: Option<usize>,
}

impl EngineParams {
    pub fn new(k: usize, seed: u64) -> EngineParams {
        EngineParams { k, seed, shards: 4, delta: None }
    }

    pub fn with_shards(mut self, shards: usize) -> EngineParams {
        self.shards = shards.max(1);
        self
    }

    pub fn with_delta(mut self, delta: usize) -> EngineParams {
        self.delta = Some(delta);
        self
    }
}

/// Build a sketcher from the registry.
pub fn build(id: AlgorithmId, p: EngineParams) -> Box<dyn Sketcher> {
    match id {
        AlgorithmId::FastGm => {
            let fg = FastGm::new(p.k, p.seed);
            Box::new(match p.delta {
                Some(d) => fg.with_delta(d),
                None => fg,
            })
        }
        AlgorithmId::FastGmC => Box::new(FastGmConference::new(p.k, p.seed)),
        AlgorithmId::Sharded => Box::new(ShardedSketcher::new(p.k, p.seed, p.shards.max(1))),
        AlgorithmId::Stream => Box::new(StreamSketcher::new(p.k, p.seed)),
        AlgorithmId::PMinHash => Box::new(PMinHash::new(p.k, p.seed)),
        AlgorithmId::Lemiesz => Box::new(Lemiesz::new(p.k, p.seed)),
        AlgorithmId::Icws => Box::new(Icws::new(p.k, p.seed)),
        AlgorithmId::BagMinHash => Box::new(BagMinHash::new(p.k, p.seed)),
        AlgorithmId::MinHash => Box::new(MinHash::new(p.k, p.seed)),
    }
}

/// Build a sketcher by registry name (config / protocol `algo` values).
pub fn build_named(name: &str, p: EngineParams) -> anyhow::Result<Box<dyn Sketcher>> {
    Ok(build(AlgorithmId::from_name(name)?, p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip_and_match_built_sketchers() {
        for id in AlgorithmId::ALL {
            assert_eq!(AlgorithmId::from_name(id.name()).unwrap(), id);
            let s = build(id, EngineParams::new(8, 7));
            assert_eq!(s.name(), id.name(), "registry name drifted for {id:?}");
            assert_eq!(s.family(), id.family());
            assert_eq!(s.k(), 8);
            assert_eq!(s.seed(), 7);
        }
    }

    #[test]
    fn unknown_name_is_an_error_listing_known_names() {
        let err = build_named("quantum", EngineParams::new(8, 1)).unwrap_err().to_string();
        assert!(err.contains("unknown sketch algorithm 'quantum'"), "{err}");
        assert!(err.contains("fastgm"), "{err}");
    }

    #[test]
    fn scratch_counts_uses() {
        let mut s = SketchScratch::new();
        assert_eq!(s.uses(), 0);
        assert!(!s.begin_use());
        assert!(s.begin_use());
        assert_eq!(s.uses(), 2);
    }
}
