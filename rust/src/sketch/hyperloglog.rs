//! HyperLogLog (Flajolet et al. '07) — unweighted cardinality baseline for
//! the weighted-vs-unweighted ablation (a Gumbel-Max `y` sketch over unit
//! weights estimates the same quantity; `fastgm exp ablation-accel` and the
//! simnet mean-size estimator compare the two).

use crate::util::rng::fmix64;

#[derive(Debug, Clone, PartialEq)]
pub struct HyperLogLog {
    /// log2 of the register count.
    p: u32,
    regs: Vec<u8>,
}

impl HyperLogLog {
    /// `p` in [4, 18]; m = 2^p registers.
    pub fn new(p: u32) -> Self {
        assert!((4..=18).contains(&p));
        HyperLogLog { p, regs: vec![0; 1 << p] }
    }

    pub fn m(&self) -> usize {
        self.regs.len()
    }

    pub fn insert(&mut self, id: u64) {
        let h = fmix64(id ^ 0x9E37_79B9_7F4A_7C15);
        let idx = (h >> (64 - self.p)) as usize;
        let rest = h << self.p;
        let rho = rest.leading_zeros().min(63 - self.p) as u8 + 1;
        if rho > self.regs[idx] {
            self.regs[idx] = rho;
        }
    }

    pub fn merge(&mut self, other: &HyperLogLog) {
        assert_eq!(self.p, other.p);
        for (a, &b) in self.regs.iter_mut().zip(&other.regs) {
            if b > *a {
                *a = b;
            }
        }
    }

    /// Bias-corrected estimate with small/large range corrections.
    pub fn estimate(&self) -> f64 {
        let m = self.m() as f64;
        let alpha = match self.regs.len() {
            16 => 0.673,
            32 => 0.697,
            64 => 0.709,
            _ => 0.7213 / (1.0 + 1.079 / m),
        };
        let sum: f64 = self.regs.iter().map(|&r| 2f64.powi(-(r as i32))).sum();
        let raw = alpha * m * m / sum;
        if raw <= 2.5 * m {
            let zeros = self.regs.iter().filter(|&&r| r == 0).count();
            if zeros > 0 {
                return m * (m / zeros as f64).ln(); // linear counting
            }
        }
        raw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimates_within_expected_error() {
        for &n in &[100u64, 10_000, 200_000] {
            let mut hll = HyperLogLog::new(12); // m=4096, rse ≈ 1.04/64 ≈ 1.6%
            for i in 0..n {
                hll.insert(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            }
            let est = hll.estimate();
            let err = (est - n as f64).abs() / n as f64;
            assert!(err < 0.08, "n={n} est={est} err={err}");
        }
    }

    #[test]
    fn duplicates_do_not_inflate() {
        let mut hll = HyperLogLog::new(10);
        for _ in 0..5 {
            for i in 0..1000u64 {
                hll.insert(i);
            }
        }
        let est = hll.estimate();
        assert!((est - 1000.0).abs() / 1000.0 < 0.1, "est={est}");
    }

    #[test]
    fn merge_equals_union() {
        let mut a = HyperLogLog::new(10);
        let mut b = HyperLogLog::new(10);
        let mut u = HyperLogLog::new(10);
        for i in 0..3000u64 {
            if i % 2 == 0 {
                a.insert(i);
            } else {
                b.insert(i);
            }
            u.insert(i);
        }
        a.merge(&b);
        assert_eq!(a, u);
    }
}
