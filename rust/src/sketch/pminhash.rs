//! P-MinHash — the straightforward `O(k·n⁺)` Gumbel-Max sketch
//! (Moulton & Jiang 2018), the paper's Task-1 baseline.
//!
//! For every positive element `i` and register `j`, draw
//! `b_ij = -ln(a_ij)/v_i` with the **Direct** counter RNG and keep the
//! per-register min/argmin. This is the construction the Pallas dense
//! kernel mirrors, so CPU P-MinHash sketches and accelerator sketches are
//! interchangeable (same family, same seed ⇒ same registers up to f32
//! rounding; the runtime integration test checks that).

use crate::util::rng::direct_element_hash;
use super::engine::SketchScratch;
use super::kernels;
use super::{fold_id, Family, GumbelMaxSketch, Sketcher, SparseVector};

#[derive(Debug, Clone)]
pub struct PMinHash {
    pub k: usize,
    /// Unified `u64` seed (like every other sketcher); folded with
    /// [`fold_id`] into the 32-bit Direct-RNG index space, exactly as
    /// element ids are. Seeds below 2^32 fold to themselves, so existing
    /// sketches and the Pallas kernels are unaffected.
    pub seed: u64,
}

impl PMinHash {
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 1);
        PMinHash { k, seed }
    }

    /// The 32-bit seed actually fed to the Direct counter RNG.
    pub fn rng_seed(&self) -> u32 {
        fold_id(self.seed)
    }
}

impl Sketcher for PMinHash {
    fn name(&self) -> &'static str {
        "pminhash"
    }

    fn family(&self) -> Family {
        Family::Direct
    }

    fn k(&self) -> usize {
        self.k
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn sketch_into(&self, v: &SparseVector, scratch: &mut SketchScratch, out: &mut GumbelMaxSketch) {
        out.reset(Family::Direct, self.seed, self.k);
        let rng_seed = self.rng_seed();
        // Per element: hoist the j-invariant hash half, stage the EXP(1)
        // row in the pooled scratch buffer, then run the fused min/argmin
        // update — both kernel stages are bit-identical to the historical
        // `direct_exp(seed, i, j) * (1/w)` inner loop.
        let row = scratch.direct_row_mut(self.k);
        for (id, w) in v.positive() {
            let h = direct_element_hash(rng_seed, fold_id(id));
            kernels::direct_exp_row(h, 0, row);
            kernels::scaled_min_update(row, 1.0 / w, id, &mut out.y, &mut out.s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;
    use crate::util::stats::OnlineStats;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let v = SparseVector::new(vec![1, 2, 3], vec![0.5, 1.0, 0.25]);
        let a = PMinHash::new(64, 7).sketch(&v);
        let b = PMinHash::new(64, 7).sketch(&v);
        let c = PMinHash::new(64, 8).sketch(&v);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn consistency_across_vectors() {
        // Shared elements see the same race variables: if u ⊂ v and an
        // element of u wins register j in v, then u's register j must hold
        // the same (y, s).
        let u = SparseVector::new(vec![10, 20], vec![1.0, 2.0]);
        let v = SparseVector::new(vec![10, 20, 30], vec![1.0, 2.0, 0.5]);
        let su = PMinHash::new(128, 3).sketch(&u);
        let sv = PMinHash::new(128, 3).sketch(&v);
        for j in 0..128 {
            if sv.s[j] != 30 {
                assert_eq!(sv.s[j], su.s[j]);
                assert_eq!(sv.y[j], su.y[j]);
            }
        }
    }

    #[test]
    fn argmax_distribution_proportional_to_weight() {
        let v = SparseVector::new(vec![0, 1, 2], vec![0.2, 0.5, 0.3]);
        let k = 4000;
        let sk = PMinHash::new(k, 99).sketch(&v);
        let mut counts = [0usize; 3];
        for &s in &sk.s {
            counts[s as usize] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let p = c as f64 / k as f64;
            assert!((p - v.weights[i]).abs() < 0.03, "element {i}: p={p}");
        }
    }

    #[test]
    fn y_mean_matches_exponential_total_weight() {
        let mut r = SplitMix64::new(4);
        let mut stats = OnlineStats::new();
        for seed in 0..60u64 {
            let v = SparseVector::new(
                (0..20u64).collect(),
                (0..20).map(|_| r.next_f64() + 0.1).collect(),
            );
            let total = v.total_weight();
            let sk = PMinHash::new(64, seed).sketch(&v);
            for y in sk.y {
                stats.push(y * total); // normalize to EXP(1)
            }
        }
        assert!((stats.mean() - 1.0).abs() < 0.03, "mean={}", stats.mean());
    }

    #[test]
    fn empty_vector() {
        let sk = PMinHash::new(8, 1).sketch(&SparseVector::default());
        assert!(sk.y.iter().all(|y| y.is_infinite()));
    }

    /// Seeds ≥ 2^32 fold into the Direct RNG like element ids do, while the
    /// sketch keeps the full u64 seed tag (so merge discipline still sees
    /// distinct seeds as distinct).
    #[test]
    fn u64_seed_folds_for_rng_but_tags_losslessly() {
        let v = SparseVector::new(vec![1, 2, 3], vec![0.5, 1.0, 0.25]);
        let big = (7u64 << 32) | 7; // fold_id(big) == 0
        let a = PMinHash::new(32, big).sketch(&v);
        let b = PMinHash::new(32, 0).sketch(&v);
        assert_eq!(a.y, b.y, "folded seeds must drive identical registers");
        assert_eq!(a.s, b.s);
        assert_eq!(a.seed, big, "seed tag must stay the full u64");
        assert!(a.merge(&b).is_err(), "distinct u64 seeds must not merge");
        // Small seeds fold to themselves: the pre-unification behaviour.
        assert_eq!(PMinHash::new(32, 7).rng_seed(), 7);
    }
}
