//! SIMD-speed sketch kernels, bit-identical to their scalar twins.
//!
//! Every hot inner loop of the sketchers funnels through this module:
//! batched SplitMix64 / exponential variate generation (Ordered family),
//! Direct-family per-element rows, register min-merges, argmin/argmax scans
//! over register arrays, and the match-count at the heart of `estimate_jp`.
//!
//! Two backends exist per kernel:
//!
//! * **`Backend::Scalar`** — plain Rust, *the* reference semantics. This is
//!   the code path the property tests pin against and the one every other
//!   platform runs.
//! * **`Backend::Simd`** — AVX2 intrinsics behind **runtime** feature
//!   detection (`is_x86_feature_detected!`), so a single portable binary
//!   picks the fast path on capable x86-64 hosts and silently falls back to
//!   scalar elsewhere. No `RUSTFLAGS=-Ctarget-cpu=native` required (see
//!   README §Kernels).
//!
//! The contract — enforced by `rust/tests/kernel_equivalence.rs` — is that
//! both backends produce **bit-identical** outputs. That is only possible
//! because each vectorized kernel is built exclusively from operations that
//! are exact or IEEE-deterministic:
//!
//! * integer adds/xors/shifts/multiplies (exact mod 2^64 — the 64-bit `mullo`
//!   is emulated from `mul_epu32` partial products, which is exact);
//! * `u64 → f64` via the `OR 0x4330…; subtract 2^52` trick (exact: the
//!   mantissa is < 2^52) and dyadic `+0.5`, `×2^-52` (exact);
//! * IEEE `min`/`max`/compares/blends (exact, no reassociation);
//! * `ln` stays **scalar libm in both backends** — a polynomial vector log
//!   would diverge in the last ulp, so we never vectorize it.
//!
//! Floating-point *sums* are deliberately absent: SIMD reassociation changes
//! rounding, and nothing here is allowed to change a single output bit.
//!
//! NaN note: register arrays never contain NaN by construction (arrivals are
//! `-ln(u)` with `u ∈ (0,1)` scaled by a positive weight — strictly positive
//! or `+inf`, never `0·inf`), which the two-pass SIMD argmin/argmax relies
//! on. The scalar scans are total either way.

use crate::util::rng::{direct_exp_from_hash, SplitMix64};
use std::sync::atomic::{AtomicU8, Ordering};

use super::EMPTY_REGISTER;

// ---------------------------------------------------------------------------
// Backend selection.
// ---------------------------------------------------------------------------

/// Which implementation family a kernel call runs on.
///
/// `Simd` means "the widest vectorized path this host supports" — AVX2 on
/// x86-64 with runtime support, otherwise it degrades to the scalar code.
/// Because the backends are bit-identical, selection is a pure performance
/// knob and is safe to flip at any time, even mid-stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Simd,
}

/// Process-wide override: 0 = auto (use [`detected`]), 1 = scalar, 2 = simd.
static FORCED: AtomicU8 = AtomicU8::new(0);

/// Force every auto-dispatched kernel call onto one backend (`None` returns
/// to auto-detection). Used by `perf_probe` to measure scalar-vs-SIMD pairs
/// and by the equivalence suite; harmless anywhere because the backends
/// agree bit-for-bit.
pub fn set_forced(backend: Option<Backend>) {
    let v = match backend {
        None => 0,
        Some(Backend::Scalar) => 1,
        Some(Backend::Simd) => 2,
    };
    FORCED.store(v, Ordering::Relaxed);
}

/// The best backend this host supports.
pub fn detected() -> Backend {
    #[cfg(target_arch = "x86_64")]
    if cpu_has_avx2() {
        return Backend::Simd;
    }
    Backend::Scalar
}

/// The backend auto-dispatched calls use right now ([`detected`] unless
/// overridden by [`set_forced`]).
pub fn active() -> Backend {
    match FORCED.load(Ordering::Relaxed) {
        1 => Backend::Scalar,
        2 => Backend::Simd,
        _ => detected(),
    }
}

#[cfg(target_arch = "x86_64")]
fn cpu_has_avx2() -> bool {
    use std::sync::OnceLock;
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
}

/// Whether `backend` resolves to the AVX2 code paths on this host.
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
#[inline]
fn simd_available(backend: Backend) -> bool {
    match backend {
        Backend::Scalar => false,
        Backend::Simd => {
            #[cfg(target_arch = "x86_64")]
            {
                cpu_has_avx2()
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Batched Ordered-family variates (SplitMix64 stream).
// ---------------------------------------------------------------------------

/// Fill `out` with the next `out.len()` draws of `rng`'s `next_u64` stream,
/// leaving `rng` exactly where the scalar loop would.
pub fn fill_u64_block(rng: &mut SplitMix64, out: &mut [u64]) {
    fill_u64_block_with(active(), rng, out)
}

/// [`fill_u64_block`] on an explicit backend.
pub fn fill_u64_block_with(backend: Backend, rng: &mut SplitMix64, out: &mut [u64]) {
    #[cfg(target_arch = "x86_64")]
    if simd_available(backend) {
        let m = out.len() & !3;
        if m > 0 {
            let base = rng.raw_state();
            // SAFETY: AVX2 support verified at runtime by `simd_available`.
            unsafe { avx2::fill_u64(base, &mut out[..m]) };
            let gamma = crate::util::rng::GOLDEN_GAMMA;
            rng.set_raw_state(base.wrapping_add(gamma.wrapping_mul(m as u64)));
        }
        for x in &mut out[m..] {
            *x = rng.next_u64();
        }
        return;
    }
    let _ = backend;
    for x in out.iter_mut() {
        *x = rng.next_u64();
    }
}

/// Fill `out` with the next `out.len()` draws of `rng`'s `next_f64` stream
/// (uniform in the open unit interval), bit-identical to the scalar loop.
pub fn fill_uniform_block(rng: &mut SplitMix64, out: &mut [f64]) {
    fill_uniform_block_with(active(), rng, out)
}

/// [`fill_uniform_block`] on an explicit backend.
pub fn fill_uniform_block_with(backend: Backend, rng: &mut SplitMix64, out: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if simd_available(backend) {
        let m = out.len() & !3;
        if m > 0 {
            let base = rng.raw_state();
            // SAFETY: AVX2 support verified at runtime by `simd_available`.
            unsafe { avx2::fill_uniform(base, &mut out[..m]) };
            let gamma = crate::util::rng::GOLDEN_GAMMA;
            rng.set_raw_state(base.wrapping_add(gamma.wrapping_mul(m as u64)));
        }
        for x in &mut out[m..] {
            *x = rng.next_f64();
        }
        return;
    }
    let _ = backend;
    for x in out.iter_mut() {
        *x = rng.next_f64();
    }
}

/// Fill `out` with the next `out.len()` draws of `rng`'s `next_exp` stream
/// (the Gumbel-race EXP(1) arrivals), bit-identical to the scalar loop.
///
/// The uniform stage is vectorized; the `-ln(u)` stage is scalar libm in
/// BOTH backends (see module docs), so batching wins exactly the RNG share
/// of the cost — `perf_probe` tracks both `kernel.uniform_batch_*` and
/// `kernel.gumbel_batch_*` to keep that split honest.
pub fn fill_exp_block(rng: &mut SplitMix64, out: &mut [f64]) {
    fill_exp_block_with(active(), rng, out)
}

/// [`fill_exp_block`] on an explicit backend.
pub fn fill_exp_block_with(backend: Backend, rng: &mut SplitMix64, out: &mut [f64]) {
    fill_uniform_block_with(backend, rng, out);
    for x in out.iter_mut() {
        *x = -x.ln();
    }
}

// ---------------------------------------------------------------------------
// Direct-family rows (stateless counter RNG).
// ---------------------------------------------------------------------------

/// Write `out[t] = direct_exp_from_hash(h, j0 + t)` — one element's EXP(1)
/// row across consecutive registers. `h` is the hoisted
/// `direct_element_hash(seed, i)`; because the Direct RNG is stateless per
/// `(h, j)`, callers may produce a long row in chunks at any `j0` split and
/// get the same bits.
pub fn direct_exp_row(h: u32, j0: u32, out: &mut [f32]) {
    direct_exp_row_with(active(), h, j0, out)
}

/// [`direct_exp_row`] on an explicit backend.
pub fn direct_exp_row_with(backend: Backend, h: u32, j0: u32, out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if simd_available(backend) {
        // SAFETY: AVX2 support verified at runtime by `simd_available`.
        unsafe { avx2::direct_exp_row(h, j0, out) };
        return;
    }
    let _ = backend;
    for (t, slot) in out.iter_mut().enumerate() {
        *slot = direct_exp_from_hash(h, j0.wrapping_add(t as u32));
    }
}

/// Fused register update for the Direct-family sketchers: for each `j`,
/// `b = row[j] as f64 * inv_w; if b < y[j] { y[j] = b; s[j] = id; }`.
///
/// `row` is an EXP(1) row from [`direct_exp_row`]; `inv_w` is `1/w`
/// (possibly `+inf` for denormal-adjacent weights — the product is then
/// `+inf`, never NaN, since the row is strictly positive).
pub fn scaled_min_update(row: &[f32], inv_w: f64, id: u64, y: &mut [f64], s: &mut [u64]) {
    scaled_min_update_with(active(), row, inv_w, id, y, s)
}

/// [`scaled_min_update`] on an explicit backend.
pub fn scaled_min_update_with(
    backend: Backend,
    row: &[f32],
    inv_w: f64,
    id: u64,
    y: &mut [f64],
    s: &mut [u64],
) {
    assert!(row.len() == y.len() && y.len() == s.len(), "kernel length mismatch");
    #[cfg(target_arch = "x86_64")]
    if simd_available(backend) {
        // SAFETY: lengths checked above; AVX2 verified at runtime.
        unsafe { avx2::scaled_min_update(row, inv_w, id, y, s) };
        return;
    }
    let _ = backend;
    for j in 0..y.len() {
        let b = row[j] as f64 * inv_w;
        if b < y[j] {
            y[j] = b;
            s[j] = id;
        }
    }
}

// ---------------------------------------------------------------------------
// Register-array scans.
// ---------------------------------------------------------------------------

/// Index of the maximum of `xs` (first index on ties — the prune-threshold
/// scan `y* = max_j y_j` of FastGM/Stream-FastGM). `xs` must be non-empty
/// and NaN-free.
pub fn argmax_f64(xs: &[f64]) -> usize {
    argmax_f64_with(active(), xs)
}

/// [`argmax_f64`] on an explicit backend.
pub fn argmax_f64_with(backend: Backend, xs: &[f64]) -> usize {
    assert!(!xs.is_empty(), "argmax of empty slice");
    #[cfg(target_arch = "x86_64")]
    if simd_available(backend) {
        // SAFETY: non-empty checked above; AVX2 verified at runtime.
        return unsafe { avx2::argmax(xs) };
    }
    let _ = backend;
    let mut best = 0;
    for (j, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = j;
        }
    }
    best
}

/// Index of the minimum of `xs` (first index on ties). `xs` must be
/// non-empty and NaN-free.
pub fn argmin_f64(xs: &[f64]) -> usize {
    argmin_f64_with(active(), xs)
}

/// [`argmin_f64`] on an explicit backend.
pub fn argmin_f64_with(backend: Backend, xs: &[f64]) -> usize {
    assert!(!xs.is_empty(), "argmin of empty slice");
    #[cfg(target_arch = "x86_64")]
    if simd_available(backend) {
        // SAFETY: non-empty checked above; AVX2 verified at runtime.
        return unsafe { avx2::argmin(xs) };
    }
    let _ = backend;
    let mut best = 0;
    for (j, &x) in xs.iter().enumerate() {
        if x < xs[best] {
            best = j;
        }
    }
    best
}

/// Lane-wise min-merge of register pairs: where `oy[j] < y[j]`, take
/// `(oy[j], os[j])`. Strict `<` keeps the left operand on ties, exactly like
/// the historical scalar loop in `GumbelMaxSketch::merge_in_place`.
pub fn merge_min_into(y: &mut [f64], s: &mut [u64], oy: &[f64], os: &[u64]) {
    merge_min_into_with(active(), y, s, oy, os)
}

/// [`merge_min_into`] on an explicit backend.
pub fn merge_min_into_with(backend: Backend, y: &mut [f64], s: &mut [u64], oy: &[f64], os: &[u64]) {
    assert!(
        y.len() == s.len() && y.len() == oy.len() && y.len() == os.len(),
        "kernel length mismatch"
    );
    #[cfg(target_arch = "x86_64")]
    if simd_available(backend) {
        // SAFETY: lengths checked above; AVX2 verified at runtime.
        unsafe { avx2::merge_min_into(y, s, oy, os) };
        return;
    }
    let _ = backend;
    for j in 0..y.len() {
        if oy[j] < y[j] {
            y[j] = oy[j];
            s[j] = os[j];
        }
    }
}

/// Number of registers still holding [`EMPTY_REGISTER`].
pub fn count_empty(s: &[u64]) -> usize {
    count_empty_with(active(), s)
}

/// [`count_empty`] on an explicit backend.
pub fn count_empty_with(backend: Backend, s: &[u64]) -> usize {
    #[cfg(target_arch = "x86_64")]
    if simd_available(backend) {
        // SAFETY: AVX2 verified at runtime.
        return unsafe { avx2::count_empty(s) };
    }
    let _ = backend;
    s.iter().filter(|&&x| x == EMPTY_REGISTER).count()
}

/// Number of register positions where `a` and `b` agree on a **filled**
/// register — the numerator of `estimate_jp`. Positions where both sides
/// are [`EMPTY_REGISTER`] do not count as matches.
pub fn match_count(a: &[u64], b: &[u64]) -> usize {
    match_count_with(active(), a, b)
}

/// [`match_count`] on an explicit backend.
pub fn match_count_with(backend: Backend, a: &[u64], b: &[u64]) -> usize {
    assert_eq!(a.len(), b.len(), "kernel length mismatch");
    #[cfg(target_arch = "x86_64")]
    if simd_available(backend) {
        // SAFETY: lengths checked above; AVX2 verified at runtime.
        return unsafe { avx2::match_count(a, b) };
    }
    let _ = backend;
    let mut n = 0;
    for j in 0..a.len() {
        if a[j] != EMPTY_REGISTER && a[j] == b[j] {
            n += 1;
        }
    }
    n
}

// ---------------------------------------------------------------------------
// AVX2 backend. Compiled on every x86-64 build, entered only behind runtime
// detection. Every function here mirrors one scalar loop above — see the
// module docs for why each operation sequence is bit-exact.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::EMPTY_REGISTER;
    use crate::util::rng::GOLDEN_GAMMA;
    use std::arch::x86_64::*;

    /// `a * b mod 2^64` per 64-bit lane, from 32×32→64 partial products:
    /// `lo·lo + ((lo·hi + hi·lo) << 32)`. Exact — the dropped `hi·hi` term
    /// only feeds bits ≥ 64.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn mullo_epi64(a: __m256i, b: __m256i) -> __m256i {
        let a_hi = _mm256_srli_epi64(a, 32);
        let b_hi = _mm256_srli_epi64(b, 32);
        let ll = _mm256_mul_epu32(a, b);
        let lh = _mm256_mul_epu32(a, b_hi);
        let hl = _mm256_mul_epu32(a_hi, b);
        _mm256_add_epi64(ll, _mm256_slli_epi64(_mm256_add_epi64(lh, hl), 32))
    }

    /// The SplitMix64 output mix over four pre-advanced counter states.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn splitmix4(state: __m256i) -> __m256i {
        let m1 = _mm256_set1_epi64x(0xBF58_476D_1CE4_E5B9_u64 as i64);
        let m2 = _mm256_set1_epi64x(0x94D0_49BB_1331_11EB_u64 as i64);
        let mut z = state;
        z = mullo_epi64(_mm256_xor_si256(z, _mm256_srli_epi64(z, 30)), m1);
        z = mullo_epi64(_mm256_xor_si256(z, _mm256_srli_epi64(z, 27)), m2);
        _mm256_xor_si256(z, _mm256_srli_epi64(z, 31))
    }

    /// Counter states for draws `i+1 ..= i+4` from base state `base`.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn counter4(base: u64, i: u64) -> __m256i {
        let g = GOLDEN_GAMMA;
        let s = base.wrapping_add(g.wrapping_mul(i));
        _mm256_setr_epi64x(
            s.wrapping_add(g) as i64,
            s.wrapping_add(g.wrapping_mul(2)) as i64,
            s.wrapping_add(g.wrapping_mul(3)) as i64,
            s.wrapping_add(g.wrapping_mul(4)) as i64,
        )
    }

    /// `out.len()` must be a multiple of 4 (caller handles the tail).
    #[target_feature(enable = "avx2")]
    pub unsafe fn fill_u64(base: u64, out: &mut [u64]) {
        debug_assert_eq!(out.len() % 4, 0);
        let mut i = 0;
        while i < out.len() {
            let z = splitmix4(counter4(base, i as u64));
            _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, z);
            i += 4;
        }
    }

    /// u64 → uniform f64 in (0,1): `((z >> 12) + 0.5) * 2^-52`, with the
    /// integer→double step done exactly via `OR 2^52; subtract 2^52`.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn uniform4(z: __m256i) -> __m256d {
        const TWO52: f64 = 4_503_599_627_370_496.0;
        let mant = _mm256_srli_epi64(z, 12);
        let biased = _mm256_or_si256(mant, _mm256_set1_epi64x(0x4330_0000_0000_0000_u64 as i64));
        let x = _mm256_sub_pd(_mm256_castsi256_pd(biased), _mm256_set1_pd(TWO52));
        _mm256_mul_pd(_mm256_add_pd(x, _mm256_set1_pd(0.5)), _mm256_set1_pd(1.0 / TWO52))
    }

    /// `out.len()` must be a multiple of 4 (caller handles the tail).
    #[target_feature(enable = "avx2")]
    pub unsafe fn fill_uniform(base: u64, out: &mut [f64]) {
        debug_assert_eq!(out.len() % 4, 0);
        let mut i = 0;
        while i < out.len() {
            let u = uniform4(splitmix4(counter4(base, i as u64)));
            _mm256_storeu_pd(out.as_mut_ptr().add(i), u);
            i += 4;
        }
    }

    /// Direct-family EXP(1) row: 8 registers per iteration. The two fmix32
    /// rounds and the `(bits >> 9) + 0.5` scaling are vectorized (integer /
    /// dyadic — exact); the final `-ln` stays scalar libm.
    #[target_feature(enable = "avx2")]
    pub unsafe fn direct_exp_row(h: u32, j0: u32, out: &mut [f32]) {
        let m = out.len() & !7;
        let hvec = _mm256_set1_epi32(h as i32);
        let jmul = _mm256_set1_epi32(0x85EB_CA77_u32 as i32);
        let c1 = _mm256_set1_epi32(0x85EB_CA6B_u32 as i32);
        let c2 = _mm256_set1_epi32(0xC2B2_AE35_u32 as i32);
        let lane = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
        let half = _mm256_set1_ps(0.5);
        let scale = _mm256_set1_ps(1.0 / 8_388_608.0);
        let mut i = 0;
        let mut buf = [0.0f32; 8];
        while i < m {
            let j = _mm256_add_epi32(_mm256_set1_epi32(j0.wrapping_add(i as u32) as i32), lane);
            // fmix32(h ^ j·0x85EB_CA77), vectorized (wrapping integer ops).
            let mut x = _mm256_xor_si256(hvec, _mm256_mullo_epi32(j, jmul));
            x = _mm256_xor_si256(x, _mm256_srli_epi32(x, 16));
            x = _mm256_mullo_epi32(x, c1);
            x = _mm256_xor_si256(x, _mm256_srli_epi32(x, 13));
            x = _mm256_mullo_epi32(x, c2);
            x = _mm256_xor_si256(x, _mm256_srli_epi32(x, 16));
            // (bits >> 9) fits in 23 bits → cvtepi32_ps is exact.
            let u = _mm256_mul_ps(
                _mm256_add_ps(_mm256_cvtepi32_ps(_mm256_srli_epi32(x, 9)), half),
                scale,
            );
            _mm256_storeu_ps(buf.as_mut_ptr(), u);
            for (t, &v) in buf.iter().enumerate() {
                *out.get_unchecked_mut(i + t) = -v.ln();
            }
            i += 8;
        }
        for t in m..out.len() {
            out[t] = super::direct_exp_from_hash(h, j0.wrapping_add(t as u32));
        }
    }

    /// Fused `b = row[j]·inv_w; if b < y[j] { y[j] = b; s[j] = id }`.
    /// `cvtps_pd` is exact (f32 ⊂ f64) and the single multiply rounds once,
    /// exactly like the scalar expression.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scaled_min_update(row: &[f32], inv_w: f64, id: u64, y: &mut [f64], s: &mut [u64]) {
        let m = y.len() & !3;
        let wvec = _mm256_set1_pd(inv_w);
        let idvec = _mm256_set1_epi64x(id as i64);
        let mut i = 0;
        while i < m {
            let r = _mm256_cvtps_pd(_mm_loadu_ps(row.as_ptr().add(i)));
            let b = _mm256_mul_pd(r, wvec);
            let yv = _mm256_loadu_pd(y.as_ptr().add(i));
            let lt = _mm256_cmp_pd::<_CMP_LT_OQ>(b, yv);
            _mm256_storeu_pd(y.as_mut_ptr().add(i), _mm256_blendv_pd(yv, b, lt));
            let sv = _mm256_loadu_si256(s.as_ptr().add(i) as *const __m256i);
            let picked = _mm256_blendv_epi8(sv, idvec, _mm256_castpd_si256(lt));
            _mm256_storeu_si256(s.as_mut_ptr().add(i) as *mut __m256i, picked);
            i += 4;
        }
        for j in m..y.len() {
            let b = row[j] as f64 * inv_w;
            if b < y[j] {
                y[j] = b;
                s[j] = id;
            }
        }
    }

    /// Two-pass argmax: fold the maximum value, then find its first index.
    /// Equivalent to the scalar strict-`>` first-wins scan for NaN-free
    /// input (IEEE max and `==` are exact; +inf compares normally).
    #[target_feature(enable = "avx2")]
    pub unsafe fn argmax(xs: &[f64]) -> usize {
        let m = xs.len() & !3;
        let mut best = xs[0];
        if m >= 4 {
            let mut acc = _mm256_loadu_pd(xs.as_ptr());
            let mut i = 4;
            while i < m {
                acc = _mm256_max_pd(acc, _mm256_loadu_pd(xs.as_ptr().add(i)));
                i += 4;
            }
            let mut lanes = [0.0f64; 4];
            _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
            for &t in &lanes {
                if t > best {
                    best = t;
                }
            }
        }
        for &x in &xs[m..] {
            if x > best {
                best = x;
            }
        }
        let needle = _mm256_set1_pd(best);
        let mut i = 0;
        while i < m {
            let eq = _mm256_cmp_pd::<_CMP_EQ_OQ>(_mm256_loadu_pd(xs.as_ptr().add(i)), needle);
            let mask = _mm256_movemask_pd(eq);
            if mask != 0 {
                return i + mask.trailing_zeros() as usize;
            }
            i += 4;
        }
        for (j, &x) in xs[m..].iter().enumerate() {
            if x == best {
                return m + j;
            }
        }
        // Unreachable for NaN-free input; mirror the scalar scan's fallback.
        0
    }

    /// Two-pass argmin; see [`argmax`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn argmin(xs: &[f64]) -> usize {
        let m = xs.len() & !3;
        let mut best = xs[0];
        if m >= 4 {
            let mut acc = _mm256_loadu_pd(xs.as_ptr());
            let mut i = 4;
            while i < m {
                acc = _mm256_min_pd(acc, _mm256_loadu_pd(xs.as_ptr().add(i)));
                i += 4;
            }
            let mut lanes = [0.0f64; 4];
            _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
            for &t in &lanes {
                if t < best {
                    best = t;
                }
            }
        }
        for &x in &xs[m..] {
            if x < best {
                best = x;
            }
        }
        let needle = _mm256_set1_pd(best);
        let mut i = 0;
        while i < m {
            let eq = _mm256_cmp_pd::<_CMP_EQ_OQ>(_mm256_loadu_pd(xs.as_ptr().add(i)), needle);
            let mask = _mm256_movemask_pd(eq);
            if mask != 0 {
                return i + mask.trailing_zeros() as usize;
            }
            i += 4;
        }
        for (j, &x) in xs[m..].iter().enumerate() {
            if x == best {
                return m + j;
            }
        }
        0
    }

    /// Lane-wise min-merge; strict `<` keeps the left side on ties, exactly
    /// like the scalar loop.
    #[target_feature(enable = "avx2")]
    pub unsafe fn merge_min_into(y: &mut [f64], s: &mut [u64], oy: &[f64], os: &[u64]) {
        let m = y.len() & !3;
        let mut i = 0;
        while i < m {
            let yv = _mm256_loadu_pd(y.as_ptr().add(i));
            let ov = _mm256_loadu_pd(oy.as_ptr().add(i));
            let lt = _mm256_cmp_pd::<_CMP_LT_OQ>(ov, yv);
            _mm256_storeu_pd(y.as_mut_ptr().add(i), _mm256_blendv_pd(yv, ov, lt));
            let sv = _mm256_loadu_si256(s.as_ptr().add(i) as *const __m256i);
            let osv = _mm256_loadu_si256(os.as_ptr().add(i) as *const __m256i);
            let picked = _mm256_blendv_epi8(sv, osv, _mm256_castpd_si256(lt));
            _mm256_storeu_si256(s.as_mut_ptr().add(i) as *mut __m256i, picked);
            i += 4;
        }
        for j in m..y.len() {
            if oy[j] < y[j] {
                y[j] = oy[j];
                s[j] = os[j];
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn count_empty(s: &[u64]) -> usize {
        let m = s.len() & !3;
        let needle = _mm256_set1_epi64x(EMPTY_REGISTER as i64);
        let mut count = 0usize;
        let mut i = 0;
        while i < m {
            let eq =
                _mm256_cmpeq_epi64(_mm256_loadu_si256(s.as_ptr().add(i) as *const __m256i), needle);
            count += _mm256_movemask_pd(_mm256_castsi256_pd(eq)).count_ones() as usize;
            i += 4;
        }
        count + s[m..].iter().filter(|&&x| x == EMPTY_REGISTER).count()
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn match_count(a: &[u64], b: &[u64]) -> usize {
        let m = a.len() & !3;
        let empty = _mm256_set1_epi64x(EMPTY_REGISTER as i64);
        let mut count = 0usize;
        let mut i = 0;
        while i < m {
            let av = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let bv = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
            let eq = _mm256_cmpeq_epi64(av, bv);
            let is_empty = _mm256_cmpeq_epi64(av, empty);
            // matched AND NOT empty.
            let hit = _mm256_andnot_si256(is_empty, eq);
            count += _mm256_movemask_pd(_mm256_castsi256_pd(hit)).count_ones() as usize;
            i += 4;
        }
        for j in m..a.len() {
            if a[j] != EMPTY_REGISTER && a[j] == b[j] {
                count += 1;
            }
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run `f` on both backends; on hosts without AVX2 the Simd leg simply
    /// re-exercises the scalar path (still a valid identity).
    fn both<T: PartialEq + std::fmt::Debug>(f: impl Fn(Backend) -> T) -> T {
        let a = f(Backend::Scalar);
        let b = f(Backend::Simd);
        assert_eq!(a, b, "backends diverged");
        a
    }

    #[test]
    fn u64_block_matches_scalar_stream_and_state() {
        for len in [0usize, 1, 3, 4, 5, 8, 31, 64, 65] {
            let mut want = SplitMix64::new(0xFEED);
            let scalar: Vec<u64> = (0..len).map(|_| want.next_u64()).collect();
            for backend in [Backend::Scalar, Backend::Simd] {
                let mut rng = SplitMix64::new(0xFEED);
                let mut out = vec![0u64; len];
                fill_u64_block_with(backend, &mut rng, &mut out);
                assert_eq!(out, scalar, "len {len} backend {backend:?}");
                // Stream continues exactly where the scalar loop left it.
                assert_eq!(rng.next_u64(), want.clone().next_u64(), "len {len} continuation");
            }
        }
    }

    #[test]
    fn uniform_and_exp_blocks_are_bit_identical_across_backends() {
        for len in [1usize, 4, 7, 33] {
            let bits = both(|backend| {
                let mut rng = SplitMix64::new(42);
                let mut out = vec![0.0f64; len];
                fill_uniform_block_with(backend, &mut rng, &mut out);
                out.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            });
            let mut want = SplitMix64::new(42);
            for (i, b) in bits.iter().enumerate() {
                assert_eq!(*b, want.next_f64().to_bits(), "uniform #{i} of {len}");
            }
            let exp_bits = both(|backend| {
                let mut rng = SplitMix64::new(42);
                let mut out = vec![0.0f64; len];
                fill_exp_block_with(backend, &mut rng, &mut out);
                out.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            });
            let mut want = SplitMix64::new(42);
            for (i, b) in exp_bits.iter().enumerate() {
                assert_eq!(*b, want.next_exp().to_bits(), "exp #{i} of {len}");
            }
        }
    }

    #[test]
    fn direct_rows_and_fused_update_match_scalar() {
        use crate::util::rng::direct_element_hash;
        let h = direct_element_hash(99, 1234);
        for len in [1usize, 7, 8, 9, 100] {
            let row = both(|backend| {
                let mut out = vec![0.0f32; len];
                direct_exp_row_with(backend, h, 5, &mut out);
                out.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            });
            for (t, b) in row.iter().enumerate() {
                assert_eq!(*b, direct_exp_from_hash(h, 5 + t as u32).to_bits(), "row[{t}]");
            }
            // Chunk-splitting invariance (the lemiesz push pattern).
            let mut whole = vec![0.0f32; len];
            direct_exp_row(h, 0, &mut whole);
            let mut split = vec![0.0f32; len];
            let cut = len / 2;
            direct_exp_row(h, 0, &mut split[..cut]);
            direct_exp_row(h, cut as u32, &mut split[cut..]);
            assert_eq!(
                whole.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                split.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            let mut row_f = vec![0.0f32; len];
            direct_exp_row(h, 0, &mut row_f);
            both(|backend| {
                let mut y = vec![0.9f64; len];
                let mut s = vec![EMPTY_REGISTER; len];
                scaled_min_update_with(backend, &row_f, 2.0, 77, &mut y, &mut s);
                (y.iter().map(|x| x.to_bits()).collect::<Vec<_>>(), s)
            });
        }
    }

    #[test]
    fn scans_agree_with_scalar_reference_on_awkward_shapes() {
        let mut r = SplitMix64::new(3);
        for len in [1usize, 2, 3, 4, 5, 7, 8, 9, 63, 64, 65] {
            let mut xs: Vec<f64> = (0..len).map(|_| r.next_exp()).collect();
            // Force ties and infinities into the mix.
            if len >= 4 {
                xs[len / 2] = xs[0];
                xs[len - 1] = f64::INFINITY;
            }
            both(|backend| argmax_f64_with(backend, &xs));
            both(|backend| argmin_f64_with(backend, &xs));
            let a: Vec<u64> = (0..len)
                .map(|_| {
                    if r.next_f64() < 0.3 {
                        EMPTY_REGISTER
                    } else {
                        r.next_range(0, 3) as u64
                    }
                })
                .collect();
            let b: Vec<u64> = a
                .iter()
                .map(|&x| if r.next_f64() < 0.5 { x } else { r.next_range(0, 3) as u64 })
                .collect();
            both(|backend| count_empty_with(backend, &a));
            both(|backend| match_count_with(backend, &a, &b));
            let oy: Vec<f64> = (0..len).map(|_| r.next_exp()).collect();
            let os: Vec<u64> = (0..len).map(|_| r.next_u64()).collect();
            both(|backend| {
                let mut y = xs.clone();
                let mut s = os.clone();
                merge_min_into_with(backend, &mut y, &mut s, &oy, &os);
                (y.iter().map(|x| x.to_bits()).collect::<Vec<_>>(), s)
            });
        }
    }

    #[test]
    fn forced_backend_round_trips() {
        let before = active();
        set_forced(Some(Backend::Scalar));
        assert_eq!(active(), Backend::Scalar);
        set_forced(None);
        assert_eq!(active(), detected());
        let _ = before;
    }
}
