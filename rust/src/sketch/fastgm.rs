//! FastGM — Algorithm 1 of the paper.
//!
//! Computes the k-length Gumbel-Max sketch in `O(k ln k + n⁺)` expected time
//! by releasing the per-element exponential races ([`ElementRace`]) in
//! approximate global arrival order:
//!
//! * **FastSearch** — rounds of a growing budget `R` (step `Δ`, default k):
//!   each queue `Q_i` releases up to `R_i = ⌈R·v*_i⌉` customers (`v*` the
//!   normalized weights), so heavy elements — the likely Gumbel-Max winners
//!   — go first. The phase ends when every register has been appointed at
//!   least once (expected after `R ≈ k ln k` releases; coupon collector).
//! * **FastPrune** — with `y* = max_j y_j` known, a queue is closed the
//!   moment its next arrival exceeds `y*`: later arrivals are larger still
//!   and can never win a register. `y*` shrinks as registers improve, which
//!   accelerates the cascade of queue closures.
//!
//! The output is **bit-identical** to the brute-force drain of all queues
//! ([`order_stats::oracle_registers`]) — early termination is lossless, not
//! approximate. The property test below locks that in.

use super::engine::SketchScratch;
use super::kernels;
use super::order_stats::ElementRace;
use super::{Family, GumbelMaxSketch, Sketcher, SparseVector};

/// FastGM sketcher (Algorithm 1).
#[derive(Debug, Clone)]
pub struct FastGm {
    pub k: usize,
    pub seed: u64,
    /// FastSearch budget step `Δ`; the paper uses `Δ = k` and reports low
    /// sensitivity (we reproduce that in the `ablation-delta` experiment).
    pub delta: usize,
}

/// Work counters reported by [`FastGm::sketch_counted`] — the quantity the
/// paper's complexity claim is about (variables generated vs. `n⁺·k`).
#[derive(Debug, Clone, Copy, Default)]
pub struct FastGmStats {
    /// Exponential variables generated during FastSearch.
    pub search_released: u64,
    /// Exponential variables generated during FastPrune.
    pub prune_released: u64,
    /// FastSearch rounds (budget increments) used.
    pub rounds: u64,
}

impl FastGmStats {
    pub fn total_released(&self) -> u64 {
        self.search_released + self.prune_released
    }
}

impl FastGm {
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 1, "sketch length k must be >= 1");
        FastGm { k, seed, delta: k }
    }

    pub fn with_delta(mut self, delta: usize) -> Self {
        assert!(delta >= 1);
        self.delta = delta;
        self
    }

    /// Sketch with work counters (used by the complexity experiments).
    pub fn sketch_counted(&self, v: &SparseVector) -> (GumbelMaxSketch, FastGmStats) {
        let mut scratch = SketchScratch::new();
        let mut out = GumbelMaxSketch::empty(Family::Ordered, self.seed, self.k);
        let stats = self.sketch_counted_into(v, &mut scratch, &mut out);
        (out, stats)
    }

    /// The allocation-free core: sketch `v` into `out` reusing `scratch`'s
    /// race queues and worklists. Bit-identical to [`FastGm::sketch_counted`]
    /// regardless of scratch state.
    pub fn sketch_counted_into(
        &self,
        v: &SparseVector,
        scratch: &mut SketchScratch,
        out: &mut GumbelMaxSketch,
    ) -> FastGmStats {
        let k = self.k;
        out.reset(Family::Ordered, self.seed, k);
        let mut stats = FastGmStats::default();

        scratch.elements.clear();
        scratch.elements.extend(v.positive());
        if scratch.elements.is_empty() {
            return stats;
        }
        let n = scratch.elements.len();
        let total_w: f64 = scratch.elements.iter().map(|(_, w)| w).sum();

        // Re-arm the pooled races in place; grow the pool only on demand.
        for (idx, &(id, w)) in scratch.elements.iter().enumerate() {
            if idx < scratch.races.len() {
                scratch.races[idx].reset(self.seed, id, w, k);
            } else {
                scratch.races.push(ElementRace::new(self.seed, id, w, k));
            }
        }
        let elements = &scratch.elements[..n];
        let races = &mut scratch.races[..n];

        // ------------------------------------------------------- FastSearch
        let mut unfilled = k;
        let mut budget = 0.0f64; // R in the paper
        while unfilled > 0 {
            budget += self.delta as f64;
            stats.rounds += 1;
            for (idx, race) in races.iter_mut().enumerate() {
                let (id, w) = elements[idx];
                // R_i = ceil(R · v*_i), capped at k by the race itself.
                let r_i = (budget * w / total_w).ceil() as u32;
                while race.z < r_i {
                    let Some((b, c)) = race.next() else { break };
                    stats.search_released += 1;
                    let c = c as usize;
                    if out.s[c] == super::EMPTY_REGISTER {
                        out.y[c] = b;
                        out.s[c] = id;
                        unfilled -= 1;
                    } else if b < out.y[c] {
                        out.y[c] = b;
                        out.s[c] = id;
                    }
                }
            }
            if races.iter().all(|r| r.exhausted()) {
                // Every queue fully drained (k·n⁺ small): each queue touches
                // every register once, so all registers are filled.
                debug_assert_eq!(unfilled, 0);
                break;
            }
        }

        // ------------------------------------------------------- FastPrune
        // j* = argmax_j y_j; a queue whose next arrival exceeds y_{j*} can
        // never improve any register.
        let mut jstar = kernels::argmax_f64(&out.y);
        let alive = &mut scratch.alive;
        let next_alive = &mut scratch.next_alive;
        alive.clear();
        alive.extend((0..n).filter(|&i| !races[i].exhausted()));
        while !alive.is_empty() {
            budget += self.delta as f64;
            next_alive.clear();
            'queues: for &idx in alive.iter() {
                let (id, w) = elements[idx];
                let race = &mut races[idx];
                // At least one release per round: a feather-weight element
                // would otherwise sit idle (scanned but unreleased) for
                // ~total_w/(Δ·v_i) rounds before its first prune check —
                // the pathology the §Perf log documents (3.4 ms → fixed).
                // The prune rule is schedule-independent, so the output is
                // unchanged (delta_invariance + oracle tests).
                let r_i = ((budget * w / total_w).ceil() as u32).max(race.z + 1);
                while race.z < r_i {
                    let Some((b, c)) = race.next() else { break };
                    stats.prune_released += 1;
                    if b > out.y[jstar] {
                        continue 'queues; // queue closed for good
                    }
                    let c = c as usize;
                    if b < out.y[c] {
                        out.y[c] = b;
                        out.s[c] = id;
                        if c == jstar {
                            jstar = kernels::argmax_f64(&out.y);
                        }
                    }
                }
                if !race.exhausted() {
                    next_alive.push(idx);
                }
            }
            std::mem::swap(alive, next_alive);
        }

        stats
    }
}

impl Sketcher for FastGm {
    fn name(&self) -> &'static str {
        "fastgm"
    }

    fn family(&self) -> Family {
        Family::Ordered
    }

    fn k(&self) -> usize {
        self.k
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn sketch_into(&self, v: &SparseVector, scratch: &mut SketchScratch, out: &mut GumbelMaxSketch) {
        self.sketch_counted_into(v, scratch, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::order_stats::oracle_registers;
    use crate::util::proptest::forall_explain;
    use crate::util::rng::SplitMix64;
    use crate::util::stats::OnlineStats;

    fn random_vector(r: &mut SplitMix64, max_n: usize) -> SparseVector {
        let n = r.next_range(1, max_n);
        let mut v = SparseVector::default();
        for _ in 0..n {
            // Skewed weights exercise both heavy and feather-light queues.
            let w = r.next_exp() * 10f64.powi(r.next_range(0, 3) as i32 - 1);
            v.push(r.next_u64(), w);
        }
        v
    }

    /// THE core correctness property: FastGM == brute-force oracle, exactly.
    #[test]
    fn matches_oracle_exactly() {
        forall_explain(
            60,
            |r| {
                let k = [1, 2, 8, 33, 64][r.next_range(0, 4)];
                let seed = r.next_u64();
                (seed, k, random_vector(r, 50))
            },
            |(seed, k, v)| {
                let (sk, _) = FastGm::new(*k, *seed).sketch_counted(v);
                let elements: Vec<(u64, f64)> = v.positive().collect();
                let (oy, os) = oracle_registers(*seed, &elements, *k);
                if sk.y == oy && sk.s == os {
                    Ok(())
                } else {
                    Err(format!("sketch != oracle for k={k}\ny={:?}\noy={:?}", sk.y, oy))
                }
            },
        );
    }

    /// Δ must not change the output (only the work schedule).
    #[test]
    fn delta_invariance() {
        forall_explain(
            30,
            |r| (r.next_u64(), random_vector(r, 40)),
            |(seed, v)| {
                let k = 32;
                let base = FastGm::new(k, *seed).sketch(v);
                for delta in [1usize, 7, k / 2, 2 * k, 16 * k] {
                    let alt = FastGm::new(k, *seed).with_delta(delta).sketch(v);
                    if alt != base {
                        return Err(format!("delta={delta} changed the sketch"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_vector_yields_empty_sketch() {
        let sk = FastGm::new(16, 1).sketch(&SparseVector::default());
        assert!(sk.y.iter().all(|y| y.is_infinite()));
        assert!(sk.s.iter().all(|&s| s == super::super::EMPTY_REGISTER));
        let sk2 = FastGm::new(16, 1).sketch(&SparseVector::new(vec![3], vec![0.0]));
        assert_eq!(sk, sk2);
    }

    #[test]
    fn scale_invariance_of_argmax_part() {
        // s(v) only depends on v up to scale; y scales by 1/c.
        let mut r = SplitMix64::new(5);
        let v = random_vector(&mut r, 30);
        let scaled =
            SparseVector::new(v.ids.clone(), v.weights.iter().map(|w| w * 7.5).collect());
        let a = FastGm::new(64, 9).sketch(&v);
        let b = FastGm::new(64, 9).sketch(&scaled);
        assert_eq!(a.s, b.s);
        for j in 0..64 {
            assert!((a.y[j] / 7.5 - b.y[j]).abs() < 1e-9 * a.y[j].abs().max(1.0));
        }
    }

    #[test]
    fn single_element_vector() {
        let v = SparseVector::new(vec![77], vec![3.0]);
        let sk = FastGm::new(8, 2).sketch(&v);
        assert!(sk.s.iter().all(|&s| s == 77));
        assert!(sk.y.iter().all(|&y| y.is_finite() && y > 0.0));
    }

    /// Work released should be ~O(k ln k + n⁺), far below n⁺·k for large n.
    #[test]
    fn work_is_subquadratic() {
        let mut r = SplitMix64::new(11);
        let k = 128;
        let n = 4000;
        let v = SparseVector::new(
            (0..n as u64).collect(),
            (0..n).map(|_| r.next_f64() + 1e-3).collect(),
        );
        let (_, stats) = FastGm::new(k, 1).sketch_counted(&v);
        let brute = (n * k) as u64;
        let bound = (8.0 * (k as f64) * (k as f64).ln() + 4.0 * n as f64) as u64;
        assert!(
            stats.total_released() < bound.min(brute / 4),
            "released {} (brute {brute}, bound {bound})",
            stats.total_released()
        );
    }

    /// Gumbel-Max distribution: P(s_j = i) = v_i / Σv — the defining
    /// property of the trick.
    #[test]
    fn argmax_distribution_proportional_to_weight() {
        let v = SparseVector::new(vec![0, 1, 2], vec![0.6, 0.3, 0.1]);
        let k = 2000;
        let sk = FastGm::new(k, 123).sketch(&v);
        let mut counts = [0usize; 3];
        for &s in &sk.s {
            counts[s as usize] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let p = c as f64 / k as f64;
            let want = v.weights[i];
            assert!((p - want).abs() < 0.04, "element {i}: p={p} want={want}");
        }
    }

    /// y_j ~ EXP(Σv): mean 1/Σv (paper §2.5).
    #[test]
    fn y_registers_are_exponential_in_total_weight() {
        let v = SparseVector::new(vec![0, 1, 2, 3], vec![0.5, 1.0, 0.25, 0.25]);
        let total = 2.0;
        let mut stats = OnlineStats::new();
        for seed in 0..200u64 {
            let sk = FastGm::new(64, seed).sketch(&v);
            for y in sk.y {
                stats.push(y);
            }
        }
        assert!(
            (stats.mean() - 1.0 / total).abs() < 0.01,
            "mean={} want={}",
            stats.mean(),
            1.0 / total
        );
    }
}
