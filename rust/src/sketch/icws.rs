//! ICWS — Improved Consistent Weighted Sampling (Ioffe, ICDM'10).
//! Related-work baseline: the CWS family all cost `O(k·n⁺)`, the regime
//! FastGM escapes.
//!
//! Per element `i` (weight `w`) and register `j`, with a deterministic
//! stream per `(i, j)`:
//!
//! ```text
//!   r, c ~ Gamma(2,1),  β ~ UNI(0,1)
//!   t = ⌊ln w / r + β⌋,   y = exp(r(t-β)),   a = c / (y·e^r)
//! ```
//!
//! The register keeps the argmin-`a` element together with its quantized
//! level `t`; the full `(i, t)` signature collides between two vectors with
//! probability **exactly** `J_W` (Ioffe's consistency theorem). Matching on
//! `i` alone (0-bit CWS, Li '15) is also exposed — it is biased upward for
//! strongly correlated weight changes, which one of the tests demonstrates.

use crate::util::rng::{fmix64, SplitMix64};
use super::engine::SketchScratch;
use super::{Family, GumbelMaxSketch, Sketcher, SparseVector};

const ICWS_SALT: u64 = 0x1C75_5EED_0FF1_CE00;

/// Full ICWS signature: a view over the common Gumbel-Max registers
/// (`base.y` holds the minimal `a` values, `base.s` the argmin ids, family
/// [`Family::Icws`]) plus the quantized weight level `t` of each winner —
/// the extra coordinate the unbiased `(id, t)` estimator needs.
#[derive(Debug, Clone, PartialEq)]
pub struct IcwsSketch {
    pub base: GumbelMaxSketch,
    /// Quantized weight level `t` of the argmin element, per register.
    pub t: Vec<f64>,
}

impl IcwsSketch {
    pub fn seed(&self) -> u64 {
        self.base.seed
    }

    /// Estimate weighted Jaccard from the full `(id, t)` signature —
    /// unbiased (consistency theorem).
    pub fn estimate_jw(&self, other: &IcwsSketch) -> f64 {
        assert_eq!(self.base.seed, other.base.seed, "ICWS seeds must match");
        assert_eq!(self.base.k(), other.base.k());
        let k = self.base.k();
        let m = (0..k)
            .filter(|&j| self.base.s[j] == other.base.s[j] && self.t[j] == other.t[j])
            .count();
        m as f64 / k as f64
    }

    /// 0-bit variant: match on element id only (biased but register-free).
    pub fn estimate_jw_0bit(&self, other: &IcwsSketch) -> f64 {
        assert_eq!(self.base.seed, other.base.seed);
        let k = self.base.k();
        let m = (0..k).filter(|&j| self.base.s[j] == other.base.s[j]).count();
        m as f64 / k as f64
    }
}

#[derive(Debug, Clone)]
pub struct Icws {
    pub k: usize,
    pub seed: u64,
}

impl Icws {
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 1);
        Icws { k, seed }
    }

    /// Shared core: fill `out`'s registers and, when given, the `t` levels.
    fn fill(&self, v: &SparseVector, out: &mut GumbelMaxSketch, mut t_out: Option<&mut [f64]>) {
        let k = self.k;
        for (id, w) in v.positive() {
            let ln_w = w.ln();
            // One deterministic stream per (element, register): consistency
            // across vectors requires the same (r, c, β) for a given (i, j).
            let base = fmix64(id ^ ICWS_SALT) ^ self.seed;
            for j in 0..k {
                let mut rng = SplitMix64::new(base.wrapping_add((j as u64) << 1 | 1));
                let r = -(rng.next_f64().ln() + rng.next_f64().ln()); // Gamma(2,1)
                let c = -(rng.next_f64().ln() + rng.next_f64().ln());
                let beta = rng.next_f64();
                let t = (ln_w / r + beta).floor();
                let ln_y = r * (t - beta);
                let a_ij = c * (-ln_y - r).exp();
                if a_ij < out.y[j] {
                    out.y[j] = a_ij;
                    out.s[j] = id;
                    if let Some(ts) = t_out.as_deref_mut() {
                        ts[j] = t;
                    }
                }
            }
        }
    }

    /// Full signature including the `t` levels (the unbiased estimator).
    pub fn sketch_full(&self, v: &SparseVector) -> IcwsSketch {
        let mut base = GumbelMaxSketch::empty(Family::Icws, self.seed, self.k);
        let mut t = vec![0.0f64; self.k];
        self.fill(v, &mut base, Some(&mut t));
        IcwsSketch { base, t }
    }
}

impl Sketcher for Icws {
    fn name(&self) -> &'static str {
        "icws"
    }

    fn family(&self) -> Family {
        Family::Icws
    }

    fn k(&self) -> usize {
        self.k
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn sketch_into(&self, v: &SparseVector, _scratch: &mut SketchScratch, out: &mut GumbelMaxSketch) {
        out.reset(Family::Icws, self.seed, self.k);
        self.fill(v, out, None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimate::jaccard::weighted_jaccard;
    use crate::sketch::EMPTY_REGISTER;
    use crate::util::stats::OnlineStats;

    #[test]
    fn deterministic_and_consistent() {
        let v = SparseVector::new(vec![3, 5, 9], vec![0.2, 2.0, 1.0]);
        let a = Icws::new(32, 1).sketch_full(&v);
        let b = Icws::new(32, 1).sketch_full(&v);
        assert_eq!(a, b);
        assert!(a.base.s.iter().all(|&x| x != EMPTY_REGISTER));
    }

    #[test]
    fn trait_registers_equal_full_signature_base() {
        let v = SparseVector::new(vec![3, 5, 9], vec![0.2, 2.0, 1.0]);
        let icws = Icws::new(32, 1);
        assert_eq!(icws.sketch(&v), icws.sketch_full(&v).base);
    }

    #[test]
    fn identical_vectors_match_fully() {
        let v = SparseVector::new(vec![1, 2], vec![1.5, 0.5]);
        let a = Icws::new(64, 7).sketch_full(&v);
        assert_eq!(a.estimate_jw(&a), 1.0);
    }

    /// Consistency theorem: (id, t) match probability == J_W, including
    /// shared elements with different weights.
    #[test]
    fn jw_estimator_is_unbiased() {
        let u = SparseVector::new(vec![1, 2, 3], vec![2.0, 1.0, 1.0]);
        let v = SparseVector::new(vec![1, 2, 4], vec![1.0, 1.0, 2.0]);
        let truth = weighted_jaccard(&u, &v); // (1+1)/(2+1+1+2) = 1/3
        let mut stats = OnlineStats::new();
        for seed in 0..60u64 {
            let icws = Icws::new(128, seed);
            stats.push(icws.sketch_full(&u).estimate_jw(&icws.sketch_full(&v)));
        }
        assert!(
            (stats.mean() - truth).abs() < 0.02,
            "est={} truth={truth}",
            stats.mean()
        );
    }

    /// The 0-bit shortcut is biased upward under pure rescaling (weights
    /// fully correlated) — the documented failure mode.
    #[test]
    fn zero_bit_variant_overestimates_under_rescaling() {
        let u = SparseVector::new(vec![1, 2], vec![1.0, 1.0]);
        let v2 = SparseVector::new(vec![1, 2], vec![2.0, 2.0]);
        let truth = weighted_jaccard(&u, &v2); // 0.5
        let mut full = OnlineStats::new();
        let mut zbit = OnlineStats::new();
        for seed in 0..60u64 {
            let icws = Icws::new(128, seed);
            let (su, sv) = (icws.sketch_full(&u), icws.sketch_full(&v2));
            full.push(su.estimate_jw(&sv));
            zbit.push(su.estimate_jw_0bit(&sv));
        }
        assert!((full.mean() - truth).abs() < 0.03, "full={}", full.mean());
        assert!(zbit.mean() > truth + 0.1, "0-bit should overestimate here: {}", zbit.mean());
    }
}
