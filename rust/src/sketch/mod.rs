//! Gumbel-Max sketches and the algorithms that compute them.
//!
//! The paper defines, for a non-negative vector `v` and `j = 1..k`:
//!
//! ```text
//!   y_j(v) = min_{i ∈ N⁺}  -ln(a_ij) / v_i        (Gumbel-Max part)
//!   s_j(v) = argmin_{i ∈ N⁺} -ln(a_ij) / v_i      (Gumbel-ArgMax part)
//! ```
//!
//! with `a_ij ~ UNI(0,1)` shared across vectors. [`GumbelMaxSketch`] holds
//! both parts; `x_j = -ln y_j` recovers the literal Gumbel-Max variable.
//!
//! Implementations (all constructible by name via [`engine`], the
//! zero-allocation registry; see [`Sketcher::sketch_into`]):
//! * [`fastgm`] — the paper's contribution, `O(k ln k + n⁺)` (Algorithm 1).
//! * [`sharded`] — FastGM fanned out over weight-balanced shards and merged
//!   (§2.3 union property): bit-identical, multi-core.
//! * [`stream_fastgm`] — one-pass streaming variant (Algorithm 2).
//! * [`fastgm_c`] — the WWW'20 conference version (prune-only baseline).
//! * [`pminhash`] — straightforward `O(k n⁺)` P-MinHash (Moulton & Jiang).
//! * [`lemiesz`] — Lemiesz's weighted-cardinality sketch (`y` part only).
//! * [`bagminhash`] — BagMinHash-style weighted-Jaccard baseline (Ertl '18).
//! * [`icws`] — Improved Consistent Weighted Sampling (Ioffe '10).
//! * [`minhash`] — classic binary MinHash (substrate / related work).
//! * [`hyperloglog`] — HLL for unweighted cardinality (ablation baseline).
//! * [`order_stats`] — the ascending-exponential + streamed-Fisher–Yates
//!   generator both FastGM variants and BagMinHash build on.
//!
//! [`codec`] is not an algorithm: it is the versioned binary snapshot
//! format the coordinator's keyed sketch store persists through.

pub mod order_stats;
pub mod codec;
pub mod engine;
pub mod kernels;
pub mod fastgm;
pub mod sharded;
pub mod stream_fastgm;
pub mod fastgm_c;
pub mod pminhash;
pub mod lemiesz;
pub mod bagminhash;
pub mod icws;
pub mod minhash;
pub mod hyperloglog;

pub use engine::{AlgorithmId, EngineParams, SketchScratch};

use crate::util::json::Value;

/// RNG family backing a sketch (see [`crate::util::rng`] and README.md
/// §RNG-families). Sketches are only comparable
/// within a family; [`GumbelMaxSketch::merge`] and the estimators enforce it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    /// SplitMix64 per-element streams through the order-statistics
    /// construction (FastGM, Stream-FastGM, FastGM-c, sharded).
    Ordered,
    /// Stateless counter RNG `direct_bits(seed, i, j)`, mirrored by the
    /// Pallas kernels (P-MinHash, Lemiesz, dense accelerator).
    Direct,
    /// ICWS race values (Ioffe '10): estimates J_W, comparable only with
    /// other ICWS sketches.
    Icws,
    /// BagMinHash Poisson-point races (Ertl '18): estimates J_W, comparable
    /// only with other BagMinHash sketches.
    Bag,
    /// Classic binary MinHash over the support set (unweighted).
    MinHash,
}

impl Family {
    pub fn name(self) -> &'static str {
        match self {
            Family::Ordered => "ordered",
            Family::Direct => "direct",
            Family::Icws => "icws",
            Family::Bag => "bagminhash",
            Family::MinHash => "minhash",
        }
    }

    pub fn from_name(s: &str) -> anyhow::Result<Family> {
        match s {
            "ordered" => Ok(Family::Ordered),
            "direct" => Ok(Family::Direct),
            "icws" => Ok(Family::Icws),
            "bagminhash" => Ok(Family::Bag),
            "minhash" => Ok(Family::MinHash),
            _ => anyhow::bail!("unknown sketch family '{s}'"),
        }
    }

    /// Whether this family's `y` registers are `EXP(Σw)` Gumbel-Max races —
    /// the precondition of the cardinality algebra (Theorem 2 / Lemiesz)
    /// and of the `J_P` ArgMax-match estimator. ICWS and BagMinHash
    /// registers race different variables (their dedicated `estimate_jw`
    /// views apply); MinHash `y` holds uniform hash projections.
    pub fn has_exponential_registers(self) -> bool {
        matches!(self, Family::Ordered | Family::Direct)
    }
}

/// Sentinel for an untouched ArgMax register.
pub const EMPTY_REGISTER: u64 = u64::MAX;

/// Fold a 64-bit element id into the 32-bit Direct-RNG index space (the
/// Pallas kernel indexes dense columns with u32; sparse ids are folded the
/// same way on both sides).
#[inline]
pub fn fold_id(id: u64) -> u32 {
    (id ^ (id >> 32)) as u32
}

/// A sparse non-negative vector: parallel `ids` / `weights` arrays.
/// Ids are arbitrary u64 (hashed tokens, packet ids, or dense indices);
/// entries with non-positive weight are ignored by every sketcher.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparseVector {
    pub ids: Vec<u64>,
    pub weights: Vec<f64>,
}

impl SparseVector {
    pub fn new(ids: Vec<u64>, weights: Vec<f64>) -> Self {
        assert_eq!(ids.len(), weights.len(), "ids/weights length mismatch");
        SparseVector { ids, weights }
    }

    /// Build from a dense slice; indices become ids.
    pub fn from_dense(xs: &[f64]) -> Self {
        let mut ids = Vec::new();
        let mut weights = Vec::new();
        for (i, &x) in xs.iter().enumerate() {
            if x > 0.0 {
                ids.push(i as u64);
                weights.push(x);
            }
        }
        SparseVector { ids, weights }
    }

    /// Iterator over strictly positive, finite entries.
    pub fn positive(&self) -> impl Iterator<Item = (u64, f64)> + '_ {
        self.ids
            .iter()
            .zip(&self.weights)
            .filter(|(_, &w)| w > 0.0 && w.is_finite())
            .map(|(&i, &w)| (i, w))
    }

    pub fn n_plus(&self) -> usize {
        self.positive().count()
    }

    pub fn total_weight(&self) -> f64 {
        self.positive().map(|(_, w)| w).sum()
    }

    pub fn is_empty_positive(&self) -> bool {
        self.positive().next().is_none()
    }

    pub fn push(&mut self, id: u64, w: f64) {
        self.ids.push(id);
        self.weights.push(w);
    }
}

/// A k-length Gumbel-Max sketch: the `y` (min value) and `s` (argmin id)
/// register arrays, tagged with the RNG family and seed that produced it.
#[derive(Debug, Clone, PartialEq)]
pub struct GumbelMaxSketch {
    pub family: Family,
    pub seed: u64,
    pub y: Vec<f64>,
    pub s: Vec<u64>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum MergeError {
    #[error("sketch family mismatch: {0} vs {1}")]
    FamilyMismatch(&'static str, &'static str),
    #[error("sketch seed mismatch: {0} vs {1}")]
    SeedMismatch(u64, u64),
    #[error("sketch length mismatch: {0} vs {1}")]
    LengthMismatch(usize, usize),
    /// The sketches are mutually compatible but the requested estimator is
    /// not defined for their family (e.g. cardinality algebra on MinHash
    /// registers, `J_P` on ICWS races). Failing loudly here keeps the new
    /// per-request `algo` surface from silently returning biased numbers.
    #[error("no {estimator} estimator for '{family}' sketches ({hint})")]
    EstimatorUnsupported {
        estimator: &'static str,
        family: &'static str,
        hint: &'static str,
    },
    /// A merge was asked to combine zero sketches. There is no meaningful
    /// identity element (the empty sketch of *which* family/seed/k?), and
    /// the cluster gather path reaches this exact case when every site is
    /// down — it must surface as an error, never a panic.
    #[error("cannot merge an empty set of sketches")]
    EmptyMerge,
}

impl GumbelMaxSketch {
    pub fn empty(family: Family, seed: u64, k: usize) -> Self {
        GumbelMaxSketch {
            family,
            seed,
            y: vec![f64::INFINITY; k],
            s: vec![EMPTY_REGISTER; k],
        }
    }

    /// Re-initialize in place to the empty sketch of `(family, seed, k)`,
    /// reusing the register allocations. Every [`Sketcher::sketch_into`]
    /// implementation starts with this, so a dirty output buffer can never
    /// leak into a result.
    pub fn reset(&mut self, family: Family, seed: u64, k: usize) {
        self.family = family;
        self.seed = seed;
        self.y.clear();
        self.y.resize(k, f64::INFINITY);
        self.s.clear();
        self.s.resize(k, EMPTY_REGISTER);
    }

    pub fn k(&self) -> usize {
        self.y.len()
    }

    /// The literal Gumbel-Max variables `x_j = -ln y_j`.
    pub fn gumbel_values(&self) -> Vec<f64> {
        self.y.iter().map(|y| -y.ln()).collect()
    }

    pub fn check_compatible(&self, other: &GumbelMaxSketch) -> Result<(), MergeError> {
        if self.family != other.family {
            return Err(MergeError::FamilyMismatch(self.family.name(), other.family.name()));
        }
        if self.seed != other.seed {
            return Err(MergeError::SeedMismatch(self.seed, other.seed));
        }
        if self.k() != other.k() {
            return Err(MergeError::LengthMismatch(self.k(), other.k()));
        }
        Ok(())
    }

    /// Merge (union semantics, §2.3): per register, keep the smaller `y`
    /// and its `s`. The result is exactly the sketch of the union multiset.
    pub fn merge(&self, other: &GumbelMaxSketch) -> Result<GumbelMaxSketch, MergeError> {
        self.check_compatible(other)?;
        let mut out = self.clone();
        out.merge_in_place(other)?;
        Ok(out)
    }

    pub fn merge_in_place(&mut self, other: &GumbelMaxSketch) -> Result<(), MergeError> {
        self.check_compatible(other)?;
        kernels::merge_min_into(&mut self.y, &mut self.s, &other.y, &other.s);
        Ok(())
    }

    /// Merge many sketches (e.g. the per-site sketches of §2.3). Zero
    /// sketches is [`MergeError::EmptyMerge`] — there is no identity
    /// element to return.
    pub fn merge_all<'a>(
        sketches: impl IntoIterator<Item = &'a GumbelMaxSketch>,
    ) -> Result<GumbelMaxSketch, MergeError> {
        let mut it = sketches.into_iter();
        let first = it.next().ok_or(MergeError::EmptyMerge)?;
        let mut acc = first.clone();
        for s in it {
            acc.merge_in_place(s)?;
        }
        Ok(acc)
    }

    // -- JSON wire format (used by coordinator::protocol and persistence) --

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("family", Value::str(self.family.name())),
            ("seed", Value::u64(self.seed)),
            // Infinity is not valid JSON; empty registers encode as -1.
            (
                "y",
                Value::Arr(
                    self.y
                        .iter()
                        .map(|&y| Value::Num(if y.is_finite() { y } else { -1.0 }))
                        .collect(),
                ),
            ),
            // EMPTY_REGISTER (u64::MAX) is not f64-exact; encode as -1.
            (
                "s",
                Value::Arr(
                    self.s
                        .iter()
                        .map(|&s| {
                            if s == EMPTY_REGISTER {
                                Value::Num(-1.0)
                            } else {
                                Value::u64(s)
                            }
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<GumbelMaxSketch> {
        let family = Family::from_name(v.req_str("family")?)?;
        let seed = v
            .req("seed")?
            .as_u64_lossless()
            .ok_or_else(|| anyhow::anyhow!("seed not a valid u64"))?;
        let y: Vec<f64> = v
            .req("y")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("y not an array"))?
            .iter()
            .map(|x| {
                x.as_f64()
                    .map(|f| if f < 0.0 { f64::INFINITY } else { f })
                    .ok_or_else(|| anyhow::anyhow!("y entry not a number"))
            })
            .collect::<anyhow::Result<_>>()?;
        let s: Vec<u64> = v
            .req("s")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("s not an array"))?
            .iter()
            .map(|x| {
                if let Some(f) = x.as_f64() {
                    if f < 0.0 {
                        return Ok(EMPTY_REGISTER);
                    }
                }
                x.as_u64_lossless()
                    .ok_or_else(|| anyhow::anyhow!("s entry not a valid id"))
            })
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(y.len() == s.len(), "y/s length mismatch");
        Ok(GumbelMaxSketch { family, seed, y, s })
    }
}

/// Anything that turns a [`SparseVector`] into a [`GumbelMaxSketch`].
///
/// The trait is object-safe and uniform across all algorithms (`u64` seeds
/// everywhere): the engine registry ([`engine::build_named`]) hands out
/// `Box<dyn Sketcher>` by algorithm name, and the coordinator's worker pool
/// drives every request through [`Sketcher::sketch_into`] with a per-worker
/// [`SketchScratch`] so the hot path allocates nothing per request.
///
/// Contract: `sketch_into` must (a) fully re-initialize `out` (start with
/// [`GumbelMaxSketch::reset`]) and (b) be **bit-identical** to a fresh
/// [`Sketcher::sketch`] call no matter how dirty `scratch` is — scratch
/// reuse is an allocation optimization, never an approximation. The
/// registry-wide property suite in `rust/tests/engine_props.rs` enforces
/// this for every registered algorithm.
pub trait Sketcher: Send + Sync {
    fn name(&self) -> &'static str;
    fn family(&self) -> Family;
    fn k(&self) -> usize;
    /// Seed tagged into produced sketches (unified `u64` for every
    /// algorithm; Direct-family implementations fold it with [`fold_id`]).
    fn seed(&self) -> u64;
    /// Sketch `v` into `out`, reusing `scratch`'s buffers.
    fn sketch_into(&self, v: &SparseVector, scratch: &mut SketchScratch, out: &mut GumbelMaxSketch);
    /// Convenience allocating wrapper around [`Sketcher::sketch_into`].
    fn sketch(&self, v: &SparseVector) -> GumbelMaxSketch {
        let mut scratch = SketchScratch::new();
        let mut out = GumbelMaxSketch::empty(self.family(), self.seed(), self.k());
        self.sketch_into(v, &mut scratch, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_vector_filters_nonpositive() {
        let v = SparseVector::new(vec![1, 2, 3, 4], vec![0.5, 0.0, -1.0, 2.0]);
        assert_eq!(v.n_plus(), 2);
        assert!((v.total_weight() - 2.5).abs() < 1e-12);
        let d = SparseVector::from_dense(&[0.0, 1.5, 0.0, 0.25]);
        assert_eq!(d.ids, vec![1, 3]);
    }

    #[test]
    fn merge_takes_pointwise_min() {
        let a = GumbelMaxSketch {
            family: Family::Ordered,
            seed: 1,
            y: vec![0.5, 2.0],
            s: vec![10, 11],
        };
        let b = GumbelMaxSketch {
            family: Family::Ordered,
            seed: 1,
            y: vec![0.7, 1.0],
            s: vec![20, 21],
        };
        let m = a.merge(&b).unwrap();
        assert_eq!(m.y, vec![0.5, 1.0]);
        assert_eq!(m.s, vec![10, 21]);
    }

    #[test]
    fn merge_rejects_mismatches() {
        let a = GumbelMaxSketch::empty(Family::Ordered, 1, 4);
        let b = GumbelMaxSketch::empty(Family::Direct, 1, 4);
        assert!(matches!(a.merge(&b), Err(MergeError::FamilyMismatch(_, _))));
        let c = GumbelMaxSketch::empty(Family::Ordered, 2, 4);
        assert!(matches!(a.merge(&c), Err(MergeError::SeedMismatch(1, 2))));
        let d = GumbelMaxSketch::empty(Family::Ordered, 1, 8);
        assert!(matches!(a.merge(&d), Err(MergeError::LengthMismatch(4, 8))));
    }

    #[test]
    fn merge_all_of_nothing_is_a_typed_error() {
        assert_eq!(
            GumbelMaxSketch::merge_all(std::iter::empty()).unwrap_err(),
            MergeError::EmptyMerge
        );
    }

    #[test]
    fn merge_is_commutative_and_idempotent() {
        let a = GumbelMaxSketch {
            family: Family::Direct,
            seed: 3,
            y: vec![0.1, 5.0, 2.0],
            s: vec![1, 2, 3],
        };
        let b = GumbelMaxSketch {
            family: Family::Direct,
            seed: 3,
            y: vec![0.2, 4.0, 2.5],
            s: vec![4, 5, 6],
        };
        assert_eq!(a.merge(&b).unwrap(), b.merge(&a).unwrap());
        assert_eq!(a.merge(&a).unwrap(), a);
    }

    #[test]
    fn json_roundtrip_preserves_empty_registers() {
        let mut a = GumbelMaxSketch::empty(Family::Ordered, 42, 3);
        a.y[1] = 0.25;
        a.s[1] = 77;
        let text = a.to_json().to_string();
        let back = GumbelMaxSketch::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.s[0], EMPTY_REGISTER);
        assert_eq!(back.s[1], 77);
        assert_eq!(back.y[1], 0.25);
        assert!(back.y[0].is_infinite());
        assert_eq!(back.family, Family::Ordered);
    }

    fn from_json_str(text: &str) -> anyhow::Result<GumbelMaxSketch> {
        GumbelMaxSketch::from_json(&crate::util::json::parse(text).unwrap())
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        for (text, missing) in [
            (r#"{"seed":1,"y":[1],"s":[2]}"#, "family"),
            (r#"{"family":"ordered","y":[1],"s":[2]}"#, "seed"),
            (r#"{"family":"ordered","seed":1,"s":[2]}"#, "y"),
            (r#"{"family":"ordered","seed":1,"y":[1]}"#, "s"),
        ] {
            let err = from_json_str(text).unwrap_err().to_string();
            assert!(err.contains(missing), "for {text}: {err}");
        }
    }

    #[test]
    fn from_json_rejects_lossy_or_invalid_seeds() {
        // Fractional and negative numbers cannot be u64 seeds.
        assert!(from_json_str(r#"{"family":"ordered","seed":1.5,"y":[],"s":[]}"#).is_err());
        assert!(from_json_str(r#"{"family":"ordered","seed":-3,"y":[],"s":[]}"#).is_err());
        // Non-numeric strings fail the lossless decimal path.
        assert!(from_json_str(r#"{"family":"ordered","seed":"abc","y":[],"s":[]}"#).is_err());
        // A > 2^53 seed survives exactly via the string encoding.
        let sk = from_json_str(
            r#"{"family":"direct","seed":"18446744073709551615","y":[0.5],"s":[1]}"#,
        )
        .unwrap();
        assert_eq!(sk.seed, u64::MAX);
        // And to_json re-encodes it losslessly (string, not a rounded f64).
        let back = GumbelMaxSketch::from_json(
            &crate::util::json::parse(&sk.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.seed, u64::MAX);
    }

    #[test]
    fn from_json_rejects_bad_families_and_registers() {
        assert!(from_json_str(r#"{"family":"quantum","seed":1,"y":[],"s":[]}"#).is_err());
        assert!(from_json_str(r#"{"family":"ordered","seed":1,"y":["x"],"s":[1]}"#).is_err());
        // Fractional argmin ids are invalid (ids are integers on the wire).
        assert!(from_json_str(r#"{"family":"ordered","seed":1,"y":[1],"s":[1.5]}"#).is_err());
        // y/s arity mismatch.
        assert!(from_json_str(r#"{"family":"ordered","seed":1,"y":[1,2],"s":[1]}"#).is_err());
    }

    #[test]
    fn from_json_decodes_negative_entries_as_empty_registers() {
        // -1 is the wire encoding of EMPTY_REGISTER / +inf (not valid JSON).
        let sk = from_json_str(
            r#"{"family":"ordered","seed":7,"y":[-1,0.25],"s":[-1,9]}"#,
        )
        .unwrap();
        assert!(sk.y[0].is_infinite());
        assert_eq!(sk.s[0], EMPTY_REGISTER);
        assert_eq!(sk.y[1], 0.25);
        assert_eq!(sk.s[1], 9);
        // Any negative number maps to the sentinel, not just -1.
        let sk = from_json_str(
            r#"{"family":"ordered","seed":7,"y":[-2.5],"s":[-42]}"#,
        )
        .unwrap();
        assert!(sk.y[0].is_infinite());
        assert_eq!(sk.s[0], EMPTY_REGISTER);
    }

    #[test]
    fn gumbel_values_are_neg_log() {
        let a = GumbelMaxSketch {
            family: Family::Ordered,
            seed: 0,
            y: vec![1.0, std::f64::consts::E],
            s: vec![0, 0],
        };
        let g = a.gumbel_values();
        assert!((g[0] - 0.0).abs() < 1e-12);
        assert!((g[1] + 1.0).abs() < 1e-12);
    }
}
