//! Stream-FastGM — Algorithm 2 of the paper.
//!
//! One-pass sketching of a data stream `Π = o₁o₂…` where each object `i`
//! carries a fixed weight `v_i` and may occur many times. Each arriving
//! element replays its deterministic ascending race; once every register
//! has been appointed (`FlagFastPrune`), an element's race is aborted the
//! moment its next arrival exceeds `y* = max_j y_j`.
//!
//! Because races are deterministic per `(seed, element)`, re-occurrences of
//! an element are idempotent, and the final sketch equals the FastGM /
//! oracle sketch of the stream's de-duplicated weighted vector — the
//! equivalence test below locks that in.

use super::engine::SketchScratch;
use super::kernels;
use super::order_stats::ElementRace;
use super::{Family, GumbelMaxSketch, MergeError, Sketcher, SparseVector, EMPTY_REGISTER};

/// Incremental Stream-FastGM state. Feed elements with [`push`](Self::push);
/// read the sketch at any time with [`sketch`](Self::sketch).
#[derive(Debug, Clone)]
pub struct StreamFastGm {
    k: usize,
    seed: u64,
    y: Vec<f64>,
    s: Vec<u64>,
    unfilled: usize,
    /// argmax_j y_j, valid once `unfilled == 0` (`FlagFastPrune` true).
    jstar: usize,
    /// Elements processed (stream length seen).
    pub processed: u64,
    /// Exponential variables generated (work counter for Fig 8/11).
    pub released: u64,
}

impl StreamFastGm {
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 1);
        StreamFastGm {
            k,
            seed,
            y: vec![f64::INFINITY; k],
            s: vec![EMPTY_REGISTER; k],
            unfilled: k,
            jstar: 0,
            processed: 0,
            released: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Re-initialize in place to a fresh `(k, seed)` state, keeping the
    /// register allocations (scratch reuse). Equivalent to
    /// `*self = StreamFastGm::new(k, seed)` without the allocation.
    pub fn reset(&mut self, k: usize, seed: u64) {
        self.k = k;
        self.seed = seed;
        self.y.clear();
        self.y.resize(k, f64::INFINITY);
        self.s.clear();
        self.s.resize(k, EMPTY_REGISTER);
        self.unfilled = k;
        self.jstar = 0;
        self.processed = 0;
        self.released = 0;
    }

    /// Process one stream element `(id, weight)`. Weight must be the fixed
    /// weight of that object; non-positive weights are ignored.
    pub fn push(&mut self, id: u64, weight: f64) {
        self.processed += 1;
        if weight <= 0.0 || !weight.is_finite() {
            return;
        }
        let mut race = ElementRace::new(self.seed, id, weight, self.k);
        if self.unfilled > 0 {
            // FlagFastPrune == false: must release the full queue, updating
            // registers and possibly completing the fill.
            while let Some((b, c)) = race.next() {
                self.released += 1;
                let c = c as usize;
                if self.s[c] == EMPTY_REGISTER {
                    self.y[c] = b;
                    self.s[c] = id;
                    self.unfilled -= 1;
                    if self.unfilled == 0 {
                        self.jstar = kernels::argmax_f64(&self.y);
                        // Switch to pruning for the REST of this element.
                        self.drain_pruned(&mut race, id);
                        return;
                    }
                } else if b < self.y[c] {
                    self.y[c] = b;
                    self.s[c] = id;
                }
            }
        } else {
            self.drain_pruned(&mut race, id);
        }
    }

    /// FlagFastPrune == true: abort on the first arrival beyond y*.
    fn drain_pruned(&mut self, race: &mut ElementRace, id: u64) {
        while let Some((b, c)) = race.next() {
            self.released += 1;
            if b > self.y[self.jstar] {
                return;
            }
            let c = c as usize;
            if b < self.y[c] {
                self.y[c] = b;
                self.s[c] = id;
                if c == self.jstar {
                    self.jstar = kernels::argmax_f64(&self.y);
                }
            }
        }
    }

    /// Merge another Ordered-family sketch's registers into this live
    /// stream state (per register: keep the smaller `y` and its `s`) —
    /// the anti-entropy repair primitive. §2.3 makes this safe: the
    /// resulting registers equal what this state would hold had it also
    /// seen every element behind `other`, because races are deterministic
    /// per `(seed, element)` and re-occurrences are idempotent — so
    /// repair *merges* missed history in, never overwrites local history,
    /// and repeating the merge is a no-op. Future `push`es behave exactly
    /// as if the union stream had been consumed here: the fill/prune
    /// bookkeeping (`unfilled`, `jstar`) is recomputed from the merged
    /// registers. `processed`/`released` stay local-only counters (the
    /// merge cannot know how long the remote stream was).
    pub fn merge_sketch(&mut self, other: &GumbelMaxSketch) -> Result<(), MergeError> {
        if other.family != Family::Ordered {
            return Err(MergeError::FamilyMismatch(Family::Ordered.name(), other.family.name()));
        }
        if other.seed != self.seed {
            return Err(MergeError::SeedMismatch(self.seed, other.seed));
        }
        if other.k() != self.k {
            return Err(MergeError::LengthMismatch(self.k, other.k()));
        }
        kernels::merge_min_into(&mut self.y, &mut self.s, &other.y, &other.s);
        self.unfilled = kernels::count_empty(&self.s);
        if self.unfilled == 0 {
            self.jstar = kernels::argmax_f64(&self.y);
        }
        Ok(())
    }

    /// Current sketch (clones the registers).
    pub fn sketch(&self) -> GumbelMaxSketch {
        GumbelMaxSketch {
            family: Family::Ordered,
            seed: self.seed,
            y: self.y.clone(),
            s: self.s.clone(),
        }
    }

    /// Copy the current registers into `out`, reusing its allocations.
    pub fn write_into(&self, out: &mut GumbelMaxSketch) {
        out.family = Family::Ordered;
        out.seed = self.seed;
        out.y.clear();
        out.y.extend_from_slice(&self.y);
        out.s.clear();
        out.s.extend_from_slice(&self.s);
    }
}

/// Batch adapter driving [`StreamFastGm`] over a [`SparseVector`]'s positive
/// entries — the `stream` registry entry. Registers are identical to FastGM's
/// (both are lossless early terminations of the same Ordered-family races),
/// so this is chiefly useful for exercising the streaming path under the
/// uniform [`Sketcher`] API.
#[derive(Debug, Clone)]
pub struct StreamSketcher {
    pub k: usize,
    pub seed: u64,
}

impl StreamSketcher {
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 1);
        StreamSketcher { k, seed }
    }
}

impl Sketcher for StreamSketcher {
    fn name(&self) -> &'static str {
        "stream"
    }

    fn family(&self) -> Family {
        Family::Ordered
    }

    fn k(&self) -> usize {
        self.k
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn sketch_into(&self, v: &SparseVector, scratch: &mut SketchScratch, out: &mut GumbelMaxSketch) {
        let st = scratch.stream_mut(self.k, self.seed);
        for (id, w) in v.positive() {
            st.push(id, w);
        }
        st.write_into(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::fastgm::FastGm;
    use crate::sketch::{Sketcher, SparseVector};
    use crate::util::proptest::forall_explain;
    use crate::util::rng::SplitMix64;

    /// Streaming (with duplicates, any order) must equal batch FastGM on the
    /// de-duplicated weighted vector — exact register equality.
    #[test]
    fn stream_equals_batch_fastgm() {
        forall_explain(
            40,
            |r| {
                let k = [1, 4, 16, 48][r.next_range(0, 3)];
                let n = r.next_range(1, 60);
                let elements: Vec<(u64, f64)> =
                    (0..n).map(|i| (i as u64 * 7 + 1, r.next_exp() + 0.01)).collect();
                // A stream with duplicates in shuffled order.
                let mut stream: Vec<(u64, f64)> = Vec::new();
                for &(id, w) in &elements {
                    for _ in 0..r.next_range(1, 3) {
                        stream.push((id, w));
                    }
                }
                r.shuffle(&mut stream);
                (r.next_u64(), k, elements, stream)
            },
            |(seed, k, elements, stream)| {
                let mut sf = StreamFastGm::new(*k, *seed);
                for &(id, w) in stream {
                    sf.push(id, w);
                }
                let batch = FastGm::new(*k, *seed).sketch(&SparseVector::new(
                    elements.iter().map(|e| e.0).collect(),
                    elements.iter().map(|e| e.1).collect(),
                ));
                if sf.sketch() == batch {
                    Ok(())
                } else {
                    Err("stream sketch != batch sketch".to_string())
                }
            },
        );
    }

    #[test]
    fn duplicates_are_idempotent() {
        let mut a = StreamFastGm::new(32, 5);
        let mut b = StreamFastGm::new(32, 5);
        for (id, w) in [(1u64, 0.5), (2, 1.5), (3, 0.2)] {
            a.push(id, w);
            b.push(id, w);
            b.push(id, w); // duplicate immediately
        }
        b.push(1, 0.5); // and again later
        assert_eq!(a.sketch(), b.sketch());
    }

    #[test]
    fn ignores_nonpositive_weights() {
        let mut a = StreamFastGm::new(16, 3);
        a.push(1, 1.0);
        let snap = a.sketch();
        a.push(2, 0.0);
        a.push(3, -4.0);
        a.push(4, f64::NAN);
        assert_eq!(a.sketch(), snap);
    }

    /// After the fill phase, heavy pruning: work per element must flatline.
    #[test]
    fn prune_work_is_sublinear_in_k() {
        let k = 256;
        let mut sf = StreamFastGm::new(k, 7);
        let mut r = SplitMix64::new(1);
        let n = 2000u64;
        for id in 0..n {
            sf.push(id, r.next_f64() + 0.01);
        }
        // Brute force would be n·k = 512_000 releases.
        assert!(
            sf.released < (n * k as u64) / 8,
            "released={} vs brute={}",
            sf.released,
            n * k as u64
        );
    }

    #[test]
    fn stream_sketcher_adapter_matches_fastgm() {
        let mut r = SplitMix64::new(17);
        let v = SparseVector::new(
            (0..40u64).map(|i| i * 11 + 3).collect(),
            (0..40).map(|_| r.next_exp() + 0.01).collect(),
        );
        let a = StreamSketcher::new(32, 9).sketch(&v);
        let b = FastGm::new(32, 9).sketch(&v);
        assert_eq!(a, b);
    }

    #[test]
    fn reset_state_equals_fresh_state() {
        let mut dirty = StreamFastGm::new(48, 1);
        for id in 0..200u64 {
            dirty.push(id, 0.5 + (id % 7) as f64);
        }
        dirty.reset(16, 5);
        let mut fresh = StreamFastGm::new(16, 5);
        for (id, w) in [(3u64, 0.5), (9, 2.0), (12, 0.25)] {
            dirty.push(id, w);
            fresh.push(id, w);
        }
        assert_eq!(dirty.sketch(), fresh.sketch());
        assert_eq!(dirty.processed, fresh.processed);
        assert_eq!(dirty.released, fresh.released);
    }

    /// Repair semantics: merging a peer's sketch into a partial stream
    /// state yields exactly the state of the union stream — including the
    /// fill/prune bookkeeping, so subsequent pushes stay bit-identical.
    #[test]
    fn merge_sketch_equals_union_stream_state() {
        let mut r = SplitMix64::new(23);
        for k in [4usize, 32, 96] {
            let all: Vec<(u64, f64)> =
                (0..150u64).map(|i| (i * 13 + 2, r.next_f64() + 0.01)).collect();
            let (left, right) = all.split_at(60);
            let mut a = StreamFastGm::new(k, 9);
            for &(id, w) in left {
                a.push(id, w);
            }
            let mut b = StreamFastGm::new(k, 9);
            for &(id, w) in right {
                b.push(id, w);
            }
            // a absorbs b's registers; overlap with its own history is
            // idempotent (merge in b's view of the FULL stream too).
            let mut full_view = StreamFastGm::new(k, 9);
            for &(id, w) in &all {
                full_view.push(id, w);
            }
            a.merge_sketch(&b.sketch()).unwrap();
            assert_eq!(a.sketch(), full_view.sketch(), "k={k}: merge != union");
            // Re-merging is a no-op (anti-entropy repair is idempotent).
            let snap = a.sketch();
            a.merge_sketch(&b.sketch()).unwrap();
            a.merge_sketch(&full_view.sketch()).unwrap();
            assert_eq!(a.sketch(), snap);
            // Future pushes behave as if `a` had seen the whole stream.
            let more: Vec<(u64, f64)> =
                (0..40u64).map(|i| (i * 7 + 5000, r.next_f64() + 0.01)).collect();
            for &(id, w) in &more {
                a.push(id, w);
                full_view.push(id, w);
            }
            assert_eq!(a.sketch(), full_view.sketch(), "k={k}: post-merge pushes diverged");
        }
    }

    #[test]
    fn merge_sketch_rejects_incompatible_sketches() {
        let mut a = StreamFastGm::new(16, 1);
        a.push(1, 1.0);
        let wrong_seed = StreamFastGm::new(16, 2).sketch();
        assert_eq!(a.merge_sketch(&wrong_seed), Err(MergeError::SeedMismatch(1, 2)));
        let wrong_k = StreamFastGm::new(8, 1).sketch();
        assert_eq!(a.merge_sketch(&wrong_k), Err(MergeError::LengthMismatch(16, 8)));
        let mut wrong_family = StreamFastGm::new(16, 1).sketch();
        wrong_family.family = Family::Direct;
        assert!(matches!(a.merge_sketch(&wrong_family), Err(MergeError::FamilyMismatch(_, _))));
    }

    #[test]
    fn empty_stream_is_empty_sketch() {
        let sf = StreamFastGm::new(8, 1);
        let sk = sf.sketch();
        assert!(sk.y.iter().all(|y| y.is_infinite()));
    }
}
