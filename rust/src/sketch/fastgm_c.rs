//! FastGM-c — the WWW'20 conference-version baseline.
//!
//! The conference algorithm ("Fast Generating A Large Number of Gumbel-Max
//! Variables") already generates each element's race in ascending order and
//! prunes against `y*`, but it processes elements **in input order without
//! the FastSearch budget schedule**: the registers are filled by whichever
//! elements happen to come first (each paying the full coupon-collector
//! cost), instead of letting heavy elements race ahead in `⌈R·v*_i⌉`-sized
//! rounds. The journal version's speedup over this baseline (1.2–4× in the
//! paper's Fig. 4/5) comes exactly from that scheduling difference; keeping
//! the baseline here lets the `fig4`/`fig5` experiments reproduce the
//! comparison.
//!
//! The output registers are identical to FastGM's (both are lossless early
//! terminations of the same Ordered-family race), which the test asserts.
//!
//! The hot loops live in the shared [`StreamFastGm`](super::stream_fastgm)
//! core, so this baseline rides the `sketch::kernels` argmax/merge layer
//! transitively — the FastGM-vs-conference perf comparison stays about the
//! *schedule*, not about who got vectorized.

use super::engine::SketchScratch;
use super::{Family, GumbelMaxSketch, Sketcher, SparseVector};

#[derive(Debug, Clone)]
pub struct FastGmConference {
    pub k: usize,
    pub seed: u64,
}

impl FastGmConference {
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 1);
        FastGmConference { k, seed }
    }

    /// Sketch and return the number of exponential variables generated.
    pub fn sketch_counted(&self, v: &SparseVector) -> (GumbelMaxSketch, u64) {
        let mut scratch = SketchScratch::new();
        let mut out = GumbelMaxSketch::empty(Family::Ordered, self.seed, self.k);
        let released = self.sketch_counted_into(v, &mut scratch, &mut out);
        (out, released)
    }

    /// Allocation-free core: drive the scratch's streaming state over `v`'s
    /// positive entries in input order (the conference schedule).
    pub fn sketch_counted_into(
        &self,
        v: &SparseVector,
        scratch: &mut SketchScratch,
        out: &mut GumbelMaxSketch,
    ) -> u64 {
        let st = scratch.stream_mut(self.k, self.seed);
        for (id, w) in v.positive() {
            st.push(id, w);
        }
        st.write_into(out);
        st.released
    }
}

impl Sketcher for FastGmConference {
    fn name(&self) -> &'static str {
        "fastgm-c"
    }

    fn family(&self) -> Family {
        Family::Ordered
    }

    fn k(&self) -> usize {
        self.k
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn sketch_into(&self, v: &SparseVector, scratch: &mut SketchScratch, out: &mut GumbelMaxSketch) {
        self.sketch_counted_into(v, scratch, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::fastgm::FastGm;
    use crate::util::proptest::forall_explain;
    use crate::util::rng::SplitMix64;

    #[test]
    fn same_registers_as_fastgm() {
        forall_explain(
            30,
            |r| {
                let n = r.next_range(1, 50);
                let v = SparseVector::new(
                    (0..n as u64).map(|i| i * 13 + 5).collect(),
                    (0..n).map(|_| r.next_exp() + 0.01).collect(),
                );
                (r.next_u64(), v)
            },
            |(seed, v)| {
                let a = FastGmConference::new(24, *seed).sketch(v);
                let b = FastGm::new(24, *seed).sketch(v);
                if a == b {
                    Ok(())
                } else {
                    Err("conference version diverged from FastGM".into())
                }
            },
        );
    }

    /// FastGM's schedule should release no MORE variables than the
    /// conference version on weight-skewed vectors (the journal paper's
    /// improvement claim), at least in aggregate.
    #[test]
    fn fastgm_releases_fewer_variables_on_skewed_input() {
        let mut r = SplitMix64::new(42);
        let k = 256;
        let mut total_c = 0u64;
        let mut total_j = 0u64;
        for seed in 0..10u64 {
            let n = 500;
            // Zipf-ish skew: weight ~ 1/(rank+1).
            let v = SparseVector::new(
                (0..n as u64).collect(),
                (0..n).map(|i| 1.0 / (i as f64 + 1.0) * (r.next_f64() + 0.5)).collect(),
            );
            total_c += FastGmConference::new(k, seed).sketch_counted(&v).1;
            total_j += FastGm::new(k, seed).sketch_counted(&v).1.total_released();
        }
        assert!(
            total_j < total_c,
            "journal FastGM released {total_j}, conference {total_c}"
        );
    }
}
