//! Lemiesz's sketch (VLDB'21) — the Task-2 weighted-cardinality baseline.
//!
//! Exactly the `y` part of the Direct-family Gumbel-Max sketch, maintained
//! incrementally over a stream (Eq. 2 of the paper): each arriving object
//! `i` with weight `v_i` updates `y_j ← min(y_j, -ln(a_ij)/v_i)` for **all**
//! `j` — `O(k)` per stream element, which is what Stream-FastGM beats.
//! `Σ y_j ~ Γ(k, c)` gives the estimator `ĉ = (k-1)/Σ y_j`
//! (see `estimate::cardinality`).

use crate::util::rng::direct_element_hash;
use super::engine::SketchScratch;
use super::kernels;
use super::{fold_id, Family, GumbelMaxSketch, Sketcher, SparseVector, EMPTY_REGISTER};

/// Incremental Lemiesz sketch over a stream. Seed is the unified `u64`,
/// folded with [`fold_id`] into the 32-bit Direct-RNG space (seeds < 2^32
/// are unchanged by the fold).
#[derive(Debug, Clone)]
pub struct LemieszSketch {
    seed: u64,
    y: Vec<f64>,
    s: Vec<u64>,
    /// Work counter: exponential variables generated (k per element).
    pub released: u64,
}

impl LemieszSketch {
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 1);
        LemieszSketch {
            seed,
            y: vec![f64::INFINITY; k],
            s: vec![EMPTY_REGISTER; k],
            released: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.y.len()
    }

    /// Process one stream object. Duplicates are idempotent (deterministic
    /// a_ij). The straightforward algorithm draws all k variables.
    pub fn push(&mut self, id: u64, weight: f64) {
        if weight <= 0.0 || !weight.is_finite() {
            return;
        }
        self.released += update_registers(fold_id(self.seed), id, weight, &mut self.y, &mut self.s);
    }

    pub fn sketch(&self) -> GumbelMaxSketch {
        GumbelMaxSketch {
            family: Family::Direct,
            seed: self.seed,
            y: self.y.clone(),
            s: self.s.clone(),
        }
    }
}

/// Batch adapter so Lemiesz's sketch plugs into the [`Sketcher`] harnesses.
#[derive(Debug, Clone)]
pub struct Lemiesz {
    pub k: usize,
    pub seed: u64,
}

impl Lemiesz {
    pub fn new(k: usize, seed: u64) -> Self {
        Lemiesz { k, seed }
    }
}

impl Sketcher for Lemiesz {
    fn name(&self) -> &'static str {
        "lemiesz"
    }

    fn family(&self) -> Family {
        Family::Direct
    }

    fn k(&self) -> usize {
        self.k
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn sketch_into(&self, v: &SparseVector, _scratch: &mut SketchScratch, out: &mut GumbelMaxSketch) {
        out.reset(Family::Direct, self.seed, self.k);
        let rng_seed = fold_id(self.seed);
        for (id, w) in v.positive() {
            update_registers(rng_seed, id, w, &mut out.y, &mut out.s);
        }
    }
}

/// One object's register updates — the single definition shared by the
/// incremental [`LemieszSketch::push`] and the batch [`Sketcher`] path, so
/// the two can never drift. Returns the exponentials drawn (= k).
#[inline]
fn update_registers(rng_seed: u32, id: u64, w: f64, y: &mut [f64], s: &mut [u64]) -> u64 {
    debug_assert!(w > 0.0 && w.is_finite());
    let h = direct_element_hash(rng_seed, fold_id(id));
    let inv_w = 1.0 / w;
    // Chunked through a stack row buffer (the incremental push has no
    // scratch arena). Splitting at any j is lossless because the Direct
    // RNG is stateless per (h, j) — every chunk draws the same bits the
    // historical full-row loop drew.
    let mut row = [0.0f32; 64];
    let mut j0 = 0usize;
    while j0 < y.len() {
        let m = (y.len() - j0).min(row.len());
        kernels::direct_exp_row(h, j0 as u32, &mut row[..m]);
        kernels::scaled_min_update(&row[..m], inv_w, id, &mut y[j0..j0 + m], &mut s[j0..j0 + m]);
        j0 += m;
    }
    y.len() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::pminhash::PMinHash;
    use crate::sketch::Sketcher;
    use crate::util::rng::SplitMix64;

    #[test]
    fn y_part_equals_pminhash() {
        // Same Direct family, same RNG ⇒ identical registers.
        let mut r = SplitMix64::new(8);
        let v = SparseVector::new(
            (0..30u64).map(|i| i * 3 + 1).collect(),
            (0..30).map(|_| r.next_f64() + 0.05).collect(),
        );
        let a = Lemiesz::new(64, 5).sketch(&v);
        let b = PMinHash::new(64, 5).sketch(&v);
        assert_eq!(a, b);
    }

    #[test]
    fn duplicates_idempotent_and_mergeable() {
        let mut a = LemieszSketch::new(32, 1);
        a.push(10, 0.5);
        a.push(11, 1.5);
        let once = a.sketch();
        a.push(10, 0.5);
        assert_eq!(a.sketch(), once);

        // Merge of two sites == single-site union (§2.3 mergeability).
        let mut site1 = LemieszSketch::new(32, 1);
        let mut site2 = LemieszSketch::new(32, 1);
        site1.push(10, 0.5);
        site2.push(11, 1.5);
        site2.push(10, 0.5); // shared object
        let merged = site1.sketch().merge(&site2.sketch()).unwrap();
        assert_eq!(merged, once);
    }

    #[test]
    fn work_is_k_per_distinct_push() {
        let mut a = LemieszSketch::new(100, 2);
        for id in 0..50u64 {
            a.push(id, 1.0);
        }
        assert_eq!(a.released, 50 * 100);
    }
}
