//! Dense-batch sketch accelerator: the bridge between coordinator requests
//! and the AOT `sketch_*` artifacts.
//!
//! Requests carry dense weight rows of arbitrary length; the accelerator
//! buckets them to the smallest compiled `(B, N, K)` shape that fits
//! (padding rows with zeros — absent elements — and the batch with empty
//! rows), executes on PJRT, and converts outputs back into
//! [`GumbelMaxSketch`]es of the **Direct** family, interchangeable with CPU
//! P-MinHash sketches of the same seed (runtime tests pin that).

use crate::sketch::{Family, GumbelMaxSketch, EMPTY_REGISTER};
use super::Runtime;

/// A compiled dense-sketch shape.
#[derive(Debug, Clone)]
pub struct Bucket {
    pub name: String,
    pub b: usize,
    pub n: usize,
    pub k: usize,
}

pub struct DenseSketchAccel {
    runtime: Runtime,
    buckets: Vec<Bucket>,
}

impl DenseSketchAccel {
    /// Wrap a runtime, indexing every `sketch_*` (Pallas) artifact.
    pub fn new(runtime: Runtime) -> anyhow::Result<DenseSketchAccel> {
        let mut buckets = Vec::new();
        for name in runtime.names() {
            if !name.starts_with("sketch_b") {
                continue;
            }
            let spec = runtime.spec(name).unwrap();
            buckets.push(Bucket {
                name: name.to_string(),
                b: spec.inputs[1].shape[0],
                n: spec.inputs[1].shape[1],
                k: spec.outputs[0].shape[1],
            });
        }
        anyhow::ensure!(!buckets.is_empty(), "no sketch_* artifacts in runtime");
        // Smallest-first so `pick` finds the tightest fit.
        buckets.sort_by_key(|b| (b.n, b.k, b.b));
        Ok(DenseSketchAccel { runtime, buckets })
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// The tightest bucket with n ≥ len and exactly k registers.
    pub fn pick(&self, len: usize, k: usize) -> Option<&Bucket> {
        self.buckets.iter().find(|b| b.n >= len && b.k == k)
    }

    /// Max dense length any bucket of sketch length k accepts.
    pub fn max_len(&self, k: usize) -> usize {
        self.buckets.iter().filter(|b| b.k == k).map(|b| b.n).max().unwrap_or(0)
    }

    /// Sketch a batch of dense rows (ids = dense indices). Rows longer than
    /// every bucket are rejected — the router sends those to CPU FastGM.
    /// The u64 seed is folded to the kernel's 32-bit space with
    /// [`crate::sketch::fold_id`] (identity for seeds < 2^32), exactly as
    /// the CPU P-MinHash fallback folds it, so the two stay interchangeable.
    pub fn sketch_batch(
        &self,
        seed: u64,
        rows: &[Vec<f64>],
        k: usize,
    ) -> anyhow::Result<Vec<GumbelMaxSketch>> {
        anyhow::ensure!(!rows.is_empty(), "empty batch");
        let longest = rows.iter().map(|r| r.len()).max().unwrap();
        let bucket = self
            .pick(longest, k)
            .ok_or_else(|| {
                anyhow::anyhow!("no bucket fits dense length {longest} with k={k}")
            })?
            .clone();

        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(bucket.b) {
            // Pad rows to n and the chunk to b with zero rows.
            let mut flat = vec![0f32; bucket.b * bucket.n];
            for (r, row) in chunk.iter().enumerate() {
                for (i, &w) in row.iter().enumerate() {
                    if w > 0.0 {
                        flat[r * bucket.n + i] = w as f32;
                    }
                }
            }
            let seed_lit = xla::Literal::vec1(&[crate::sketch::fold_id(seed)]);
            let v_lit = xla::Literal::vec1(&flat)
                .reshape(&[bucket.b as i64, bucket.n as i64])?;
            let outs = self.runtime.execute(&bucket.name, &[seed_lit, v_lit])?;
            let y: Vec<f32> = outs[0].to_vec()?;
            let s: Vec<i32> = outs[1].to_vec()?;
            for (r, row) in chunk.iter().enumerate() {
                let mut sk = GumbelMaxSketch::empty(Family::Direct, seed, bucket.k);
                let empty_row = row.iter().all(|&w| w <= 0.0);
                for j in 0..bucket.k {
                    let yv = y[r * bucket.k + j] as f64;
                    if yv.is_finite() && !empty_row {
                        sk.y[j] = yv;
                        sk.s[j] = s[r * bucket.k + j] as u64;
                    } else {
                        sk.y[j] = f64::INFINITY;
                        sk.s[j] = EMPTY_REGISTER;
                    }
                }
                out.push(sk);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{pminhash::PMinHash, Sketcher, SparseVector};
    use crate::util::rng::SplitMix64;

    fn accel() -> Option<DenseSketchAccel> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            eprintln!("skipping accel test: artifacts not built");
            return None;
        }
        Some(DenseSketchAccel::new(Runtime::load(dir).unwrap()).unwrap())
    }

    #[test]
    fn buckets_indexed_and_picked() {
        let Some(a) = accel() else { return };
        assert!(a.buckets().len() >= 2);
        let b = a.pick(700, 256).unwrap();
        assert!(b.n >= 700 && b.k == 256);
        assert!(a.pick(100_000, 256).is_none());
        assert!(a.max_len(256) >= 1024);
    }

    #[test]
    fn batch_matches_cpu_pminhash() {
        let Some(a) = accel() else { return };
        let mut rng = SplitMix64::new(4);
        let rows: Vec<Vec<f64>> = (0..10)
            .map(|_| {
                (0..600)
                    .map(|_| if rng.next_f64() < 0.4 { 0.0 } else { rng.next_f64() })
                    .collect()
            })
            .collect();
        let sketches = a.sketch_batch(77, &rows, 256).unwrap();
        assert_eq!(sketches.len(), 10);
        let cpu = PMinHash::new(256, 77);
        for (row, sk) in rows.iter().zip(&sketches) {
            let want = cpu.sketch(&SparseVector::from_dense(row));
            let mism = (0..256).filter(|&j| want.s[j] != sk.s[j]).count();
            assert!(mism <= 3, "{mism}/256 argmax registers disagree");
            for j in 0..256 {
                if want.s[j] == sk.s[j] && want.y[j].is_finite() {
                    let rel = (want.y[j] - sk.y[j]).abs() / want.y[j].max(1e-9);
                    assert!(rel < 1e-4, "register {j}: {} vs {}", want.y[j], sk.y[j]);
                }
            }
        }
    }

    #[test]
    fn empty_and_padded_rows_are_empty_sketches() {
        let Some(a) = accel() else { return };
        let rows = vec![vec![0.0; 64], vec![1.0; 64]];
        let sketches = a.sketch_batch(1, &rows, 256).unwrap();
        assert!(sketches[0].y.iter().all(|y| y.is_infinite()));
        assert!(sketches[0].s.iter().all(|&s| s == EMPTY_REGISTER));
        assert!(sketches[1].y.iter().all(|y| y.is_finite()));
    }

    #[test]
    fn oversized_rows_are_rejected() {
        let Some(a) = accel() else { return };
        let rows = vec![vec![1.0; 100_000]];
        assert!(a.sketch_batch(1, &rows, 256).is_err());
    }
}
