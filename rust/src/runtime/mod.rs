//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `python/compile/aot.py`) and executes them on
//! the request path through the `xla` crate's CPU PJRT client.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids. Every module is lowered with `return_tuple=True`, so
//! results are un-tupled here.
//!
//! **Feature gate:** everything that touches PJRT ([`Runtime`], [`accel`])
//! is behind the off-by-default `accel` cargo feature, because the `xla`
//! crate is not in the offline crate set (README.md §Accelerator). Manifest
//! *parsing* ([`read_manifest`], [`TensorSpec`], [`ArtifactSpec`]) is always
//! compiled — the coordinator reads bucket metadata through it and treats a
//! missing manifest (or a build without `accel`) as "accelerator off".

#[cfg(feature = "accel")]
pub mod accel;

use crate::util::json::{self, Value};
#[cfg(feature = "accel")]
use std::collections::HashMap;
use std::path::Path;

/// Tensor shape+dtype from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(v: &Value) -> anyhow::Result<TensorSpec> {
        let shape = v
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<anyhow::Result<_>>()?;
        Ok(TensorSpec { shape, dtype: v.req_str("dtype")?.to_string() })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parse `<dir>/manifest.json` WITHOUT touching PJRT — usable from any
/// thread (the xla wrapper types are !Send, so the service reads bucket
/// metadata this way and leaves executable construction to the thread
/// that owns the runtime).
pub fn read_manifest(dir: &str) -> anyhow::Result<Vec<ArtifactSpec>> {
    let manifest_path = Path::new(dir).join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
        anyhow::anyhow!(
            "no artifact manifest at {} (run `make artifacts`): {e}",
            manifest_path.display()
        )
    })?;
    let manifest = json::parse(&text)?;
    let mut specs = Vec::new();
    for art in manifest
        .req("artifacts")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("artifacts not an array"))?
    {
        specs.push(ArtifactSpec {
            name: art.req_str("name")?.to_string(),
            file: art.req_str("file")?.to_string(),
            kind: art.req_str("kind")?.to_string(),
            inputs: art
                .req("inputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<_>>()?,
            outputs: art
                .req("outputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<_>>()?,
        });
    }
    anyhow::ensure!(!specs.is_empty(), "manifest listed no artifacts");
    Ok(specs)
}

/// The runtime: a PJRT client plus the compiled executables.
///
/// NOT `Send`/`Sync` (the underlying wrapper holds `Rc`s): construct and
/// use it on one thread — the batcher owns one on its flush thread.
#[cfg(feature = "accel")]
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, (ArtifactSpec, xla::PjRtLoadedExecutable)>,
}

#[cfg(feature = "accel")]
impl Runtime {
    /// Load every artifact listed in `<dir>/manifest.json`. Returns an
    /// error if the directory or manifest is missing — callers that can
    /// operate CPU-only (the coordinator) treat that as "accelerator off".
    pub fn load(dir: &str) -> anyhow::Result<Runtime> {
        let specs = read_manifest(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        for spec in specs {
            let hlo_path = Path::new(dir).join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path
                    .to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            log::info!("compiled artifact '{}' ({})", spec.name, spec.kind);
            executables.insert(spec.name.clone(), (spec, exe));
        }
        Ok(Runtime { client, executables })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.executables.get(name).map(|(s, _)| s)
    }

    /// Execute artifact `name` with the given input literals; returns the
    /// un-tupled output literals (one per manifest output).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let (spec, exe) = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?;
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "artifact '{name}' expects {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
        let result = exe.execute::<xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

#[cfg(test)]
mod manifest_tests {
    use super::*;

    #[test]
    fn missing_manifest_is_error() {
        let err = read_manifest("/nonexistent/path").unwrap_err();
        assert!(err.to_string().contains("manifest"), "{err}");
    }

    #[test]
    fn parses_a_minimal_manifest() {
        // Process-unique dir: concurrent test runs must not race on it.
        let dir =
            std::env::temp_dir().join(format!("fastgm_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"name":"sketch_b8_n1024_k256","file":"s.hlo.txt","kind":"pallas",
                "inputs":[{"shape":[1],"dtype":"uint32"},{"shape":[8,1024],"dtype":"float32"}],
                "outputs":[{"shape":[8,256],"dtype":"float32"},{"shape":[8,256],"dtype":"int32"}]}]}"#,
        )
        .unwrap();
        let specs = read_manifest(dir.to_str().unwrap()).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].inputs[1].shape, vec![8, 1024]);
        assert_eq!(specs[0].outputs[0].elements(), 8 * 256);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[cfg(all(test, feature = "accel"))]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<String> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if Path::new(dir).join("manifest.json").exists() {
            Some(dir.to_string())
        } else {
            eprintln!("skipping runtime test: artifacts not built (`make artifacts`)");
            None
        }
    }

    #[test]
    fn loads_manifest_and_lists_artifacts() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(&dir).unwrap();
        assert!(rt.names().iter().any(|n| n.starts_with("sketch_b8")));
        let spec = rt.spec("sketch_b8_n1024_k256").unwrap();
        assert_eq!(spec.inputs[1].shape, vec![8, 1024]);
        assert_eq!(spec.outputs[0].shape, vec![8, 256]);
        assert_eq!(spec.outputs[0].dtype, "float32");
        assert_eq!(spec.outputs[1].dtype, "int32");
    }

    #[test]
    fn executes_sketch_artifact_and_matches_cpu() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(&dir).unwrap();
        let spec = rt.spec("sketch_b8_n1024_k256").unwrap().clone();
        let (b, n) = (spec.inputs[1].shape[0], spec.inputs[1].shape[1]);
        let k = spec.outputs[0].shape[1];
        // Deterministic pseudo-random dense weights.
        let mut rng = crate::util::rng::SplitMix64::new(9);
        let v: Vec<f32> = (0..b * n)
            .map(|_| if rng.next_f64() < 0.3 { 0.0 } else { rng.next_f64() as f32 })
            .collect();
        let seed_lit = xla::Literal::vec1(&[42u32]);
        let v_lit = xla::Literal::vec1(&v).reshape(&[b as i64, n as i64]).unwrap();
        let out = rt.execute("sketch_b8_n1024_k256", &[seed_lit, v_lit]).unwrap();
        let y: Vec<f32> = out[0].to_vec().unwrap();
        let s: Vec<i32> = out[1].to_vec().unwrap();
        assert_eq!(y.len(), b * k);
        assert_eq!(s.len(), b * k);

        // Cross-layer consistency: row 0 must match the CPU Direct-family
        // P-MinHash sketch up to f32 rounding (libm vs XLA log, ≤ few ulp).
        use crate::sketch::{pminhash::PMinHash, Sketcher, SparseVector};
        let row: Vec<f64> = v[0..n].iter().map(|&x| x as f64).collect();
        let cpu = PMinHash::new(k, 42).sketch(&SparseVector::from_dense(&row));
        let mut mismatched = 0;
        for j in 0..k {
            let ya = y[j] as f64;
            if cpu.s[j] != s[j] as u64 {
                mismatched += 1;
            } else if cpu.y[j].is_finite() {
                let rel = (ya - cpu.y[j]).abs() / cpu.y[j].max(1e-9);
                assert!(rel < 1e-4, "register {j}: accel {ya} vs cpu {}", cpu.y[j]);
            }
        }
        assert!(
            mismatched <= k / 100,
            "argmax registers disagree in {mismatched}/{k} positions"
        );
    }

    #[test]
    fn executes_simmat_artifact() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(&dir).unwrap();
        let spec = rt.spec("simmat_q16_c128_k256").unwrap().clone();
        let (q, k) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
        let c = spec.inputs[1].shape[0];
        // All-equal signatures → similarity 1 everywhere.
        let sq = xla::Literal::vec1(&vec![7i32; q * k])
            .reshape(&[q as i64, k as i64])
            .unwrap();
        let sc = xla::Literal::vec1(&vec![7i32; c * k])
            .reshape(&[c as i64, k as i64])
            .unwrap();
        let out = rt.execute("simmat_q16_c128_k256", &[sq, sc]).unwrap();
        let sim: Vec<f32> = out[0].to_vec().unwrap();
        assert_eq!(sim.len(), q * c);
        assert!(sim.iter().all(|&x| (x - 1.0).abs() < 1e-6));
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Runtime::load("/nonexistent/path").is_err());
    }
}
