//! Locality-sensitive hashing over Gumbel-ArgMax sketches.
//!
//! The paper (§1) notes that each `s_j(·)` maps similar vectors to the same
//! value with probability `J_P`, so the classic banding scheme applies:
//! split the k registers into `b` bands of `r` rows; two vectors collide in
//! a band iff all r registers match, so
//! `P(candidate) = 1 − (1 − J_P^r)^b` — the usual S-curve. The index stores
//! band-hash → vector ids and answers top-k queries in sub-linear time,
//! re-ranking candidates with the full-sketch estimator.

use crate::estimate::jaccard::estimate_jp;
use crate::sketch::{GumbelMaxSketch, MergeError};
use crate::util::hash::hash_u64s;
use std::collections::HashMap;

/// Banding parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LshParams {
    pub bands: usize,
    pub rows: usize,
}

impl LshParams {
    /// Choose (bands, rows) for sketch length k targeting threshold `t`:
    /// the S-curve midpoint is ≈ (1/b)^(1/r). Every row count 1..=k is
    /// considered with `bands = ⌈k/rows⌉` — the trailing band may be ragged
    /// (shorter than `rows`), so a prime k still gets a real multi-row
    /// layout instead of degenerating to `bands=k, rows=1`.
    pub fn for_threshold(k: usize, t: f64) -> LshParams {
        assert!(k >= 1);
        let t = t.clamp(0.01, 0.99);
        let mut best = LshParams { bands: k, rows: 1 };
        let mut best_err = f64::INFINITY;
        for rows in 1..=k {
            let bands = k.div_ceil(rows);
            let mid = (1.0 / bands as f64).powf(1.0 / rows as f64);
            let err = (mid - t).abs();
            if err < best_err {
                best_err = err;
                best = LshParams { bands, rows };
            }
        }
        best
    }

    /// Collision probability of the banding scheme at similarity `j`.
    /// Computed with `powf` so large band/row counts can never overflow an
    /// `i32` exponent cast.
    pub fn candidate_probability(&self, j: f64) -> f64 {
        let j = j.clamp(0.0, 1.0);
        1.0 - (1.0 - j.powf(self.rows as f64)).powf(self.bands as f64)
    }
}

/// A banded LSH index over ArgMax sketches.
pub struct LshIndex {
    params: LshParams,
    seed: u64,
    /// band index → (bucket key → vector ids)
    tables: Vec<HashMap<u64, Vec<u64>>>,
    /// id → full sketch, for re-ranking.
    sketches: HashMap<u64, GumbelMaxSketch>,
}

impl LshIndex {
    pub fn new(params: LshParams) -> Self {
        LshIndex {
            params,
            seed: 0x15B_5EED,
            tables: (0..params.bands).map(|_| HashMap::new()).collect(),
            sketches: HashMap::new(),
        }
    }

    pub fn params(&self) -> LshParams {
        self.params
    }

    pub fn len(&self) -> usize {
        self.sketches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sketches.is_empty()
    }

    fn band_keys(&self, sk: &GumbelMaxSketch) -> Vec<u64> {
        let LshParams { bands, rows } = self.params;
        assert!(bands >= 1 && rows >= 1, "degenerate band layout {bands}x{rows}");
        assert!(
            (bands - 1) * rows < sk.k(),
            "band layout {bands}x{rows} exceeds sketch length {}",
            sk.k()
        );
        (0..bands)
            .map(|b| {
                // The final band may be ragged (shorter than `rows`) when
                // rows does not divide k — see LshParams::for_threshold.
                let start = b * rows;
                let end = (start + rows).min(sk.k());
                hash_u64s(&sk.s[start..end], self.seed ^ b as u64)
            })
            .collect()
    }

    /// Insert a vector's sketch under `id` (replaces a previous insert).
    pub fn insert(&mut self, id: u64, sk: GumbelMaxSketch) {
        if self.sketches.contains_key(&id) {
            self.remove(id);
        }
        for (b, key) in self.band_keys(&sk).into_iter().enumerate() {
            self.tables[b].entry(key).or_default().push(id);
        }
        self.sketches.insert(id, sk);
    }

    /// Explicit replace-or-insert (what [`LshIndex::insert`] already does;
    /// named for call sites that maintain the index incrementally, e.g.
    /// [`crate::coordinator::store::SketchStore`]).
    pub fn upsert(&mut self, id: u64, sk: GumbelMaxSketch) {
        self.insert(id, sk);
    }

    pub fn remove(&mut self, id: u64) -> bool {
        let Some(sk) = self.sketches.remove(&id) else {
            return false;
        };
        for (b, key) in self.band_keys(&sk).into_iter().enumerate() {
            if let Some(bucket) = self.tables[b].get_mut(&key) {
                bucket.retain(|&x| x != id);
                if bucket.is_empty() {
                    self.tables[b].remove(&key);
                }
            }
        }
        true
    }

    /// Raw candidate set (unique ids colliding in ≥1 band).
    pub fn candidates(&self, query: &GumbelMaxSketch) -> Vec<u64> {
        let mut out = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for (b, key) in self.band_keys(query).into_iter().enumerate() {
            if let Some(bucket) = self.tables[b].get(&key) {
                for &id in bucket {
                    if seen.insert(id) {
                        out.push(id);
                    }
                }
            }
        }
        out
    }

    /// Top-`limit` ids by estimated J_P among the candidates.
    pub fn query(
        &self,
        query: &GumbelMaxSketch,
        limit: usize,
    ) -> Result<Vec<(u64, f64)>, MergeError> {
        self.query_stats(query, limit).map(|(hits, _)| hits)
    }

    /// [`LshIndex::query`] plus probe statistics (candidate set size and
    /// how many candidates were re-ranked with the full-sketch estimator) —
    /// what the coordinator's top-k metrics report.
    pub fn query_stats(
        &self,
        query: &GumbelMaxSketch,
        limit: usize,
    ) -> Result<(Vec<(u64, f64)>, QueryStats), MergeError> {
        let candidates = self.candidates(query);
        let stats = QueryStats { candidates: candidates.len(), reranked: candidates.len() };
        let mut scored = Vec::with_capacity(candidates.len());
        for id in candidates {
            let sk = &self.sketches[&id];
            scored.push((id, estimate_jp(query, sk)?));
        }
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        scored.truncate(limit);
        Ok((scored, stats))
    }
}

/// Probe statistics from [`LshIndex::query_stats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueryStats {
    /// Unique ids colliding with the query in ≥ 1 band.
    pub candidates: usize,
    /// Candidates scored with the full-sketch estimator.
    pub reranked: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::fastgm::FastGm;
    use crate::sketch::{Sketcher, SparseVector};
    use crate::util::rng::SplitMix64;

    fn vec_with_overlap(r: &mut SplitMix64, base: &SparseVector, keep: f64) -> SparseVector {
        // Copy `keep` fraction of base's mass, fresh ids for the rest.
        let mut v = SparseVector::default();
        for (id, w) in base.positive() {
            if r.next_f64() < keep {
                v.push(id, w);
            } else {
                v.push(r.next_u64() | (1 << 63), w);
            }
        }
        v
    }

    /// (bands, rows) tile the k registers: every band starts in range and
    /// only the last may be ragged.
    fn assert_covers(p: LshParams, k: usize) {
        assert!((p.bands - 1) * p.rows < k, "{p:?} over-runs k={k}");
        assert!(p.bands * p.rows >= k, "{p:?} under-covers k={k}");
    }

    #[test]
    fn params_for_threshold_are_sane() {
        let p = LshParams::for_threshold(256, 0.5);
        assert_covers(p, 256);
        assert!(p.candidate_probability(0.9) > 0.95);
        assert!(p.candidate_probability(0.05) < 0.35);
        // S-curve monotone.
        assert!(p.candidate_probability(0.6) > p.candidate_probability(0.4));
    }

    /// Prime k must not degenerate to `bands=k, rows=1` (which makes every
    /// sketch a candidate regardless of threshold) — the ragged trailing
    /// band keeps the S-curve midpoint near the requested threshold.
    #[test]
    fn prime_and_small_k_hit_the_threshold() {
        for k in [2usize, 3, 7, 13, 31, 127, 251] {
            for t in [0.3, 0.5, 0.8] {
                let p = LshParams::for_threshold(k, t);
                assert_covers(p, k);
                let mid = (1.0 / p.bands as f64).powf(1.0 / p.rows as f64);
                // The best achievable midpoint over all (⌈k/r⌉, r) layouts;
                // for k ≥ 31 that is always within 0.15 of the target.
                if k >= 31 {
                    assert!(
                        (mid - t).abs() < 0.15,
                        "k={k} t={t}: got {p:?} with midpoint {mid:.3}"
                    );
                    assert!(p.rows > 1, "k={k} t={t} degenerated to rows=1: {p:?}");
                }
            }
        }
        // The fix's concrete shape: 127 registers at t=0.5 get a real
        // multi-row layout with a ragged last band.
        let p = LshParams::for_threshold(127, 0.5);
        assert!(p.rows > 1 && p.bands > 1 && p.bands < 127, "{p:?}");
        assert!(p.bands * p.rows > 127, "expected a ragged trailing band: {p:?}");
    }

    /// Huge band/row counts must not overflow (the old `as i32` cast UB
    /// territory); probabilities stay in [0, 1].
    #[test]
    fn candidate_probability_is_safe_for_extreme_params() {
        let p = LshParams { bands: usize::MAX / 2, rows: usize::MAX / 2 };
        for j in [0.0, 1e-9, 0.5, 1.0 - 1e-9, 1.0] {
            let c = p.candidate_probability(j);
            assert!((0.0..=1.0).contains(&c), "j={j} -> {c}");
        }
        assert_eq!(p.candidate_probability(1.0), 1.0);
        assert_eq!(p.candidate_probability(0.0), 0.0);
    }

    /// A ragged layout indexes and queries correctly end to end.
    #[test]
    fn ragged_band_layout_round_trips() {
        let k = 127; // prime
        let f = FastGm::new(k, 9);
        let params = LshParams::for_threshold(k, 0.5);
        let mut index = LshIndex::new(params);
        let v1 = SparseVector::new(vec![1, 2, 3], vec![1.0, 2.0, 0.5]);
        let v2 = SparseVector::new(vec![50, 60], vec![1.0, 1.0]);
        index.upsert(1, f.sketch(&v1));
        index.upsert(2, f.sketch(&v2));
        let (hits, stats) = index.query_stats(&f.sketch(&v1), 2).unwrap();
        assert_eq!(hits[0].0, 1);
        assert_eq!(hits[0].1, 1.0);
        assert!(stats.candidates >= 1);
        assert_eq!(stats.reranked, stats.candidates);
        assert!(index.remove(1));
        assert!(index.query(&f.sketch(&v1), 2).unwrap().iter().all(|h| h.0 != 1));
    }

    #[test]
    fn near_duplicates_are_found_far_ones_mostly_not() {
        let mut r = SplitMix64::new(77);
        let f = FastGm::new(128, 5);
        let base = SparseVector::new(
            (0..40u64).collect(),
            (0..40).map(|_| r.next_f64() + 0.1).collect(),
        );
        let mut index = LshIndex::new(LshParams::for_threshold(128, 0.5));
        // id 0 = near-duplicate (J_P high), ids 1.. = unrelated.
        let near = vec_with_overlap(&mut r, &base, 0.95);
        index.insert(0, f.sketch(&near));
        for id in 1..60u64 {
            let far = SparseVector::new(
                (0..40).map(|i| id * 1000 + i).collect(),
                (0..40).map(|_| r.next_f64() + 0.1).collect(),
            );
            index.insert(id, f.sketch(&far));
        }
        let hits = index.query(&f.sketch(&base), 5).unwrap();
        assert_eq!(hits[0].0, 0, "near-duplicate must rank first: {hits:?}");
        assert!(hits[0].1 > 0.5);
        // The far vectors should mostly not even be candidates.
        let cands = index.candidates(&f.sketch(&base));
        assert!(cands.len() < 30, "too many candidates: {}", cands.len());
    }

    #[test]
    fn insert_remove_roundtrip() {
        let f = FastGm::new(64, 1);
        let v = SparseVector::new(vec![1, 2, 3], vec![1.0, 1.0, 1.0]);
        let mut index = LshIndex::new(LshParams { bands: 16, rows: 4 });
        index.insert(9, f.sketch(&v));
        assert_eq!(index.len(), 1);
        assert!(!index.candidates(&f.sketch(&v)).is_empty());
        assert!(index.remove(9));
        assert!(!index.remove(9));
        assert!(index.candidates(&f.sketch(&v)).is_empty());
        assert!(index.is_empty());
    }

    #[test]
    fn reinsert_replaces() {
        let f = FastGm::new(64, 1);
        let v1 = SparseVector::new(vec![1, 2], vec![1.0, 1.0]);
        let v2 = SparseVector::new(vec![8, 9], vec![1.0, 1.0]);
        let mut index = LshIndex::new(LshParams { bands: 16, rows: 4 });
        index.insert(5, f.sketch(&v1));
        index.insert(5, f.sketch(&v2));
        assert_eq!(index.len(), 1);
        // Query v1 must not find the stale entry in every band.
        let hits = index.query(&f.sketch(&v2), 1).unwrap();
        assert_eq!(hits[0].0, 5);
        assert_eq!(hits[0].1, 1.0);
    }

    /// Empirical candidate rate tracks the analytic S-curve.
    #[test]
    fn candidate_rate_matches_scurve() {
        let mut r = SplitMix64::new(3);
        let k = 64;
        let params = LshParams { bands: 16, rows: 4 };
        let f = FastGm::new(k, 2);
        let mut hits = 0;
        let trials = 200;
        let mut expected = 0.0;
        for _ in 0..trials {
            let base = SparseVector::new(
                (0..30u64).map(|i| i + (r.next_u64() << 32)).collect(),
                (0..30).map(|_| r.next_f64() + 0.1).collect(),
            );
            let other = vec_with_overlap(&mut r, &base, 0.7);
            let jp = crate::estimate::jaccard::probability_jaccard(&base, &other);
            expected += params.candidate_probability(jp);
            let mut index = LshIndex::new(params);
            index.insert(1, f.sketch(&other));
            if !index.candidates(&f.sketch(&base)).is_empty() {
                hits += 1;
            }
        }
        let rate = hits as f64 / trials as f64;
        let want = expected / trials as f64;
        assert!((rate - want).abs() < 0.12, "rate={rate} want={want}");
    }
}
