//! Braided-chain wireless sensor network simulator — the substrate of the
//! paper's Fig. 9–11 experiments (following Lemiesz's setting).
//!
//! Two sensor chains `S^A`, `S^B` of depth `d`. The first node of each
//! chain is a traffic source generating `n` packets whose sizes follow
//! Beta(5,5). A packet held by node `s_ℓ^X` is forwarded to the next
//! layer's same-chain node with probability `p₁` and, independently, a
//! copy to the cross-chain node with probability `p₂`. Every node builds a
//! Gumbel-Max sketch of the (duplicate-bearing) packet sequence passing
//! through it; sketches answer, per layer (Fig. 10):
//!
//! * (a) total size of distinct packets from each source seen at `s_ℓ^A`,
//! * (b) mean size of distinct packets at `s_ℓ^A`,
//! * (c) total size of packets from source A lost by layer ℓ,
//! * (d) weighted Jaccard similarity between `s_ℓ^A` and `s_ℓ^B`,
//!
//! with exact ground truth maintained alongside via per-node packet sets.
//! The mean-size estimate (b) divides the weighted-cardinality estimate by
//! a unit-weight cardinality estimate from a second sketch over the same
//! sequence — both mergeable, as §2.3 requires.

use crate::estimate::cardinality::{
    estimate_cardinality, estimate_difference_union, estimate_intersection,
    estimate_weighted_jaccard,
};
use crate::sketch::stream_fastgm::StreamFastGm;
use crate::sketch::lemiesz::LemieszSketch;
use crate::sketch::GumbelMaxSketch;
use crate::util::rng::SplitMix64;
use std::collections::HashSet;

/// Simulation parameters (paper defaults: p1=0.9, p2=0.1, d=30, n=10_000,
/// Beta(5,5) packet sizes, k=200).
#[derive(Debug, Clone, Copy)]
pub struct SimParams {
    pub depth: usize,
    pub packets_per_source: usize,
    pub p1: f64,
    pub p2: f64,
    pub k: usize,
    pub seed: u64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams { depth: 30, packets_per_source: 10_000, p1: 0.9, p2: 0.1, k: 200, seed: 42 }
    }
}

/// Which sketcher the nodes run (the Fig. 11 efficiency comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeSketcher {
    StreamFastGm,
    Lemiesz,
}

/// Per-node state: exact packet set (ground truth) + two sketches (weighted
/// and unit-weight) of the sequence received.
pub struct Node {
    /// Exact distinct packets received (id).
    pub packets: HashSet<u64>,
    /// Weighted sketch of the received sequence.
    pub sketch_w: GumbelMaxSketch,
    /// Unit-weight sketch (for distinct counts / mean size).
    pub sketch_1: GumbelMaxSketch,
    /// Stream events processed (duplicates included).
    pub events: u64,
}

/// The simulated network: `nodes[chain][layer]`, chain 0 = A, 1 = B.
pub struct SimNet {
    pub params: SimParams,
    pub nodes: Vec<Vec<Node>>,
    /// Packet sizes: `sizes[id]` for ids 0..2n (A: 0..n, B: n..2n).
    pub sizes: Vec<f64>,
    /// Total sketching time per node sketcher run (seconds).
    pub sketch_seconds: f64,
}

impl SimNet {
    /// Run the full simulation with the given node sketcher.
    pub fn run(params: SimParams, sketcher: NodeSketcher) -> SimNet {
        let n = params.packets_per_source;
        let mut rng = SplitMix64::new(params.seed);
        // Packet sizes Beta(5,5); source A owns ids 0..n, B owns n..2n.
        let sizes: Vec<f64> = (0..2 * n).map(|_| rng.next_beta(5.0, 5.0).max(1e-9)).collect();

        // Per-layer received sequences, built layer by layer. A node's
        // sequence is the concatenation of what the two previous-layer
        // nodes forward to it (duplicates preserved).
        let source_a: Vec<u64> = (0..n as u64).collect();
        let source_b: Vec<u64> = (n as u64..2 * n as u64).collect();
        let mut prev: [Vec<u64>; 2] = [source_a, source_b];

        let mut nodes: Vec<Vec<Node>> = vec![Vec::new(), Vec::new()];
        let mut sketch_seconds = 0.0;

        for layer in 0..params.depth {
            let mut next: [Vec<u64>; 2] = [Vec::new(), Vec::new()];
            for chain in 0..2 {
                let seq = std::mem::take(&mut prev[chain]);
                // Build this node's state from its received sequence.
                let t0 = std::time::Instant::now();
                let (sketch_w, sketch_1) = sketch_sequence(&seq, &sizes, params, sketcher);
                sketch_seconds += t0.elapsed().as_secs_f64();
                let packets: HashSet<u64> = seq.iter().copied().collect();
                nodes[chain].push(Node {
                    packets,
                    sketch_w,
                    sketch_1,
                    events: seq.len() as u64,
                });
                // Forward to the next layer (if any).
                if layer + 1 < params.depth {
                    for &pkt in &seq {
                        if rng.next_f64() < params.p1 {
                            next[chain].push(pkt);
                        }
                        if rng.next_f64() < params.p2 {
                            next[1 - chain].push(pkt);
                        }
                    }
                }
            }
            prev = next;
        }
        SimNet { params, nodes, sizes, sketch_seconds }
    }

    /// Exact weighted size of a packet set.
    pub fn exact_size(&self, packets: &HashSet<u64>) -> f64 {
        packets.iter().map(|&p| self.sizes[p as usize]).sum()
    }

    /// Ids generated by source A / B.
    fn source_set(&self, chain: usize) -> HashSet<u64> {
        let n = self.params.packets_per_source as u64;
        if chain == 0 {
            (0..n).collect()
        } else {
            (n..2 * n).collect()
        }
    }

    /// Fig. 10a: per layer, (truth_A, est_A, truth_B, est_B) — total size of
    /// distinct packets from each source seen at node `s_ℓ^A`.
    pub fn fig10a(&self) -> Vec<(f64, f64, f64, f64)> {
        let src: [&HashSet<u64>; 2] = [&self.source_set(0), &self.source_set(1)];
        // Source sketches: exactly the layer-0 node sketches.
        let src_sk = [&self.nodes[0][0].sketch_w, &self.nodes[1][0].sketch_w];
        self.nodes[0]
            .iter()
            .map(|node| {
                let t_a = self.exact_size(&node.packets.intersection(src[0]).copied().collect());
                let t_b = self.exact_size(&node.packets.intersection(src[1]).copied().collect());
                let e_a = estimate_intersection(src_sk[0], &node.sketch_w).unwrap();
                let e_b = estimate_intersection(src_sk[1], &node.sketch_w).unwrap();
                (t_a, e_a, t_b, e_b)
            })
            .collect()
    }

    /// Fig. 10b: per layer, (truth, estimate) mean distinct-packet size at
    /// `s_ℓ^A`; estimate = weighted cardinality / unit cardinality.
    pub fn fig10b(&self) -> Vec<(f64, f64)> {
        self.nodes[0]
            .iter()
            .map(|node| {
                let count = node.packets.len().max(1) as f64;
                let truth = self.exact_size(&node.packets) / count;
                let cw = estimate_cardinality(&node.sketch_w);
                let c1 = estimate_cardinality(&node.sketch_1).max(1e-12);
                (truth, cw / c1)
            })
            .collect()
    }

    /// Fig. 10c: per layer, (truth, estimate) total size of source-A packets
    /// lost by layer ℓ: `|N_A \ (N_{sℓA} ∪ N_{sℓB})|_w`.
    pub fn fig10c(&self) -> Vec<(f64, f64)> {
        let src_a = self.source_set(0);
        let src_sk = &self.nodes[0][0].sketch_w;
        (0..self.params.depth)
            .map(|l| {
                let union: HashSet<u64> = self.nodes[0][l]
                    .packets
                    .union(&self.nodes[1][l].packets)
                    .copied()
                    .collect();
                let lost: HashSet<u64> = src_a.difference(&union).copied().collect();
                let truth = self.exact_size(&lost);
                let est = estimate_difference_union(
                    src_sk,
                    &self.nodes[0][l].sketch_w,
                    &self.nodes[1][l].sketch_w,
                )
                .unwrap();
                (truth, est)
            })
            .collect()
    }

    /// Fig. 10d: per layer, (truth, estimate) weighted Jaccard between the
    /// packet sets of `s_ℓ^A` and `s_ℓ^B`.
    pub fn fig10d(&self) -> Vec<(f64, f64)> {
        (0..self.params.depth)
            .map(|l| {
                let a = &self.nodes[0][l];
                let b = &self.nodes[1][l];
                let inter: HashSet<u64> = a.packets.intersection(&b.packets).copied().collect();
                let union: HashSet<u64> = a.packets.union(&b.packets).copied().collect();
                let truth = if union.is_empty() {
                    0.0
                } else {
                    self.exact_size(&inter) / self.exact_size(&union)
                };
                let est = estimate_weighted_jaccard(&a.sketch_w, &b.sketch_w).unwrap();
                (truth, est)
            })
            .collect()
    }
}

/// Sketch one node's received sequence with the selected algorithm,
/// producing the weighted and unit-weight sketches.
fn sketch_sequence(
    seq: &[u64],
    sizes: &[f64],
    params: SimParams,
    sketcher: NodeSketcher,
) -> (GumbelMaxSketch, GumbelMaxSketch) {
    match sketcher {
        NodeSketcher::StreamFastGm => {
            let mut w = StreamFastGm::new(params.k, params.seed);
            let mut u = StreamFastGm::new(params.k, params.seed ^ 0xDEAD);
            for &pkt in seq {
                w.push(pkt, sizes[pkt as usize]);
                u.push(pkt, 1.0);
            }
            (w.sketch(), u.sketch())
        }
        NodeSketcher::Lemiesz => {
            let mut w = LemieszSketch::new(params.k, params.seed);
            let mut u = LemieszSketch::new(params.k, params.seed ^ 0xDEAD);
            for &pkt in seq {
                w.push(pkt, sizes[pkt as usize]);
                u.push(pkt, 1.0);
            }
            (w.sketch(), u.sketch())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> SimParams {
        SimParams { depth: 6, packets_per_source: 600, p1: 0.9, p2: 0.1, k: 256, seed: 7 }
    }

    #[test]
    fn packet_flow_decays_with_depth() {
        let net = SimNet::run(small_params(), NodeSketcher::StreamFastGm);
        // Total distinct packets seen at A-chain nodes decays (p1+p2 ≈ 1 but
        // losses accumulate). First layer holds exactly the source.
        assert_eq!(net.nodes[0][0].packets.len(), 600);
        let first = net.exact_size(&net.nodes[0][0].packets);
        let last = net.exact_size(&net.nodes[0][5].packets);
        assert!(last < first, "packet mass should decay: {first} -> {last}");
    }

    #[test]
    fn cross_chain_mixing_occurs() {
        let net = SimNet::run(small_params(), NodeSketcher::StreamFastGm);
        // By layer 2, A-chain nodes should hold some B-source packets.
        let n = net.params.packets_per_source as u64;
        let from_b = net.nodes[0][2].packets.iter().filter(|&&p| p >= n).count();
        assert!(from_b > 0, "no cross-chain packets reached chain A");
    }

    #[test]
    fn fig10_estimates_track_truth() {
        let net = SimNet::run(small_params(), NodeSketcher::StreamFastGm);
        // (a) source-A mass at layer ℓ: relative error bounded by the k=256
        // intersection estimator noise (inclusion-exclusion amplifies; be
        // generous but meaningful).
        for (l, (t_a, e_a, _, _)) in net.fig10a().iter().enumerate().take(4) {
            let rel = (t_a - e_a).abs() / t_a.max(1.0);
            assert!(rel < 0.35, "fig10a layer {l}: truth={t_a} est={e_a}");
        }
        // (b) mean size ≈ 0.5 (Beta(5,5)); estimates within 20%.
        for (l, (t, e)) in net.fig10b().iter().enumerate() {
            assert!((t - 0.5).abs() < 0.05, "layer {l} truth mean={t}");
            assert!((t - e).abs() / t < 0.2, "fig10b layer {l}: truth={t} est={e}");
        }
        // (d) weighted Jaccard in [0,1], increasing mixing over depth,
        // estimates within 0.15 absolute.
        let d = net.fig10d();
        for (l, (t, e)) in d.iter().enumerate() {
            assert!((0.0..=1.0).contains(t));
            assert!((t - e).abs() < 0.15, "fig10d layer {l}: truth={t} est={e}");
        }
        assert!(
            d[4].0 > d[1].0,
            "chains should mix with depth: {:?}",
            d.iter().map(|x| x.0).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fig10c_lost_mass_grows_with_depth() {
        let net = SimNet::run(small_params(), NodeSketcher::StreamFastGm);
        let c = net.fig10c();
        assert!(c[0].0 == 0.0, "nothing lost at the source layer");
        assert!(c[5].0 >= c[1].0, "losses accumulate");
        // Estimate of the last layer within 35% relative (3-way algebra).
        let (t, e) = c[5];
        if t > 5.0 {
            assert!((t - e).abs() / t < 0.35, "truth={t} est={e}");
        }
    }

    #[test]
    fn both_sketchers_agree_on_estimates_shape() {
        // Same family? No — different RNG families; but both must track the
        // same truth within tolerance.
        let a = SimNet::run(small_params(), NodeSketcher::StreamFastGm);
        let b = SimNet::run(small_params(), NodeSketcher::Lemiesz);
        let da = a.fig10b();
        let db = b.fig10b();
        for l in 0..a.params.depth {
            assert!((da[l].1 - db[l].1).abs() < 0.15, "layer {l}: {} vs {}", da[l].1, db[l].1);
        }
    }
}
