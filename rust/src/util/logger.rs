//! Tiny leveled logger backing the `log` crate facade: timestamps relative
//! to process start, level filtering via `FASTGM_LOG` (error|warn|info|debug|
//! trace), safe to initialize more than once.

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

struct Logger {
    start: Instant,
}

static LOGGER: OnceLock<Logger> = OnceLock::new();

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:10.4} {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Level comes from `FASTGM_LOG`,
/// defaulting to `info`.
pub fn init() {
    let level = match std::env::var("FASTGM_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| Logger { start: Instant::now() });
    // set_logger fails if already set (e.g. tests calling init twice) — fine.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}
