//! Micro-benchmark harness (criterion is not in the offline crate set).
//!
//! Calibrates iteration counts against a wall-clock budget, reports
//! median / mean / p10 / p90 per iteration, and can append JSON-lines
//! records so `cargo bench` output is machine-readable (results/*.jsonl).
//! Used both by `benches/figures.rs` (`harness = false`) and by the
//! in-binary experiment harness (`fastgm exp ...`).

use super::stats::{fmt_duration, percentile};
use std::hint::black_box;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration.
    pub median: f64,
    pub mean: f64,
    pub p10: f64,
    pub p90: f64,
    pub iters: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::obj(vec![
            ("name", Value::str(self.name.clone())),
            ("median_s", Value::num(self.median)),
            ("mean_s", Value::num(self.mean)),
            ("p10_s", Value::num(self.p10)),
            ("p90_s", Value::num(self.p90)),
            ("iters", Value::num(self.iters as f64)),
            ("samples", Value::num(self.samples as f64)),
        ])
    }
}

#[derive(Debug, Clone)]
pub struct Bencher {
    /// Total wall-clock budget per benchmark (seconds).
    pub budget: f64,
    /// Number of timed samples to aim for within the budget.
    pub samples: usize,
    /// Warmup time before measurement (seconds).
    pub warmup: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { budget: 1.0, samples: 15, warmup: 0.15 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { budget: 0.3, samples: 7, warmup: 0.05 }
    }

    /// From env: `FASTGM_BENCH_BUDGET` (seconds/bench) for CI tuning.
    pub fn from_env() -> Self {
        let mut b = Bencher::default();
        if let Ok(s) = std::env::var("FASTGM_BENCH_BUDGET") {
            if let Ok(x) = s.parse::<f64>() {
                b.budget = x.max(0.05);
            }
        }
        b
    }

    /// Benchmark `f`, which performs ONE logical iteration per call and may
    /// return a value (fed to `black_box` so the work is not elided).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup + calibration: how many iters fit in one sample slot?
        let warm_start = Instant::now();
        let mut calib_iters = 0u64;
        while warm_start.elapsed().as_secs_f64() < self.warmup || calib_iters == 0 {
            black_box(f());
            calib_iters += 1;
            if calib_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / calib_iters as f64;
        let slot = self.budget / self.samples as f64;
        let iters_per_sample = ((slot / per_iter).floor() as u64).clamp(1, 10_000_000);

        let mut samples = Vec::with_capacity(self.samples);
        let mut total_iters = 0u64;
        let bench_start = Instant::now();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / iters_per_sample as f64);
            total_iters += iters_per_sample;
            if bench_start.elapsed().as_secs_f64() > self.budget * 2.0 {
                break; // hard stop for badly calibrated (slow) cases
            }
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        BenchResult {
            name: name.to_string(),
            median: percentile(&samples, 0.5),
            mean,
            p10: percentile(&samples, 0.1),
            p90: percentile(&samples, 0.9),
            iters: total_iters,
            samples: samples.len(),
        }
    }
}

/// A named collection of benchmark results with table + JSONL output.
#[derive(Default)]
pub struct Suite {
    pub results: Vec<BenchResult>,
    pub jsonl_path: Option<String>,
}

impl Suite {
    pub fn new() -> Self {
        Suite::default()
    }

    /// Write each result as a JSON line to `path` (appending).
    pub fn with_jsonl(mut self, path: &str) -> Self {
        self.jsonl_path = Some(path.to_string());
        self
    }

    pub fn record(&mut self, r: BenchResult) {
        println!(
            "  {:<48} {:>12} /iter   (p10 {:>10}, p90 {:>10}, n={})",
            r.name,
            fmt_duration(r.median),
            fmt_duration(r.p10),
            fmt_duration(r.p90),
            r.iters
        );
        if let Some(path) = &self.jsonl_path {
            use std::io::Write;
            if let Ok(mut f) =
                std::fs::OpenOptions::new().create(true).append(true).open(path)
            {
                let _ = writeln!(f, "{}", r.to_json());
            }
        }
        self.results.push(r);
    }

    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Speedup of `b` relative to `a` (a.median / b.median).
    pub fn speedup(&self, a: &str, b: &str) -> Option<f64> {
        Some(self.get(a)?.median / self.get(b)?.median)
    }

    /// The whole suite as one JSON object: benchmark name → `ns_per_op`
    /// (median) + `ops_per_s` throughput (+ spread and sample counts).
    /// This is the machine-readable summary `perf_probe --json` writes so
    /// perf trajectories can be diffed across commits.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::Obj(
            self.results
                .iter()
                .map(|r| {
                    (
                        r.name.clone(),
                        Value::obj(vec![
                            ("ns_per_op", Value::num(r.median * 1e9)),
                            (
                                "ops_per_s",
                                Value::num(if r.median > 0.0 { 1.0 / r.median } else { 0.0 }),
                            ),
                            ("p10_ns", Value::num(r.p10 * 1e9)),
                            ("p90_ns", Value::num(r.p90 * 1e9)),
                            ("iters", Value::num(r.iters as f64)),
                            ("samples", Value::num(r.samples as f64)),
                        ]),
                    )
                })
                .collect(),
        )
    }

    /// Write [`Suite::to_json`] to `path` (overwriting — each run is one
    /// self-contained summary, unlike the appending JSONL stream).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher { budget: 0.05, samples: 3, warmup: 0.01 };
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median > 0.0);
        assert!(r.p10 <= r.p90);
        assert!(r.iters > 0);
    }

    #[test]
    fn suite_records_and_speedup() {
        let b = Bencher { budget: 0.04, samples: 3, warmup: 0.005 };
        let mut suite = Suite::new();
        suite.record(b.run("fast", || 1u64));
        suite.record(b.run("slow", || {
            // black_box each step so release builds cannot collapse the
            // loop to a constant (this self-test was flaky without it).
            let mut s = 0u64;
            for i in 0..2000u64 {
                s = black_box(s.wrapping_add(black_box(i)));
            }
            s
        }));
        let sp = suite.speedup("slow", "fast").unwrap();
        assert!(sp > 1.0, "speedup={sp}");
    }

    #[test]
    fn json_summary_maps_name_to_ns_and_throughput() {
        let b = Bencher { budget: 0.02, samples: 2, warmup: 0.005 };
        let mut suite = Suite::new();
        suite.record(b.run("alpha", || 1u8));
        let path = std::env::temp_dir().join("fastgm_bench_summary_test.json");
        suite.write_json(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(&text).unwrap();
        let entry = v.get("alpha").expect("bench keyed by name");
        let ns = entry.get("ns_per_op").unwrap().as_f64().unwrap();
        let ops = entry.get("ops_per_s").unwrap().as_f64().unwrap();
        assert!(ns > 0.0 && ops > 0.0);
        // ns/op and ops/s are consistent inverses.
        assert!((ns * ops / 1e9 - 1.0).abs() < 1e-9, "ns={ns} ops={ops}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn jsonl_written() {
        let path = std::env::temp_dir().join("fastgm_bench_test.jsonl");
        let _ = std::fs::remove_file(&path);
        let b = Bencher { budget: 0.02, samples: 2, warmup: 0.005 };
        let mut suite = Suite::new().with_jsonl(path.to_str().unwrap());
        suite.record(b.run("x", || 0u8));
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("x"));
        let _ = std::fs::remove_file(&path);
    }
}
