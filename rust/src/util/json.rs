//! Minimal JSON: a `Value` tree, a recursive-descent parser and a writer.
//!
//! Used for the wire protocol (`coordinator::protocol`), the artifact
//! manifest (`runtime`), benchmark result files (`util::bench`) and the
//! experiment harness output. Object key order is preserved (Vec of pairs)
//! so emitted reports are stable and diffable.

use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(xs) => xs.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Required-field helpers used by the protocol layer.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow::anyhow!("field '{key}' not a string"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| anyhow::anyhow!("field '{key}' not a number"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow::anyhow!("field '{key}' not a usize"))
    }

    /// Builder helpers.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Value {
        Value::Num(x.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    pub fn arr_u64(xs: &[u64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::u64(x)).collect())
    }

    /// Lossless u64: ids above 2^53 don't fit an f64 mantissa, so they are
    /// encoded as decimal strings (the wire-protocol property test caught
    /// silent truncation of hashed 64-bit ids).
    pub fn u64(x: u64) -> Value {
        if x <= (1u64 << 53) {
            Value::Num(x as f64)
        } else {
            Value::Str(x.to_string())
        }
    }

    /// Lossless u64 read: accepts either a number or a decimal string.
    pub fn as_u64_lossless(&self) -> Option<u64> {
        match self {
            Value::Num(_) => self.as_u64(),
            Value::Str(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, v: Value) {
        if let Value::Obj(pairs) = self {
            if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                slot.1 = v;
            } else {
                pairs.push((key.to_string(), v));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

pub fn parse(src: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(xs)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => f.write_str("null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(xs) => {
                f.write_str("[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Value::Obj(pairs) => {
                f.write_str("{")?;
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::SplitMix64;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" -3.5e2 ").unwrap(), Value::Num(-350.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x","d":{}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b"), Some(&Value::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("d").unwrap(), &Value::Obj(vec![]));
    }

    #[test]
    fn parse_unicode_escape_and_utf8() {
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
        assert_eq!(parse("\"héllo→\"").unwrap().as_str(), Some("héllo→"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn display_integers_without_fraction() {
        assert_eq!(Value::Num(3.0).to_string(), "3");
        assert_eq!(Value::Num(3.25).to_string(), "3.25");
    }

    /// Property: display → parse round-trips arbitrary value trees.
    #[test]
    fn roundtrip_property() {
        fn gen_value(r: &mut SplitMix64, depth: usize) -> Value {
            match if depth == 0 { r.next_range(0, 3) } else { r.next_range(0, 5) } {
                0 => Value::Null,
                1 => Value::Bool(r.next_u64() & 1 == 0),
                2 => Value::Num((r.next_f64() * 2000.0 - 1000.0 * (r.next_range(0, 1) as f64)).round() / 8.0),
                3 => {
                    let n = r.next_range(0, 8);
                    Value::Str((0..n).map(|_| char::from_u32(r.next_range(32, 0x2FF) as u32).unwrap_or('x')).collect())
                }
                4 => Value::Arr((0..r.next_range(0, 4)).map(|_| gen_value(r, depth - 1)).collect()),
                _ => Value::Obj(
                    (0..r.next_range(0, 4))
                        .map(|i| (format!("k{i}"), gen_value(r, depth - 1)))
                        .collect(),
                ),
            }
        }
        forall(200, |r| gen_value(r, 3), |v| {
            let text = v.to_string();
            parse(&text).map(|back| back == *v).unwrap_or(false)
        });
    }

    #[test]
    fn set_replaces_or_appends() {
        let mut v = Value::obj(vec![("a", Value::num(1.0))]);
        v.set("a", Value::num(2.0));
        v.set("b", Value::str("x"));
        assert_eq!(v.get("a").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
    }
}
