//! Declarative CLI argument parsing for the `fastgm` launcher (clap is not
//! in the offline crate set). Supports subcommands, `--flag`, `--opt value`
//! / `--opt=value`, repeated options, positionals and generated help text.

#[derive(Debug, Clone)]
struct OptSpec {
    name: &'static str,
    takes_value: bool,
    repeated: bool,
    help: &'static str,
    default: Option<&'static str>,
}

#[derive(Debug, Default)]
pub struct ArgSpec {
    command: String,
    about: &'static str,
    opts: Vec<OptSpec>,
    positionals: Vec<(&'static str, &'static str)>,
}

#[derive(Debug, Default)]
pub struct Args {
    flags: Vec<&'static str>,
    values: Vec<(&'static str, String)>,
    positionals: Vec<String>,
}

impl ArgSpec {
    pub fn new(command: &str, about: &'static str) -> Self {
        ArgSpec { command: command.to_string(), about, ..Default::default() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, takes_value: false, repeated: false, help, default: None });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            takes_value: true,
            repeated: false,
            help,
            default: Some(default),
        });
        self
    }

    /// An option that may be given multiple times (e.g. `--set`).
    pub fn multi(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, takes_value: true, repeated: true, help, default: None });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("fastgm {} — {}\n\nUSAGE:\n  fastgm {}", self.command, self.about, self.command);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p:<14}> {h}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let head = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  {head:<20} {}{def}\n", o.help));
        }
        s.push_str("  --help               print this help\n");
        s
    }

    pub fn parse(&self, argv: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(raw) = it.next() {
            if raw == "--help" || raw == "-h" {
                anyhow::bail!("{}", self.help_text());
            }
            if let Some(body) = raw.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n\n{}", self.help_text()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("--{name} requires a value"))?
                            .clone(),
                    };
                    if !spec.repeated && args.values.iter().any(|(n, _)| *n == spec.name) {
                        anyhow::bail!("--{name} given more than once");
                    }
                    args.values.push((spec.name, v));
                } else {
                    if inline.is_some() {
                        anyhow::bail!("--{name} does not take a value");
                    }
                    args.flags.push(spec.name);
                }
            } else {
                args.positionals.push(raw.clone());
            }
        }
        if args.positionals.len() > self.positionals.len() {
            anyhow::bail!(
                "unexpected positional '{}'\n\n{}",
                args.positionals[self.positionals.len()],
                self.help_text()
            );
        }
        // Fill defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                if !args.values.iter().any(|(n, _)| *n == o.name) {
                    args.values.push((o.name, d.to_string()));
                }
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| *f == name)
    }

    pub fn str(&self, name: &str) -> String {
        self.values
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.clone())
            .unwrap_or_default()
    }

    pub fn all(&self, name: &str) -> Vec<String> {
        self.values.iter().filter(|(n, _)| *n == name).map(|(_, v)| v.clone()).collect()
    }

    pub fn usize(&self, name: &str) -> anyhow::Result<usize> {
        self.str(name)
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{}'", self.str(name)))
    }

    pub fn u64(&self, name: &str) -> anyhow::Result<u64> {
        self.str(name)
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{}'", self.str(name)))
    }

    pub fn f64(&self, name: &str) -> anyhow::Result<f64> {
        self.str(name)
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{}'", self.str(name)))
    }

    /// Parse a comma-separated list of integers, supporting `a..b` (powers
    /// kept explicit) — e.g. `64,128,256`.
    pub fn usize_list(&self, name: &str) -> anyhow::Result<Vec<usize>> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--{name}: bad integer '{s}'"))
            })
            .collect()
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test", "unit test command")
            .flag("verbose", "chatty")
            .opt("k", "1024", "sketch length")
            .multi("set", "config override")
            .positional("input", "input file")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_opts_positionals() {
        let a = spec().parse(&sv(&["--verbose", "--k", "256", "file.txt"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.usize("k").unwrap(), 256);
        assert_eq!(a.positional(0), Some("file.txt"));
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = spec().parse(&sv(&["--k=64"])).unwrap();
        assert_eq!(a.usize("k").unwrap(), 64);
        let a = spec().parse(&sv(&[])).unwrap();
        assert_eq!(a.usize("k").unwrap(), 1024); // default
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn repeated_options_collect() {
        let a = spec().parse(&sv(&["--set", "a=1", "--set", "b=2"])).unwrap();
        assert_eq!(a.all("set"), vec!["a=1".to_string(), "b=2".to_string()]);
    }

    #[test]
    fn rejects_unknown_and_duplicates() {
        assert!(spec().parse(&sv(&["--nope"])).is_err());
        assert!(spec().parse(&sv(&["--k", "1", "--k", "2"])).is_err());
        assert!(spec().parse(&sv(&["a", "b"])).is_err()); // too many positionals
        assert!(spec().parse(&sv(&["--k"])).is_err()); // missing value
        assert!(spec().parse(&sv(&["--verbose=x"])).is_err());
    }

    #[test]
    fn help_lists_everything() {
        let h = spec().help_text();
        assert!(h.contains("--k"));
        assert!(h.contains("default: 1024"));
        assert!(h.contains("<input"));
        let err = spec().parse(&sv(&["--help"])).unwrap_err().to_string();
        assert!(err.contains("USAGE"));
    }

    #[test]
    fn usize_list_parses() {
        let spec = ArgSpec::new("t", "x").opt("ks", "64,128", "list");
        let a = spec.parse(&sv(&[])).unwrap();
        assert_eq!(a.usize_list("ks").unwrap(), vec![64, 128]);
    }
}
