//! TOML-subset configuration loader for the launcher.
//!
//! Supports the subset a deployment config actually needs: `[section]` /
//! `[a.b]` headers, `key = value` with strings, integers, floats, booleans
//! and flat arrays, plus `#` comments. Values flatten into dotted keys
//! (`server.port`) stored as [`json::Value`], with typed getters and CLI
//! `--set key=value` overrides layered on top.

use super::json::Value;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

#[derive(Debug, thiserror::Error)]
#[error("config error at line {line}: {msg}")]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl Config {
    pub fn new() -> Self {
        Config::default()
    }

    pub fn from_file(path: &str) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read config '{path}': {e}"))?;
        Ok(Config::parse(&text)?)
    }

    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| ConfigError { line: lineno + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.trim().to_string();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim();
                if key.is_empty() {
                    return Err(err("empty key"));
                }
                let full = if section.is_empty() {
                    key.to_string()
                } else {
                    format!("{section}.{key}")
                };
                let value = parse_value(v.trim()).map_err(|m| err(&m))?;
                cfg.values.insert(full, value);
            } else {
                return Err(err("expected 'key = value' or '[section]'"));
            }
        }
        Ok(cfg)
    }

    /// Apply a `--set key=value` CLI override.
    pub fn set_override(&mut self, spec: &str) -> anyhow::Result<()> {
        let (k, v) = spec
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--set expects key=value, got '{spec}'"))?;
        let value = parse_value(v.trim()).map_err(|m| anyhow::anyhow!("--set {k}: {m}"))?;
        self.values.insert(k.trim().to_string(), value);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.as_u64()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut xs = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                xs.push(parse_value(p)?);
            }
        }
        return Ok(Value::Arr(xs));
    }
    s.parse::<f64>().map(Value::Num).map_err(|_| format!("cannot parse value '{s}'"))
}

/// Split on commas that are not inside quotes (flat arrays only).
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# FastGM service config
name = "demo"            # inline comment
[server]
port = 7878
workers = 4
shed = true

[sketch]
k = 1024
seed = 42
families = ["ordered", "direct"]
rates = [0.5, 1.5]

[accel.dense]
max_batch = 64
deadline_ms = 2.5
"#;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(SAMPLE).unwrap();
        assert_eq!(cfg.str("name", ""), "demo");
        assert_eq!(cfg.usize("server.port", 0), 7878);
        assert!(cfg.bool("server.shed", false));
        assert_eq!(cfg.usize("sketch.k", 0), 1024);
        assert_eq!(cfg.f64("accel.dense.deadline_ms", 0.0), 2.5);
        let fams = cfg.get("sketch.families").unwrap().as_arr().unwrap();
        assert_eq!(fams[0].as_str(), Some("ordered"));
        let rates = cfg.get("sketch.rates").unwrap().as_arr().unwrap();
        assert_eq!(rates[1].as_f64(), Some(1.5));
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.usize("server.port", 7878), 7878);
        assert_eq!(cfg.str("name", "x"), "x");
    }

    #[test]
    fn overrides_win() {
        let mut cfg = Config::parse(SAMPLE).unwrap();
        cfg.set_override("server.port=9000").unwrap();
        cfg.set_override("extra.flag=true").unwrap();
        assert_eq!(cfg.usize("server.port", 0), 9000);
        assert!(cfg.bool("extra.flag", false));
        assert!(cfg.set_override("nonsense").is_err());
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Config::parse("a = 1\nbogus line\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Config::parse("[oops\n").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let cfg = Config::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(cfg.str("tag", ""), "a#b");
    }
}
