//! Infrastructure substrates built in-crate (the offline environment carries
//! no clap/serde/criterion/tokio, so the pieces a framework normally pulls
//! from the ecosystem are implemented here): deterministic RNGs shared with
//! the Pallas kernels, hashing, JSON, a TOML-subset config loader, CLI
//! argument parsing, statistics, logging, a micro-benchmark harness and a
//! small property-testing helper.

pub mod rng;
pub mod hash;
pub mod json;
pub mod config;
pub mod argparse;
pub mod stats;
pub mod logger;
pub mod bench;
pub mod poll;
pub mod readiness;
pub mod proptest;
