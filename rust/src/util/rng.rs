//! Deterministic random number generation.
//!
//! Two RNG *families* back the sketches (README.md §RNG-families):
//!
//! * the **`Ordered` family** — a [`SplitMix64`] stream per vector element,
//!   seeded from `fmix64(element) ^ seed`, consumed by the ascending
//!   exponential generator (`sketch::order_stats`). Used by FastGM,
//!   Stream-FastGM and FastGM-c.
//! * the **`Direct` family** — a stateless counter RNG
//!   [`direct_bits`]`(seed, i, j)` over 32-bit murmur finalizers, mirrored
//!   bit-for-bit by the Pallas kernels (`python/compile/kernels/ref.py`).
//!   Used by P-MinHash, Lemiesz's sketch and the dense AOT accelerator.
//!
//! Golden-value tests at the bottom of this file and in
//! `python/tests/test_rng.py` pin both implementations to the same
//! constants so the two layers can never silently diverge.

/// SplitMix64's golden-ratio increment. Public so the batched kernels
/// (`sketch::kernels`) can derive per-lane counter states: the state after
/// `t` draws from base state `s` is exactly `s + t·GOLDEN_GAMMA (mod 2^64)`,
/// which is what makes the stream counter-parallelizable without changing a
/// single output bit.
pub const GOLDEN_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// The murmur3 32-bit finalizer: a cheap, high-quality avalanche function.
#[inline(always)]
pub fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85EB_CA6B);
    h ^= h >> 13;
    h = h.wrapping_mul(0xC2B2_AE35);
    h ^= h >> 16;
    h
}

/// The murmur3 / splitmix 64-bit finalizer.
#[inline(always)]
pub fn fmix64(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    h ^= h >> 33;
    h = h.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    h ^= h >> 33;
    h
}

// ---------------------------------------------------------------------------
// Direct family: stateless counter RNG shared with the Pallas kernels.
// ---------------------------------------------------------------------------

/// Domain-separation constant folded into the seed (also in `ref.py`).
pub const DIRECT_SALT: u32 = 0xA076_1D64;

/// First finalizer round of [`direct_bits`]: the `j`-invariant half, mixing
/// only `(seed, i)`. Hoisting it lets the batched row kernels
/// (`sketch::kernels::direct_exp_row`) pay for it once per element instead
/// of once per register — with bit-identical output, since `direct_bits`
/// itself is defined through this split.
#[inline(always)]
pub fn direct_element_hash(seed: u32, i: u32) -> u32 {
    fmix32(seed ^ DIRECT_SALT ^ i.wrapping_mul(0x9E37_79B1))
}

/// Second finalizer round of [`direct_bits`], given a precomputed
/// [`direct_element_hash`].
#[inline(always)]
pub fn direct_bits_from_hash(h: u32, j: u32) -> u32 {
    fmix32(h ^ j.wrapping_mul(0x85EB_CA77))
}

/// 32 uniform bits for cell `(i, j)` under `seed`.
///
/// Two chained finalizer rounds: the first mixes `(seed, i)`, the second
/// mixes in `j`. Identical arithmetic (wrapping u32) on the Python side.
#[inline(always)]
pub fn direct_bits(seed: u32, i: u32, j: u32) -> u32 {
    direct_bits_from_hash(direct_element_hash(seed, i), j)
}

/// Uniform in the *open* interval (0, 1) with 23 usable bits.
///
/// `((bits >> 9) + 0.5) * 2^-23` — never 0 and never 1, so `-ln(u)` is a
/// strictly positive, finite EXP(1) variable. f32 to match the kernel.
#[inline(always)]
pub fn direct_uniform(seed: u32, i: u32, j: u32) -> f32 {
    direct_uniform_from_hash(direct_element_hash(seed, i), j)
}

/// [`direct_uniform`] given a precomputed [`direct_element_hash`].
#[inline(always)]
pub fn direct_uniform_from_hash(h: u32, j: u32) -> f32 {
    ((direct_bits_from_hash(h, j) >> 9) as f32 + 0.5) * (1.0 / 8_388_608.0)
}

/// A standard exponential EXP(1) draw for cell `(i, j)`.
#[inline(always)]
pub fn direct_exp(seed: u32, i: u32, j: u32) -> f32 {
    -direct_uniform(seed, i, j).ln()
}

/// [`direct_exp`] given a precomputed [`direct_element_hash`].
#[inline(always)]
pub fn direct_exp_from_hash(h: u32, j: u32) -> f32 {
    -direct_uniform_from_hash(h, j).ln()
}

// ---------------------------------------------------------------------------
// Ordered family: SplitMix64 streams.
// ---------------------------------------------------------------------------

/// SplitMix64: tiny, fast, passes BigCrush when cascaded; one stream per
/// vector element keyed by `element_stream`.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Stream for element `i` of a sketch keyed by `seed`. Consistency
    /// across vectors (the Gumbel-Max requirement that *the same* a_{i,j}
    /// back every vector) follows from keying only on `(seed, i)`.
    pub fn for_element(seed: u64, i: u64) -> Self {
        SplitMix64::new(fmix64(i.wrapping_add(GOLDEN_GAMMA)) ^ seed)
    }

    /// The raw counter state, for the batched kernels: lane `t` of a SIMD
    /// block draws from state `raw_state + t·GOLDEN_GAMMA` and the stream
    /// resumes at `raw_state + m·GOLDEN_GAMMA` after `m` block draws.
    #[inline(always)]
    pub(crate) fn raw_state(&self) -> u64 {
        self.state
    }

    /// Counterpart of [`SplitMix64::raw_state`]: fast-forward the stream to
    /// exactly where a block of scalar `next_u64` calls would have left it.
    #[inline(always)]
    pub(crate) fn set_raw_state(&mut self, state: u64) {
        self.state = state;
    }

    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN_GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    #[inline(always)]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in the open interval (0, 1) — 52 bits + ½ulp offset.
    #[inline(always)]
    pub fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 12) as f64 + 0.5) * (1.0 / 4_503_599_627_370_496.0)
    }

    /// Standard exponential EXP(1): `-ln(U)`, strictly positive and finite.
    #[inline(always)]
    pub fn next_exp(&mut self) -> f64 {
        -self.next_f64().ln()
    }

    /// Uniform integer in `[lo, hi]` (inclusive). Uses Lemire-style
    /// widening-multiply rejection-free mapping (bias < 2^-32 for our
    /// ranges, all ≤ 2^20).
    #[inline(always)]
    pub fn next_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        lo + (((self.next_u32() as u64).wrapping_mul(span)) >> 32) as usize
    }

    /// Standard normal via Box–Muller (fresh pair each call; we do not cache
    /// the second variate to stay reproducible under interleaving).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Gamma(shape α > 0, scale 1) via Marsaglia–Tsang, with the standard
    /// α < 1 boosting transform.
    pub fn next_gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // G(α) = G(α+1) · U^{1/α}
            let g = self.next_gamma(alpha + 1.0);
            return g * self.next_f64().powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Beta(α, β) from two gammas.
    pub fn next_beta(&mut self, alpha: f64, beta: f64) -> f64 {
        let a = self.next_gamma(alpha);
        let b = self.next_gamma(beta);
        a / (a + b)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for z in (1..xs.len()).rev() {
            let j = self.next_range(0, z);
            xs.swap(z, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden values for the Direct family — the SAME constants are asserted
    /// in `python/tests/test_rng.py`. If either side changes, both tests
    /// fail and the families cannot silently diverge.
    #[test]
    fn direct_family_golden() {
        assert_eq!(fmix32(0), 0);
        assert_eq!(fmix32(1), 0x514E_28B7);
        assert_eq!(fmix32(0xDEAD_BEEF), 0x0DE5_C6A9);
        assert_eq!(direct_bits(0, 0, 0), 0x74B4_A163);
        assert_eq!(direct_bits(42, 7, 1023), 0xDEFD_EE35);
        assert_eq!(direct_bits(0xFFFF_FFFF, 123_456, 89), 0x4894_4F12);
    }

    /// The hoisted two-stage form (`direct_element_hash` +
    /// `*_from_hash`) is definitionally the same arithmetic; pin it anyway
    /// so a future "optimization" of either half cannot split the family.
    #[test]
    fn direct_hash_split_is_lossless() {
        for seed in [0u32, 42, 0xFFFF_FFFF] {
            for i in [0u32, 7, 123_456] {
                let h = direct_element_hash(seed, i);
                for j in [0u32, 1, 1023] {
                    assert_eq!(direct_bits_from_hash(h, j), direct_bits(seed, i, j));
                    assert_eq!(
                        direct_exp_from_hash(h, j).to_bits(),
                        direct_exp(seed, i, j).to_bits()
                    );
                }
            }
        }
    }

    #[test]
    fn raw_state_round_trips_the_stream() {
        let mut a = SplitMix64::new(987);
        let _ = a.next_u64();
        let mut b = SplitMix64::new(0);
        b.set_raw_state(a.raw_state());
        assert_eq!(a.next_u64(), b.next_u64());
        // Counter property: m draws advance the state by m·GOLDEN_GAMMA.
        let mut c = SplitMix64::new(55);
        let base = c.raw_state();
        for _ in 0..5 {
            let _ = c.next_u64();
        }
        assert_eq!(c.raw_state(), base.wrapping_add(GOLDEN_GAMMA.wrapping_mul(5)));
    }

    #[test]
    fn direct_uniform_is_open_unit_interval() {
        for i in 0..1000u32 {
            for j in 0..16u32 {
                let u = direct_uniform(7, i, j);
                assert!(u > 0.0 && u < 1.0, "u={u} at ({i},{j})");
            }
        }
    }

    #[test]
    fn direct_exp_mean_close_to_one() {
        let n = 200_000u32;
        let mean = (0..n).map(|i| direct_exp(3, i, 0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn splitmix_golden() {
        // Reference sequence for seed 1234567 (matches the published
        // SplitMix64 test vectors construction).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn splitmix_uniform_moments() {
        let mut r = SplitMix64::new(99);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let u = r.next_f64();
            assert!(u > 0.0 && u < 1.0);
            sum += u;
            sumsq += u * u;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn exp_moments() {
        let mut r = SplitMix64::new(7);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_exp()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_beta_moments() {
        let mut r = SplitMix64::new(13);
        let n = 100_000;
        // Gamma(5): mean 5, var 5.
        let xs: Vec<f64> = (0..n).map(|_| r.next_gamma(5.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.1, "gamma mean={mean}");
        // Beta(5,5): mean .5, var 1/44.
        let bs: Vec<f64> = (0..n).map(|_| r.next_beta(5.0, 5.0)).collect();
        let bmean = bs.iter().sum::<f64>() / n as f64;
        let bvar = bs.iter().map(|x| (x - bmean) * (x - bmean)).sum::<f64>() / n as f64;
        assert!((bmean - 0.5).abs() < 0.01, "beta mean={bmean}");
        assert!((bvar - 1.0 / 44.0).abs() < 0.005, "beta var={bvar}");
        // Gamma(0.5) small-shape path: mean 0.5.
        let gs: Vec<f64> = (0..n).map(|_| r.next_gamma(0.5)).collect();
        let gmean = gs.iter().sum::<f64>() / n as f64;
        assert!((gmean - 0.5).abs() < 0.05, "gamma(.5) mean={gmean}");
    }

    #[test]
    fn next_range_covers_inclusive_bounds() {
        let mut r = SplitMix64::new(5);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.next_range(2, 9);
            assert!((2..=9).contains(&v));
            seen[v - 2] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(21);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn element_streams_are_decorrelated() {
        // Consecutive element ids must yield unrelated streams.
        let a = SplitMix64::for_element(0, 1).next_u64();
        let b = SplitMix64::for_element(0, 2).next_u64();
        assert_ne!(a, b);
        assert!(((a ^ b).count_ones() as i32 - 32).abs() < 20);
    }
}
