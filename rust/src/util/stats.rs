//! Statistics helpers shared by the estimators, the experiment harness and
//! the benchmark runner: online moments (Welford), percentiles, RMSE, and a
//! fixed-width table printer for paper-style result tables.

/// Online mean/variance accumulator (Welford). Numerically stable for the
//  long benchmark series the experiment harness feeds it.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * (self.n as f64) * (other.n as f64) / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Root-mean-square error between estimates and a (scalar) ground truth.
pub fn rmse_scalar(estimates: &[f64], truth: f64) -> f64 {
    if estimates.is_empty() {
        return 0.0;
    }
    (estimates.iter().map(|e| (e - truth) * (e - truth)).sum::<f64>() / estimates.len() as f64)
        .sqrt()
}

/// RMSE between paired estimates and truths.
pub fn rmse_paired(estimates: &[f64], truths: &[f64]) -> f64 {
    assert_eq!(estimates.len(), truths.len());
    if estimates.is_empty() {
        return 0.0;
    }
    (estimates
        .iter()
        .zip(truths)
        .map(|(e, t)| (e - t) * (e - t))
        .sum::<f64>()
        / estimates.len() as f64)
        .sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 1]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 0.5)
}

/// Fixed-width ASCII table used by `fastgm exp ...` to print paper-style
/// rows (also written under `results/`).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }
}

/// Human formatting for seconds (benchmark output).
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Format a count like 12345678 → "12.3M".
pub fn fmt_count(x: f64) -> String {
    let a = x.abs();
    if a >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if a >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if a >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::SplitMix64;

    #[test]
    fn online_stats_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn merge_equals_concat_property() {
        forall(
            100,
            |r| {
                let n1 = r.next_range(0, 50);
                let n2 = r.next_range(0, 50);
                let a: Vec<f64> = (0..n1).map(|_| r.next_normal() * 10.0).collect();
                let b: Vec<f64> = (0..n2).map(|_| r.next_normal() * 10.0).collect();
                (a, b)
            },
            |(a, b)| {
                let mut s1 = OnlineStats::new();
                a.iter().for_each(|&x| s1.push(x));
                let mut s2 = OnlineStats::new();
                b.iter().for_each(|&x| s2.push(x));
                s1.merge(&s2);
                let mut s3 = OnlineStats::new();
                a.iter().chain(b.iter()).for_each(|&x| s3.push(x));
                (s1.mean() - s3.mean()).abs() < 1e-9 && (s1.var() - s3.var()).abs() < 1e-9
            },
        );
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn rmse_basics() {
        assert_eq!(rmse_scalar(&[3.0, 3.0], 3.0), 0.0);
        assert!((rmse_scalar(&[2.0, 4.0], 3.0) - 1.0).abs() < 1e-12);
        assert!((rmse_paired(&[1.0, 2.0], &[0.0, 2.0]) - (0.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["k", "time"]);
        t.row(vec!["64".into(), "1.2 ms".into()]);
        t.row(vec!["4096".into(), "10.0 ms".into()]);
        let s = t.render();
        assert!(s.contains("|    k |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_count(1_500_000.0), "1.50M");
    }

    #[test]
    fn percentile_random_agrees_with_sort() {
        let mut r = SplitMix64::new(3);
        let xs: Vec<f64> = (0..101).map(|_| r.next_f64()).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(percentile(&xs, 0.5), sorted[50]);
    }
}
