//! Minimal safe wrapper over `poll(2)` — just enough readiness polling
//! for the event-driven transport, with no async runtime and no new
//! dependencies (std already links libc; we declare the one extern fn
//! ourselves).
//!
//! On non-Unix targets the module still compiles and [`poll`] returns a
//! clean error; the event server is `#[cfg(unix)]`-gated, so nothing
//! else reaches this path.

/// Readiness flags, matching `<poll.h>` on every libc we target.
pub const POLLIN: i16 = 0x001;
pub const POLLOUT: i16 = 0x004;
pub const POLLERR: i16 = 0x008;
pub const POLLHUP: i16 = 0x010;
pub const POLLNVAL: i16 = 0x020;

/// One descriptor's interest + result set. Layout-compatible with the
/// kernel's `struct pollfd` (fd, events, revents — all naturally
/// aligned, no padding).
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    pub fd: i32,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: i32, events: i16) -> Self {
        PollFd { fd, events, revents: 0 }
    }

    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLHUP | POLLERR) != 0
    }

    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLERR) != 0
    }

    pub fn error(&self) -> bool {
        self.revents & (POLLERR | POLLNVAL) != 0
    }
}

#[cfg(unix)]
mod sys {
    // `nfds_t` is `unsigned long` on Linux and `unsigned int` on the
    // BSDs/macOS; pick per-OS rather than guessing from pointer width.
    #[cfg(target_os = "linux")]
    pub type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    pub type NfdsT = std::os::raw::c_uint;

    extern "C" {
        pub fn poll(fds: *mut super::PollFd, nfds: NfdsT, timeout: std::os::raw::c_int) -> i32;
    }
}

/// Block until at least one descriptor in `fds` is ready, the timeout
/// elapses (`Ok(0)`), or an error occurs. `timeout_ms < 0` blocks
/// indefinitely. `EINTR` is retried internally so callers never see a
/// spurious failure from a signal.
#[cfg(unix)]
pub fn poll(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
    loop {
        let rc = unsafe { sys::poll(fds.as_mut_ptr(), fds.len() as sys::NfdsT, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = std::io::Error::last_os_error();
        if err.kind() != std::io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

#[cfg(not(unix))]
pub fn poll(_fds: &mut [PollFd], _timeout_ms: i32) -> std::io::Result<usize> {
    Err(std::io::Error::new(
        std::io::ErrorKind::Unsupported,
        "poll(2) readiness loop is only available on unix targets",
    ))
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn timeout_returns_zero_ready() {
        let (a, _b) = UnixStream::pair().unwrap();
        let mut fds = [PollFd::new(a.as_raw_fd(), POLLIN)];
        let n = poll(&mut fds, 10).unwrap();
        assert_eq!(n, 0);
        assert!(!fds[0].readable());
    }

    #[test]
    fn written_byte_wakes_pollin() {
        let (a, mut b) = UnixStream::pair().unwrap();
        b.write_all(&[1]).unwrap();
        let mut fds = [PollFd::new(a.as_raw_fd(), POLLIN)];
        let n = poll(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].readable());
    }

    #[test]
    fn idle_socket_is_immediately_writable() {
        let (a, _b) = UnixStream::pair().unwrap();
        let mut fds = [PollFd::new(a.as_raw_fd(), POLLOUT)];
        let n = poll(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].writable());
    }

    #[test]
    fn hangup_reports_readable_for_eof_draining() {
        let (a, b) = UnixStream::pair().unwrap();
        drop(b);
        let mut fds = [PollFd::new(a.as_raw_fd(), POLLIN)];
        let n = poll(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].readable());
    }
}
