//! String / byte hashing used to map external object identifiers (packet
//! ids, document tokens, …) into the `u64` element-id space the sketches
//! index by, plus an FNV-1a fallback for short keys.

use super::rng::fmix64;

/// FNV-1a 64-bit — stable, allocation-free, good enough for short tokens.
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_chain(0xCBF2_9CE4_8422_2325, bytes)
}

/// Continue an FNV-1a fold from a previous [`fnv1a64`] state. The fold is
/// a plain byte-by-byte recurrence, so
/// `fnv1a64_chain(fnv1a64(a), b) == fnv1a64` of `a` and `b` concatenated —
/// which lets the framed transport checksum a frame spliced from several
/// buffers (header span, codec blob, trailer) without ever concatenating
/// them.
#[inline]
pub fn fnv1a64_chain(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// wyhash-style 64-bit mix of two words (used for composite keys).
#[inline]
pub fn mix2(a: u64, b: u64) -> u64 {
    fmix64(a.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ b.wrapping_add(0x2545_F491_4F6C_DD1D))
}

/// Hash a string token to an element id.
#[inline]
pub fn token_id(s: &str) -> u64 {
    fmix64(fnv1a64(s.as_bytes()))
}

/// Hash `bytes` with an explicit seed (for LSH band hashing).
#[inline]
pub fn seeded(bytes: &[u8], seed: u64) -> u64 {
    fmix64(fnv1a64(bytes) ^ seed.wrapping_mul(0xA076_1D64_78BD_642F))
}

/// Hash a slice of u64 values with a seed (LSH band signature → bucket key).
pub fn hash_u64s(xs: &[u64], seed: u64) -> u64 {
    let mut h = seed ^ 0x9E37_79B9_7F4A_7C15;
    for &x in xs {
        h = mix2(h, x);
    }
    fmix64(h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_values() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171F73967E8);
    }

    /// The chained fold equals the one-shot fold of the concatenation, at
    /// every split point — what the spliced frame checksum relies on.
    #[test]
    fn chained_fold_matches_concatenation() {
        let bytes = b"the quick brown fox jumps over the lazy dog";
        let whole = fnv1a64(bytes);
        for split in 0..=bytes.len() {
            let (a, b) = bytes.split_at(split);
            assert_eq!(fnv1a64_chain(fnv1a64(a), b), whole, "split at {split}");
        }
        // Three-way splits chain too (prefix | blob | nothing-left).
        let h = fnv1a64_chain(fnv1a64_chain(fnv1a64(&bytes[..9]), &bytes[9..20]), &bytes[20..]);
        assert_eq!(h, whole);
    }

    #[test]
    fn token_ids_distinct_and_stable() {
        let a = token_id("alpha");
        let b = token_id("beta");
        assert_ne!(a, b);
        assert_eq!(a, token_id("alpha"));
    }

    #[test]
    fn seeded_varies_with_seed() {
        assert_ne!(seeded(b"x", 1), seeded(b"x", 2));
    }

    #[test]
    fn hash_u64s_order_sensitive() {
        assert_ne!(hash_u64s(&[1, 2, 3], 0), hash_u64s(&[3, 2, 1], 0));
        assert_eq!(hash_u64s(&[1, 2, 3], 5), hash_u64s(&[1, 2, 3], 5));
    }
}
