//! A small property-testing helper (the `proptest` crate is not available
//! in the offline crate set). Deterministic by default; set
//! `FASTGM_PROPTEST_SEED` / `FASTGM_PROPTEST_CASES` to vary.

use super::rng::SplitMix64;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Run `prop` over `cases` inputs drawn by `gen`. Panics with the seed and
/// a debug dump of the failing case so it can be replayed.
pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    gen: impl Fn(&mut SplitMix64) -> T,
    prop: impl Fn(&T) -> bool,
) {
    let cases = env_u64("FASTGM_PROPTEST_CASES", cases as u64) as usize;
    let seed = env_u64("FASTGM_PROPTEST_SEED", 0xFA57_6D5E);
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        assert!(
            prop(&input),
            "property failed (case {case}, seed {seed:#x}); input = {input:#?}"
        );
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so failures
/// can explain themselves.
pub fn forall_explain<T: std::fmt::Debug>(
    cases: usize,
    gen: impl Fn(&mut SplitMix64) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let cases = env_u64("FASTGM_PROPTEST_CASES", cases as u64) as usize;
    let seed = env_u64("FASTGM_PROPTEST_SEED", 0xFA57_6D5E);
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property failed (case {case}, seed {seed:#x}): {msg}\ninput = {input:#?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(50, |r| r.next_f64(), |u| *u > 0.0 && *u < 1.0);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(50, |r| r.next_range(0, 10), |x| *x < 10);
    }
}
