//! Readiness backends for the event-driven transport: one trait, two
//! implementations, picked at runtime.
//!
//! [`PollBackend`] wraps [`super::poll`] and rebuilds its `pollfd` array
//! per wait — O(watched descriptors) every wakeup, but portable to every
//! unix. [`EpollBackend`] (Linux only) keeps the interest set in the
//! kernel: registration changes are incremental `epoll_ctl` calls and a
//! wakeup costs O(ready descriptors), so an event loop over 10k mostly
//! idle sockets stops paying for the 9 990 quiet ones. [`make_backend`]
//! prefers epoll where it exists and falls back to poll — set
//! `FASTGM_READINESS=poll` to force the fallback (each backend reports
//! readiness identically, so the choice is invisible above this module).
//!
//! Like [`super::poll`], the epoll syscalls are self-declared `extern`
//! fns — std already links libc and the offline build carries no libc
//! crate.

use super::poll::{poll, PollFd, POLLIN, POLLOUT};
use std::collections::HashMap;

/// One ready descriptor, by the caller's key (not the raw fd): readable /
/// writable mirror [`PollFd::readable`] / [`PollFd::writable`] — errors
/// and hangups surface as both, so the caller's read/write path observes
/// the failure and closes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Readiness {
    pub key: usize,
    pub readable: bool,
    pub writable: bool,
}

/// A pluggable readiness notifier. `update` replaces (or installs) the
/// interest set of `fd` under the caller-chosen `key`; `remove` must be
/// called before the descriptor is closed; `wait` blocks up to
/// `timeout_ms` and appends ready descriptors to `out` (cleared first).
pub trait ReadinessBackend: Send {
    fn name(&self) -> &'static str;
    fn update(&mut self, fd: i32, key: usize, read: bool, write: bool) -> std::io::Result<()>;
    fn remove(&mut self, fd: i32);
    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Readiness>) -> std::io::Result<()>;
}

/// Portable fallback: interest lives in a map; every `wait` materializes
/// it into a fresh `pollfd` array (the O(connections) rebuild the epoll
/// backend exists to avoid).
pub struct PollBackend {
    interest: HashMap<i32, (usize, i16)>,
    /// Scratch reused across waits (allocation-free steady state).
    fds: Vec<PollFd>,
    keys: Vec<usize>,
}

impl PollBackend {
    pub fn new() -> PollBackend {
        PollBackend { interest: HashMap::new(), fds: Vec::new(), keys: Vec::new() }
    }
}

impl Default for PollBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ReadinessBackend for PollBackend {
    fn name(&self) -> &'static str {
        "poll"
    }

    fn update(&mut self, fd: i32, key: usize, read: bool, write: bool) -> std::io::Result<()> {
        let mut events = 0i16;
        if read {
            events |= POLLIN;
        }
        if write {
            events |= POLLOUT;
        }
        self.interest.insert(fd, (key, events));
        Ok(())
    }

    fn remove(&mut self, fd: i32) {
        self.interest.remove(&fd);
    }

    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Readiness>) -> std::io::Result<()> {
        out.clear();
        self.fds.clear();
        self.keys.clear();
        for (&fd, &(key, events)) in &self.interest {
            // Zero-interest fds stay registered: poll still reports
            // errors/hangups for them, matching epoll's semantics.
            self.fds.push(PollFd::new(fd, events));
            self.keys.push(key);
        }
        poll(&mut self.fds, timeout_ms)?;
        for (fd, &key) in self.fds.iter().zip(&self.keys) {
            if fd.readable() || fd.writable() {
                out.push(Readiness { key, readable: fd.readable(), writable: fd.writable() });
            }
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
mod sys {
    pub const EPOLL_CLOEXEC: i32 = 0o2000000;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;

    /// `struct epoll_event` is `__attribute__((packed))` on x86-64 (and
    /// only there); `#[repr(C, packed)]` matches the kernel ABI on every
    /// architecture Rust targets for Linux.
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout: std::os::raw::c_int,
        ) -> i32;
        pub fn close(fd: i32) -> i32;
    }
}

/// Kernel-side interest set via `epoll(7)`. The local `armed` map only
/// mirrors what the kernel holds so `update` can pick ADD vs MOD and skip
/// the syscall entirely when nothing changed — the steady-state cost of a
/// wakeup is one `epoll_wait` returning just the ready descriptors.
#[cfg(target_os = "linux")]
pub struct EpollBackend {
    epfd: i32,
    /// fd → (key, armed event mask).
    armed: HashMap<i32, (usize, u32)>,
    events: Vec<sys::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl EpollBackend {
    pub fn new() -> std::io::Result<EpollBackend> {
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(EpollBackend {
            epfd,
            armed: HashMap::new(),
            events: vec![sys::EpollEvent { events: 0, data: 0 }; 256],
        })
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollBackend {
    fn drop(&mut self) {
        unsafe { sys::close(self.epfd) };
    }
}

#[cfg(target_os = "linux")]
impl ReadinessBackend for EpollBackend {
    fn name(&self) -> &'static str {
        "epoll"
    }

    fn update(&mut self, fd: i32, key: usize, read: bool, write: bool) -> std::io::Result<()> {
        let mut mask = 0u32;
        if read {
            mask |= sys::EPOLLIN;
        }
        if write {
            mask |= sys::EPOLLOUT;
        }
        let op = match self.armed.get(&fd) {
            Some(&(k, m)) if k == key && m == mask => return Ok(()),
            Some(_) => sys::EPOLL_CTL_MOD,
            None => sys::EPOLL_CTL_ADD,
        };
        let mut ev = sys::EpollEvent { events: mask, data: key as u64 };
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(std::io::Error::last_os_error());
        }
        self.armed.insert(fd, (key, mask));
        Ok(())
    }

    fn remove(&mut self, fd: i32) {
        if self.armed.remove(&fd).is_some() {
            let mut ev = sys::EpollEvent { events: 0, data: 0 };
            // Best-effort: the close() that follows detaches it anyway.
            unsafe { sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_DEL, fd, &mut ev) };
        }
    }

    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Readiness>) -> std::io::Result<()> {
        out.clear();
        let n = loop {
            let rc = unsafe {
                sys::epoll_wait(
                    self.epfd,
                    self.events.as_mut_ptr(),
                    self.events.len() as i32,
                    timeout_ms,
                )
            };
            if rc >= 0 {
                break rc as usize;
            }
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                return Err(err);
            }
        };
        for ev in &self.events[..n] {
            let (mask, key) = (ev.events, ev.data as usize);
            out.push(Readiness {
                key,
                readable: mask & (sys::EPOLLIN | sys::EPOLLHUP | sys::EPOLLERR) != 0,
                writable: mask & (sys::EPOLLOUT | sys::EPOLLERR) != 0,
            });
        }
        if n == self.events.len() {
            // Saturated: more may be ready; grow so one wakeup can report
            // a larger burst next time.
            self.events.resize(n * 2, sys::EpollEvent { events: 0, data: 0 });
        }
        Ok(())
    }
}

/// The best backend this platform offers: epoll on Linux (unless
/// `FASTGM_READINESS=poll` forces the fallback or `epoll_create1` fails),
/// poll everywhere else.
pub fn make_backend() -> Box<dyn ReadinessBackend> {
    #[cfg(target_os = "linux")]
    {
        if std::env::var("FASTGM_READINESS").as_deref() != Ok("poll") {
            match EpollBackend::new() {
                Ok(b) => return Box::new(b),
                Err(e) => log::warn!("epoll unavailable ({e}); falling back to poll"),
            }
        }
    }
    Box::new(PollBackend::new())
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    fn backends() -> Vec<Box<dyn ReadinessBackend>> {
        let mut all: Vec<Box<dyn ReadinessBackend>> = vec![Box::new(PollBackend::new())];
        #[cfg(target_os = "linux")]
        all.push(Box::new(EpollBackend::new().unwrap()));
        all
    }

    /// Both backends report the same readiness transitions for the same
    /// socket activity — the property that makes the runtime selection
    /// invisible to the event loop.
    #[test]
    fn backends_agree_on_read_write_and_hangup() {
        for mut b in backends() {
            let name = b.name();
            let (a, mut peer) = UnixStream::pair().unwrap();
            let fd = a.as_raw_fd();
            let mut out = Vec::new();
            // Read interest, quiet socket: timeout, nothing ready.
            b.update(fd, 7, true, false).unwrap();
            b.wait(10, &mut out).unwrap();
            assert!(out.is_empty(), "[{name}] quiet socket reported {out:?}");
            // A written byte wakes readability under the caller's key.
            peer.write_all(&[1]).unwrap();
            b.wait(1000, &mut out).unwrap();
            assert_eq!(out.len(), 1, "[{name}]");
            assert!(out[0].readable && out[0].key == 7, "[{name}] {out:?}");
            let mut sink = [0u8; 8];
            let _ = (&a).read(&mut sink);
            // Write interest on an idle socket is immediately ready.
            b.update(fd, 7, false, true).unwrap();
            b.wait(1000, &mut out).unwrap();
            assert!(out.iter().any(|r| r.key == 7 && r.writable), "[{name}] {out:?}");
            // Zero interest: the fd stays registered but reports nothing.
            b.update(fd, 7, false, false).unwrap();
            peer.write_all(&[2]).unwrap();
            b.wait(10, &mut out).unwrap();
            assert!(
                !out.iter().any(|r| r.key == 7 && r.readable),
                "[{name}] zero-interest fd reported readable: {out:?}"
            );
            // Hangup surfaces as readable (EOF drain), like PollFd does.
            b.update(fd, 7, true, false).unwrap();
            drop(peer);
            b.wait(1000, &mut out).unwrap();
            assert!(out.iter().any(|r| r.key == 7 && r.readable), "[{name}] {out:?}");
            // Removal: no further events, and re-adding works.
            b.remove(fd);
            b.wait(10, &mut out).unwrap();
            assert!(out.is_empty(), "[{name}] removed fd still reported: {out:?}");
            b.update(fd, 9, true, false).unwrap();
            b.wait(1000, &mut out).unwrap();
            assert!(out.iter().any(|r| r.key == 9 && r.readable), "[{name}] {out:?}");
        }
    }

    /// Updates are cheap no-ops when nothing changed, and key remapping
    /// takes effect (slot recycling depends on this).
    #[test]
    fn rearming_and_key_remap() {
        for mut b in backends() {
            let name = b.name();
            let (a, mut peer) = UnixStream::pair().unwrap();
            let fd = a.as_raw_fd();
            b.update(fd, 1, true, false).unwrap();
            b.update(fd, 1, true, false).unwrap(); // identical re-arm
            b.update(fd, 2, true, false).unwrap(); // same mask, new key
            peer.write_all(&[1]).unwrap();
            let mut out = Vec::new();
            b.wait(1000, &mut out).unwrap();
            assert_eq!(out.len(), 1, "[{name}] {out:?}");
            assert_eq!(out[0].key, 2, "[{name}] stale key survived remap");
        }
    }

    #[test]
    fn make_backend_returns_a_working_backend() {
        let mut b = make_backend();
        #[cfg(target_os = "linux")]
        assert_eq!(b.name(), "epoll");
        let (a, mut peer) = UnixStream::pair().unwrap();
        b.update(a.as_raw_fd(), 3, true, false).unwrap();
        peer.write_all(&[1]).unwrap();
        let mut out = Vec::new();
        b.wait(1000, &mut out).unwrap();
        assert_eq!(out, vec![Readiness { key: 3, readable: true, writable: false }]);
    }
}
