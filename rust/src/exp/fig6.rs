//! Fig. 6 — J_P estimation accuracy (RMSE vs k) for FastGM vs P-MinHash on
//! two dataset analogs. Paper shape: identical accuracy for both
//! algorithms, tracking the theoretical √(J(1−J)/k).

use super::ExpOptions;
use crate::data::corpus::Corpus;
use crate::estimate::jaccard::{estimate_jp, jp_estimator_std, probability_jaccard};
use crate::sketch::fastgm::FastGm;
use crate::sketch::pminhash::PMinHash;
use crate::sketch::Sketcher;
use crate::util::rng::SplitMix64;
use crate::util::stats::Table;

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let ks: Vec<usize> =
        if opts.full { vec![64, 128, 256, 512, 1024] } else { vec![64, 256] };
    let pairs_per_ds = if opts.full { 200 } else { 40 };
    let datasets = ["real-sim", "movielens"];

    let mut t = Table::new(&["dataset", "k", "rmse fastgm", "rmse pminhash", "theory (mean)"]);
    for name in datasets {
        let corpus = Corpus::by_name(name, 3).unwrap();
        let mut rng = SplitMix64::new(0xF16_6);
        // Pre-draw vector pairs (random pairs share head features via Zipf).
        let pairs: Vec<(crate::sketch::SparseVector, crate::sketch::SparseVector, f64)> = (0
            ..pairs_per_ds)
            .map(|_| {
                let i = rng.next_range(0, 2000);
                let j = rng.next_range(0, 2000);
                let u = corpus.vector(i);
                let v = corpus.vector(j);
                let jp = probability_jaccard(&u, &v);
                (u, v, jp)
            })
            .collect();
        for &k in &ks {
            let mut se_f = 0.0;
            let mut se_p = 0.0;
            let mut theory = 0.0;
            for (idx, (u, v, jp)) in pairs.iter().enumerate() {
                let seed = idx as u64;
                let fg = FastGm::new(k, seed);
                let e1 = estimate_jp(&fg.sketch(u), &fg.sketch(v)).unwrap();
                let pm = PMinHash::new(k, seed);
                let e2 = estimate_jp(&pm.sketch(u), &pm.sketch(v)).unwrap();
                se_f += (e1 - jp) * (e1 - jp);
                se_p += (e2 - jp) * (e2 - jp);
                theory += jp_estimator_std(*jp, k);
            }
            let n = pairs.len() as f64;
            t.row(vec![
                name.to_string(),
                k.to_string(),
                format!("{:.4}", (se_f / n).sqrt()),
                format!("{:.4}", (se_p / n).sqrt()),
                format!("{:.4}", theory / n),
            ]);
        }
    }
    opts.emit("fig6", "Fig 6: J_P estimation RMSE vs k (FastGM == P-MinHash == theory)", &t)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Both families' RMSE must track √(J(1-J)/k) — the "no accuracy loss"
    /// claim of the paper, checked end-to-end on corpus-analog pairs.
    #[test]
    fn rmse_tracks_theory() {
        let corpus = Corpus::by_name("real-sim", 3).unwrap();
        let u = corpus.vector(1);
        let v = corpus.vector(2);
        let jp = probability_jaccard(&u, &v);
        let k = 256;
        let runs = 60;
        let mut se_f = 0.0;
        for seed in 0..runs {
            let fg = FastGm::new(k, seed);
            let e = estimate_jp(&fg.sketch(&u), &fg.sketch(&v)).unwrap();
            se_f += (e - jp) * (e - jp);
        }
        let rmse = (se_f / runs as f64).sqrt();
        let theory = jp_estimator_std(jp, k);
        assert!(
            rmse < 2.0 * theory + 1e-3,
            "rmse={rmse} should track theory={theory} (jp={jp})"
        );
    }
}
