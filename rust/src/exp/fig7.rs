//! Fig. 7 — weighted cardinality estimation RMSE on synthetic datasets,
//! weights UNI(0,1) and N(1, 0.1), FastGM sketch vs Lemiesz's sketch.
//! Paper shape: identical accuracy (both `y` parts are EXP(c) registers),
//! relative RMSE ≈ √(2/k).

use super::ExpOptions;
use crate::data::stream::generate;
use crate::data::synthetic::WeightDist;
use crate::estimate::cardinality::{cardinality_rel_std, estimate_cardinality};
use crate::sketch::lemiesz::LemieszSketch;
use crate::sketch::stream_fastgm::StreamFastGm;
use crate::util::rng::SplitMix64;
use crate::util::stats::Table;

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let ks: Vec<usize> = if opts.full { vec![64, 128, 256, 512, 1024] } else { vec![64, 256] };
    let ns: Vec<usize> = if opts.full { vec![1000, 10_000] } else { vec![1000] };
    let runs = if opts.full { 200 } else { 50 };
    let dists = [WeightDist::Uniform01, WeightDist::Normal(1.0, 0.1)];

    let mut t = Table::new(&[
        "weights", "n", "k", "rel-RMSE fastgm", "rel-RMSE lemiesz", "theory sqrt(2/k)",
    ]);
    for dist in dists {
        for &n in &ns {
            let mut rng = SplitMix64::new(0xF16_7);
            let stream = generate(&mut rng, n, 1.0, dist, 0);
            let truth = stream.weighted_cardinality();
            for &k in &ks {
                let mut se_f = 0.0;
                let mut se_l = 0.0;
                for seed in 0..runs as u64 {
                    let mut f = StreamFastGm::new(k, seed);
                    let mut l = LemieszSketch::new(k, seed);
                    for &(id, w) in &stream.events {
                        f.push(id, w);
                        l.push(id, w);
                    }
                    let ef = estimate_cardinality(&f.sketch());
                    let el = estimate_cardinality(&l.sketch());
                    se_f += (ef / truth - 1.0) * (ef / truth - 1.0);
                    se_l += (el / truth - 1.0) * (el / truth - 1.0);
                }
                t.row(vec![
                    dist.name(),
                    n.to_string(),
                    k.to_string(),
                    format!("{:.4}", (se_f / runs as f64).sqrt()),
                    format!("{:.4}", (se_l / runs as f64).sqrt()),
                    format!("{:.4}", cardinality_rel_std(k)),
                ]);
            }
        }
    }
    opts.emit("fig7", "Fig 7: weighted cardinality rel-RMSE vs k", &t)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// FastGM's and Lemiesz's estimators have the same error profile,
    /// matching √(2/k) — the Fig. 7 claim.
    #[test]
    fn both_sketches_match_theory() {
        let mut rng = SplitMix64::new(5);
        let stream = generate(&mut rng, 500, 0.5, WeightDist::Uniform01, 0);
        let truth = stream.weighted_cardinality();
        let k = 256;
        let runs = 60;
        let mut se_f = 0.0;
        let mut se_l = 0.0;
        for seed in 0..runs as u64 {
            let mut f = StreamFastGm::new(k, seed);
            let mut l = LemieszSketch::new(k, seed);
            for &(id, w) in &stream.events {
                f.push(id, w);
                l.push(id, w);
            }
            se_f += (estimate_cardinality(&f.sketch()) / truth - 1.0).powi(2);
            se_l += (estimate_cardinality(&l.sketch()) / truth - 1.0).powi(2);
        }
        let rmse_f = (se_f / runs as f64).sqrt();
        let rmse_l = (se_l / runs as f64).sqrt();
        let theory = cardinality_rel_std(k);
        for (name, rmse) in [("fastgm", rmse_f), ("lemiesz", rmse_l)] {
            assert!(
                rmse < 1.6 * theory && rmse > theory / 1.6,
                "{name}: rmse={rmse} theory={theory}"
            );
        }
    }
}
