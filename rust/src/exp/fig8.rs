//! Fig. 8 — streaming sketch generation time: Stream-FastGM vs Lemiesz's
//! sketch. (a) n=1000 objects, varying k; (b) k=1024, varying n.
//! Paper shape: Stream-FastGM 23× faster at n=1000 (average over k),
//! ~120× at n=10⁶ with k=1024.

use super::ExpOptions;
use crate::data::stream::{generate, Stream};
use crate::data::synthetic::WeightDist;
use crate::sketch::lemiesz::LemieszSketch;
use crate::sketch::stream_fastgm::StreamFastGm;
use crate::util::rng::SplitMix64;
use crate::util::stats::{fmt_duration, Table};
use std::time::Instant;

fn time_stream_fastgm(stream: &Stream, k: usize) -> f64 {
    let t0 = Instant::now();
    let mut s = StreamFastGm::new(k, 1);
    for &(id, w) in &stream.events {
        s.push(id, w);
    }
    std::hint::black_box(s.sketch());
    t0.elapsed().as_secs_f64()
}

fn time_lemiesz(stream: &Stream, k: usize) -> f64 {
    let t0 = Instant::now();
    let mut s = LemieszSketch::new(k, 1);
    for &(id, w) in &stream.events {
        s.push(id, w);
    }
    std::hint::black_box(s.sketch());
    t0.elapsed().as_secs_f64()
}

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut rng = SplitMix64::new(0xF16_8);

    // (a) n = 1000, varying k.
    let ks: Vec<usize> =
        if opts.full { vec![64, 128, 256, 512, 1024, 2048, 4096] } else { vec![64, 256, 1024] };
    let stream = generate(&mut rng, 1000, 1.0, WeightDist::Uniform01, 0);
    let mut t = Table::new(&["n", "k", "stream-fastgm", "lemiesz", "speedup"]);
    for &k in &ks {
        let tf = time_stream_fastgm(&stream, k);
        let tl = time_lemiesz(&stream, k);
        t.row(vec![
            "1000".into(),
            k.to_string(),
            fmt_duration(tf),
            fmt_duration(tl),
            format!("{:.1}x", tl / tf),
        ]);
    }
    opts.emit("fig8_a", "Fig 8(a): streaming sketch time vs k (n=1000)", &t)?;

    // (b) k = 1024, varying n.
    let k = 1024;
    let ns: Vec<usize> =
        if opts.full { vec![1000, 10_000, 100_000, 1_000_000] } else { vec![1000, 10_000, 50_000] };
    let mut t2 = Table::new(&["k", "n", "stream-fastgm", "lemiesz", "speedup"]);
    for &n in &ns {
        let stream = generate(&mut rng, n, 0.5, WeightDist::Uniform01, 0);
        let tf = time_stream_fastgm(&stream, k);
        let tl = time_lemiesz(&stream, k);
        t2.row(vec![
            k.to_string(),
            n.to_string(),
            fmt_duration(tf),
            fmt_duration(tl),
            format!("{:.1}x", tl / tf),
        ]);
    }
    opts.emit("fig8_b", "Fig 8(b): streaming sketch time vs n (k=1024)", &t2)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline Task-2 efficiency claim, scaled down: Stream-FastGM
    /// must beat Lemiesz by a wide, k-growing margin.
    #[test]
    #[cfg_attr(debug_assertions, ignore = "timing ratios need --release")]
    fn stream_fastgm_dominates_lemiesz() {
        let mut rng = SplitMix64::new(2);
        let stream = generate(&mut rng, 2000, 0.5, WeightDist::Uniform01, 0);
        let s512 = time_lemiesz(&stream, 512) / time_stream_fastgm(&stream, 512);
        assert!(s512 > 2.0, "expected >2x at k=512, got {s512:.1}x");
        let s64 = time_lemiesz(&stream, 64) / time_stream_fastgm(&stream, 64);
        assert!(s512 > s64, "speedup should grow with k: {s64:.1}x -> {s512:.1}x");
    }
}
