//! Experiment harness: one module per paper table/figure (README.md
//! §Experiments).
//!
//! Every experiment prints the paper-style table to stdout and writes it
//! (plus machine-readable JSONL) under `--out`. `--full` runs paper-scale
//! parameters; the default "quick" scale keeps `cargo bench` and CI fast
//! while preserving the comparisons' *shape* (who wins, by what factor).

pub mod table1;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig10;
pub mod fig11;
pub mod ablation;

use crate::util::bench::Bencher;
use crate::util::stats::Table;

#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub out_dir: String,
    pub full: bool,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions { out_dir: "results".into(), full: false }
    }
}

impl ExpOptions {
    /// Benchmark budget per cell.
    pub fn bencher(&self) -> Bencher {
        let mut b = Bencher::from_env();
        if !self.full {
            b.budget = b.budget.min(0.25);
            b.samples = 7;
            b.warmup = 0.03;
        }
        b
    }

    /// Write a rendered table (also echoed to stdout) to `results/<name>.txt`.
    pub fn emit(&self, name: &str, title: &str, table: &Table) -> anyhow::Result<()> {
        let text = format!("# {title}\n{}", table.render());
        println!("\n{text}");
        std::fs::create_dir_all(&self.out_dir)?;
        std::fs::write(format!("{}/{name}.txt", self.out_dir), &text)?;
        Ok(())
    }

    pub fn jsonl_path(&self, name: &str) -> String {
        let _ = std::fs::create_dir_all(&self.out_dir);
        format!("{}/{name}.jsonl", self.out_dir)
    }
}

/// All experiment names, in run order for `exp all`.
pub const ALL: &[&str] = &[
    "table1", "fig4", "fig5", "fig6", "fig7", "fig8", "fig10", "fig11",
    "ablation-delta", "ablation-accel",
];

pub fn run(name: &str, opts: &ExpOptions) -> anyhow::Result<()> {
    match name {
        "table1" => table1::run(opts),
        "fig4" => fig4::run(opts),
        "fig5" => fig5::run(opts),
        "fig6" => fig6::run(opts),
        "fig7" => fig7::run(opts),
        "fig8" => fig8::run(opts),
        "fig10" => fig10::run(opts),
        "fig11" => fig11::run(opts),
        "ablation-delta" => ablation::run_delta(opts),
        "ablation-accel" => ablation::run_accel(opts),
        "all" => {
            for n in ALL {
                log::info!("=== experiment {n} ===");
                run(n, opts)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment '{other}' (try: {}, all)", ALL.join(", ")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_dispatch() {
        // Unknown names rejected; known names are at least wired (not run —
        // they're exercised by `cargo bench` / the CLI).
        assert!(run("nope", &ExpOptions::default()).is_err());
        for n in ALL {
            assert!(ALL.contains(n));
        }
    }

    #[test]
    fn emit_writes_table() {
        let dir = std::env::temp_dir().join("fastgm_exp_test");
        let opts =
            ExpOptions { out_dir: dir.to_str().unwrap().to_string(), full: false };
        let mut t = Table::new(&["a"]);
        t.row(vec!["1".into()]);
        opts.emit("unit", "unit test", &t).unwrap();
        let text = std::fs::read_to_string(dir.join("unit.txt")).unwrap();
        assert!(text.contains("unit test"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
