//! Fig. 5 — Task-1 sketching efficiency on the six real-dataset analogs
//! (Table 1): mean per-vector sketch time across k for FastGM, FastGM-c,
//! P-MinHash and BagMinHash. Paper shape: FastGM fastest everywhere,
//! ~8–26× over P-MinHash on the sparse text corpora.

use super::ExpOptions;
use super::fig4::ALGOS;
use crate::data::corpus::{Corpus, CORPORA};
use crate::sketch::engine::{self, AlgorithmId, EngineParams, SketchScratch};
use crate::sketch::{GumbelMaxSketch, Sketcher};
use crate::util::stats::{fmt_duration, Table};
use std::time::Instant;

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let ks: Vec<usize> = if opts.full { vec![64, 256, 1024, 4096] } else { vec![256] };
    let vectors_per_corpus = if opts.full { 300 } else { 60 };

    let mut t = Table::new(&[
        "dataset", "k", "fastgm", "fastgm-c", "pminhash", "bagminhash", "speedup vs pminhash",
    ]);
    let mut scratch = SketchScratch::new();
    for spec in CORPORA {
        let corpus = Corpus::new(*spec, 7);
        let vectors = corpus.vectors(vectors_per_corpus);
        for &k in &ks {
            // Each baseline from the registry, timed through the reused
            // scratch (the engine's zero-allocation serving path).
            let mut times = Vec::with_capacity(ALGOS.len());
            for name in ALGOS {
                let id = AlgorithmId::from_name(name).expect("fig5 algo registered");
                let s = engine::build(id, EngineParams::new(k, 1));
                let mut sk = GumbelMaxSketch::empty(s.family(), s.seed(), k);
                let t0 = Instant::now();
                for v in &vectors {
                    s.sketch_into(v, &mut scratch, &mut sk);
                    std::hint::black_box(&sk);
                }
                times.push(t0.elapsed().as_secs_f64() / vectors.len() as f64);
            }
            let (t_fg, t_fgc, t_pm, t_bm) = (times[0], times[1], times[2], times[3]);
            t.row(vec![
                spec.name.to_string(),
                k.to_string(),
                fmt_duration(t_fg),
                fmt_duration(t_fgc),
                fmt_duration(t_pm),
                fmt_duration(t_bm),
                format!("{:.1}x", t_pm / t_fg),
            ]);
        }
    }
    opts.emit("fig5", "Fig 5: per-vector sketch time on dataset analogs", &t)?;
    Ok(())
}
