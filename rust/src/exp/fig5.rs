//! Fig. 5 — Task-1 sketching efficiency on the six real-dataset analogs
//! (Table 1): mean per-vector sketch time across k for FastGM, FastGM-c,
//! P-MinHash and BagMinHash. Paper shape: FastGM fastest everywhere,
//! ~8–26× over P-MinHash on the sparse text corpora.

use super::ExpOptions;
use crate::data::corpus::{Corpus, CORPORA};
use crate::sketch::bagminhash::BagMinHash;
use crate::sketch::fastgm::FastGm;
use crate::sketch::fastgm_c::FastGmConference;
use crate::sketch::pminhash::PMinHash;
use crate::sketch::Sketcher;
use crate::util::stats::{fmt_duration, Table};
use std::time::Instant;

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let ks: Vec<usize> = if opts.full { vec![64, 256, 1024, 4096] } else { vec![256] };
    let vectors_per_corpus = if opts.full { 300 } else { 60 };

    let mut t = Table::new(&[
        "dataset", "k", "fastgm", "fastgm-c", "pminhash", "bagminhash", "speedup vs pminhash",
    ]);
    for spec in CORPORA {
        let corpus = Corpus::new(*spec, 7);
        let vectors = corpus.vectors(vectors_per_corpus);
        for &k in &ks {
            let fg = FastGm::new(k, 1);
            let fgc = FastGmConference::new(k, 1);
            let pm = PMinHash::new(k, 1);
            let bm = BagMinHash::new(k, 1);
            let time_per_vec = |f: &dyn Fn(&crate::sketch::SparseVector)| {
                let t0 = Instant::now();
                for v in &vectors {
                    f(v);
                }
                t0.elapsed().as_secs_f64() / vectors.len() as f64
            };
            let t_fg = time_per_vec(&|v| {
                fg.sketch(v);
            });
            let t_fgc = time_per_vec(&|v| {
                fgc.sketch(v);
            });
            let t_pm = time_per_vec(&|v| {
                pm.sketch(v);
            });
            let t_bm = time_per_vec(&|v| {
                bm.sketch(v);
            });
            t.row(vec![
                spec.name.to_string(),
                k.to_string(),
                fmt_duration(t_fg),
                fmt_duration(t_fgc),
                fmt_duration(t_pm),
                fmt_duration(t_bm),
                format!("{:.1}x", t_pm / t_fg),
            ]);
        }
    }
    opts.emit("fig5", "Fig 5: per-vector sketch time on dataset analogs", &t)?;
    Ok(())
}
