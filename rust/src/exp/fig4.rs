//! Fig. 4 — Task-1 sketching efficiency on synthetic vectors (UNI(0,1)
//! weights): FastGM vs FastGM-c vs P-MinHash vs BagMinHash.
//! (a–c) time vs k at fixed n; (d–f) time vs n at fixed k.
//! Paper shape: FastGM ≫ P-MinHash (13–125×), FastGM > BagMinHash below
//! n ≈ 10⁵, FastGM 1.2–4× FastGM-c.

use super::ExpOptions;
use crate::data::synthetic::{dense_vector, WeightDist};
use crate::sketch::engine::{self, AlgorithmId, EngineParams, SketchScratch};
use crate::sketch::{GumbelMaxSketch, Sketcher, SparseVector};
use crate::util::bench::Suite;
use crate::util::rng::SplitMix64;
use crate::util::stats::{fmt_duration, Table};

pub const ALGOS: &[&str] = &["fastgm", "fastgm-c", "pminhash", "bagminhash"];

/// Median seconds to sketch `v` with each algorithm at length k. All four
/// baselines run through the engine registry with a reused scratch — the
/// same zero-allocation path the coordinator serves.
pub fn time_all(
    opts: &ExpOptions,
    suite: &mut Suite,
    label: &str,
    v: &SparseVector,
    k: usize,
) -> Vec<(String, f64)> {
    let b = opts.bencher();
    let mut out = Vec::new();
    let mut scratch = SketchScratch::new();
    for name in ALGOS {
        let id = AlgorithmId::from_name(name).expect("fig4 algo registered");
        let s = engine::build(id, EngineParams::new(k, 1));
        let mut sk = GumbelMaxSketch::empty(s.family(), s.seed(), k);
        out.push((name.to_string(), {
            let r = b.run(&format!("{label}/{name}"), || {
                s.sketch_into(v, &mut scratch, &mut sk);
                sk.y[0]
            });
            let m = r.median;
            suite.record(r);
            m
        }));
    }
    out
}

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut rng = SplitMix64::new(0xF16_4);
    let mut suite = Suite::new().with_jsonl(&opts.jsonl_path("fig4"));

    // (a–c): time vs k at fixed n.
    let ks: Vec<usize> =
        if opts.full { vec![64, 128, 256, 512, 1024, 2048, 4096] } else { vec![64, 256, 1024] };
    let ns: Vec<usize> = if opts.full { vec![100, 1000, 10_000] } else { vec![100, 1000] };
    let mut t = Table::new(&["n", "k", "fastgm", "fastgm-c", "pminhash", "bagminhash", "speedup vs pminhash"]);
    for &n in &ns {
        let v = dense_vector(&mut rng, n, WeightDist::Uniform01);
        for &k in &ks {
            let res = time_all(opts, &mut suite, &format!("fig4/n{n}/k{k}"), &v, k);
            let fast = res[0].1;
            let pm = res[2].1;
            t.row(vec![
                n.to_string(),
                k.to_string(),
                fmt_duration(res[0].1),
                fmt_duration(res[1].1),
                fmt_duration(res[2].1),
                fmt_duration(res[3].1),
                format!("{:.1}x", pm / fast),
            ]);
        }
    }
    opts.emit("fig4_abc", "Fig 4(a-c): sketch time vs k (UNI(0,1) weights)", &t)?;

    // (d–f): time vs n at fixed k.
    let ks2: Vec<usize> = if opts.full { vec![256, 1024, 4096] } else { vec![256] };
    let ns2: Vec<usize> =
        if opts.full { vec![100, 1000, 10_000, 100_000] } else { vec![100, 1000, 10_000] };
    let mut t2 = Table::new(&["k", "n", "fastgm", "fastgm-c", "pminhash", "bagminhash", "speedup vs pminhash"]);
    for &k in &ks2 {
        for &n in &ns2 {
            let v = dense_vector(&mut rng, n, WeightDist::Uniform01);
            let res = time_all(opts, &mut suite, &format!("fig4/k{k}/n{n}"), &v, k);
            t2.row(vec![
                k.to_string(),
                n.to_string(),
                fmt_duration(res[0].1),
                fmt_duration(res[1].1),
                fmt_duration(res[2].1),
                fmt_duration(res[3].1),
                format!("{:.1}x", res[2].1 / res[0].1),
            ]);
        }
    }
    opts.emit("fig4_def", "Fig 4(d-f): sketch time vs n (UNI(0,1) weights)", &t2)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's headline: FastGM beats P-MinHash by a growing factor.
    #[test]
    #[cfg_attr(debug_assertions, ignore = "timing ratios need --release")]
    fn fastgm_beats_pminhash_at_moderate_scale() {
        let opts = ExpOptions { out_dir: std::env::temp_dir().join("fastgm_fig4_test").to_str().unwrap().into(), full: false };
        let mut rng = SplitMix64::new(1);
        let v = dense_vector(&mut rng, 2000, WeightDist::Uniform01);
        let mut suite = Suite::new();
        let res = time_all(&opts, &mut suite, "test", &v, 512);
        let fast = res.iter().find(|(n, _)| n == "fastgm").unwrap().1;
        let pm = res.iter().find(|(n, _)| n == "pminhash").unwrap().1;
        assert!(
            pm / fast > 3.0,
            "expected ≥3x speedup at n=2000,k=512; got {:.2}x",
            pm / fast
        );
        let _ = std::fs::remove_dir_all(&opts.out_dir);
    }
}
