//! Ablations beyond the paper's figures (README.md §Experiments, A1/A2):
//!
//! * **A1 `ablation-delta`** — FastSearch budget step Δ. The paper claims
//!   low sensitivity; we sweep Δ ∈ {k/4 … 8k} and report time + variables
//!   released (output is provably Δ-invariant; a unit test asserts it).
//! * **A2 `ablation-accel`** — dense-batch throughput: CPU FastGM vs CPU
//!   P-MinHash vs the AOT accelerator (when artifacts are built), across
//!   batch sizes. Locates the sparse/dense crossover the router encodes.

use super::ExpOptions;
use crate::data::synthetic::{dense_vector, WeightDist};
use crate::sketch::fastgm::FastGm;
use crate::sketch::pminhash::PMinHash;
use crate::sketch::{Sketcher, SparseVector};
use crate::util::rng::SplitMix64;
use crate::util::stats::{fmt_duration, Table};
use std::time::Instant;

pub fn run_delta(opts: &ExpOptions) -> anyhow::Result<()> {
    let b = opts.bencher();
    let k = 1024;
    let n = if opts.full { 10_000 } else { 2000 };
    let mut rng = SplitMix64::new(0xAB1);
    let v = dense_vector(&mut rng, n, WeightDist::Uniform01);

    let mut t = Table::new(&["delta", "time", "released", "vs delta=k"]);
    let deltas = [k / 4, k / 2, k, 2 * k, 4 * k, 8 * k];
    let mut base_time = 0.0;
    for &delta in &deltas {
        let fg = FastGm::new(k, 1).with_delta(delta);
        let r = b.run(&format!("delta{delta}"), || fg.sketch(&v));
        let (_, stats) = fg.sketch_counted(&v);
        if delta == k {
            base_time = r.median;
        }
        t.row(vec![
            format!("{}k", delta as f64 / k as f64),
            fmt_duration(r.median),
            stats.total_released().to_string(),
            if base_time > 0.0 { format!("{:.2}x", r.median / base_time) } else { "-".into() },
        ]);
    }
    opts.emit("ablation_delta", "A1: FastSearch step Δ sensitivity (k=1024)", &t)?;
    Ok(())
}

pub fn run_accel(opts: &ExpOptions) -> anyhow::Result<()> {
    let k = 256;
    let n = 1024; // dense length matching the compiled bucket
    let batch = if opts.full { 256 } else { 64 };
    let mut rng = SplitMix64::new(0xAB2);
    let rows: Vec<Vec<f64>> = (0..batch)
        .map(|_| (0..n).map(|_| if rng.next_f64() < 0.5 { 0.0 } else { rng.next_f64() }).collect())
        .collect();
    let sparse: Vec<SparseVector> =
        rows.iter().map(|r| SparseVector::from_dense(r)).collect();

    let mut t = Table::new(&["engine", "batch", "total", "per-vector", "family"]);

    // CPU FastGM (Ordered).
    let fg = FastGm::new(k, 42);
    let t0 = Instant::now();
    for v in &sparse {
        std::hint::black_box(fg.sketch(v));
    }
    let t_fg = t0.elapsed().as_secs_f64();
    t.row(vec![
        "cpu fastgm".into(),
        batch.to_string(),
        fmt_duration(t_fg),
        fmt_duration(t_fg / batch as f64),
        "ordered".into(),
    ]);

    // CPU P-MinHash (Direct, the dense baseline).
    let pm = PMinHash::new(k, 42);
    let t0 = Instant::now();
    for v in &sparse {
        std::hint::black_box(pm.sketch(v));
    }
    let t_pm = t0.elapsed().as_secs_f64();
    t.row(vec![
        "cpu pminhash".into(),
        batch.to_string(),
        fmt_duration(t_pm),
        fmt_duration(t_pm / batch as f64),
        "direct".into(),
    ]);

    // Accelerator (Direct), if artifacts are present and the crate was
    // built with the `accel` feature. Runtime is !Send so build and use it
    // inline on this thread.
    #[cfg(feature = "accel")]
    {
        let dir = "artifacts";
        if std::path::Path::new(dir).join("manifest.json").exists() {
            match crate::runtime::Runtime::load(dir)
                .and_then(crate::runtime::accel::DenseSketchAccel::new)
            {
                Ok(accel) => {
                    // Warm-up execution (first PJRT call pays setup).
                    let _ = accel.sketch_batch(42, &rows[0..1.min(rows.len())], k);
                    let t0 = Instant::now();
                    let out = accel.sketch_batch(42, &rows, k)?;
                    let t_ac = t0.elapsed().as_secs_f64();
                    assert_eq!(out.len(), batch);
                    t.row(vec![
                        "aot accel (pjrt cpu)".into(),
                        batch.to_string(),
                        fmt_duration(t_ac),
                        fmt_duration(t_ac / batch as f64),
                        "direct".into(),
                    ]);
                }
                Err(e) => log::warn!("accelerator unavailable for ablation: {e}"),
            }
        } else {
            log::warn!("artifacts not built; ablation-accel reports CPU rows only");
        }
    }
    #[cfg(not(feature = "accel"))]
    log::warn!("built without the `accel` feature; ablation-accel reports CPU rows only");

    opts.emit(
        "ablation_accel",
        "A2: dense-batch engines (n=1024, k=256) — CPU vs AOT accelerator",
        &t,
    )?;
    Ok(())
}
