//! Fig. 11 — sensor-network sketching time: total per-node sketch build
//! cost across the simulated network, Stream-FastGM vs Lemiesz.
//! (a) d=30, varying k; (b) k=1024, varying depth.
//! Paper shape: Stream-FastGM ~52× faster at k=2048; speedup grows with k.

use super::ExpOptions;
use crate::simnet::{NodeSketcher, SimNet, SimParams};
use crate::util::stats::{fmt_duration, Table};

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let base = if opts.full {
        SimParams::default()
    } else {
        SimParams { depth: 8, packets_per_source: 1500, ..SimParams::default() }
    };

    // (a) varying k at fixed depth.
    let ks: Vec<usize> =
        if opts.full { vec![64, 256, 1024, 2048] } else { vec![64, 256, 1024] };
    let mut t = Table::new(&["d", "k", "stream-fastgm", "lemiesz", "speedup"]);
    for &k in &ks {
        let p = SimParams { k, ..base };
        let tf = SimNet::run(p, NodeSketcher::StreamFastGm).sketch_seconds;
        let tl = SimNet::run(p, NodeSketcher::Lemiesz).sketch_seconds;
        t.row(vec![
            base.depth.to_string(),
            k.to_string(),
            fmt_duration(tf),
            fmt_duration(tl),
            format!("{:.1}x", tl / tf),
        ]);
    }
    opts.emit("fig11_a", "Fig 11(a): per-network sketching time vs k", &t)?;

    // (b) varying depth at fixed k.
    let k = if opts.full { 1024 } else { 256 };
    let depths: Vec<usize> = if opts.full { vec![10, 20, 30, 40] } else { vec![4, 8, 12] };
    let mut t2 = Table::new(&["k", "d", "stream-fastgm", "lemiesz", "speedup"]);
    for &d in &depths {
        let p = SimParams { depth: d, k, ..base };
        let tf = SimNet::run(p, NodeSketcher::StreamFastGm).sketch_seconds;
        let tl = SimNet::run(p, NodeSketcher::Lemiesz).sketch_seconds;
        t2.row(vec![
            k.to_string(),
            d.to_string(),
            fmt_duration(tf),
            fmt_duration(tl),
            format!("{:.1}x", tl / tf),
        ]);
    }
    opts.emit("fig11_b", "Fig 11(b): per-network sketching time vs depth", &t2)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "timing ratios need --release")]
    fn stream_fastgm_faster_in_network() {
        let p = SimParams { depth: 4, packets_per_source: 1000, k: 512, ..SimParams::default() };
        let tf = SimNet::run(p, NodeSketcher::StreamFastGm).sketch_seconds;
        let tl = SimNet::run(p, NodeSketcher::Lemiesz).sketch_seconds;
        assert!(tl / tf > 2.0, "expected >2x, got {:.1}x", tl / tf);
    }
}
