//! Fig. 10 — sensor-network estimates (truth vs sketch) per layer:
//! (a) per-source distinct-packet mass at s_ℓ^A, (b) mean packet size,
//! (c) lost mass from source A, (d) weighted Jaccard between chains.
//! Paper setting: d=30, n=10⁴, p₁=0.9, p₂=0.1, Beta(5,5) sizes, k=200.

use super::ExpOptions;
use crate::simnet::{NodeSketcher, SimNet, SimParams};
use crate::util::stats::Table;

pub fn run(opts: &ExpOptions) -> anyhow::Result<()> {
    let params = if opts.full {
        SimParams::default() // d=30, n=10_000, k=200
    } else {
        SimParams { depth: 10, packets_per_source: 2000, ..SimParams::default() }
    };
    let net = SimNet::run(params, NodeSketcher::StreamFastGm);

    let a = net.fig10a();
    let b = net.fig10b();
    let c = net.fig10c();
    let d = net.fig10d();
    let mut t = Table::new(&[
        "layer",
        "A-mass truth", "A-mass est",
        "B-mass truth", "B-mass est",
        "mean truth", "mean est",
        "lost truth", "lost est",
        "J_W truth", "J_W est",
    ]);
    for l in 0..params.depth {
        t.row(vec![
            l.to_string(),
            format!("{:.1}", a[l].0),
            format!("{:.1}", a[l].1),
            format!("{:.1}", a[l].2),
            format!("{:.1}", a[l].3),
            format!("{:.3}", b[l].0),
            format!("{:.3}", b[l].1),
            format!("{:.1}", c[l].0),
            format!("{:.1}", c[l].1),
            format!("{:.3}", d[l].0),
            format!("{:.3}", d[l].1),
        ]);
    }
    opts.emit(
        "fig10",
        &format!(
            "Fig 10: sensor network (d={}, n={}, k={}) — truth vs sketch estimates",
            params.depth, params.packets_per_source, params.k
        ),
        &t,
    )?;
    Ok(())
}
