//! Weighted sampling and partition-function estimation from Gumbel-Max
//! sketches — the Gumbel-Max Trick's *native* application, served from the
//! registers the store already holds.
//!
//! **Register-as-sample.** Each register `j` of a Gumbel-Max sketch races
//! every element `i` with an independent `EXP(w_i)` arrival; the winner
//! `s_j = argmin_i -ln(a_ij)/w_i` is therefore an exact weighted sample,
//! `P[s_j = i] = w_i / Σw` (the Gumbel-Max Trick, one register = one
//! draw). So sampling an element ∝ weight from a *stored* sketch costs one
//! uniform draw over the k registers — no access to the original vector —
//! and repeated queries amortize to O(1) each, the regime Mussmann et al.
//! (arxiv 1707.03372) motivate. Registers are mutually independent, but a
//! sketch holds only k of them: more than k draws necessarily revisit
//! registers, so distinct-sample diversity saturates at k (pick k ≥ the
//! needed distinct-draw budget).
//!
//! **Union sampling.** §2.3 merging keeps, per register, the globally
//! smallest race value — the merged sketch *is* the sketch of the
//! concatenated vector, bit for bit. Sampling from a merge therefore
//! samples from the exact union distribution, which is what lets the
//! store/cluster layers sample across keys without touching raw vectors.
//!
//! **Partition function.** The same registers' `y_j ~ EXP(Z)` for
//! `Z = Σ_i w_i` (the log-linear partition function when `w_i = exp φ_i`),
//! so `Ẑ = (k-1)/Σ_j y_j` is the minimum-variance unbiased estimator of
//! `Z` with relative standard deviation `≈ sqrt(2/k)` — one member of the
//! Gumbel-trick estimator family of Balog et al. (arxiv 1706.04161).
//! `ln Ẑ` estimates the log-partition-function with an `O(1/k)` Jensen
//! bias (the log of an unbiased estimate is not unbiased); at serving k
//! (≥ 256) the bias is far below the sampling noise and we document it
//! rather than correct it.
//!
//! Family discipline matches the cardinality algebra: only families whose
//! `y` registers are true `EXP(Σw)` races (Ordered / Direct) support any
//! of this; ICWS / BagMinHash / MinHash sketches are rejected loudly.

use crate::sketch::{GumbelMaxSketch, MergeError, EMPTY_REGISTER};
use crate::util::rng::SplitMix64;

use super::cardinality::estimate_cardinality;

/// Why a sampling request could not be served from a sketch.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum SampleError {
    /// Family gate / merge incompatibility (wraps the estimator algebra's
    /// error so cluster gathers surface one error type).
    #[error(transparent)]
    Merge(#[from] MergeError),
    /// Every register is [`EMPTY_REGISTER`]: the sketch of an empty vector
    /// (or an empty union) carries no samples to draw.
    #[error("cannot sample from an empty sketch (no occupied registers)")]
    EmptySketch,
}

/// The shared family gate: register-as-sample and the partition estimators
/// both require `y_j ~ EXP(Σw)` races (see module docs).
fn gate(sk: &GumbelMaxSketch, estimator: &'static str) -> Result<(), MergeError> {
    if !sk.family.has_exponential_registers() {
        return Err(MergeError::EstimatorUnsupported {
            estimator,
            family: sk.family.name(),
            hint: "register-as-sample needs EXP-register families (ordered/direct)",
        });
    }
    Ok(())
}

/// The occupied ArgMax registers of `sk` — each one an independent exact
/// weighted sample. Exposed so callers that sample repeatedly can collect
/// once and draw many times (the amortized serving path).
pub fn occupied_registers(sk: &GumbelMaxSketch) -> Vec<u64> {
    sk.s.iter().copied().filter(|&s| s != EMPTY_REGISTER).collect()
}

/// Draw one element id ∝ weight from `sk` using `rng` (one uniform draw
/// over the occupied registers).
pub fn sample_one(sk: &GumbelMaxSketch, rng: &mut SplitMix64) -> Result<u64, SampleError> {
    gate(sk, "sample")?;
    let ids = occupied_registers(sk);
    if ids.is_empty() {
        return Err(SampleError::EmptySketch);
    }
    Ok(ids[rng.next_range(0, ids.len() - 1)])
}

/// Draw `n` element ids ∝ weight from `sk`, reproducibly: the same
/// `(sketch, n, seed)` always yields the same ids, on every node and
/// transport (the wire ops are thin shims over this function). Draws are
/// with replacement over the k registers — see the module note on
/// distinct-sample saturation.
pub fn sample_n(sk: &GumbelMaxSketch, n: usize, seed: u64) -> Result<Vec<u64>, SampleError> {
    gate(sk, "sample")?;
    let ids = occupied_registers(sk);
    if ids.is_empty() {
        return Err(SampleError::EmptySketch);
    }
    let mut rng = SplitMix64::new(seed);
    Ok((0..n).map(|_| ids[rng.next_range(0, ids.len() - 1)]).collect())
}

/// Sample `n` ids from the **union** of the given sketches (§2.3 merge,
/// then [`sample_n`]): bit-identical to sampling the sketch of the
/// concatenated vector. Zero sketches is [`MergeError::EmptyMerge`].
pub fn sample_union(
    sketches: &[&GumbelMaxSketch],
    n: usize,
    seed: u64,
) -> Result<Vec<u64>, SampleError> {
    let merged = GumbelMaxSketch::merge_all(sketches.iter().copied())?;
    sample_n(&merged, n, seed)
}

/// `Ẑ = (k-1)/Σ y_j`: unbiased estimate of the total weight (partition
/// function) `Z = Σ_i w_i` of the sketched vector. Relative std
/// ≈ [`partition_rel_std`]. Returns 0 for an empty sketch.
pub fn total_weight(sk: &GumbelMaxSketch) -> Result<f64, MergeError> {
    gate(sk, "partition")?;
    Ok(estimate_cardinality(sk))
}

/// `ln Ẑ`: the log-partition-function estimate (`-∞` for an empty
/// sketch). Carries the `O(1/k)` Jensen bias documented in the module
/// docs — prefer comparing `log_partition` *differences* (log-odds),
/// where the bias cancels to first order.
pub fn log_partition(sk: &GumbelMaxSketch) -> Result<f64, MergeError> {
    Ok(total_weight(sk)?.ln())
}

/// Theoretical relative standard deviation of [`total_weight`]
/// (`Σy ~ Γ(k, Z)` ⇒ `Var(Ẑ/Z) ≈ 2/k`, same algebra as Theorem 2).
pub fn partition_rel_std(k: usize) -> f64 {
    (2.0 / k as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::engine::{build, AlgorithmId, EngineParams};
    use crate::sketch::fastgm::FastGm;
    use crate::sketch::{Family, Sketcher, SparseVector};
    use crate::util::stats::OnlineStats;

    fn vocab(n: usize) -> SparseVector {
        // Zipf-flavored weights so frequencies are genuinely non-uniform.
        SparseVector::new(
            (0..n as u64).collect(),
            (0..n).map(|i| 1.0 / (i + 1) as f64).collect(),
        )
    }

    #[test]
    fn sampling_rejects_non_exponential_families() {
        let v = SparseVector::new(vec![1, 2], vec![1.0, 2.0]);
        for id in [AlgorithmId::Icws, AlgorithmId::BagMinHash, AlgorithmId::MinHash] {
            let sk = build(id, EngineParams::new(16, 1)).sketch(&v);
            let err = sample_n(&sk, 4, 0).unwrap_err();
            assert!(
                matches!(err, SampleError::Merge(MergeError::EstimatorUnsupported { .. })),
                "{id:?}: {err}"
            );
            assert!(matches!(
                total_weight(&sk),
                Err(MergeError::EstimatorUnsupported { .. })
            ));
        }
    }

    #[test]
    fn empty_sketch_is_a_typed_error_and_zero_weight() {
        let empty = GumbelMaxSketch::empty(Family::Ordered, 7, 16);
        assert_eq!(sample_n(&empty, 3, 0).unwrap_err(), SampleError::EmptySketch);
        let mut rng = SplitMix64::new(0);
        assert_eq!(sample_one(&empty, &mut rng).unwrap_err(), SampleError::EmptySketch);
        assert_eq!(total_weight(&empty).unwrap(), 0.0);
        assert_eq!(log_partition(&empty).unwrap(), f64::NEG_INFINITY);
    }

    #[test]
    fn sampling_is_seed_reproducible_and_seed_sensitive() {
        let sk = FastGm::new(64, 42).sketch(&vocab(100));
        let a = sample_n(&sk, 32, 7).unwrap();
        let b = sample_n(&sk, 32, 7).unwrap();
        assert_eq!(a, b);
        let c = sample_n(&sk, 32, 8).unwrap();
        assert_ne!(a, c); // 32 draws colliding across seeds: ~impossible
        // Every sample is a real element of the vector.
        assert!(a.iter().all(|id| *id < 100));
    }

    #[test]
    fn sample_frequencies_track_weights() {
        // With k registers and heavy-head Zipf weights, the head element
        // (weight share ~19% at n=50) must dominate the samples.
        let v = vocab(50);
        let total: f64 = v.total_weight();
        let sk = FastGm::new(4096, 1).sketch(&v);
        let samples = sample_n(&sk, 20_000, 99).unwrap();
        let head = samples.iter().filter(|&&id| id == 0).count() as f64
            / samples.len() as f64;
        let expect = 1.0 / total;
        assert!(
            (head - expect).abs() < 0.04,
            "head frequency {head} vs expected {expect}"
        );
    }

    #[test]
    fn union_sampling_is_bit_identical_to_concatenated_sketch() {
        let a = SparseVector::new((0..300).collect(), vec![1.0; 300]);
        let b = SparseVector::new((200..500).collect(), vec![1.0; 300]);
        let mut cat = a.clone();
        for (id, w) in b.positive() {
            cat.push(id, w);
        }
        let f = FastGm::new(128, 3);
        let (sa, sb, scat) = (f.sketch(&a), f.sketch(&b), f.sketch(&cat));
        // Duplicate ids keep max weight under union semantics; here all
        // weights are 1.0 so concat == union element-wise and the merged
        // sketch equals the concatenated sketch register for register.
        let merged = sa.merge(&sb).unwrap();
        assert_eq!(merged, scat);
        assert_eq!(
            sample_union(&[&sa, &sb], 64, 11).unwrap(),
            sample_n(&scat, 64, 11).unwrap()
        );
    }

    #[test]
    fn union_of_nothing_is_empty_merge() {
        assert_eq!(
            sample_union(&[], 4, 0).unwrap_err(),
            SampleError::Merge(MergeError::EmptyMerge)
        );
    }

    #[test]
    fn total_weight_is_unbiased_within_theory() {
        let v = vocab(200);
        let truth = v.total_weight();
        let k = 128;
        let mut stats = OnlineStats::new();
        for seed in 0..120u64 {
            stats.push(total_weight(&FastGm::new(k, seed).sketch(&v)).unwrap());
        }
        let rel_err = (stats.mean() - truth).abs() / truth;
        assert!(rel_err < 0.03, "mean={} truth={truth}", stats.mean());
        let rel_std = stats.std() / truth;
        let theo = partition_rel_std(k);
        assert!(rel_std < 1.5 * theo && rel_std > theo / 1.5, "rel_std={rel_std} theo={theo}");
    }

    #[test]
    fn log_partition_is_ln_of_total_weight() {
        let sk = FastGm::new(256, 5).sketch(&vocab(64));
        let z = total_weight(&sk).unwrap();
        assert!((log_partition(&sk).unwrap() - z.ln()).abs() < 1e-12);
    }
}
