//! RMSE experiment runner: repeats an estimator over independent seeds and
//! reports the empirical root-mean-square error — the metric of the paper's
//! §4.3 (Fig. 6 and Fig. 7 are produced through this).

use crate::util::stats::rmse_scalar;

/// Result of an error experiment.
#[derive(Debug, Clone)]
pub struct ErrorReport {
    pub truth: f64,
    pub mean_estimate: f64,
    pub rmse: f64,
    pub runs: usize,
}

/// Run `estimate(seed)` for `runs` seeds against scalar ground `truth`.
pub fn rmse_experiment(
    truth: f64,
    runs: usize,
    mut estimate: impl FnMut(u64) -> f64,
) -> ErrorReport {
    let estimates: Vec<f64> = (0..runs as u64).map(&mut estimate).collect();
    ErrorReport {
        truth,
        mean_estimate: estimates.iter().sum::<f64>() / runs.max(1) as f64,
        rmse: rmse_scalar(&estimates, truth),
        runs,
    }
}

/// Paired variant: `estimate(seed)` returns (estimate, truth) per run —
/// used when the workload itself is resampled per run (Fig. 6's vector
/// pairs).
pub fn rmse_experiment_paired(
    runs: usize,
    mut run: impl FnMut(u64) -> (f64, f64),
) -> ErrorReport {
    let pairs: Vec<(f64, f64)> = (0..runs as u64).map(&mut run).collect();
    let se: f64 = pairs.iter().map(|(e, t)| (e - t) * (e - t)).sum();
    let mean_t = pairs.iter().map(|(_, t)| t).sum::<f64>() / runs.max(1) as f64;
    let mean_e = pairs.iter().map(|(e, _)| e).sum::<f64>() / runs.max(1) as f64;
    ErrorReport {
        truth: mean_t,
        mean_estimate: mean_e,
        rmse: (se / runs.max(1) as f64).sqrt(),
        runs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_estimator_has_zero_rmse() {
        let r = rmse_experiment(5.0, 10, |_| 5.0);
        assert_eq!(r.rmse, 0.0);
        assert_eq!(r.mean_estimate, 5.0);
    }

    #[test]
    fn biased_estimator_rmse_equals_bias() {
        let r = rmse_experiment(5.0, 10, |_| 6.0);
        assert!((r.rmse - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paired_runner_averages() {
        let r = rmse_experiment_paired(4, |s| (s as f64, s as f64 + 0.5));
        assert!((r.rmse - 0.5).abs() < 1e-12);
        assert!((r.truth - 2.0).abs() < 1e-12);
    }
}
