//! Estimators over Gumbel-Max sketches: probability/weighted Jaccard
//! similarity ([`jaccard`]), weighted cardinality and the mergeable set
//! algebra of Lemiesz ([`cardinality`]), weighted sampling and
//! partition-function estimation ([`sample`] — the Gumbel-Max Trick's
//! native workload), and an RMSE experiment runner ([`error`]) used by
//! the Fig. 6/7 reproductions.

pub mod jaccard;
pub mod cardinality;
pub mod sample;
pub mod error;
