//! Jaccard similarities: exact computations on sparse vectors (ground
//! truth) and the sketch-based estimators.
//!
//! * `J_P(u,v) = Σ_{i∈N⁺_{u,v}} 1 / Σ_l max(u_l/u_i, v_l/v_i)` — probability
//!   Jaccard (Moulton & Jiang). Estimated by the ArgMax-register match
//!   fraction; unbiased with variance `J(1-J)/k` (Theorem 1).
//! * `J_W(u,v) = Σ min(u_i,v_i) / Σ max(u_i,v_i)` — weighted Jaccard
//!   (ground truth for BagMinHash/ICWS and the simnet Fig. 10d metric).

use crate::sketch::{kernels, GumbelMaxSketch, MergeError, SparseVector};
use std::collections::HashMap;

/// Exact probability Jaccard similarity.
pub fn probability_jaccard(u: &SparseVector, v: &SparseVector) -> f64 {
    let mu: HashMap<u64, f64> = u.positive().collect();
    let mv: HashMap<u64, f64> = v.positive().collect();
    let mut total = 0.0;
    for (&i, &ui) in &mu {
        let Some(&vi) = mv.get(&i) else { continue };
        // denom = Σ_l max(u_l/u_i, v_l/v_i), over the union support.
        let mut denom = 0.0;
        for (&l, &ul) in &mu {
            let vl = mv.get(&l).copied().unwrap_or(0.0);
            denom += (ul / ui).max(vl / vi);
        }
        for (&l, &vl) in &mv {
            if !mu.contains_key(&l) {
                denom += vl / vi;
            }
        }
        total += 1.0 / denom;
    }
    total
}

/// Exact weighted Jaccard similarity.
pub fn weighted_jaccard(u: &SparseVector, v: &SparseVector) -> f64 {
    let mu: HashMap<u64, f64> = u.positive().collect();
    let mv: HashMap<u64, f64> = v.positive().collect();
    let mut num = 0.0;
    let mut den = 0.0;
    for (&i, &ui) in &mu {
        let vi = mv.get(&i).copied().unwrap_or(0.0);
        num += ui.min(vi);
        den += ui.max(vi);
    }
    for (&i, &vi) in &mv {
        if !mu.contains_key(&i) {
            den += vi;
        }
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Estimate `J_P` from two Gumbel-Max sketches: the fraction of ArgMax
/// registers that agree. Errors on family/seed/length mismatch, and on
/// every family whose ArgMax registers are not `EXP(w)` races: for
/// ICWS/BagMinHash the match fraction is the *biased* 0-bit estimator
/// (their dedicated `estimate_jw` views apply), and for MinHash it is
/// unweighted support-set resemblance, not `J_P` — returning it here would
/// be a silently mislabeled number on weighted inputs.
pub fn estimate_jp(
    a: &GumbelMaxSketch,
    b: &GumbelMaxSketch,
) -> Result<f64, MergeError> {
    a.check_compatible(b)?;
    if !a.family.has_exponential_registers() {
        let hint = match a.family {
            crate::sketch::Family::Icws => "use Icws::sketch_full + IcwsSketch::estimate_jw",
            crate::sketch::Family::Bag => "use BagMinHash::sketch_bag + BagSketch::estimate_jw",
            _ => "minhash estimates unweighted resemblance; use MinHashSketch::resemblance",
        };
        return Err(MergeError::EstimatorUnsupported {
            estimator: "J_P",
            family: a.family.name(),
            hint,
        });
    }
    let k = a.k();
    let m = kernels::match_count(&a.s, &b.s);
    Ok(m as f64 / k as f64)
}

/// Estimate `J_P` of one query sketch against many candidates in one pass —
/// the serving re-rank primitive (`coordinator::store` top-k and the cluster
/// client's scatter-gather re-rank).
///
/// Defined as the per-pair loop over [`estimate_jp`], so estimates, tie
/// behaviour (order is preserved, ranking stays stable downstream) and
/// error semantics — including the family-rejection paths — are *identical
/// by construction* to calling `estimate_jp` per candidate; the SIMD win
/// lives inside the shared `match_count` kernel. The first failing
/// candidate aborts the batch, exactly like the historical caller loops.
pub fn estimate_jp_batch<'a, K>(
    query: &GumbelMaxSketch,
    candidates: impl IntoIterator<Item = (K, &'a GumbelMaxSketch)>,
) -> Result<Vec<(K, f64)>, MergeError> {
    let mut out = Vec::new();
    for (key, sk) in candidates {
        out.push((key, estimate_jp(query, sk)?));
    }
    Ok(out)
}

/// Theoretical standard deviation of the J_P estimator (Theorem 1).
pub fn jp_estimator_std(jp: f64, k: usize) -> f64 {
    (jp * (1.0 - jp) / k as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::fastgm::FastGm;
    use crate::sketch::pminhash::PMinHash;
    use crate::sketch::{Family, Sketcher};
    use crate::util::proptest::forall_explain;
    use crate::util::rng::SplitMix64;
    use crate::util::stats::OnlineStats;

    #[test]
    fn jp_identical_vectors_is_one() {
        let v = SparseVector::new(vec![1, 2, 3], vec![0.2, 0.5, 0.3]);
        assert!((probability_jaccard(&v, &v) - 1.0).abs() < 1e-12);
        assert!((weighted_jaccard(&v, &v) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn jp_disjoint_is_zero() {
        let u = SparseVector::new(vec![1], vec![1.0]);
        let v = SparseVector::new(vec![2], vec![1.0]);
        assert_eq!(probability_jaccard(&u, &v), 0.0);
        assert_eq!(weighted_jaccard(&u, &v), 0.0);
    }

    #[test]
    fn jp_is_scale_invariant_jw_is_not() {
        let u = SparseVector::new(vec![1, 2], vec![1.0, 2.0]);
        let v = SparseVector::new(vec![1, 2, 3], vec![2.0, 1.0, 1.0]);
        let v_scaled = SparseVector::new(vec![1, 2, 3], vec![6.0, 3.0, 3.0]);
        let a = probability_jaccard(&u, &v);
        let b = probability_jaccard(&u, &v_scaled);
        assert!((a - b).abs() < 1e-12, "J_P must be scale-invariant");
        let wa = weighted_jaccard(&u, &v);
        let wb = weighted_jaccard(&u, &v_scaled);
        assert!((wa - wb).abs() > 0.05, "J_W must change under scaling");
    }

    #[test]
    fn jp_symmetry_property() {
        forall_explain(
            40,
            |r| {
                let n = r.next_range(1, 12);
                let mk = |r: &mut SplitMix64| {
                    SparseVector::new(
                        (0..n as u64).collect(),
                        (0..n).map(|_| if r.next_f64() < 0.3 { 0.0 } else { r.next_exp() }).collect(),
                    )
                };
                (mk(r), mk(r))
            },
            |(u, v)| {
                let a = probability_jaccard(u, v);
                let b = probability_jaccard(v, u);
                if (a - b).abs() < 1e-9 && (0.0..=1.0 + 1e-9).contains(&a) {
                    Ok(())
                } else {
                    Err(format!("J_P asymmetric or out of range: {a} vs {b}"))
                }
            },
        );
    }

    /// Theorem 1: the sketch estimator is unbiased for J_P with variance
    /// J(1-J)/k — check both with the Ordered (FastGM) and Direct
    /// (P-MinHash) families.
    #[test]
    fn estimator_unbiased_both_families() {
        let u = SparseVector::new(vec![1, 2, 3, 4], vec![1.0, 0.5, 2.0, 0.0]);
        let v = SparseVector::new(vec![1, 2, 3, 5], vec![0.5, 0.5, 1.0, 1.0]);
        let truth = probability_jaccard(&u, &v);
        let k = 256;
        let runs = 80;

        let mut ord = OnlineStats::new();
        let mut dir = OnlineStats::new();
        for seed in 0..runs as u64 {
            // Both families through the unified u64-seed Sketcher API.
            let f = FastGm::new(k, seed);
            ord.push(estimate_jp(&f.sketch(&u), &f.sketch(&v)).unwrap());
            let p = PMinHash::new(k, seed);
            dir.push(estimate_jp(&p.sketch(&u), &p.sketch(&v)).unwrap());
        }
        let tol = 3.0 * jp_estimator_std(truth, k) / (runs as f64).sqrt();
        assert!((ord.mean() - truth).abs() < tol, "ordered mean={} truth={truth}", ord.mean());
        assert!((dir.mean() - truth).abs() < tol, "direct mean={} truth={truth}", dir.mean());
        // Variance within 2x of theory (loose; runs is small).
        let theo_var = truth * (1.0 - truth) / k as f64;
        assert!(ord.var() < 2.5 * theo_var && ord.var() > theo_var / 2.5,
            "ordered var={} theory={theo_var}", ord.var());
    }

    #[test]
    fn estimator_rejects_cross_family() {
        let v = SparseVector::new(vec![1], vec![1.0]);
        let a = FastGm::new(16, 1).sketch(&v);
        let b = PMinHash::new(16, 1).sketch(&v);
        assert!(matches!(estimate_jp(&a, &b), Err(MergeError::FamilyMismatch(_, _))));
        assert_eq!(a.family, Family::Ordered);
    }

    /// ICWS/BagMinHash ArgMax matching is the biased 0-bit estimator, and
    /// MinHash matching is unweighted resemblance — the J_P estimator must
    /// refuse all three loudly and point at the right dedicated estimator.
    #[test]
    fn estimator_rejects_non_race_families() {
        use crate::sketch::engine::{build, AlgorithmId, EngineParams};
        // Identical support, very different weights: true J_P < 1, but a
        // MinHash match fraction would claim 1.0 — the silent bias the
        // gate exists to prevent.
        let v = SparseVector::new(vec![1, 2], vec![100.0, 0.01]);
        for (id, hint) in [
            (AlgorithmId::Icws, "estimate_jw"),
            (AlgorithmId::BagMinHash, "estimate_jw"),
            (AlgorithmId::MinHash, "resemblance"),
        ] {
            let sk = build(id, EngineParams::new(16, 1)).sketch(&v);
            let err = estimate_jp(&sk, &sk).unwrap_err();
            assert!(
                matches!(err, MergeError::EstimatorUnsupported { .. }),
                "{id:?}: {err}"
            );
            assert!(err.to_string().contains(hint), "{err}");
        }
    }
}
