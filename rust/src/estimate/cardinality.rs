//! Weighted cardinality estimation and the mergeable sketch set algebra
//! (Lemiesz VLDB'21; Theorem 2 of the paper).
//!
//! Each `y_j ~ EXP(c)` for `c = Σ_{i∈N} v_i`, so `Σ_j y_j ~ Γ(k, c)` and
//! `ĉ = (k-1)/Σ_j y_j` is the minimum-variance unbiased estimator with
//! `Var(ĉ/c) ≈ 2/k`. Unions come free from sketch merge; intersections,
//! differences and weighted Jaccard follow by inclusion–exclusion — the
//! operations the sensor-network experiments (Fig. 10) are built on.

use crate::sketch::{GumbelMaxSketch, MergeError};

/// `ĉ = (k-1)/Σ y_j`. Returns 0 for an empty sketch (all registers ∞) and
/// requires k ≥ 2 (the k=1 estimator has no finite mean).
pub fn estimate_cardinality(sk: &GumbelMaxSketch) -> f64 {
    let k = sk.k();
    assert!(k >= 2, "cardinality estimation needs k >= 2");
    let sum: f64 = sk.y.iter().sum();
    if !sum.is_finite() || sum <= 0.0 {
        return 0.0;
    }
    (k as f64 - 1.0) / sum
}

/// Theoretical relative standard deviation of the estimator (Theorem 2).
pub fn cardinality_rel_std(k: usize) -> f64 {
    (2.0 / k as f64).sqrt()
}

/// Estimated weighted cardinality of the union of the underlying sets.
/// Errors unless the family's `y` registers are `EXP(Σw)` races (Ordered /
/// Direct) — the precondition of the whole algebra; ICWS, BagMinHash and
/// MinHash registers would yield meaningless numbers. This gate covers
/// every derived operation below (intersection, difference, `J_W`).
pub fn estimate_union(sketches: &[&GumbelMaxSketch]) -> Result<f64, MergeError> {
    let merged = GumbelMaxSketch::merge_all(sketches.iter().copied())?;
    if !merged.family.has_exponential_registers() {
        return Err(MergeError::EstimatorUnsupported {
            estimator: "cardinality",
            family: merged.family.name(),
            hint: "cardinality algebra needs EXP-register families (ordered/direct)",
        });
    }
    Ok(estimate_cardinality(&merged))
}

/// Inclusion–exclusion: `|A∩B| = ĉ_A + ĉ_B − ĉ_{A∪B}`. May be slightly
/// negative due to estimation noise; clamped at 0.
pub fn estimate_intersection(
    a: &GumbelMaxSketch,
    b: &GumbelMaxSketch,
) -> Result<f64, MergeError> {
    let ca = estimate_cardinality(a);
    let cb = estimate_cardinality(b);
    let cu = estimate_union(&[a, b])?;
    Ok((ca + cb - cu).max(0.0))
}

/// `|A \ B| = ĉ_{A∪B} − ĉ_B`, clamped at 0.
pub fn estimate_difference(
    a: &GumbelMaxSketch,
    b: &GumbelMaxSketch,
) -> Result<f64, MergeError> {
    let cu = estimate_union(&[a, b])?;
    Ok((cu - estimate_cardinality(b)).max(0.0))
}

/// Weighted Jaccard from cardinality algebra:
/// `J_W = (ĉ_A + ĉ_B − ĉ_U) / ĉ_U`, clamped to [0, 1].
pub fn estimate_weighted_jaccard(
    a: &GumbelMaxSketch,
    b: &GumbelMaxSketch,
) -> Result<f64, MergeError> {
    let cu = estimate_union(&[a, b])?;
    if cu <= 0.0 {
        return Ok(0.0);
    }
    let inter = estimate_cardinality(a) + estimate_cardinality(b) - cu;
    Ok((inter / cu).clamp(0.0, 1.0))
}

/// `|A \ (B ∪ C)| = ĉ_{A∪B∪C} − ĉ_{B∪C}` — the "lost packets" metric of
/// Fig. 10c (packets from source A that reached neither node).
pub fn estimate_difference_union(
    a: &GumbelMaxSketch,
    b: &GumbelMaxSketch,
    c: &GumbelMaxSketch,
) -> Result<f64, MergeError> {
    let cabc = estimate_union(&[a, b, c])?;
    let cbc = estimate_union(&[b, c])?;
    Ok((cabc - cbc).max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::lemiesz::LemieszSketch;
    use crate::sketch::stream_fastgm::StreamFastGm;
    use crate::util::rng::SplitMix64;
    use crate::util::stats::OnlineStats;

    /// The algebra assumes EXP-register races; ICWS/BagMinHash/MinHash
    /// registers would silently produce nonsense, so the gate in
    /// `estimate_union` must fail every derived operation loudly.
    #[test]
    fn cardinality_algebra_rejects_non_exponential_families() {
        use crate::sketch::engine::{build, AlgorithmId, EngineParams};
        use crate::sketch::{Sketcher, SparseVector};
        let v = SparseVector::new(vec![1, 2], vec![1.0, 2.0]);
        for id in [AlgorithmId::Icws, AlgorithmId::BagMinHash, AlgorithmId::MinHash] {
            let sk = build(id, EngineParams::new(16, 1)).sketch(&v);
            for err in [
                estimate_union(&[&sk]).unwrap_err(),
                estimate_intersection(&sk, &sk).unwrap_err(),
                estimate_weighted_jaccard(&sk, &sk).unwrap_err(),
            ] {
                assert!(
                    matches!(err, MergeError::EstimatorUnsupported { .. }),
                    "{id:?}: {err}"
                );
            }
        }
    }

    fn lemiesz_of(k: usize, seed: u64, items: &[(u64, f64)]) -> GumbelMaxSketch {
        let mut s = LemieszSketch::new(k, seed);
        for &(id, w) in items {
            s.push(id, w);
        }
        s.sketch()
    }

    #[test]
    fn unbiased_within_theory() {
        let items: Vec<(u64, f64)> = (0..500).map(|i| (i as u64, 0.5 + (i % 7) as f64 * 0.1)).collect();
        let truth: f64 = items.iter().map(|(_, w)| w).sum();
        let k = 128;
        let mut stats = OnlineStats::new();
        for seed in 0..150u64 {
            stats.push(estimate_cardinality(&lemiesz_of(k, seed, &items)));
        }
        let rel_err = (stats.mean() - truth).abs() / truth;
        assert!(rel_err < 0.02, "mean={} truth={truth}", stats.mean());
        // Var(ĉ/c) ≈ 2/k.
        let rel_std = stats.std() / truth;
        let theo = cardinality_rel_std(k);
        assert!(rel_std < 1.5 * theo && rel_std > theo / 1.5, "rel_std={rel_std} theo={theo}");
    }

    #[test]
    fn stream_fastgm_sketch_estimates_equally_well() {
        // The Ordered family y-part is also EXP(c) — the estimator is
        // family-agnostic.
        let items: Vec<(u64, f64)> = (0..300).map(|i| (i as u64 * 3 + 7, 1.0)).collect();
        let truth = 300.0;
        let mut stats = OnlineStats::new();
        for seed in 0..100u64 {
            let mut s = StreamFastGm::new(128, seed);
            for &(id, w) in &items {
                s.push(id, w);
            }
            stats.push(estimate_cardinality(&s.sketch()));
        }
        assert!((stats.mean() - truth).abs() / truth < 0.03, "mean={}", stats.mean());
    }

    #[test]
    fn union_intersection_difference_consistency() {
        let a_items: Vec<(u64, f64)> = (0..400).map(|i| (i, 1.0)).collect();
        let b_items: Vec<(u64, f64)> = (200..600).map(|i| (i, 1.0)).collect();
        let k = 512;
        let mut u_est = OnlineStats::new();
        let mut i_est = OnlineStats::new();
        let mut d_est = OnlineStats::new();
        let mut j_est = OnlineStats::new();
        for seed in 0..60u64 {
            let sa = lemiesz_of(k, seed, &a_items);
            let sb = lemiesz_of(k, seed, &b_items);
            u_est.push(estimate_union(&[&sa, &sb]).unwrap());
            i_est.push(estimate_intersection(&sa, &sb).unwrap());
            d_est.push(estimate_difference(&sa, &sb).unwrap());
            j_est.push(estimate_weighted_jaccard(&sa, &sb).unwrap());
        }
        assert!((u_est.mean() - 600.0).abs() / 600.0 < 0.05, "union={}", u_est.mean());
        assert!((i_est.mean() - 200.0).abs() / 200.0 < 0.2, "inter={}", i_est.mean());
        assert!((d_est.mean() - 200.0).abs() / 200.0 < 0.2, "diff={}", d_est.mean());
        assert!((j_est.mean() - 200.0 / 600.0).abs() < 0.05, "jw={}", j_est.mean());
    }

    #[test]
    fn difference_union_three_way() {
        // A = 0..300, B = 100..300, C = 200..400 → A \ (B∪C) = 0..100.
        let k = 512;
        let mut stats = OnlineStats::new();
        for seed in 0..60u64 {
            let sa = lemiesz_of(k, seed, &(0..300).map(|i| (i, 1.0)).collect::<Vec<_>>());
            let sb = lemiesz_of(k, seed, &(100..300).map(|i| (i, 1.0)).collect::<Vec<_>>());
            let sc = lemiesz_of(k, seed, &(200..400).map(|i| (i, 1.0)).collect::<Vec<_>>());
            stats.push(estimate_difference_union(&sa, &sb, &sc).unwrap());
        }
        assert!((stats.mean() - 100.0).abs() / 100.0 < 0.25, "mean={}", stats.mean());
    }

    #[test]
    fn empty_sketch_estimates_zero() {
        let empty = GumbelMaxSketch::empty(crate::sketch::Family::Direct, 1, 16);
        assert_eq!(estimate_cardinality(&empty), 0.0);
    }

    #[test]
    fn weights_change_the_answer() {
        // Same support, doubled weights → doubled cardinality (what HLL
        // cannot see; ablation hook).
        let mut r = SplitMix64::new(1);
        let items: Vec<(u64, f64)> = (0..200).map(|i| (i, r.next_f64() + 0.5)).collect();
        let doubled: Vec<(u64, f64)> = items.iter().map(|&(i, w)| (i, 2.0 * w)).collect();
        let mut ratio = OnlineStats::new();
        for seed in 0..40u64 {
            let a = estimate_cardinality(&lemiesz_of(256, seed, &items));
            let b = estimate_cardinality(&lemiesz_of(256, seed, &doubled));
            ratio.push(b / a);
        }
        assert!((ratio.mean() - 2.0).abs() < 0.05, "ratio={}", ratio.mean());
    }
}
