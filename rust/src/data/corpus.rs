//! Synthetic analogs of the paper's six real-world datasets (Table 1).
//!
//! The real corpora are not redistributable inside this environment, so
//! each analog reproduces the statistics FastGM's runtime actually depends
//! on — vector count, feature-space size, the per-vector positive-entry
//! (n⁺) profile, and a TF-IDF-like weight distribution — via Zipf feature
//! popularity and log-normal n⁺ draws (README.md §Datasets documents the
//! substitution). Real svmlight files drop in through [`super::svmlight`]
//! and the `--dataset path:<file>` CLI syntax.
//!
//! | analog     | #vectors | #features | mean n⁺ (approx) |
//! |------------|----------|-----------|------------------|
//! | real-sim   | 72,309   | 20,958    | 52               |
//! | rcv1       | 20,242   | 47,236    | 74               |
//! | news20     | 19,996   | 1,355,191 | 455              |
//! | libimseti  | 220,970  | 220,970   | 78               |
//! | wiki10     | 14,146   | 104,374   | 97               |
//! | movielens  | 69,878   | 80,555    | 143              |

use super::synthetic::Zipf;
use crate::sketch::SparseVector;
use crate::util::rng::SplitMix64;

/// Static description of a corpus analog.
#[derive(Debug, Clone, Copy)]
pub struct CorpusSpec {
    pub name: &'static str,
    pub vectors: usize,
    pub features: usize,
    /// Mean positive entries per vector (log-normal across vectors).
    pub mean_nplus: f64,
    /// log-std of the per-vector n⁺ distribution.
    pub nplus_sigma: f64,
    /// Zipf exponent of feature popularity.
    pub zipf_s: f64,
}

pub const CORPORA: &[CorpusSpec] = &[
    CorpusSpec { name: "real-sim", vectors: 72_309, features: 20_958, mean_nplus: 52.0, nplus_sigma: 0.9, zipf_s: 1.05 },
    CorpusSpec { name: "rcv1", vectors: 20_242, features: 47_236, mean_nplus: 74.0, nplus_sigma: 0.8, zipf_s: 1.1 },
    CorpusSpec { name: "news20", vectors: 19_996, features: 1_355_191, mean_nplus: 455.0, nplus_sigma: 1.0, zipf_s: 1.2 },
    CorpusSpec { name: "libimseti", vectors: 220_970, features: 220_970, mean_nplus: 78.0, nplus_sigma: 1.2, zipf_s: 0.9 },
    CorpusSpec { name: "wiki10", vectors: 14_146, features: 104_374, mean_nplus: 97.0, nplus_sigma: 0.7, zipf_s: 1.1 },
    CorpusSpec { name: "movielens", vectors: 69_878, features: 80_555, mean_nplus: 143.0, nplus_sigma: 1.1, zipf_s: 1.0 },
];

pub fn spec(name: &str) -> Option<&'static CorpusSpec> {
    CORPORA.iter().find(|c| c.name == name)
}

/// Deterministic generator of corpus vectors (seeded by corpus + index so
/// experiments can stream any subset without materializing the corpus).
pub struct Corpus {
    pub spec: CorpusSpec,
    zipf: Zipf,
    seed: u64,
}

impl Corpus {
    pub fn new(spec: CorpusSpec, seed: u64) -> Self {
        // Cap the Zipf table so news20-scale feature spaces stay cheap;
        // the tail beyond the cap is sampled uniformly.
        let table = spec.features.min(200_000);
        Corpus { spec, zipf: Zipf::new(table, spec.zipf_s), seed }
    }

    pub fn by_name(name: &str, seed: u64) -> Option<Corpus> {
        spec(name).map(|s| Corpus::new(*s, seed))
    }

    /// Generate vector `idx` (0 ≤ idx < spec.vectors).
    pub fn vector(&self, idx: usize) -> SparseVector {
        let mut rng = SplitMix64::new(
            self.seed ^ crate::util::hash::mix2(0xC0_4B05 ^ self.spec.zipf_s.to_bits(), idx as u64),
        );
        // Per-vector n⁺ ~ LogNormal(ln(mean) - σ²/2, σ), clamped.
        let mu = self.spec.mean_nplus.ln() - self.spec.nplus_sigma * self.spec.nplus_sigma / 2.0;
        let nplus = (mu + self.spec.nplus_sigma * rng.next_normal()).exp().round() as usize;
        let nplus = nplus.clamp(1, self.spec.features.min(20_000));

        let table = self.spec.features.min(200_000);
        let mut seen = std::collections::HashSet::with_capacity(nplus * 2);
        let mut v = SparseVector::default();
        let mut guard = 0;
        while v.ids.len() < nplus && guard < nplus * 40 {
            guard += 1;
            // Head features by Zipf, plus a uniform tail for huge spaces.
            let f = if self.spec.features > table && rng.next_f64() < 0.15 {
                table + rng.next_range(0, self.spec.features - table - 1)
            } else {
                self.zipf.sample(&mut rng)
            } as u64;
            if seen.insert(f) {
                // TF-IDF-like: log-normal weight, heavier for rare features.
                let tf = (1.0 + rng.next_exp()).ln() + 0.1;
                let idf = (1.0 + (self.spec.features as f64 / (1.0 + f as f64))).ln();
                v.push(f, tf * idf);
            }
        }
        v
    }

    /// First `count` vectors.
    pub fn vectors(&self, count: usize) -> Vec<SparseVector> {
        (0..count.min(self.spec.vectors)).map(|i| self.vector(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::OnlineStats;

    #[test]
    fn all_specs_resolve() {
        for c in CORPORA {
            assert!(spec(c.name).is_some());
        }
        assert!(spec("nope").is_none());
    }

    #[test]
    fn vectors_are_deterministic() {
        let c = Corpus::by_name("rcv1", 7).unwrap();
        assert_eq!(c.vector(5), c.vector(5));
        assert_ne!(c.vector(5), c.vector(6));
        let c2 = Corpus::by_name("rcv1", 8).unwrap();
        assert_ne!(c.vector(5), c2.vector(5));
    }

    #[test]
    fn nplus_profile_matches_spec() {
        let c = Corpus::by_name("real-sim", 1).unwrap();
        let mut s = OnlineStats::new();
        for i in 0..400 {
            let v = c.vector(i);
            assert!(v.n_plus() >= 1);
            assert!(v.ids.iter().all(|&f| (f as usize) < c.spec.features));
            s.push(v.n_plus() as f64);
        }
        // Log-normal mean ≈ spec mean within sampling tolerance.
        assert!(
            (s.mean() - c.spec.mean_nplus).abs() < c.spec.mean_nplus * 0.35,
            "mean n⁺ = {} vs spec {}",
            s.mean(),
            c.spec.mean_nplus
        );
    }

    #[test]
    fn weights_positive_and_skewed() {
        let c = Corpus::by_name("wiki10", 3).unwrap();
        let v = c.vector(0);
        assert!(v.weights.iter().all(|&w| w > 0.0));
        let mx = v.weights.iter().cloned().fold(0.0, f64::max);
        let mn = v.weights.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(mx / mn > 2.0, "TF-IDF-like weights should be spread");
    }

    #[test]
    fn corpus_vectors_share_popular_features() {
        // Zipf popularity ⇒ nonzero pairwise overlap on head features.
        let c = Corpus::by_name("news20", 2).unwrap();
        let a = c.vector(0);
        let b = c.vector(1);
        let sa: std::collections::HashSet<u64> = a.ids.iter().copied().collect();
        let shared = b.ids.iter().filter(|i| sa.contains(i)).count();
        assert!(shared > 0, "corpus vectors should overlap on head features");
    }
}
