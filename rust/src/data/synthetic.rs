//! Synthetic workload generation: the weight distributions the paper's
//! evaluation draws from (UNI(0,1), EXP(1), N(1,0.1), Beta(5,5)), sparse
//! vector construction, Zipf-popularity sampling and controlled-overlap
//! vector pairs for the similarity experiments.

use crate::sketch::SparseVector;
use crate::util::rng::SplitMix64;

/// Weight distributions used across the paper's experiments.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WeightDist {
    /// UNI(0,1) — Fig. 4, Fig. 7.
    Uniform01,
    /// EXP(1) — Fig. 4 (results "similar", per the paper).
    Exp1,
    /// N(μ, σ), truncated to positive — Fig. 7 uses N(1, 0.1).
    Normal(f64, f64),
    /// Beta(α, β) — Fig. 10/11 packet sizes use Beta(5,5).
    Beta(f64, f64),
    /// Constant weight (unweighted cardinality ablation).
    Const(f64),
}

impl WeightDist {
    pub fn sample(&self, rng: &mut SplitMix64) -> f64 {
        match *self {
            WeightDist::Uniform01 => {
                let u = rng.next_f64();
                // Open interval already; keep away from exact zero weight.
                u.max(1e-12)
            }
            WeightDist::Exp1 => rng.next_exp(),
            WeightDist::Normal(mu, sigma) => {
                // Truncated at a small positive floor (weights must be > 0).
                loop {
                    let x = mu + sigma * rng.next_normal();
                    if x > 0.0 {
                        return x;
                    }
                }
            }
            WeightDist::Beta(a, b) => rng.next_beta(a, b).max(1e-12),
            WeightDist::Const(c) => c,
        }
    }

    pub fn name(&self) -> String {
        match self {
            WeightDist::Uniform01 => "UNI(0,1)".into(),
            WeightDist::Exp1 => "EXP(1)".into(),
            WeightDist::Normal(m, s) => format!("N({m},{s})"),
            WeightDist::Beta(a, b) => format!("Beta({a},{b})"),
            WeightDist::Const(c) => format!("Const({c})"),
        }
    }
}

/// A fully dense vector of length n with ids 0..n (the paper's synthetic
/// Task-1 setting: n⁺ = n).
pub fn dense_vector(rng: &mut SplitMix64, n: usize, dist: WeightDist) -> SparseVector {
    SparseVector::new(
        (0..n as u64).collect(),
        (0..n).map(|_| dist.sample(rng)).collect(),
    )
}

/// A sparse vector with `n_plus` distinct random ids drawn from `0..n`.
pub fn sparse_vector(
    rng: &mut SplitMix64,
    n: usize,
    n_plus: usize,
    dist: WeightDist,
) -> SparseVector {
    assert!(n_plus <= n);
    // Floyd's algorithm for a uniform n_plus-subset of 0..n.
    let mut chosen = std::collections::HashSet::with_capacity(n_plus);
    for j in (n - n_plus)..n {
        let t = rng.next_range(0, j);
        if !chosen.insert(t as u64) {
            chosen.insert(j as u64);
        }
    }
    let mut ids: Vec<u64> = chosen.into_iter().collect();
    ids.sort_unstable();
    let weights = ids.iter().map(|_| dist.sample(rng)).collect();
    SparseVector::new(ids, weights)
}

/// A pair of vectors sharing ~`overlap` fraction of their support (ids and
/// weights identical on the shared part) — the Fig. 6 workload.
pub fn overlapping_pair(
    rng: &mut SplitMix64,
    n_plus: usize,
    overlap: f64,
    dist: WeightDist,
) -> (SparseVector, SparseVector) {
    let mut u = SparseVector::default();
    let mut v = SparseVector::default();
    for i in 0..n_plus as u64 {
        let w = dist.sample(rng);
        if rng.next_f64() < overlap {
            u.push(i, w);
            v.push(i, w);
        } else if rng.next_u64() & 1 == 0 {
            u.push(i, w);
            v.push(i | (1 << 62), dist.sample(rng));
        } else {
            u.push(i | (1 << 61), dist.sample(rng));
            v.push(i, w);
        }
    }
    (u, v)
}

/// Zipf sampler over `0..n` with exponent `s` (feature popularity in the
/// corpus analogs). Uses the standard inverse-CDF over precomputed
/// cumulative weights for exactness at corpus scale.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut SplitMix64) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c < u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::OnlineStats;

    #[test]
    fn weight_dists_have_expected_means() {
        let mut r = SplitMix64::new(1);
        let cases: Vec<(WeightDist, f64)> = vec![
            (WeightDist::Uniform01, 0.5),
            (WeightDist::Exp1, 1.0),
            (WeightDist::Normal(1.0, 0.1), 1.0),
            (WeightDist::Beta(5.0, 5.0), 0.5),
            (WeightDist::Const(2.0), 2.0),
        ];
        for (dist, want) in cases {
            let mut s = OnlineStats::new();
            for _ in 0..40_000 {
                let x = dist.sample(&mut r);
                assert!(x > 0.0, "{} produced non-positive", dist.name());
                s.push(x);
            }
            assert!(
                (s.mean() - want).abs() < 0.02,
                "{}: mean={} want={want}",
                dist.name(),
                s.mean()
            );
        }
    }

    #[test]
    fn dense_vector_has_full_support() {
        let mut r = SplitMix64::new(2);
        let v = dense_vector(&mut r, 100, WeightDist::Uniform01);
        assert_eq!(v.n_plus(), 100);
    }

    #[test]
    fn sparse_vector_ids_distinct_and_bounded() {
        let mut r = SplitMix64::new(3);
        let v = sparse_vector(&mut r, 1000, 64, WeightDist::Exp1);
        assert_eq!(v.ids.len(), 64);
        let mut ids = v.ids.clone();
        ids.dedup();
        assert_eq!(ids.len(), 64, "ids must be distinct");
        assert!(v.ids.iter().all(|&i| i < 1000));
    }

    #[test]
    fn overlapping_pair_controls_similarity() {
        let mut r = SplitMix64::new(4);
        let (u, v) = overlapping_pair(&mut r, 300, 0.8, WeightDist::Uniform01);
        let jp = crate::estimate::jaccard::probability_jaccard(&u, &v);
        assert!(jp > 0.5 && jp < 0.95, "jp={jp}");
        let (u2, v2) = overlapping_pair(&mut r, 300, 0.1, WeightDist::Uniform01);
        let jp2 = crate::estimate::jaccard::probability_jaccard(&u2, &v2);
        assert!(jp2 < jp, "jp2={jp2} should be below jp={jp}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(1000, 1.1);
        let mut r = SplitMix64::new(5);
        let mut counts = vec![0usize; 1000];
        for _ in 0..20_000 {
            let i = z.sample(&mut r);
            assert!(i < 1000);
            counts[i] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[500]);
    }
}
