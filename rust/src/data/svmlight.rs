//! svmlight / libsvm sparse-format IO (`label idx:val idx:val ...`) — the
//! format the paper's six real datasets ship in, so they can be dropped
//! into every experiment via `--dataset path:<file>`.

use crate::sketch::SparseVector;
use std::io::{BufReader, Read, Write};

/// One row: label + sparse vector.
#[derive(Debug, Clone, PartialEq)]
pub struct Row {
    pub label: f64,
    pub vector: SparseVector,
}

/// Parse svmlight text. Lines starting with `#` and blank lines are
/// skipped; `#` after data starts a comment.
pub fn parse(text: &str) -> anyhow::Result<Vec<Row>> {
    let mut rows = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f64 = parts
            .next()
            .unwrap()
            .parse()
            .map_err(|_| anyhow::anyhow!("line {}: bad label", lineno + 1))?;
        let mut v = SparseVector::default();
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected idx:val, got '{tok}'", lineno + 1))?;
            let idx: u64 = idx
                .parse()
                .map_err(|_| anyhow::anyhow!("line {}: bad index '{idx}'", lineno + 1))?;
            let val: f64 = val
                .parse()
                .map_err(|_| anyhow::anyhow!("line {}: bad value '{val}'", lineno + 1))?;
            v.push(idx, val);
        }
        rows.push(Row { label, vector: v });
    }
    Ok(rows)
}

pub fn load(path: &str) -> anyhow::Result<Vec<Row>> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("cannot open svmlight file '{path}': {e}"))?;
    let mut text = String::new();
    BufReader::new(f).read_to_string(&mut text)?;
    parse(&text)
}

pub fn write(path: &str, rows: &[Row]) -> anyhow::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for r in rows {
        write!(f, "{}", r.label)?;
        for (id, w) in r.vector.ids.iter().zip(&r.vector.weights) {
            write!(f, " {id}:{w}")?;
        }
        writeln!(f)?;
    }
    Ok(())
}


#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# a comment line
1 3:0.5 17:1.25 99:2
-1 1:0.1   # trailing comment

0 5:3.5
";

    #[test]
    fn parses_labels_and_entries() {
        let rows = parse(SAMPLE).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].label, 1.0);
        assert_eq!(rows[0].vector.ids, vec![3, 17, 99]);
        assert_eq!(rows[0].vector.weights, vec![0.5, 1.25, 2.0]);
        assert_eq!(rows[1].label, -1.0);
        assert_eq!(rows[2].vector.ids, vec![5]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("1 nocolon").is_err());
        assert!(parse("notanumber 1:2").is_err());
        assert!(parse("1 x:2").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let rows = parse(SAMPLE).unwrap();
        let path = std::env::temp_dir().join("fastgm_svmlight_test.txt");
        write(path.to_str().unwrap(), &rows).unwrap();
        let back = load(path.to_str().unwrap()).unwrap();
        assert_eq!(rows, back);
        let _ = std::fs::remove_file(&path);
    }
}
