//! Stream generation for Task 2 (weighted cardinality): sequences of
//! objects with fixed per-object weights and configurable duplication, plus
//! the exact ground truth (`Σ_{distinct} v_i`) the estimators are judged
//! against.

use super::synthetic::WeightDist;
use crate::util::rng::SplitMix64;
use std::collections::HashMap;

/// A generated stream: `events` in arrival order (with duplicates) and the
/// distinct-object weight table.
#[derive(Debug, Clone)]
pub struct Stream {
    pub events: Vec<(u64, f64)>,
    pub weights: HashMap<u64, f64>,
}

impl Stream {
    /// Exact weighted cardinality `c = Σ_{i∈N} v_i`.
    pub fn weighted_cardinality(&self) -> f64 {
        self.weights.values().sum()
    }

    pub fn distinct(&self) -> usize {
        self.weights.len()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Generate a stream of `n` distinct objects (ids offset by `id_base`),
/// each repeated `1 + Poisson-ish(dup_factor)` times, shuffled.
pub fn generate(
    rng: &mut SplitMix64,
    n: usize,
    dup_factor: f64,
    dist: WeightDist,
    id_base: u64,
) -> Stream {
    let mut weights = HashMap::with_capacity(n);
    let mut events = Vec::new();
    for i in 0..n as u64 {
        let id = id_base + i;
        let w = dist.sample(rng);
        weights.insert(id, w);
        let reps = 1 + (rng.next_exp() * dup_factor).floor() as usize;
        for _ in 0..reps {
            events.push((id, w));
        }
    }
    rng.shuffle(&mut events);
    Stream { events, weights }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_covers_all_objects() {
        let mut r = SplitMix64::new(1);
        let s = generate(&mut r, 500, 1.5, WeightDist::Uniform01, 0);
        assert_eq!(s.distinct(), 500);
        assert!(s.len() >= 500);
        // Every event id is in the weight table with matching weight.
        for &(id, w) in &s.events {
            assert_eq!(s.weights[&id], w);
        }
    }

    #[test]
    fn cardinality_is_weight_sum() {
        let mut r = SplitMix64::new(2);
        let s = generate(&mut r, 100, 0.0, WeightDist::Const(2.0), 10);
        assert!((s.weighted_cardinality() - 200.0).abs() < 1e-9);
        assert_eq!(s.len(), 100); // dup_factor 0 → no duplicates beyond base
    }

    #[test]
    fn duplication_factor_increases_length() {
        let mut r = SplitMix64::new(3);
        let a = generate(&mut r, 300, 0.0, WeightDist::Uniform01, 0);
        let b = generate(&mut r, 300, 3.0, WeightDist::Uniform01, 0);
        assert!(b.len() > a.len() * 2);
        assert_eq!(a.distinct(), b.distinct());
    }
}
