//! Dataset substrate: synthetic weight/vector generators ([`synthetic`]),
//! the Table-1 real-dataset analogs ([`corpus`]), svmlight-format IO
//! ([`svmlight`]) so real datasets can drop in, and duplicate-bearing
//! stream generation ([`stream`]) for Task 2.

pub mod synthetic;
pub mod corpus;
pub mod svmlight;
pub mod stream;
