//! # FastGM — Fast Gumbel-Max Sketch and its Applications
//!
//! A full-system reproduction of the TKDE paper *"Fast Gumbel-Max Sketch and
//! its Applications"* (Zhang et al.), built as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the request-path coordinator: sketch
//!   algorithms ([`sketch`]), estimators ([`estimate`]), LSH index ([`lsh`]),
//!   dataset substrate ([`data`]), sensor-network simulator ([`simnet`]),
//!   and a serving coordinator ([`coordinator`]) with router, batcher,
//!   worker pool and backpressure.
//! * **Layer 2/1 (python/, build-time only)** — a JAX model and Pallas
//!   kernels AOT-lowered to HLO text, loaded on the request path by
//!   [`runtime`] through the PJRT CPU client (`xla` crate, behind the
//!   off-by-default `accel` cargo feature — see README.md).
//!
//! The paper's contribution — computing a k-length Gumbel-Max sketch in
//! `O(k ln k + n⁺)` instead of `O(k n⁺)` — lives in [`sketch::fastgm`] and
//! [`sketch::stream_fastgm`]; every baseline it is evaluated against in the
//! paper is implemented alongside it (see [`exp`] and README.md §Experiments
//! for the experiment index). Large sparse vectors can additionally be
//! sketched across threads with [`sketch::sharded`] — bit-identical to
//! single-threaded FastGM by the paper's §2.3 mergeability.

// Baseline for the CI `cargo clippy --all-targets -- -D warnings` job:
// register-array code indexed by `j` (mirroring the paper's notation) is
// idiomatic throughout, so the style lints below are opted out crate-wide
// rather than per-site. Correctness/perf lints stay enforced.
#![allow(
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod util;
pub mod sketch;
pub mod estimate;
pub mod lsh;
pub mod data;
pub mod simnet;
pub mod coordinator;
pub mod runtime;
pub mod exp;
