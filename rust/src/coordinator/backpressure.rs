//! Bounded admission control in front of the worker pool.
//!
//! Two policies, configured at startup (`server.shed` in the config):
//! * **Block** — producers wait for queue space (lossless ingestion,
//!   the right choice for the data-pipeline use).
//! * **Shed** — over-capacity requests fail fast with an error response
//!   (the serving posture: protect tail latency).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::Arc;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Block,
    Shed,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum AdmitError {
    #[error("queue full, request shed")]
    Shed,
    #[error("queue closed")]
    Closed,
}

/// Sender side of the bounded queue.
pub struct Admission<T> {
    tx: SyncSender<T>,
    policy: Policy,
    shed: Arc<AtomicU64>,
    admitted: Arc<AtomicU64>,
}

impl<T> Clone for Admission<T> {
    fn clone(&self) -> Self {
        Admission {
            tx: self.tx.clone(),
            policy: self.policy,
            shed: self.shed.clone(),
            admitted: self.admitted.clone(),
        }
    }
}

impl<T> Admission<T> {
    pub fn submit(&self, item: T) -> Result<(), AdmitError> {
        match self.policy {
            Policy::Block => {
                self.tx.send(item).map_err(|_| AdmitError::Closed)?;
                self.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Policy::Shed => match self.tx.try_send(item) {
                Ok(()) => {
                    self.admitted.fetch_add(1, Ordering::Relaxed);
                    Ok(())
                }
                Err(TrySendError::Full(_)) => {
                    self.shed.fetch_add(1, Ordering::Relaxed);
                    Err(AdmitError::Shed)
                }
                Err(TrySendError::Disconnected(_)) => Err(AdmitError::Closed),
            },
        }
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn admitted_count(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }
}

/// Build a bounded queue of `capacity` with the given policy.
pub fn bounded<T>(capacity: usize, policy: Policy) -> (Admission<T>, Receiver<T>) {
    let (tx, rx) = std::sync::mpsc::sync_channel(capacity);
    (
        Admission {
            tx,
            policy,
            shed: Arc::new(AtomicU64::new(0)),
            admitted: Arc::new(AtomicU64::new(0)),
        },
        rx,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shed_policy_drops_over_capacity() {
        let (adm, _rx) = bounded::<u32>(2, Policy::Shed);
        assert!(adm.submit(1).is_ok());
        assert!(adm.submit(2).is_ok());
        assert_eq!(adm.submit(3), Err(AdmitError::Shed));
        assert_eq!(adm.shed_count(), 1);
        assert_eq!(adm.admitted_count(), 2);
    }

    #[test]
    fn block_policy_waits_for_space() {
        let (adm, rx) = bounded::<u32>(1, Policy::Block);
        adm.submit(1).unwrap();
        let adm2 = adm.clone();
        let h = std::thread::spawn(move || adm2.submit(2)); // blocks until recv
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1);
        h.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
    }

    #[test]
    fn closed_queue_reports_closed() {
        let (adm, rx) = bounded::<u32>(1, Policy::Shed);
        drop(rx);
        assert_eq!(adm.submit(1), Err(AdmitError::Closed));
    }
}
