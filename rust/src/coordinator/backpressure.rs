//! Bounded admission control in front of the worker pool.
//!
//! Two policies, configured at startup (`server.shed` in the config):
//! * **Block** — producers wait for queue space (lossless ingestion,
//!   the right choice for the data-pipeline use).
//! * **Shed** — over-capacity requests fail fast with an error response
//!   (the serving posture: protect tail latency).
//!
//! The queue is split **per worker**: each consumer owns its own bounded
//! channel ([`WorkerQueue`]) and [`Admission::submit`] dispatches to the
//! *shallowest* queue (round-robin on ties), trying every live queue once
//! before blocking (retry with backoff, never pinned to one queue) or
//! shedding; a queue whose worker died is skipped until none remain. This
//! replaced a single `Mutex<Receiver>` that every worker contended on per
//! dequeue — the convoy the §Perf log flagged once worker counts grew.
//! Trade-off, stated plainly: admission is depth-aware but there is no
//! dequeue-side stealing (a job already enqueued behind a long job waits
//! there even if another worker idles) — the price of per-worker scratch
//! locality. Per-queue depth counters feed the coordinator's
//! `queue_depth` gauge.

use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::Arc;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Block,
    Shed,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum AdmitError {
    #[error("queue full, request shed")]
    Shed,
    #[error("queue closed")]
    Closed,
}

/// Producer side of the per-worker bounded queues.
pub struct Admission<T> {
    senders: Vec<SyncSender<T>>,
    depths: Vec<Arc<AtomicI64>>,
    rr: Arc<AtomicUsize>,
    policy: Policy,
    shed: Arc<AtomicU64>,
    admitted: Arc<AtomicU64>,
}

impl<T> Clone for Admission<T> {
    fn clone(&self) -> Self {
        Admission {
            senders: self.senders.clone(),
            depths: self.depths.clone(),
            rr: self.rr.clone(),
            policy: self.policy,
            shed: self.shed.clone(),
            admitted: self.admitted.clone(),
        }
    }
}

/// Consumer side: one per worker. `recv` maintains the depth gauge.
pub struct WorkerQueue<T> {
    rx: Receiver<T>,
    depth: Arc<AtomicI64>,
}

impl<T> WorkerQueue<T> {
    pub fn recv(&self) -> Result<T, std::sync::mpsc::RecvError> {
        let item = self.rx.recv()?;
        self.depth.fetch_sub(1, Ordering::Relaxed);
        Ok(item)
    }

    /// Items currently enqueued on this worker's queue.
    pub fn depth(&self) -> u64 {
        self.depth.load(Ordering::Relaxed).max(0) as u64
    }
}

impl<T> Admission<T> {
    pub fn submit(&self, item: T) -> Result<(), AdmitError> {
        let rr = self.rr.fetch_add(1, Ordering::Relaxed);
        self.submit_from(item, rr).map_err(|(_, e)| e)
    }

    /// Admit a whole batch with one round-robin advance (the event
    /// transport's admission batching: one readable wakeup drains many
    /// frames, then pays the dispatch bookkeeping once). Items spread
    /// across queues exactly as per-item `submit` would — consecutive
    /// batch slots start their scan at consecutive rotation offsets — and
    /// the policy applies per item. Rejected items come **back** to the
    /// caller (unlike [`submit`], which consumes on error) so their reply
    /// paths can be answered; once `Closed` is seen the rest of the batch
    /// short-circuits to `Closed` without rescanning dead queues.
    pub fn submit_batch(&self, items: Vec<T>) -> Vec<(T, AdmitError)> {
        let rr = self.rr.fetch_add(items.len().max(1), Ordering::Relaxed);
        let mut rejected = Vec::new();
        let mut closed = false;
        for (i, item) in items.into_iter().enumerate() {
            if closed {
                rejected.push((item, AdmitError::Closed));
                continue;
            }
            match self.submit_from(item, rr.wrapping_add(i)) {
                Ok(()) => {}
                Err((item, e)) => {
                    closed = e == AdmitError::Closed;
                    rejected.push((item, e));
                }
            }
        }
        rejected
    }

    /// The dispatch loop shared by [`submit`] and [`submit_batch`]:
    /// shallowest-queue scan from rotation offset `rr`, work-conserving
    /// try-pass, then policy. Errors hand the item back.
    fn submit_from(&self, item: T, rr: usize) -> Result<(), (T, AdmitError)> {
        let n = self.senders.len();
        let mut item = item;
        let mut backoff = std::time::Duration::from_micros(100);
        loop {
            // Start at the shallowest queue (head-of-line mitigation: a
            // short request admitted after a huge one should not wait
            // behind it when another worker's queue is emptier), rotating
            // ties round-robin. Re-picked every pass so a retry reacts to
            // queues that drained while we backed off.
            let mut start = rr % n;
            let mut best = i64::MAX;
            for off in 0..n {
                let i = (rr + off) % n;
                let d = self.depths[i].load(Ordering::Relaxed);
                if d < best {
                    best = d;
                    start = i;
                }
            }
            // Work-conserving pass: try every queue once. A disconnected
            // queue (worker died) is skipped — service degrades to the
            // surviving workers; Closed only when NO queue is left.
            let mut disconnected = 0usize;
            for off in 0..n {
                let i = (start + off) % n;
                // Count before sending so the consumer's decrement can never
                // observe a slot it outran (depth is a high-water estimate).
                self.depths[i].fetch_add(1, Ordering::Relaxed);
                match self.senders[i].try_send(item) {
                    Ok(()) => {
                        self.admitted.fetch_add(1, Ordering::Relaxed);
                        return Ok(());
                    }
                    Err(TrySendError::Full(it)) => {
                        self.depths[i].fetch_sub(1, Ordering::Relaxed);
                        item = it;
                    }
                    Err(TrySendError::Disconnected(it)) => {
                        self.depths[i].fetch_sub(1, Ordering::Relaxed);
                        item = it;
                        disconnected += 1;
                    }
                }
            }
            if disconnected == n {
                return Err((item, AdmitError::Closed));
            }
            // Every live queue full.
            match self.policy {
                Policy::Shed => {
                    self.shed.fetch_add(1, Ordering::Relaxed);
                    return Err((item, AdmitError::Shed));
                }
                // Block must stay work-conserving: rather than pinning a
                // blocking send on one queue (which would keep the producer
                // stuck behind a wedged worker while other workers drain
                // and idle), back off (exponential, capped at 2ms to bound
                // the poll CPU) and re-scan all queues. Admission order
                // among concurrently blocked producers is best-effort, not
                // FIFO — under sustained overload prefer Policy::Shed,
                // which is the serving posture anyway.
                Policy::Block => {
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(std::time::Duration::from_millis(2));
                }
            }
        }
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn admitted_count(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Total enqueued items across all worker queues (the gauge).
    pub fn queue_depth(&self) -> u64 {
        self.depths.iter().map(|d| d.load(Ordering::Relaxed).max(0) as u64).sum()
    }

    pub fn queues(&self) -> usize {
        self.senders.len()
    }
}

/// Build one bounded queue of `capacity` with the given policy.
pub fn bounded<T>(capacity: usize, policy: Policy) -> (Admission<T>, WorkerQueue<T>) {
    let (adm, mut queues) = bounded_per_worker(1, capacity, policy);
    (adm, queues.pop().expect("one queue requested"))
}

/// Build `queues` per-worker bounded queues of `per_queue_capacity` each.
pub fn bounded_per_worker<T>(
    queues: usize,
    per_queue_capacity: usize,
    policy: Policy,
) -> (Admission<T>, Vec<WorkerQueue<T>>) {
    assert!(per_queue_capacity >= 1);
    build_queues(vec![per_queue_capacity; queues], policy)
}

/// Build `queues` per-worker queues whose capacities sum to
/// `total_capacity` (remainder distributed one-per-queue; every queue gets
/// at least 1 slot, so the effective total is `max(total_capacity,
/// queues)`). This keeps a configured admission capacity meaningful when
/// it is split across workers.
pub fn bounded_split<T>(
    queues: usize,
    total_capacity: usize,
    policy: Policy,
) -> (Admission<T>, Vec<WorkerQueue<T>>) {
    assert!(queues >= 1);
    let caps: Vec<usize> = (0..queues)
        .map(|i| (total_capacity / queues + usize::from(i < total_capacity % queues)).max(1))
        .collect();
    build_queues(caps, policy)
}

fn build_queues<T>(caps: Vec<usize>, policy: Policy) -> (Admission<T>, Vec<WorkerQueue<T>>) {
    assert!(!caps.is_empty());
    let mut senders = Vec::with_capacity(caps.len());
    let mut depths = Vec::with_capacity(caps.len());
    let mut rxs = Vec::with_capacity(caps.len());
    for cap in caps {
        let (tx, rx) = std::sync::mpsc::sync_channel(cap);
        let depth = Arc::new(AtomicI64::new(0));
        senders.push(tx);
        depths.push(depth.clone());
        rxs.push(WorkerQueue { rx, depth });
    }
    (
        Admission {
            senders,
            depths,
            rr: Arc::new(AtomicUsize::new(0)),
            policy,
            shed: Arc::new(AtomicU64::new(0)),
            admitted: Arc::new(AtomicU64::new(0)),
        },
        rxs,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shed_policy_drops_over_capacity() {
        let (adm, _rx) = bounded::<u32>(2, Policy::Shed);
        assert!(adm.submit(1).is_ok());
        assert!(adm.submit(2).is_ok());
        assert_eq!(adm.submit(3), Err(AdmitError::Shed));
        assert_eq!(adm.shed_count(), 1);
        assert_eq!(adm.admitted_count(), 2);
        assert_eq!(adm.queue_depth(), 2);
    }

    #[test]
    fn block_policy_waits_for_space() {
        let (adm, rx) = bounded::<u32>(1, Policy::Block);
        adm.submit(1).unwrap();
        let adm2 = adm.clone();
        let h = std::thread::spawn(move || adm2.submit(2)); // blocks until recv
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1);
        h.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
    }

    #[test]
    fn block_policy_admits_via_any_drained_queue() {
        // The blocked producer must not pin itself to one queue: draining
        // ANY queue must unblock it.
        let (adm, rxs) = bounded_per_worker::<u32>(2, 1, Policy::Block);
        adm.submit(1).unwrap();
        adm.submit(2).unwrap();
        let adm2 = adm.clone();
        let h = std::thread::spawn(move || adm2.submit(3));
        std::thread::sleep(std::time::Duration::from_millis(20));
        let _ = rxs[1].recv().unwrap();
        h.join().unwrap().unwrap();
        assert_eq!(adm.queue_depth(), 2);
    }

    #[test]
    fn closed_queue_reports_closed() {
        let (adm, rx) = bounded::<u32>(1, Policy::Shed);
        drop(rx);
        assert_eq!(adm.submit(1), Err(AdmitError::Closed));
    }

    #[test]
    fn dispatch_spreads_and_overflows_to_free_queues() {
        let (adm, rxs) = bounded_per_worker::<u32>(3, 2, Policy::Shed);
        for i in 0..3 {
            adm.submit(i).unwrap();
        }
        // One item per queue: depth ties rotate round-robin, no queue hit
        // twice yet.
        for rx in &rxs {
            assert_eq!(rx.depth(), 1);
        }
        // Fill everything; the work-conserving pass must use every slot
        // before shedding.
        for i in 3..6 {
            adm.submit(i).unwrap();
        }
        assert_eq!(adm.queue_depth(), 6);
        assert_eq!(adm.submit(99), Err(AdmitError::Shed));
        // Draining one queue frees exactly one admission slot.
        let _ = rxs[0].recv().unwrap();
        assert_eq!(adm.queue_depth(), 5);
        assert!(adm.submit(100).is_ok());
    }

    #[test]
    fn dead_queue_is_skipped_until_all_are_dead() {
        // One worker dying must not fail 1/n of submissions: the scan
        // skips its disconnected queue and admits on the survivors.
        let (adm, mut rxs) = bounded_per_worker::<u32>(3, 2, Policy::Shed);
        drop(rxs.remove(1)); // worker 1 "panics"
        for i in 0..4 {
            adm.submit(i).unwrap_or_else(|e| panic!("submit {i} failed: {e}"));
        }
        assert_eq!(adm.queue_depth(), 4); // 2 on each surviving queue
        assert_eq!(adm.submit(99), Err(AdmitError::Shed));
        // Only when every queue is gone does submit report Closed.
        drop(rxs);
        assert_eq!(adm.submit(1), Err(AdmitError::Closed));
    }

    #[test]
    fn split_capacity_sums_to_configured_total() {
        // total 7 over 4 queues → capacities 2,2,2,1: exactly 7 admitted.
        let (adm, _rxs) = bounded_split::<u32>(4, 7, Policy::Shed);
        for i in 0..7 {
            adm.submit(i).unwrap_or_else(|e| panic!("submit {i} failed: {e}"));
        }
        assert_eq!(adm.submit(99), Err(AdmitError::Shed));
        assert_eq!(adm.queue_depth(), 7);
        // Degenerate config: every queue still gets at least one slot.
        let (tiny, _rxs2) = bounded_split::<u32>(4, 1, Policy::Shed);
        for i in 0..4 {
            tiny.submit(i).unwrap();
        }
        assert_eq!(tiny.submit(9), Err(AdmitError::Shed));
    }

    #[test]
    fn shallowest_queue_gets_the_next_job() {
        let (adm, rxs) = bounded_per_worker::<u32>(3, 4, Policy::Shed);
        for i in 0..6 {
            adm.submit(i).unwrap(); // 2 everywhere
        }
        let _ = rxs[2].recv().unwrap(); // queue 2 drains one
        adm.submit(100).unwrap();
        assert_eq!(rxs[2].depth(), 2, "new job must land on the shallowest queue");
    }

    #[test]
    fn batch_submit_spreads_and_returns_rejects_with_their_items() {
        let (adm, rxs) = bounded_per_worker::<u32>(3, 2, Policy::Shed);
        // 6 slots total: a batch of 8 admits 6 and hands back exactly the
        // overflow, items intact.
        let rejected = adm.submit_batch((0..8).collect());
        assert_eq!(rejected.len(), 2);
        for (item, err) in &rejected {
            assert!(*item < 8);
            assert_eq!(*err, AdmitError::Shed);
        }
        assert_eq!(adm.queue_depth(), 6);
        // The batch spread like per-item dispatch: every queue saturated.
        for rx in &rxs {
            assert_eq!(rx.depth(), 2);
        }
        assert_eq!(adm.admitted_count(), 6);
        assert_eq!(adm.shed_count(), 2);
    }

    #[test]
    fn batch_submit_short_circuits_once_closed() {
        let (adm, rx) = bounded::<u32>(4, Policy::Shed);
        drop(rx);
        let rejected = adm.submit_batch(vec![1, 2, 3]);
        assert_eq!(rejected.len(), 3);
        assert!(rejected.iter().all(|(_, e)| *e == AdmitError::Closed));
        // Items come back in order even on the short-circuit path.
        assert_eq!(rejected.iter().map(|(i, _)| *i).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let (adm, _rx) = bounded::<u32>(2, Policy::Shed);
        assert!(adm.submit_batch(Vec::new()).is_empty());
        assert_eq!(adm.queue_depth(), 0);
    }

    #[test]
    fn depth_gauge_tracks_recv() {
        let (adm, rx) = bounded::<u32>(8, Policy::Block);
        for i in 0..5 {
            adm.submit(i).unwrap();
        }
        assert_eq!(adm.queue_depth(), 5);
        for _ in 0..5 {
            rx.recv().unwrap();
        }
        assert_eq!(adm.queue_depth(), 0);
    }
}
