//! Dynamic batching for the dense accelerator path.
//!
//! Dense sketch requests queue here; a dedicated flush thread drains them
//! when either `max_batch` rows are pending or `deadline` has elapsed since
//! the oldest row arrived — the classic serving trade-off between device
//! utilization and tail latency. If no accelerator is configured (or the
//! crate is built without the `accel` feature) the batcher degrades to an
//! immediate CPU P-MinHash path with identical (Direct-family) semantics,
//! so callers never see the difference.

#[cfg(feature = "accel")]
use crate::runtime::accel::DenseSketchAccel;
use crate::sketch::{pminhash::PMinHash, GumbelMaxSketch, Sketcher, SparseVector};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

#[cfg(feature = "accel")]
type Accel = DenseSketchAccel;
/// Uninhabited stand-in: without the `accel` feature there is no
/// accelerator value, only the `None` arm of `Option<Accel>`.
#[cfg(not(feature = "accel"))]
type Accel = std::convert::Infallible;

/// Construct the accelerator inside the flush thread (the PJRT wrapper
/// types are `!Send`). Falls back to `None` — and therefore the CPU path —
/// on load failure or when built without the `accel` feature.
#[cfg(feature = "accel")]
fn load_accel(artifacts_dir: Option<String>) -> Option<Accel> {
    artifacts_dir.and_then(|dir| {
        match crate::runtime::Runtime::load(&dir).and_then(DenseSketchAccel::new) {
            Ok(a) => {
                log::info!(
                    "accelerator online: buckets={:?}",
                    a.buckets().iter().map(|b| (b.b, b.n, b.k)).collect::<Vec<_>>()
                );
                Some(a)
            }
            Err(e) => {
                log::warn!("accelerator disabled: {e}");
                None
            }
        }
    })
}

#[cfg(not(feature = "accel"))]
fn load_accel(artifacts_dir: Option<String>) -> Option<Accel> {
    if let Some(dir) = artifacts_dir {
        log::warn!(
            "artifacts dir '{dir}' configured but this build has no `accel` \
             feature; dense sketches use the CPU fallback"
        );
    }
    None
}

struct Pending {
    weights: Vec<f64>,
    reply: Sender<anyhow::Result<GumbelMaxSketch>>,
    enqueued: Instant,
}

#[derive(Default)]
struct Queue {
    items: Vec<Pending>,
    closed: bool,
}

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub deadline: Duration,
    pub k: usize,
    /// Unified u64 seed; the Direct RNG / Pallas kernel side folds it to 32
    /// bits exactly like [`crate::sketch::fold_id`] folds element ids.
    pub seed: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            deadline: Duration::from_millis(2),
            k: 256,
            seed: 42,
        }
    }
}

pub struct DenseBatcher {
    cfg: BatcherConfig,
    queue: Arc<(Mutex<Queue>, Condvar)>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Batches flushed (metric).
    pub flushes: Arc<std::sync::atomic::AtomicU64>,
}

impl DenseBatcher {
    /// `artifacts_dir`: where to load the accelerator from. The PJRT
    /// wrapper types are `!Send`, so the runtime is constructed *inside*
    /// the flush thread; on load failure the batcher logs and serves the
    /// CPU fallback.
    pub fn new(cfg: BatcherConfig, artifacts_dir: Option<String>) -> DenseBatcher {
        let queue = Arc::new((Mutex::new(Queue::default()), Condvar::new()));
        let flushes = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let q2 = queue.clone();
        let f2 = flushes.clone();
        let handle = std::thread::Builder::new()
            .name("fastgm-batcher".into())
            .spawn(move || flush_loop(cfg, q2, load_accel(artifacts_dir), f2))
            .expect("spawn batcher");
        DenseBatcher { cfg, queue, handle: Some(handle), flushes }
    }

    /// Enqueue a dense row; the receiver resolves when its batch flushes.
    pub fn submit(&self, weights: Vec<f64>) -> Receiver<anyhow::Result<GumbelMaxSketch>> {
        let (tx, rx) = channel();
        let (lock, cv) = &*self.queue;
        let mut q = lock.lock().unwrap();
        q.items.push(Pending { weights, reply: tx, enqueued: Instant::now() });
        cv.notify_one();
        rx
    }

    pub fn k(&self) -> usize {
        self.cfg.k
    }

    pub fn shutdown(mut self) {
        {
            let (lock, cv) = &*self.queue;
            lock.lock().unwrap().closed = true;
            cv.notify_all();
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn flush_loop(
    cfg: BatcherConfig,
    queue: Arc<(Mutex<Queue>, Condvar)>,
    accel: Option<Accel>,
    flushes: Arc<std::sync::atomic::AtomicU64>,
) {
    let (lock, cv) = &*queue;
    loop {
        let batch: Vec<Pending> = {
            let mut q = lock.lock().unwrap();
            loop {
                if q.closed && q.items.is_empty() {
                    return;
                }
                if q.items.len() >= cfg.max_batch {
                    break;
                }
                if let Some(oldest) = q.items.first().map(|p| p.enqueued) {
                    let age = oldest.elapsed();
                    if age >= cfg.deadline || q.closed {
                        break;
                    }
                    let (guard, _timeout) = cv.wait_timeout(q, cfg.deadline - age).unwrap();
                    q = guard;
                } else {
                    q = cv.wait(q).unwrap();
                }
            }
            let take = q.items.len().min(cfg.max_batch);
            q.items.drain(..take).collect()
        };
        if batch.is_empty() {
            continue;
        }
        flushes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        run_batch(&cfg, &accel, batch);
    }
}

fn run_batch(cfg: &BatcherConfig, accel: &Option<Accel>, batch: Vec<Pending>) {
    // Try the accelerator for the whole batch; on any failure (no bucket,
    // runtime error) fall back to the CPU Direct-family path per row.
    #[cfg(feature = "accel")]
    if let Some(acc) = accel {
        let rows: Vec<Vec<f64>> = batch.iter().map(|p| p.weights.clone()).collect();
        match acc.sketch_batch(cfg.seed, &rows, cfg.k) {
            Ok(sketches) => {
                for (p, sk) in batch.into_iter().zip(sketches) {
                    let _ = p.reply.send(Ok(sk));
                }
                return;
            }
            Err(e) => {
                log::debug!("accelerator batch failed ({e}); CPU fallback");
            }
        }
    }
    #[cfg(not(feature = "accel"))]
    let _ = accel;
    let cpu = PMinHash::new(cfg.k, cfg.seed);
    for p in batch {
        let sk = cpu.sketch(&SparseVector::from_dense(&p.weights));
        let _ = p.reply.send(Ok(sk));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn rows(n: usize, len: usize) -> Vec<Vec<f64>> {
        let mut r = SplitMix64::new(1);
        (0..n)
            .map(|_| (0..len).map(|_| if r.next_f64() < 0.3 { 0.0 } else { r.next_f64() }).collect())
            .collect()
    }

    #[test]
    fn cpu_fallback_matches_pminhash() {
        let b = DenseBatcher::new(
            BatcherConfig { max_batch: 4, deadline: Duration::from_millis(1), k: 64, seed: 9 },
            None,
        );
        let data = rows(6, 100);
        let rxs: Vec<_> = data.iter().map(|r| b.submit(r.clone())).collect();
        let cpu = PMinHash::new(64, 9);
        for (row, rx) in data.iter().zip(rxs) {
            let got = rx.recv().unwrap().unwrap();
            let want = cpu.sketch(&SparseVector::from_dense(row));
            assert_eq!(got, want);
        }
        assert!(b.flushes.load(std::sync::atomic::Ordering::Relaxed) >= 2);
        b.shutdown();
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        let b = DenseBatcher::new(
            BatcherConfig {
                max_batch: 1000,
                deadline: Duration::from_millis(5),
                k: 16,
                seed: 1,
            },
            None,
        );
        let rx = b.submit(vec![1.0, 2.0]);
        // Must resolve well before a full batch accumulates.
        let got = rx.recv_timeout(Duration::from_millis(500)).unwrap().unwrap();
        assert_eq!(got.k(), 16);
        b.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let b = DenseBatcher::new(
            BatcherConfig {
                max_batch: 100,
                deadline: Duration::from_secs(10), // long: rely on shutdown
                k: 8,
                seed: 1,
            },
            None,
        );
        let rx = b.submit(vec![0.5]);
        b.shutdown();
        assert!(rx.recv().unwrap().is_ok(), "pending item must still resolve");
    }

    #[test]
    fn accelerated_path_if_artifacts_present() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            eprintln!("skipping accel batcher test: artifacts not built");
            return;
        }
        let b = DenseBatcher::new(
            BatcherConfig { max_batch: 8, deadline: Duration::from_millis(2), k: 256, seed: 3 },
            Some(dir.to_string()),
        );
        let data = rows(10, 512);
        let rxs: Vec<_> = data.iter().map(|r| b.submit(r.clone())).collect();
        let cpu = PMinHash::new(256, 3);
        for (row, rx) in data.iter().zip(rxs) {
            let got = rx.recv().unwrap().unwrap();
            let want = cpu.sketch(&SparseVector::from_dense(row));
            let mism = (0..256).filter(|&j| want.s[j] != got.s[j]).count();
            assert!(mism <= 3, "{mism}/256 registers disagree");
        }
        b.shutdown();
    }
}
