//! Service metrics: counters, gauges and log₂-bucketed latency histograms
//! per operation, snapshotted to JSON for the `metrics` op and the
//! end-to-end examples' reports.
//!
//! Every statically-known counter name is pre-registered in
//! [`HOT_COUNTERS`] as a plain `AtomicU64` cell, so a hot-path bump is one
//! `fetch_add` — no mutex, no allocation, no contention with a concurrent
//! `/metrics` snapshot. Dynamically-named counters (per-engine paths,
//! per-op `ops.*`) fall back to a mutex-guarded map; the snapshot merges
//! both sources into one sorted `counters` object, so the wire output is
//! indistinguishable from the all-map implementation it replaced.

use crate::util::json::Value;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

/// Lock `m`, recovering the data if a panicking holder poisoned it. A
/// worker that panics mid-update must not wedge every later `/metrics`
/// read and counter bump — the maps only ever hold plain counters/gauges,
/// so the pre-panic value is always safe to keep serving.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Latency histogram with power-of-two microsecond buckets (1µs … ~17min).
#[derive(Debug, Default)]
pub struct LatencyHist {
    buckets: [AtomicU64; 31],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl LatencyHist {
    pub fn record(&self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0) as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(30);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from the bucket boundaries (upper edge).
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << (i + 1)) as f64;
            }
        }
        (1u64 << 31) as f64
    }
}

/// Every counter name bumped from a statically-known call site, each
/// backed by a lock-free atomic cell in [`Metrics::hot`]. MUST stay
/// sorted and duplicate-free — lookups binary-search it (enforced by a
/// unit test). Adding a name here is purely an optimization: an unlisted
/// name silently takes the map path with identical semantics.
const HOT_COUNTERS: [&str; 31] = [
    "errors",
    "path.query.merge_cached",
    "path.query.merge_keys",
    "path.query.stream",
    "path.sketch.sharded",
    "path.sketch.single",
    "path.topk.cached",
    "path.topk.probe",
    "path.topk.scan",
    "query.partition",
    "query.sample",
    "sample.draws",
    "scratch.alloc",
    "scratch.reuse",
    "store.delete",
    "store.fetch",
    "store.keys",
    "store.put",
    "store.restore",
    "store.snapshot",
    "store.upsert",
    "stream.merge",
    "topk.candidates",
    "topk.reranked",
    "transport.batches",
    "transport.bytes_in",
    "transport.bytes_out",
    "transport.frames_in",
    "transport.frames_out",
    "transport.obuf.alloc",
    "transport.obuf.reuse",
];

/// Global metrics registry.
#[derive(Default)]
pub struct Metrics {
    /// Parallel to [`HOT_COUNTERS`]: the lock-free cells.
    hot: [AtomicU64; HOT_COUNTERS.len()],
    /// Fallback for dynamically-named counters only — a hot name is never
    /// inserted here, so the snapshot merge can't double-report.
    counters: Mutex<HashMap<String, u64>>,
    gauges: Mutex<HashMap<String, f64>>,
    latencies: Mutex<HashMap<String, std::sync::Arc<LatencyHist>>>,
    started: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { started: Some(Instant::now()), ..Metrics::default() }
    }

    fn hot_idx(name: &str) -> Option<usize> {
        HOT_COUNTERS.binary_search(&name).ok()
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, delta: u64) {
        match Self::hot_idx(name) {
            Some(i) => {
                self.hot[i].fetch_add(delta, Ordering::Relaxed);
            }
            None => {
                *lock_unpoisoned(&self.counters).entry(name.to_string()).or_insert(0) +=
                    delta;
            }
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        match Self::hot_idx(name) {
            Some(i) => self.hot[i].load(Ordering::Relaxed),
            None => lock_unpoisoned(&self.counters).get(name).copied().unwrap_or(0),
        }
    }

    /// Set a last-value-wins gauge (e.g. `queue_depth`).
    pub fn gauge_set(&self, name: &str, value: f64) {
        lock_unpoisoned(&self.gauges).insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> f64 {
        lock_unpoisoned(&self.gauges).get(name).copied().unwrap_or(0.0)
    }

    pub fn hist(&self, name: &str) -> std::sync::Arc<LatencyHist> {
        lock_unpoisoned(&self.latencies)
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Record an operation's latency and bump its counter.
    pub fn observe(&self, op: &str, seconds: f64) {
        self.incr(&format!("ops.{op}"));
        self.hist(&format!("latency.{op}")).record(seconds);
    }

    pub fn snapshot(&self) -> Value {
        let counters = lock_unpoisoned(&self.counters);
        let mut items: Vec<(String, Value)> = counters
            .iter()
            .map(|(k, v)| (k.clone(), Value::num(*v as f64)))
            .collect();
        // Zero-valued hot cells are omitted: before pre-registration a
        // counter only existed once bumped, and the output stays that way.
        for (name, cell) in HOT_COUNTERS.iter().zip(&self.hot) {
            let v = cell.load(Ordering::Relaxed);
            if v > 0 {
                items.push((name.to_string(), Value::num(v as f64)));
            }
        }
        items.sort_by(|a, b| a.0.cmp(&b.0));
        let gauges = lock_unpoisoned(&self.gauges);
        let mut gauge_items: Vec<(String, Value)> =
            gauges.iter().map(|(k, v)| (k.clone(), Value::num(*v))).collect();
        gauge_items.sort_by(|a, b| a.0.cmp(&b.0));
        let lat = lock_unpoisoned(&self.latencies);
        let mut lat_items: Vec<(String, Value)> = lat
            .iter()
            .map(|(k, h)| {
                (
                    k.clone(),
                    Value::obj(vec![
                        ("count", Value::num(h.count() as f64)),
                        ("mean_us", Value::num(h.mean_us())),
                        ("p50_us", Value::num(h.quantile_us(0.5))),
                        ("p99_us", Value::num(h.quantile_us(0.99))),
                    ]),
                )
            })
            .collect();
        lat_items.sort_by(|a, b| a.0.cmp(&b.0));
        Value::obj(vec![
            (
                "uptime_s",
                Value::num(self.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)),
            ),
            ("counters", Value::Obj(items)),
            ("gauges", Value::Obj(gauge_items)),
            ("latency", Value::Obj(lat_items)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("a");
        m.incr("a");
        m.add("b", 5);
        assert_eq!(m.counter("a"), 2);
        assert_eq!(m.counter("b"), 5);
        assert_eq!(m.counter("zzz"), 0);
        // Hot (pre-registered) names behave identically through the same
        // API, atomic cell or not.
        m.incr("store.upsert");
        m.add("store.upsert", 2);
        assert_eq!(m.counter("store.upsert"), 3);
    }

    /// The binary-search table must be sorted and duplicate-free, or hot
    /// lookups silently fall through to the map and split a counter in
    /// two.
    #[test]
    fn hot_counter_table_is_sorted_and_unique() {
        for w in HOT_COUNTERS.windows(2) {
            assert!(w[0] < w[1], "HOT_COUNTERS out of order: {:?} then {:?}", w[0], w[1]);
        }
        for name in HOT_COUNTERS {
            assert_eq!(Metrics::hot_idx(name), HOT_COUNTERS.iter().position(|n| *n == name));
        }
    }

    /// Hot counters never touch the fallback mutex: bumps and reads keep
    /// working with the map lock *held* (no deadlock) and after the map
    /// is poisoned, and the snapshot merges hot and dynamic names into
    /// one sorted object exactly as the all-map implementation did.
    #[test]
    fn hot_counters_bypass_a_held_or_poisoned_map() {
        let m = Metrics::new();
        m.incr("custom.dynamic");
        {
            let _held = m.counters.lock().unwrap();
            m.incr("store.upsert");
            assert_eq!(m.counter("store.upsert"), 1);
        }
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _c = m.counters.lock().unwrap();
            panic!("holder panicked mid-update");
        }));
        assert!(caught.is_err());
        assert!(m.counters.is_poisoned(), "test setup must actually poison");
        m.incr("store.upsert");
        assert_eq!(m.counter("store.upsert"), 2);
        let snap = m.snapshot();
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.get("store.upsert").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(counters.get("custom.dynamic").and_then(|v| v.as_f64()), Some(1.0));
        // Merged output is sorted and never reports untouched hot cells.
        let Value::Obj(items) = counters else { panic!("counters must be an object") };
        assert!(items.windows(2).all(|w| w[0].0 < w[1].0), "unsorted: {items:?}");
        assert!(counters.get("store.delete").is_none(), "zero-valued cell leaked");
    }

    #[test]
    fn histogram_quantiles_are_monotone() {
        let h = LatencyHist::default();
        for us in [1.0, 10.0, 100.0, 1000.0, 10_000.0] {
            for _ in 0..100 {
                h.record(us / 1e6);
            }
        }
        assert_eq!(h.count(), 500);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.9));
        assert!(h.quantile_us(0.9) <= h.quantile_us(0.999));
        assert!(h.mean_us() > 1.0);
    }

    #[test]
    fn gauges_are_last_value_wins() {
        let m = Metrics::new();
        assert_eq!(m.gauge("queue_depth"), 0.0);
        m.gauge_set("queue_depth", 7.0);
        m.gauge_set("queue_depth", 3.0);
        assert_eq!(m.gauge("queue_depth"), 3.0);
        let v = m.snapshot();
        assert_eq!(
            v.get("gauges").unwrap().get("queue_depth").unwrap().as_f64(),
            Some(3.0)
        );
    }

    /// A caught panic while the metrics mutexes are held poisons them;
    /// every later counter bump, gauge update, histogram record and
    /// snapshot must keep working on the pre-panic data instead of
    /// panicking on `PoisonError` and wedging `/metrics` for good.
    #[test]
    fn poisoned_mutexes_recover() {
        let m = Metrics::new();
        m.incr("a");
        m.gauge_set("g", 7.0);
        m.observe("op", 0.001);
        // Poison all three maps at once: hold the raw locks across a panic.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _c = m.counters.lock().unwrap();
            let _g = m.gauges.lock().unwrap();
            let _l = m.latencies.lock().unwrap();
            panic!("worker panicked mid-update");
        }));
        assert!(caught.is_err());
        assert!(m.counters.is_poisoned(), "test setup must actually poison");
        // Every metrics surface still works, with pre-panic data intact.
        m.incr("a");
        m.gauge_set("g", 9.0);
        m.observe("op", 0.002);
        assert_eq!(m.counter("a"), 2);
        assert_eq!(m.gauge("g"), 9.0);
        let snap = m.snapshot();
        assert_eq!(
            snap.get("counters").unwrap().get("ops.op").unwrap().as_f64(),
            Some(2.0)
        );
        assert_eq!(
            snap.get("latency").unwrap().get("latency.op").unwrap().get("count").unwrap().as_f64(),
            Some(2.0)
        );
    }

    #[test]
    fn snapshot_is_json_object() {
        let m = Metrics::new();
        m.observe("sketch", 0.001);
        let v = m.snapshot();
        assert!(v.get("counters").unwrap().get("ops.sketch").is_some());
        let lat = v.get("latency").unwrap().get("latency.sketch").unwrap();
        assert_eq!(lat.get("count").unwrap().as_f64(), Some(1.0));
        // Round-trips through text.
        let text = v.to_string();
        assert!(crate::util::json::parse(&text).is_ok());
    }
}
