//! CPU worker pool: N threads, each draining its **own** bounded queue
//! (shallowest-queue dispatch, see [`super::backpressure`]) and running the
//! coordinator's request handler with a per-worker [`WorkerContext`] —
//! most importantly a long-lived [`SketchScratch`] so the sketch hot path
//! allocates nothing per request. Replies travel over one-shot mpsc
//! channels so callers can be synchronous (server connections) or
//! fire-and-forget (benchmarks).

use super::backpressure::{bounded_split, Admission, Policy};
use super::protocol::{Request, Response};
use crate::sketch::SketchScratch;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Where a finished job's response goes: a one-shot channel for
/// synchronous callers, or a callback for the event transport (which
/// encodes the response on the worker thread and hands the bytes to its
/// completion pipe — no parked thread per in-flight request).
pub enum Reply {
    Channel(Sender<Response>),
    Callback(Box<dyn FnOnce(Response) + Send>),
}

impl Reply {
    pub fn send(self, resp: Response) {
        match self {
            // The caller may have gone; a dead channel is not an error.
            Reply::Channel(tx) => drop(tx.send(resp)),
            Reply::Callback(f) => f(resp),
        }
    }
}

/// A queued unit of work.
pub struct Job {
    pub request: Request,
    pub reply: Reply,
}

/// Per-worker state threaded into every handler invocation.
pub struct WorkerContext {
    pub worker_id: usize,
    /// Reusable sketch arena — the zero-allocation engine's working memory.
    pub scratch: SketchScratch,
    /// Jobs completed by this worker.
    pub jobs_done: u64,
}

impl WorkerContext {
    pub fn new(worker_id: usize) -> WorkerContext {
        WorkerContext { worker_id, scratch: SketchScratch::new(), jobs_done: 0 }
    }
}

/// Request handler: runs on a worker thread with that worker's context.
pub type Handler = Arc<dyn Fn(Request, &mut WorkerContext) -> Response + Send + Sync>;

pub struct WorkerPool {
    admission: Admission<Job>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads, each owning one queue slice of
    /// `queue_capacity` and one [`WorkerContext`]. The configured capacity
    /// is split across the worker queues (remainder distributed; every
    /// worker keeps at least one slot, so the effective total is
    /// `max(queue_capacity, workers)`).
    pub fn new(
        workers: usize,
        queue_capacity: usize,
        policy: Policy,
        handler: Handler,
    ) -> WorkerPool {
        assert!(workers >= 1);
        let (admission, queues) = bounded_split::<Job>(workers, queue_capacity, policy);
        let mut handles = Vec::with_capacity(workers);
        for (w, queue) in queues.into_iter().enumerate() {
            let handler = handler.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fastgm-worker-{w}"))
                    .spawn(move || {
                        let mut ctx = WorkerContext::new(w);
                        loop {
                            let Ok(job) = queue.recv() else { return };
                            let resp = handler(job.request, &mut ctx);
                            ctx.jobs_done += 1;
                            job.reply.send(resp);
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        WorkerPool { admission, handles }
    }

    /// Submit a request; returns the reply receiver. A `Shed` error is
    /// converted to an immediate error response on the channel.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (tx, rx) = channel();
        let job = Job { request, reply: Reply::Channel(tx) };
        if let Err(e) = self.admission.submit(job) {
            // Channel tx moved into job; rebuild a reply channel.
            let (tx2, rx2) = channel();
            let _ = tx2.send(Response::err(e));
            return rx2;
        }
        rx
    }

    /// Admit a batch of pre-built jobs in one pass (the event transport's
    /// admission batching). Rejected jobs are answered immediately through
    /// their own reply path with an error response — the caller never has
    /// to track which slots made it in.
    pub fn submit_batch(&self, jobs: Vec<Job>) {
        for (job, e) in self.admission.submit_batch(jobs) {
            job.reply.send(Response::err(e));
        }
    }

    /// Convenience: submit and wait.
    pub fn call(&self, request: Request) -> Response {
        match self.submit(request).recv() {
            Ok(r) => r,
            Err(_) => Response::err("worker pool shut down"),
        }
    }

    pub fn shed_count(&self) -> u64 {
        self.admission.shed_count()
    }

    /// Jobs currently enqueued across all worker queues (the gauge the
    /// metrics snapshot reports).
    pub fn queue_depth(&self) -> u64 {
        self.admission.queue_depth()
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Drop the queues and join all workers.
    pub fn shutdown(self) {
        drop(self.admission);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_pool(workers: usize, cap: usize, policy: Policy) -> WorkerPool {
        WorkerPool::new(
            workers,
            cap,
            policy,
            Arc::new(|req: Request, _ctx: &mut WorkerContext| Response::Ack {
                info: req.op().to_string(),
            }),
        )
    }

    #[test]
    fn round_trips_requests() {
        let pool = echo_pool(2, 16, Policy::Block);
        let r = pool.call(Request::Ping);
        assert_eq!(r, Response::Ack { info: "ping".into() });
        pool.shutdown();
    }

    #[test]
    fn parallel_submissions_all_complete() {
        let pool = Arc::new(echo_pool(4, 64, Policy::Block));
        let mut rxs = Vec::new();
        for _ in 0..100 {
            rxs.push(pool.submit(Request::Metrics));
        }
        for rx in rxs {
            assert!(matches!(rx.recv().unwrap(), Response::Ack { .. }));
        }
    }

    #[test]
    fn shed_under_pressure_returns_error() {
        // One slow worker, capacity 1, shed policy: flooding must shed.
        let pool = WorkerPool::new(
            1,
            1,
            Policy::Shed,
            Arc::new(|_req, _ctx: &mut WorkerContext| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                Response::Pong
            }),
        );
        let mut shed_seen = false;
        let mut rxs = Vec::new();
        for _ in 0..20 {
            rxs.push(pool.submit(Request::Ping));
        }
        for rx in rxs {
            if matches!(rx.recv().unwrap(), Response::Error { .. }) {
                shed_seen = true;
            }
        }
        assert!(shed_seen, "expected at least one shed response");
        assert!(pool.shed_count() > 0);
        pool.shutdown();
    }

    #[test]
    fn per_worker_context_persists_across_jobs() {
        // The context's job counter must be per-thread and monotone: with
        // one worker, N jobs → jobs_done observed as 0..N-1 in order.
        let pool = WorkerPool::new(
            1,
            16,
            Policy::Block,
            Arc::new(|_req, ctx: &mut WorkerContext| Response::Ack {
                info: format!("{}:{}", ctx.worker_id, ctx.jobs_done),
            }),
        );
        for i in 0..5 {
            let r = pool.call(Request::Ping);
            assert_eq!(r, Response::Ack { info: format!("0:{i}") });
        }
        pool.shutdown();
    }

    #[test]
    fn queue_depth_drains_to_zero() {
        let pool = echo_pool(3, 12, Policy::Block);
        let rxs: Vec<_> = (0..12).map(|_| pool.submit(Request::Ping)).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        // All replies received → every job dequeued.
        assert_eq!(pool.queue_depth(), 0);
        pool.shutdown();
    }

    #[test]
    fn batch_submit_answers_every_job_through_its_callback() {
        let pool = echo_pool(2, 32, Policy::Block);
        let (tx, rx) = channel();
        let jobs: Vec<Job> = (0..10)
            .map(|i| {
                let tx = tx.clone();
                Job {
                    request: Request::Ping,
                    reply: Reply::Callback(Box::new(move |resp| {
                        tx.send((i, resp)).unwrap();
                    })),
                }
            })
            .collect();
        pool.submit_batch(jobs);
        let mut seen = vec![false; 10];
        for _ in 0..10 {
            let (i, resp) = rx.recv().unwrap();
            assert_eq!(resp, Response::Ack { info: "ping".into() });
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
        pool.shutdown();
    }

    #[test]
    fn batch_rejects_are_answered_not_dropped() {
        // Capacity 1, shed policy, slow worker: most of a 12-job batch
        // must come back as error responses — every callback still fires.
        let pool = WorkerPool::new(
            1,
            1,
            Policy::Shed,
            Arc::new(|_req, _ctx: &mut WorkerContext| {
                std::thread::sleep(std::time::Duration::from_millis(20));
                Response::Pong
            }),
        );
        let (tx, rx) = channel();
        let jobs: Vec<Job> = (0..12)
            .map(|_| {
                let tx = tx.clone();
                Job {
                    request: Request::Ping,
                    reply: Reply::Callback(Box::new(move |resp| {
                        tx.send(resp).unwrap();
                    })),
                }
            })
            .collect();
        pool.submit_batch(jobs);
        let mut shed = 0;
        for _ in 0..12 {
            if matches!(rx.recv().unwrap(), Response::Error { .. }) {
                shed += 1;
            }
        }
        assert!(shed > 0, "expected shed errors from an over-capacity batch");
        pool.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = echo_pool(3, 8, Policy::Block);
        pool.call(Request::Ping);
        pool.shutdown(); // must not hang
    }
}
