//! CPU worker pool: N threads draining the bounded admission queue and
//! running the coordinator's request handler. Replies travel over one-shot
//! mpsc channels so callers can be synchronous (server connections) or
//! fire-and-forget (benchmarks).

use super::backpressure::{bounded, Admission, Policy};
use super::protocol::{Request, Response};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A queued unit of work.
pub struct Job {
    pub request: Request,
    pub reply: Sender<Response>,
}

pub struct WorkerPool {
    admission: Admission<Job>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads, each calling `handler` per job.
    pub fn new(
        workers: usize,
        queue_capacity: usize,
        policy: Policy,
        handler: Arc<dyn Fn(Request) -> Response + Send + Sync>,
    ) -> WorkerPool {
        assert!(workers >= 1);
        let (admission, rx) = bounded::<Job>(queue_capacity, policy);
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = rx.clone();
            let handler = handler.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fastgm-worker-{w}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        let Ok(job) = job else { return };
                        let resp = handler(job.request);
                        let _ = job.reply.send(resp); // caller may have gone
                    })
                    .expect("spawn worker"),
            );
        }
        WorkerPool { admission, handles }
    }

    /// Submit a request; returns the reply receiver. A `Shed` error is
    /// converted to an immediate error response on the channel.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (tx, rx) = channel();
        let job = Job { request, reply: tx };
        if let Err(e) = self.admission.submit(job) {
            // Channel tx moved into job; rebuild a reply channel.
            let (tx2, rx2) = channel();
            let _ = tx2.send(Response::err(e));
            return rx2;
        }
        rx
    }

    /// Convenience: submit and wait.
    pub fn call(&self, request: Request) -> Response {
        match self.submit(request).recv() {
            Ok(r) => r,
            Err(_) => Response::err("worker pool shut down"),
        }
    }

    pub fn shed_count(&self) -> u64 {
        self.admission.shed_count()
    }

    /// Drop the queue and join all workers.
    pub fn shutdown(self) {
        drop(self.admission);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_pool(workers: usize, cap: usize, policy: Policy) -> WorkerPool {
        WorkerPool::new(
            workers,
            cap,
            policy,
            Arc::new(|req: Request| Response::Ack { info: req.op().to_string() }),
        )
    }

    #[test]
    fn round_trips_requests() {
        let pool = echo_pool(2, 16, Policy::Block);
        let r = pool.call(Request::Ping);
        assert_eq!(r, Response::Ack { info: "ping".into() });
        pool.shutdown();
    }

    #[test]
    fn parallel_submissions_all_complete() {
        let pool = Arc::new(echo_pool(4, 64, Policy::Block));
        let mut rxs = Vec::new();
        for _ in 0..100 {
            rxs.push(pool.submit(Request::Metrics));
        }
        for rx in rxs {
            assert!(matches!(rx.recv().unwrap(), Response::Ack { .. }));
        }
    }

    #[test]
    fn shed_under_pressure_returns_error() {
        // One slow worker, capacity 1, shed policy: flooding must shed.
        let pool = WorkerPool::new(
            1,
            1,
            Policy::Shed,
            Arc::new(|_req| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                Response::Pong
            }),
        );
        let mut shed_seen = false;
        let mut rxs = Vec::new();
        for _ in 0..20 {
            rxs.push(pool.submit(Request::Ping));
        }
        for rx in rxs {
            if matches!(rx.recv().unwrap(), Response::Error { .. }) {
                shed_seen = true;
            }
        }
        assert!(shed_seen, "expected at least one shed response");
        assert!(pool.shed_count() > 0);
        pool.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = echo_pool(3, 8, Policy::Block);
        pool.call(Request::Ping);
        pool.shutdown(); // must not hang
    }
}
