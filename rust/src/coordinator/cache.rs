//! Versioned read-path cache: byte-bounded, sharded LRU maps for answers
//! the store can prove are still fresh.
//!
//! The store's write metadata makes *exact* invalidation possible without
//! any write-through coupling:
//!
//! * **Merged unions** (the `sample`/`partition` key-set target) are keyed
//!   by the normalized (sorted, deduped) key set and tagged with the
//!   per-key version vector `SketchStore::merge_keys` already returns,
//!   plus the store's version-drop generation. A hit is served only after
//!   `SketchStore::members_match` re-proves every `(key, version)` against
//!   the live store — so a cached union is *bit-identical to a fresh §2.3
//!   merge by construction* (§2.3 merge is idempotent and order-free: ties
//!   only occur when the same element id drew the same `(y, s)` pair in
//!   both inputs, so register-wise min is associative/commutative down to
//!   the bit level).
//! * **Top-k rankings** are keyed by a digest of the query registers +
//!   limit and tagged with the per-shard store generation vector; any
//!   write anywhere invalidates — the right granularity for a query that
//!   ranked every entry.
//! * The cluster client reuses [`ByteLruCache`] for its `(key, version)`
//!   gather-blob cache (versioned codec blobs are immutable, so equality
//!   of version is equality of registers).
//!
//! Bounding is by *bytes*, not entries: register payloads dominate
//! (`k × 16` bytes per sketch), so an entry's cost is its estimated heap
//! footprint and eviction walks least-recently-used entries until the new
//! entry fits. Entries whose validation fails are removed eagerly
//! (`stale_drop`) — a stale entry can never become valid again, because
//! versions and generations only move forward.
//!
//! Concurrency: the map is sharded by key digest; each shard is a plain
//! `Mutex`. Validators run under the probed shard's mutex and may take
//! store *read* locks (`members_match`/`generations`), so cache → store is
//! a legal lock order; the store never touches the cache, so the combined
//! ordering stays acyclic — no deadlock is possible. LRU
//! recency is a per-entry tick from one shared counter; eviction scans its
//! shard for the minimum tick, which is O(shard entries) but only runs on
//! insert overflow — hits stay O(1).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Value;

/// One shard's entries: key → (value, byte cost, recency tick).
struct CacheShard<V> {
    entries: HashMap<u64, (V, usize, u64)>,
    bytes: usize,
}

/// Monotonic counters every probe/insert/evict updates; snapshotted into
/// `store_stats`/`metrics` and the `cache.*` counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub stale_drops: u64,
    pub bytes: u64,
    pub entries: u64,
    pub max_bytes: u64,
}

/// A byte-bounded sharded LRU keyed by a caller-computed 64-bit digest.
///
/// `get_validated` is the probe-then-prove read: the stored value is
/// handed to the caller's validator (which typically re-checks versions
/// against the live store) before it is ever returned; an invalid entry is
/// removed on the spot (it can never become valid again).
pub struct ByteLruCache<V> {
    shards: Vec<Mutex<CacheShard<V>>>,
    max_bytes_per_shard: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    stale_drops: AtomicU64,
}

impl<V: Clone> ByteLruCache<V> {
    /// `max_bytes` is the total budget, split evenly across `shards`
    /// (each at least 1 byte so a zero budget still constructs — it just
    /// refuses every insert).
    pub fn new(max_bytes: usize, shards: usize) -> ByteLruCache<V> {
        let shards = shards.max(1);
        ByteLruCache {
            max_bytes_per_shard: max_bytes / shards,
            shards: (0..shards)
                .map(|_| Mutex::new(CacheShard { entries: HashMap::new(), bytes: 0 }))
                .collect(),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            stale_drops: AtomicU64::new(0),
        }
    }

    fn shard_of(&self, key: u64) -> usize {
        (key % self.shards.len() as u64) as usize
    }

    /// Lock a shard, recovering from poison: cache state is only ever a
    /// performance hint, so a panic mid-update at worst strands some
    /// entries that validation or eviction will clean up.
    fn lock(&self, idx: usize) -> std::sync::MutexGuard<'_, CacheShard<V>> {
        self.shards[idx].lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Probe `key`; a present entry is returned only if `valid` accepts
    /// it. Present-but-invalid entries are removed and counted as
    /// `stale_drop` (which also counts as a miss: the caller must
    /// recompute either way).
    pub fn get_validated(&self, key: u64, valid: impl FnOnce(&V) -> bool) -> Option<V> {
        let idx = self.shard_of(key);
        let mut shard = self.lock(idx);
        let hit = match shard.entries.get(&key) {
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            Some((value, _, _)) => valid(value).then(|| value.clone()),
        };
        match hit {
            Some(out) => {
                let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
                shard.entries.get_mut(&key).expect("entry just read").2 = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(out)
            }
            None => {
                let (_, cost, _) = shard.entries.remove(&key).expect("entry just read");
                shard.bytes -= cost;
                self.stale_drops.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Install `key → value` at `cost` bytes, evicting least-recently-used
    /// entries until it fits. A value larger than the whole shard budget
    /// is refused (returns false) rather than wiping the shard for an
    /// entry that could never share it.
    pub fn insert(&self, key: u64, value: V, cost: usize) -> bool {
        if cost > self.max_bytes_per_shard {
            return false;
        }
        let idx = self.shard_of(key);
        let mut shard = self.lock(idx);
        if let Some((_, old_cost, _)) = shard.entries.remove(&key) {
            shard.bytes -= old_cost;
        }
        while shard.bytes + cost > self.max_bytes_per_shard {
            let Some((&lru, _)) =
                shard.entries.iter().min_by_key(|(_, (_, _, tick))| *tick)
            else {
                break;
            };
            let (_, evicted_cost, _) = shard.entries.remove(&lru).expect("lru key just found");
            shard.bytes -= evicted_cost;
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        shard.entries.insert(key, (value, cost, tick));
        shard.bytes += cost;
        true
    }

    /// Drop every entry (restore hygiene — validation would reject them
    /// all anyway, this just frees the memory now).
    pub fn clear(&self) {
        for idx in 0..self.shards.len() {
            let mut shard = self.lock(idx);
            shard.entries.clear();
            shard.bytes = 0;
        }
    }

    pub fn stats(&self) -> CacheStats {
        let mut bytes = 0u64;
        let mut entries = 0u64;
        for idx in 0..self.shards.len() {
            let shard = self.lock(idx);
            bytes += shard.bytes as u64;
            entries += shard.entries.len() as u64;
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            stale_drops: self.stale_drops.load(Ordering::Relaxed),
            bytes,
            entries,
            max_bytes: (self.max_bytes_per_shard * self.shards.len()) as u64,
        }
    }
}

/// Merge two subsystem stat snapshots (node-side merge + top-k caches are
/// reported as one `cache` object).
pub fn combine(a: CacheStats, b: CacheStats) -> CacheStats {
    CacheStats {
        hits: a.hits + b.hits,
        misses: a.misses + b.misses,
        evictions: a.evictions + b.evictions,
        stale_drops: a.stale_drops + b.stale_drops,
        bytes: a.bytes + b.bytes,
        entries: a.entries + b.entries,
        max_bytes: a.max_bytes + b.max_bytes,
    }
}

/// The `cache` JSON object surfaced through `store_stats` and `metrics`.
pub fn stats_value(enabled: bool, s: CacheStats) -> Value {
    Value::obj(vec![
        ("enabled", Value::Bool(enabled)),
        ("hits", Value::num(s.hits as f64)),
        ("misses", Value::num(s.misses as f64)),
        ("evictions", Value::num(s.evictions as f64)),
        ("stale_drops", Value::num(s.stale_drops as f64)),
        ("bytes", Value::num(s.bytes as f64)),
        ("entries", Value::num(s.entries as f64)),
        ("max_bytes", Value::num(s.max_bytes as f64)),
    ])
}

/// FNV-1a over a byte stream — the cache's key digest (collisions are a
/// correctness non-issue for the merge cache only because the validator
/// re-proves the member versions; the top-k cache additionally folds the
/// full register payload in, making a colliding *different* query
/// astronomically unlikely and bounded to serving a validly-tagged answer
/// for the wrong query never — the digest covers every register bit).
pub struct Digest(u64);

impl Digest {
    pub fn new() -> Digest {
        Digest(0xcbf29ce484222325)
    }

    pub fn u64(&mut self, x: u64) -> &mut Digest {
        for b in x.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
        self
    }

    pub fn f64(&mut self, x: f64) -> &mut Digest {
        self.u64(x.to_bits())
    }

    pub fn str(&mut self, s: &str) -> &mut Digest {
        for &b in s.as_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
        // Length-delimit so ["ab","c"] and ["a","bc"] digest differently.
        self.u64(s.len() as u64)
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Digest {
    fn default() -> Digest {
        Digest::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_validate_and_misses_count() {
        let c: ByteLruCache<u32> = ByteLruCache::new(1024, 2);
        assert_eq!(c.get_validated(7, |_| true), None);
        assert!(c.insert(7, 42, 100));
        assert_eq!(c.get_validated(7, |_| true), Some(42));
        // A failed validation drops the entry (it can never re-validate).
        assert_eq!(c.get_validated(7, |_| false), None);
        assert_eq!(c.get_validated(7, |_| true), None, "stale entry was removed");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.stale_drops), (1, 3, 1));
        assert_eq!((s.bytes, s.entries), (0, 0));
    }

    /// The byte bound holds at every step, and eviction removes the
    /// least-recently-used entry first.
    #[test]
    fn eviction_is_lru_and_respects_the_byte_bound() {
        // One shard so the LRU order is globally observable.
        let c: ByteLruCache<u32> = ByteLruCache::new(300, 1);
        assert!(c.insert(1, 10, 100));
        assert!(c.insert(2, 20, 100));
        assert!(c.insert(3, 30, 100));
        assert!(c.stats().bytes <= 300);
        // Touch 1 so 2 becomes the LRU, then overflow.
        assert_eq!(c.get_validated(1, |_| true), Some(10));
        assert!(c.insert(4, 40, 100));
        let s = c.stats();
        assert!(s.bytes <= 300, "byte bound violated: {}", s.bytes);
        assert_eq!(s.evictions, 1);
        assert_eq!(c.get_validated(2, |_| true), None, "LRU entry must be the one evicted");
        assert_eq!(c.get_validated(1, |_| true), Some(10));
        assert_eq!(c.get_validated(3, |_| true), Some(30));
        assert_eq!(c.get_validated(4, |_| true), Some(40));
        // An entry bigger than the whole budget is refused outright.
        assert!(!c.insert(9, 90, 301));
        assert!(c.stats().bytes <= 300);
        // Re-inserting an existing key replaces cost, not duplicates it.
        assert!(c.insert(4, 41, 120));
        assert!(c.stats().bytes <= 300);
        assert_eq!(c.get_validated(4, |_| true), Some(41));
    }

    #[test]
    fn zero_budget_disables_without_erroring() {
        let c: ByteLruCache<u32> = ByteLruCache::new(0, 4);
        assert!(!c.insert(1, 10, 1));
        assert_eq!(c.get_validated(1, |_| true), None);
        assert_eq!(c.stats().max_bytes, 0);
    }

    #[test]
    fn clear_empties_every_shard() {
        let c: ByteLruCache<u32> = ByteLruCache::new(4096, 4);
        for i in 0..32 {
            assert!(c.insert(i, i as u32, 8));
        }
        assert_eq!(c.stats().entries, 32);
        c.clear();
        let s = c.stats();
        assert_eq!((s.bytes, s.entries), (0, 0));
    }

    #[test]
    fn digest_is_order_and_boundary_sensitive() {
        let mut a = Digest::new();
        a.str("ab").str("c");
        let mut b = Digest::new();
        b.str("a").str("bc");
        assert_ne!(a.finish(), b.finish());
        let mut c = Digest::new();
        c.u64(1).u64(2);
        let mut d = Digest::new();
        d.u64(2).u64(1);
        assert_ne!(c.finish(), d.finish());
        let mut e = Digest::new();
        e.f64(1.5).u64(7);
        let mut f = Digest::new();
        f.f64(1.5).u64(7);
        assert_eq!(e.finish(), f.finish());
    }

    #[test]
    fn stats_value_is_a_json_object_with_every_field() {
        let v = stats_value(true, CacheStats { hits: 3, misses: 1, ..Default::default() });
        for field in
            ["enabled", "hits", "misses", "evictions", "stale_drops", "bytes", "entries", "max_bytes"]
        {
            assert!(v.get(field).is_some(), "missing cache stats field '{field}'");
        }
        assert_eq!(v.get("hits").unwrap().as_f64(), Some(3.0));
    }
}
