//! Blocking TCP client for the JSON-lines protocol — used by the CLI
//! (`fastgm client`), the examples and the load generator in
//! `examples/serve_e2e.rs`.

use super::protocol::{self, Request, Response};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("cannot connect to '{addr}': {e}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    /// Send one request and wait for its response line.
    pub fn call(&mut self, req: &Request) -> anyhow::Result<Response> {
        let line = protocol::encode_line(&req.to_json());
        self.writer.write_all(line.as_bytes())?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        protocol::decode_response(&reply)
    }

    /// Pipeline many requests, then collect all responses (cuts RTT for
    /// bulk ingestion).
    pub fn call_pipelined(&mut self, reqs: &[Request]) -> anyhow::Result<Vec<Response>> {
        let mut buf = String::new();
        for r in reqs {
            buf.push_str(&protocol::encode_line(&r.to_json()));
        }
        self.writer.write_all(buf.as_bytes())?;
        let mut out = Vec::with_capacity(reqs.len());
        for _ in reqs {
            let mut reply = String::new();
            let n = self.reader.read_line(&mut reply)?;
            anyhow::ensure!(n > 0, "server closed mid-pipeline");
            out.push(protocol::decode_response(&reply)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::Server;
    use crate::coordinator::service::{Coordinator, CoordinatorConfig};
    use std::sync::Arc;

    #[test]
    fn pipelined_requests_preserve_order() {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig { k: 32, workers: 2, ..Default::default() })
                .unwrap(),
        );
        let server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let reqs: Vec<Request> = (0..10u64)
            .map(|i| Request::Push { stream: "p".into(), items: vec![(i, 1.0)] })
            .collect();
        let resps = client.call_pipelined(&reqs).unwrap();
        assert_eq!(resps.len(), 10);
        for (i, r) in resps.iter().enumerate() {
            let Response::Ack { info } = r else { panic!("expected ack") };
            assert!(
                info.contains(&format!("processed {}", i + 1)),
                "response {i} out of order: {info}"
            );
        }
        server.stop();
    }

    #[test]
    fn connect_failure_is_clean_error() {
        assert!(Client::connect("127.0.0.1:1").is_err());
    }
}
