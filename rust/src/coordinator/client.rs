//! Blocking TCP client for the JSON-lines protocol — used by the CLI
//! (`fastgm client` / `store` / `topk` / `snapshot`), the examples and the
//! load generators in `examples/serve_e2e.rs` and
//! `examples/similarity_serve.rs`. The typed helpers below unwrap the
//! expected response variant and turn server-side `error` replies into
//! `Err`, so callers don't re-match every response.

use super::protocol::{self, Request, Response};
use crate::sketch::SparseVector;
use crate::util::json::Value;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("cannot connect to '{addr}': {e}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    /// Send one request and wait for its response line.
    pub fn call(&mut self, req: &Request) -> anyhow::Result<Response> {
        let line = protocol::encode_line(&req.to_json());
        self.writer.write_all(line.as_bytes())?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        protocol::decode_response(&reply)
    }

    /// Pipeline many requests, then collect all responses (cuts RTT for
    /// bulk ingestion).
    pub fn call_pipelined(&mut self, reqs: &[Request]) -> anyhow::Result<Vec<Response>> {
        let mut buf = String::new();
        for r in reqs {
            buf.push_str(&protocol::encode_line(&r.to_json()));
        }
        self.writer.write_all(buf.as_bytes())?;
        let mut out = Vec::with_capacity(reqs.len());
        for _ in reqs {
            let mut reply = String::new();
            let n = self.reader.read_line(&mut reply)?;
            anyhow::ensure!(n > 0, "server closed mid-pipeline");
            out.push(protocol::decode_response(&reply)?);
        }
        Ok(out)
    }

    /// Call and expect an `ack`; server-side errors become `Err`.
    fn call_ack(&mut self, req: &Request) -> anyhow::Result<String> {
        match self.call(req)? {
            Response::Ack { info } => Ok(info),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected ack, got {other:?}"),
        }
    }

    // -- typed keyed-store helpers ---------------------------------------

    /// Upsert `vector` into the keyed store under `key`.
    pub fn upsert(&mut self, key: &str, vector: SparseVector) -> anyhow::Result<String> {
        self.call_ack(&Request::Upsert { key: key.to_string(), vector })
    }

    /// Delete `key` from the keyed store (idempotent).
    pub fn delete(&mut self, key: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::Delete { key: key.to_string() })
    }

    /// Top-`limit` store entries most similar to `vector`.
    pub fn topk(
        &mut self,
        vector: SparseVector,
        limit: usize,
    ) -> anyhow::Result<Vec<(String, f64)>> {
        match self.call(&Request::TopK { vector, limit })? {
            Response::TopK { hits } => Ok(hits),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected topk, got {other:?}"),
        }
    }

    /// Keyed-store statistics (size, shard occupancy, index shape).
    pub fn store_stats(&mut self) -> anyhow::Result<Value> {
        match self.call(&Request::StoreStats)? {
            Response::Stats { stats } => Ok(stats),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected stats, got {other:?}"),
        }
    }

    /// Freeze the server's keyed store to `path` (server-side file).
    pub fn snapshot(&mut self, path: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::Snapshot { path: path.to_string() })
    }

    /// Replace the server's keyed store from the snapshot at `path`.
    pub fn restore(&mut self, path: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::Restore { path: path.to_string() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::Server;
    use crate::coordinator::service::{Coordinator, CoordinatorConfig};
    use std::sync::Arc;

    #[test]
    fn pipelined_requests_preserve_order() {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig { k: 32, workers: 2, ..Default::default() })
                .unwrap(),
        );
        let server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let reqs: Vec<Request> = (0..10u64)
            .map(|i| Request::Push { stream: "p".into(), items: vec![(i, 1.0)] })
            .collect();
        let resps = client.call_pipelined(&reqs).unwrap();
        assert_eq!(resps.len(), 10);
        for (i, r) in resps.iter().enumerate() {
            let Response::Ack { info } = r else { panic!("expected ack") };
            assert!(
                info.contains(&format!("processed {}", i + 1)),
                "response {i} out of order: {info}"
            );
        }
        server.stop();
    }

    #[test]
    fn connect_failure_is_clean_error() {
        assert!(Client::connect("127.0.0.1:1").is_err());
    }

    #[test]
    fn typed_store_helpers_roundtrip() {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig { k: 32, workers: 2, ..Default::default() })
                .unwrap(),
        );
        let server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let v = SparseVector::new(vec![1, 2], vec![1.0, 0.5]);
        assert!(client.upsert("a", v.clone()).unwrap().contains("upserted"));
        let hits = client.topk(v, 1).unwrap();
        assert_eq!(hits[0].0, "a");
        let stats = client.store_stats().unwrap();
        assert_eq!(stats.get("size").and_then(|x| x.as_f64()), Some(1.0));
        assert!(client.delete("a").unwrap().contains("deleted"));
        // Server-side error replies surface as Err, not as a panic.
        assert!(client.restore("/no/such/file.fgms").is_err());
        server.stop();
    }
}
