//! Blocking TCP client for both wire protocols — used by the CLI
//! (`fastgm client` / `store` / `topk` / `snapshot`), the examples and the
//! load generators in `examples/serve_e2e.rs` and
//! `examples/similarity_serve.rs`. The typed helpers below unwrap the
//! expected response variant and turn server-side `error` replies into
//! `Err`, so callers don't re-match every response.
//!
//! Two wire modes, switchable per connection:
//! * **JSON lines** (default) — works against every server; responses
//!   arrive strictly in request order.
//! * **Binary framed** ([`Client::set_framed`] /
//!   [`Client::connect_framed`]) — [`super::frame`] frames with
//!   client-assigned request ids. The server may complete requests **out
//!   of order**; this client matches responses back to requests by id, so
//!   `send_batch`/`recv_batch` keep their in-order API contract while the
//!   wire runs fully multiplexed. Requires a frame-capable server (the
//!   event-driven transport); the thread-per-connection JSON server does
//!   not speak frames.

use super::frame::{self, FrameMsg, FrameStatus, FrameViewStatus};
use super::protocol::{self, HelloInfo, QueryTarget, Request, Response, SketchSource};
use crate::sketch::{codec, GumbelMaxSketch, SparseVector};
use crate::util::json::Value;
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, IoSlice, Read, Write};
use std::net::TcpStream;

/// Per-connection wire state. Framed mode tracks which request ids are
/// outstanding and parks responses that complete ahead of their turn.
enum Wire {
    Json,
    Framed {
        /// Unparsed bytes read off the socket (partial next frame).
        rbuf: Vec<u8>,
        /// Outstanding request ids, oldest first.
        pending: VecDeque<u64>,
        /// Responses that arrived before their `recv_batch` turn.
        done: HashMap<u64, Response>,
        next_id: u64,
    },
}

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    wire: Wire,
}

impl Client {
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("cannot connect to '{addr}': {e}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader, wire: Wire::Json })
    }

    /// Connect speaking binary frames from the first request.
    pub fn connect_framed(addr: &str) -> anyhow::Result<Client> {
        let mut c = Client::connect(addr)?;
        c.set_framed(true)?;
        Ok(c)
    }

    /// Switch wire modes on a live connection (the server auto-detects
    /// per message, so this is purely client-side state). Leaving framed
    /// mode is refused while responses are outstanding — the id map would
    /// be dropped and the stream torn.
    pub fn set_framed(&mut self, on: bool) -> anyhow::Result<()> {
        match (&self.wire, on) {
            (Wire::Json, true) => {
                self.wire = Wire::Framed {
                    rbuf: Vec::new(),
                    pending: VecDeque::new(),
                    done: HashMap::new(),
                    next_id: 1,
                };
            }
            (Wire::Framed { rbuf, pending, done, .. }, false) => {
                anyhow::ensure!(
                    rbuf.is_empty() && pending.is_empty() && done.is_empty(),
                    "cannot leave framed mode with responses outstanding"
                );
                self.wire = Wire::Json;
            }
            _ => {}
        }
        Ok(())
    }

    pub fn is_framed(&self) -> bool {
        matches!(self.wire, Wire::Framed { .. })
    }

    /// Bound how long any read OR write waits for the server (`None` =
    /// forever, the default). A timed-out operation errors out of
    /// `call`/`send_batch`/`recv_batch` possibly mid-line, so after a
    /// timeout the connection must be discarded, not reused — the cluster
    /// layer does exactly that (timeout ⇒ node marked down), turning a
    /// hung-but-connected node (even one with a full receive buffer that
    /// would block writes forever) into the same typed degradation as a
    /// dead one.
    pub fn set_io_timeout(&mut self, timeout: Option<std::time::Duration>) -> anyhow::Result<()> {
        // Socket-level options: the reader half is a clone of the same
        // socket, so setting them on the writer covers both directions.
        self.writer.set_read_timeout(timeout)?;
        self.writer.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Phase 1 of a split-phase exchange: write `reqs` as one buffer
    /// without reading anything. Pair with [`Client::recv_batch`]. The
    /// cluster fan-out uses this to put requests on EVERY node's wire
    /// before reading any reply, so per-node server work overlaps and a
    /// scatter costs ~max(RTT) instead of sum(RTT).
    pub fn send_batch(&mut self, reqs: &[Request]) -> anyhow::Result<()> {
        match &mut self.wire {
            Wire::Json => {
                let mut buf = String::new();
                for r in reqs {
                    buf.push_str(&protocol::encode_line(&r.to_json()));
                }
                self.writer.write_all(buf.as_bytes())?;
            }
            Wire::Framed { pending, next_id, .. } => {
                // All frames coalesce into one buffer → one write syscall.
                let mut buf = Vec::new();
                for r in reqs {
                    let id = *next_id;
                    *next_id = next_id.wrapping_add(1);
                    frame::encode_request_frame(id, r, &mut buf);
                    pending.push_back(id);
                }
                self.writer.write_all(&buf)?;
            }
        }
        Ok(())
    }

    /// Phase 2: collect the `n` oldest outstanding responses, in request
    /// order. On the JSON wire that is simply the next `n` lines; on the
    /// framed wire responses may arrive out of order and are matched back
    /// by request id (early arrivals for later requests are parked, never
    /// dropped).
    pub fn recv_batch(&mut self, n: usize) -> anyhow::Result<Vec<Response>> {
        match &mut self.wire {
            Wire::Json => {
                let mut out = Vec::with_capacity(n);
                for _ in 0..n {
                    let mut reply = String::new();
                    let got = self.reader.read_line(&mut reply)?;
                    anyhow::ensure!(got > 0, "server closed the connection mid-batch");
                    out.push(protocol::decode_response(&reply)?);
                }
                Ok(out)
            }
            Wire::Framed { rbuf, pending, done, .. } => {
                anyhow::ensure!(
                    pending.len() >= n,
                    "recv_batch({n}) with only {} requests outstanding",
                    pending.len()
                );
                let wanted: Vec<u64> = pending.drain(..n).collect();
                while !wanted.iter().all(|id| done.contains_key(id)) {
                    match frame::decode_frame(rbuf)? {
                        FrameStatus::Frame { consumed, id, msg } => {
                            rbuf.drain(..consumed);
                            let FrameMsg::Response(resp) = msg else {
                                anyhow::bail!("server sent a request frame")
                            };
                            anyhow::ensure!(
                                wanted.contains(&id) || pending.contains(&id),
                                "response for unknown request id {id}"
                            );
                            anyhow::ensure!(
                                done.insert(id, resp).is_none(),
                                "duplicate response for request id {id}"
                            );
                        }
                        FrameStatus::Incomplete => {
                            let mut chunk = [0u8; 16 * 1024];
                            let got = self.reader.read(&mut chunk)?;
                            anyhow::ensure!(got > 0, "server closed the connection mid-batch");
                            rbuf.extend_from_slice(&chunk[..got]);
                        }
                    }
                }
                let mut out = Vec::with_capacity(n);
                for id in &wanted {
                    out.push(done.remove(id).expect("loop ensured presence"));
                }
                Ok(out)
            }
        }
    }

    /// [`Client::send_batch`] for blob-bearing requests: consumes the
    /// requests so that on the framed wire each `store_put_bin` /
    /// `stream_merge_bin` body is *spliced* into the outgoing buffer run
    /// — the codec blob the caller encoded is the buffer the socket
    /// writes, never copied into a contiguous frame. Non-blob requests
    /// and the JSON wire behave exactly like [`Client::send_batch`].
    pub fn send_batch_owned(&mut self, reqs: Vec<Request>) -> anyhow::Result<()> {
        match &mut self.wire {
            Wire::Json => {
                let mut buf = String::new();
                for r in &reqs {
                    buf.push_str(&protocol::encode_line(&r.to_json()));
                }
                self.writer.write_all(buf.as_bytes())?;
            }
            Wire::Framed { pending, next_id, .. } => {
                let mut parts: Vec<Vec<u8>> = Vec::new();
                for r in reqs {
                    let id = *next_id;
                    *next_id = next_id.wrapping_add(1);
                    pending.push_back(id);
                    parts.extend(frame::encode_request_frame_vectored(id, r));
                }
                write_all_vectored(&mut self.writer, &parts)?;
            }
        }
        Ok(())
    }

    /// Send one request and wait for its response line.
    pub fn call(&mut self, req: &Request) -> anyhow::Result<Response> {
        self.send_batch(std::slice::from_ref(req))?;
        Ok(self.recv_batch(1)?.pop().expect("recv_batch(1) yields one reply"))
    }

    /// [`Client::call`] consuming the request ([`Client::send_batch_owned`]
    /// semantics — blob bodies splice on the framed wire).
    pub fn call_owned(&mut self, req: Request) -> anyhow::Result<Response> {
        self.send_batch_owned(vec![req])?;
        Ok(self.recv_batch(1)?.pop().expect("recv_batch(1) yields one reply"))
    }

    /// Queue one [`PreparedRequest`]. The prepared form must match this
    /// connection's wire mode — a mismatch is a caller bug, surfaced as a
    /// clean error instead of garbage on the wire. On the framed wire the
    /// shared body bytes are written via vectored I/O between a
    /// per-connection envelope; nothing is re-encoded or re-buffered.
    pub fn send_prepared(&mut self, p: &PreparedRequest) -> anyhow::Result<()> {
        match (&mut self.wire, p) {
            (Wire::Json, PreparedRequest::Json(line)) => {
                self.writer.write_all(line.as_bytes())?;
            }
            (Wire::Framed { pending, next_id, .. }, PreparedRequest::Framed(body)) => {
                let id = *next_id;
                *next_id = next_id.wrapping_add(1);
                pending.push_back(id);
                let (prefix, trailer) = frame::request_frame_envelope(id, body);
                write_all_vectored(
                    &mut self.writer,
                    &[prefix.as_slice(), body.as_slice(), trailer.as_slice()],
                )?;
            }
            _ => anyhow::bail!("prepared request does not match the connection's wire mode"),
        }
        Ok(())
    }

    /// Pipeline many requests, then collect all responses (cuts RTT for
    /// bulk ingestion).
    pub fn call_pipelined(&mut self, reqs: &[Request]) -> anyhow::Result<Vec<Response>> {
        self.send_batch(reqs)?;
        self.recv_batch(reqs.len())
    }

    /// Call and expect an `ack`; server-side errors become `Err`.
    fn call_ack(&mut self, req: &Request) -> anyhow::Result<String> {
        match self.call(req)? {
            Response::Ack { info } => Ok(info),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected ack, got {other:?}"),
        }
    }

    // -- typed keyed-store helpers ---------------------------------------

    /// Upsert `vector` into the keyed store under `key` (store-assigned
    /// next version).
    pub fn upsert(&mut self, key: &str, vector: SparseVector) -> anyhow::Result<String> {
        self.call_ack(&Request::Upsert { key: key.to_string(), vector, version: None })
    }

    /// Upsert at an explicit write version: installs iff strictly newer
    /// than the held copy (last-writer-wins), acks "kept" otherwise.
    pub fn upsert_versioned(
        &mut self,
        key: &str,
        vector: SparseVector,
        version: u64,
    ) -> anyhow::Result<String> {
        self.call_ack(&Request::Upsert { key: key.to_string(), vector, version: Some(version) })
    }

    /// One `(key, version)` page of the store's sorted key walk — pass the
    /// last key back as `after` to continue.
    pub fn store_keys(
        &mut self,
        after: Option<&str>,
        limit: usize,
    ) -> anyhow::Result<Vec<(String, u64)>> {
        let req = Request::StoreKeys { after: after.map(str::to_string), limit };
        match self.call(&req)? {
            Response::Keys { keys } => Ok(keys),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected keys, got {other:?}"),
        }
    }

    /// Install a codec blob (key + version inside) under last-writer-wins.
    pub fn store_put(&mut self, data: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::StorePut { data: data.to_string() })
    }

    /// Binary twin of [`Client::store_put`]: `data` is the raw output of
    /// [`codec::encode_sketch_bytes`]. On the framed wire the blob is
    /// spliced into the request frame — encoded once by the caller,
    /// written once by the socket, never hexed or re-buffered. On the
    /// JSON wire it degrades to the hex form transparently.
    pub fn store_put_bin(&mut self, data: Vec<u8>) -> anyhow::Result<String> {
        self.call_owned_ack(Request::StorePutBin { data })
    }

    /// Merge a codec blob into the named live stream state (§2.3 repair).
    pub fn stream_merge(&mut self, stream: &str, data: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::StreamMerge { stream: stream.to_string(), data: data.to_string() })
    }

    /// Binary twin of [`Client::stream_merge`] ([`Client::store_put_bin`]
    /// splice semantics).
    pub fn stream_merge_bin(&mut self, stream: &str, data: Vec<u8>) -> anyhow::Result<String> {
        self.call_owned_ack(Request::StreamMergeBin { stream: stream.to_string(), data })
    }

    /// [`Client::call_ack`] for owned blob-bearing requests.
    fn call_owned_ack(&mut self, req: Request) -> anyhow::Result<String> {
        match self.call_owned(req)? {
            Response::Ack { info } => Ok(info),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected ack, got {other:?}"),
        }
    }

    /// Delete `key` from the keyed store (idempotent).
    pub fn delete(&mut self, key: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::Delete { key: key.to_string() })
    }

    /// Top-`limit` store entries most similar to `vector`.
    pub fn topk(
        &mut self,
        vector: SparseVector,
        limit: usize,
    ) -> anyhow::Result<Vec<(String, f64)>> {
        match self.call(&Request::TopK { vector, limit })? {
            Response::TopK { hits } => Ok(hits),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected topk, got {other:?}"),
        }
    }

    /// Draw `n` element ids ∝ weight from the query target's sketch
    /// (single key, §2.3 key-set union, or live stream) — reproducible:
    /// the same `(state, target, n, seed)` yields the same ids on every
    /// node and transport.
    pub fn sample(
        &mut self,
        target: QueryTarget,
        n: usize,
        seed: u64,
    ) -> anyhow::Result<Vec<u64>> {
        match self.call(&Request::Sample { target, n, seed })? {
            Response::Samples { ids } => Ok(ids),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected samples, got {other:?}"),
        }
    }

    /// Estimate the target's partition function (total weight
    /// `Z = Σ_i w_i`) from its sketch registers.
    pub fn partition(&mut self, target: QueryTarget) -> anyhow::Result<f64> {
        match self.call(&Request::Partition { target })? {
            Response::Estimate { value } => Ok(value),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected estimate, got {other:?}"),
        }
    }

    /// Keyed-store statistics (size, shard occupancy, index shape).
    pub fn store_stats(&mut self) -> anyhow::Result<Value> {
        match self.call(&Request::StoreStats)? {
            Response::Stats { stats } => Ok(stats),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected stats, got {other:?}"),
        }
    }

    /// Freeze the server's keyed store to `path` (server-side file).
    pub fn snapshot(&mut self, path: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::Snapshot { path: path.to_string() })
    }

    /// Replace the server's keyed store from the snapshot at `path`.
    pub fn restore(&mut self, path: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::Restore { path: path.to_string() })
    }

    // -- cluster handshake & gather helpers -------------------------------

    /// Version/identity handshake: protocol version, node id, state epoch
    /// and supported algorithms.
    pub fn hello(&mut self) -> anyhow::Result<HelloInfo> {
        match self.call(&Request::Hello)? {
            Response::Hello { info } => Ok(info),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected hello, got {other:?}"),
        }
    }

    /// Fetch one sketch from `source` as a codec blob and decode it —
    /// checksum-verified, bit-identical to the server's registers.
    pub fn sketch_fetch(
        &mut self,
        name: &str,
        source: SketchSource,
    ) -> anyhow::Result<GumbelMaxSketch> {
        Ok(self.sketch_fetch_versioned(name, source)?.1)
    }

    /// [`Client::sketch_fetch`] keeping the blob's write version (store
    /// source; 0 for registry/stream sketches).
    pub fn sketch_fetch_versioned(
        &mut self,
        name: &str,
        source: SketchSource,
    ) -> anyhow::Result<(u64, GumbelMaxSketch)> {
        match self.call(&Request::SketchFetch { name: name.to_string(), source })? {
            Response::SketchBlob { name: got, data } => {
                let (key, version, sk) = codec::decode_sketch_hex(&data)?;
                anyhow::ensure!(
                    got == name && key == name,
                    "sketch_fetch for '{name}' answered with '{got}' (blob key '{key}')"
                );
                Ok((version, sk))
            }
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected sketch_blob, got {other:?}"),
        }
    }

    /// Binary twin of [`Client::sketch_fetch`].
    pub fn sketch_fetch_bin(
        &mut self,
        name: &str,
        source: SketchSource,
    ) -> anyhow::Result<GumbelMaxSketch> {
        Ok(self.sketch_fetch_bin_versioned(name, source)?.1)
    }

    /// Binary twin of [`Client::sketch_fetch_versioned`]: the blob
    /// arrives as raw codec bytes in the frame body and is decoded
    /// through the borrowing frame view — the registers are sliced
    /// straight out of the connection's input buffer, never hexed and
    /// never copied into an intermediate `Response`. On the JSON wire
    /// the same request still works (the blob rides as hex inside the
    /// JSON string) and decodes to identical registers.
    pub fn sketch_fetch_bin_versioned(
        &mut self,
        name: &str,
        source: SketchSource,
    ) -> anyhow::Result<(u64, GumbelMaxSketch)> {
        let req = Request::SketchFetchBin { name: name.to_string(), source };
        if !self.is_framed() {
            return match self.call(&req)? {
                Response::SketchBlobBin { name: got, data } => {
                    let (key, version, sk) = codec::decode_sketch_bytes(&data)?;
                    anyhow::ensure!(
                        got == name && key == name,
                        "sketch_fetch_bin for '{name}' answered with '{got}' (blob key '{key}')"
                    );
                    Ok((version, sk))
                }
                Response::Error { message } => anyhow::bail!("{message}"),
                other => anyhow::bail!("expected sketch_blob_bin, got {other:?}"),
            };
        }
        self.send_batch(std::slice::from_ref(&req))?;
        self.recv_blob_bin(name)
    }

    /// Framed-wire receive for one awaited `sketch_blob_bin` reply,
    /// decoding the blob in place from the connection buffer (zero-copy
    /// read path). Out-of-order replies for other outstanding requests
    /// are materialized and parked exactly as in [`Client::recv_batch`].
    fn recv_blob_bin(&mut self, want_name: &str) -> anyhow::Result<(u64, GumbelMaxSketch)> {
        let Wire::Framed { rbuf, pending, done, .. } = &mut self.wire else {
            anyhow::bail!("recv_blob_bin requires framed mode");
        };
        let want = pending
            .pop_front()
            .ok_or_else(|| anyhow::anyhow!("no request outstanding"))?;
        if let Some(resp) = done.remove(&want) {
            // Already arrived during an earlier batch read — the owned
            // Response path (one copy) is unavoidable here.
            return match resp {
                Response::SketchBlobBin { name: got, data } => {
                    let (key, version, sk) = codec::decode_sketch_bytes(&data)?;
                    anyhow::ensure!(
                        got == want_name && key == want_name,
                        "sketch_fetch_bin for '{want_name}' answered with '{got}' (blob key '{key}')"
                    );
                    Ok((version, sk))
                }
                Response::Error { message } => anyhow::bail!("{message}"),
                other => anyhow::bail!("expected sketch_blob_bin, got {other:?}"),
            };
        }
        loop {
            // Fill until a whole frame is buffered: the view borrows
            // `rbuf`, so all reads happen before the borrow starts.
            while matches!(frame::decode_frame_view(rbuf)?, FrameViewStatus::Incomplete) {
                let mut chunk = [0u8; 16 * 1024];
                let got = self.reader.read(&mut chunk)?;
                anyhow::ensure!(got > 0, "server closed the connection mid-frame");
                rbuf.extend_from_slice(&chunk[..got]);
            }
            let FrameViewStatus::Frame(view) = frame::decode_frame_view(rbuf)? else {
                unreachable!("loop above buffered a full frame")
            };
            let consumed = view.consumed;
            let id = view.id;
            if id == want {
                let outcome = (|| -> anyhow::Result<(u64, GumbelMaxSketch)> {
                    match view.sketch_blob_bin()? {
                        Some((got, blob)) => {
                            // `blob` borrows the connection buffer: the
                            // registers decode from the wire bytes with
                            // no intermediate copy.
                            let (key, version, sk) = codec::decode_sketch_bytes(blob)?;
                            anyhow::ensure!(
                                got == want_name && key == want_name,
                                "sketch_fetch_bin for '{want_name}' answered with '{got}' (blob key '{key}')"
                            );
                            Ok((version, sk))
                        }
                        None => match view.message()? {
                            FrameMsg::Response(Response::Error { message }) => {
                                anyhow::bail!("{message}")
                            }
                            FrameMsg::Response(other) => {
                                anyhow::bail!("expected sketch_blob_bin, got {other:?}")
                            }
                            FrameMsg::Request(_) => anyhow::bail!("server sent a request frame"),
                        },
                    }
                })();
                rbuf.drain(..consumed);
                return outcome;
            }
            // Someone else's reply: materialize and park it so a later
            // recv_batch can claim it.
            let msg = view.message()?;
            let FrameMsg::Response(resp) = msg else {
                anyhow::bail!("server sent a request frame");
            };
            anyhow::ensure!(
                pending.contains(&id),
                "server answered unknown request id {id}"
            );
            anyhow::ensure!(
                done.insert(id, resp).is_none(),
                "server answered request id {id} twice"
            );
            rbuf.drain(..consumed);
        }
    }
}

/// `write_all` over a run of buffers using vectored I/O: spliced frames
/// (`[prefix, blob, trailer]`) reach the socket in one syscall in the
/// common case without ever being copied into a contiguous allocation.
fn write_all_vectored<B: AsRef<[u8]>>(w: &mut TcpStream, parts: &[B]) -> std::io::Result<()> {
    let mut idx = 0;
    let mut off = 0;
    while idx < parts.len() {
        if off == parts[idx].as_ref().len() {
            idx += 1;
            off = 0;
            continue;
        }
        let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(parts.len() - idx);
        slices.push(IoSlice::new(&parts[idx].as_ref()[off..]));
        for p in &parts[idx + 1..] {
            if !p.as_ref().is_empty() {
                slices.push(IoSlice::new(p.as_ref()));
            }
        }
        let mut n = match w.write_vectored(&slices) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "failed to write spliced frame",
                ))
            }
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        while n > 0 {
            let left = parts[idx].as_ref().len() - off;
            if n >= left {
                n -= left;
                idx += 1;
                off = 0;
            } else {
                off += n;
                n = 0;
            }
        }
    }
    Ok(())
}

/// A request serialized once for fan-out to many connections — the
/// replica-write and repair-install paths, where the SAME payload goes to
/// R owners. JSON connections share the serialized line verbatim; framed
/// connections share the encoded frame *body* (the request id lives in
/// the envelope, so [`Client::send_prepared`] derives only the 14-byte
/// prefix and the checksum trailer per connection — the body, blob
/// included, is never re-encoded).
pub enum PreparedRequest {
    /// One `encode_line` output, newline included.
    Json(String),
    /// One `frame::encode_request_body` output (id-independent).
    Framed(Vec<u8>),
}

impl PreparedRequest {
    /// Serialize `req` once for the wire mode the target connections
    /// speak (`framed` must match [`Client::is_framed`] of every target).
    pub fn new(req: &Request, framed: bool) -> PreparedRequest {
        if framed {
            let mut body = Vec::new();
            frame::encode_request_body(req, &mut body);
            PreparedRequest::Framed(body)
        } else {
            PreparedRequest::Json(protocol::encode_line(&req.to_json()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::Server;
    use crate::coordinator::service::{Coordinator, CoordinatorConfig};
    use crate::sketch::Sketcher;
    use std::sync::Arc;

    #[test]
    fn pipelined_requests_preserve_order() {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig { k: 32, workers: 2, ..Default::default() })
                .unwrap(),
        );
        let server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let reqs: Vec<Request> = (0..10u64)
            .map(|i| Request::Push { stream: "p".into(), items: vec![(i, 1.0)] })
            .collect();
        let resps = client.call_pipelined(&reqs).unwrap();
        assert_eq!(resps.len(), 10);
        for (i, r) in resps.iter().enumerate() {
            let Response::Ack { info } = r else { panic!("expected ack") };
            assert!(
                info.contains(&format!("processed {}", i + 1)),
                "response {i} out of order: {info}"
            );
        }
        server.stop();
    }

    #[test]
    fn connect_failure_is_clean_error() {
        assert!(Client::connect("127.0.0.1:1").is_err());
    }

    #[cfg(unix)]
    mod framed {
        use super::*;
        use crate::coordinator::event_server::EventServer;

        fn start_event(workers: usize) -> (Arc<Coordinator>, EventServer) {
            let coord = Arc::new(
                Coordinator::new(CoordinatorConfig {
                    k: 32,
                    workers,
                    ..Default::default()
                })
                .unwrap(),
            );
            let server = EventServer::start(coord.clone(), "127.0.0.1:0").unwrap();
            (coord, server)
        }

        #[test]
        fn framed_pipeline_matches_responses_by_id() {
            let (coord, server) = start_event(4);
            let mut client = Client::connect_framed(&server.addr.to_string()).unwrap();
            assert!(client.is_framed());
            let reqs: Vec<Request> = (0..20u64)
                .map(|i| Request::Push { stream: "p".into(), items: vec![(i, 1.0)] })
                .collect();
            // Even with 4 workers completing out of order on the wire, the
            // id matching restores request order at the API.
            let resps = client.call_pipelined(&reqs).unwrap();
            assert_eq!(resps.len(), 20);
            for (i, r) in resps.iter().enumerate() {
                let Response::Ack { info } = r else { panic!("expected ack, got {r:?}") };
                assert!(
                    info.contains(&format!("processed {}", i + 1)),
                    "response {i} misrouted: {info}"
                );
            }
            drop(client);
            server.stop();
            Arc::try_unwrap(coord).ok().expect("still referenced").shutdown();
        }

        #[test]
        fn typed_helpers_work_identically_over_frames() {
            let (coord, server) = start_event(2);
            let mut client = Client::connect_framed(&server.addr.to_string()).unwrap();
            let hello = client.hello().unwrap();
            assert_eq!(hello.protocol, protocol::PROTOCOL_VERSION);
            let v = SparseVector::new(vec![1, 2], vec![1.0, 0.5]);
            assert!(client.upsert("a", v.clone()).unwrap().contains("upserted"));
            let hits = client.topk(v.clone(), 1).unwrap();
            assert_eq!(hits[0].0, "a");
            // Blob fetch rides raw codec bytes on this wire.
            let fetched = client.sketch_fetch("a", SketchSource::Store).unwrap();
            assert_eq!(fetched, crate::sketch::fastgm::FastGm::new(32, 42).sketch(&v));
            assert!(client.restore("/no/such/file.fgms").is_err());
            drop(client);
            server.stop();
            Arc::try_unwrap(coord).ok().expect("still referenced").shutdown();
        }

        /// The binary blob helpers must move bit-identical registers over
        /// both wires: spliced `store_put_bin`/`stream_merge_bin` writes
        /// and the zero-copy `sketch_fetch_bin` read against the framed
        /// server, the hex-in-JSON degradation against the line server.
        #[test]
        fn binary_blob_helpers_roundtrip_and_match_hex() {
            let (coord, server) = start_event(2);
            let mut client = Client::connect_framed(&server.addr.to_string()).unwrap();
            let v = SparseVector::new(vec![1, 2, 7], vec![1.0, 0.5, 2.5]);
            let sk = crate::sketch::fastgm::FastGm::new(32, 42).sketch(&v);
            // Spliced install, zero-copy fetch: registers survive untouched.
            let blob = codec::encode_sketch_bytes("doc", 3, &sk);
            assert!(client.store_put_bin(blob.clone()).unwrap().contains("installed"));
            let (version, got) =
                client.sketch_fetch_bin_versioned("doc", SketchSource::Store).unwrap();
            assert_eq!((version, &got), (3, &sk));
            // ...and bit-identical to what the hex path reports.
            assert_eq!(
                client.sketch_fetch_versioned("doc", SketchSource::Store).unwrap(),
                (3, sk.clone())
            );
            // Zero-copy receive still parks out-of-order replies: queue a
            // ping ahead of the fetch, claim it afterwards.
            client.send_batch(&[Request::Ping]).unwrap();
            client
                .send_batch(&[Request::SketchFetchBin {
                    name: "doc".into(),
                    source: SketchSource::Store,
                }])
                .unwrap();
            // Consume the ping first so the blob reply lands in `done`,
            // exercising the parked-response branch too.
            assert_eq!(client.recv_batch(1).unwrap(), vec![Response::Pong]);
            assert_eq!(client.recv_blob_bin("doc").unwrap(), (3, sk.clone()));
            // Stream merge twin: binary merge is idempotent (§2.3).
            for _ in 0..2 {
                let ack = client.stream_merge_bin("s", blob.clone()).unwrap();
                assert!(ack.contains("merged"), "unexpected ack: {ack}");
            }
            assert_eq!(client.sketch_fetch_bin("s", SketchSource::Stream).unwrap(), sk);
            // Missing keys are clean errors through the view path.
            assert!(client.sketch_fetch_bin("ghost", SketchSource::Store).is_err());
            drop(client);
            server.stop();
            Arc::try_unwrap(coord).ok().expect("still referenced").shutdown();

            // Same helpers over the JSON wire (hex degradation).
            let json_coord = Arc::new(
                Coordinator::new(CoordinatorConfig { k: 32, workers: 1, ..Default::default() })
                    .unwrap(),
            );
            let json_server = Server::start(json_coord, "127.0.0.1:0").unwrap();
            let mut json = Client::connect(&json_server.addr.to_string()).unwrap();
            let blob = codec::encode_sketch_bytes("doc", 3, &sk);
            assert!(json.store_put_bin(blob).unwrap().contains("installed"));
            assert_eq!(
                json.sketch_fetch_bin_versioned("doc", SketchSource::Store).unwrap(),
                (3, sk)
            );
            drop(json);
            json_server.stop();
        }

        /// `sample`/`partition` must answer bit-identically over the JSON
        /// and framed wires: two servers with equal state (sketching is
        /// seed-deterministic), one client per wire, same query seeds.
        #[test]
        fn sample_and_partition_agree_across_wires() {
            let (coord, server) = start_event(2);
            let mut framed = Client::connect_framed(&server.addr.to_string()).unwrap();
            let json_coord = Arc::new(
                Coordinator::new(CoordinatorConfig { k: 32, workers: 2, ..Default::default() })
                    .unwrap(),
            );
            let json_server = Server::start(json_coord, "127.0.0.1:0").unwrap();
            let mut json = Client::connect(&json_server.addr.to_string()).unwrap();
            let va = SparseVector::new(vec![1, 2, 3], vec![1.0, 0.5, 2.0]);
            let vb = SparseVector::new(vec![3, 4], vec![1.5, 1.0]);
            for c in [&mut framed, &mut json] {
                c.upsert("a", va.clone()).unwrap();
                c.upsert("b", vb.clone()).unwrap();
            }
            let target = || QueryTarget::Keys(vec!["a".into(), "b".into()]);
            let f_ids = framed.sample(target(), 16, 9).unwrap();
            assert_eq!(f_ids, json.sample(target(), 16, 9).unwrap());
            assert!(f_ids.iter().all(|id| *id >= 1 && *id <= 4));
            assert_eq!(
                framed.partition(target()).unwrap(),
                json.partition(target()).unwrap()
            );
            // Single-key targets and error replies behave alike per wire.
            for c in [&mut framed, &mut json] {
                let ids = c.sample(QueryTarget::key("a"), 4, 1).unwrap();
                assert!(ids.iter().all(|id| [1, 2, 3].contains(id)));
                assert!(c.partition(QueryTarget::key("ghost")).is_err());
            }
            drop(framed);
            drop(json);
            server.stop();
            json_server.stop();
            Arc::try_unwrap(coord).ok().expect("still referenced").shutdown();
        }

        #[test]
        fn mode_switch_mid_connection_is_safe_and_guarded() {
            let (coord, server) = start_event(1);
            let mut client = Client::connect(&server.addr.to_string()).unwrap();
            // JSON first, frames second, back to JSON — one connection.
            assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
            client.set_framed(true).unwrap();
            assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
            client.set_framed(false).unwrap();
            assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
            // Leaving framed mode with responses in flight is refused.
            client.set_framed(true).unwrap();
            client.send_batch(&[Request::Ping]).unwrap();
            assert!(client.set_framed(false).is_err());
            assert_eq!(client.recv_batch(1).unwrap(), vec![Response::Pong]);
            client.set_framed(false).unwrap();
            drop(client);
            server.stop();
            Arc::try_unwrap(coord).ok().expect("still referenced").shutdown();
        }

        #[test]
        fn prepared_requests_fan_out_and_refuse_wire_mismatch() {
            let (coord, server) = start_event(1);
            let mut client = Client::connect_framed(&server.addr.to_string()).unwrap();
            // One serialization, many sends — each frame gets its own id.
            let prepared = PreparedRequest::new(&Request::Ping, true);
            client.send_prepared(&prepared).unwrap();
            client.send_prepared(&prepared).unwrap();
            assert_eq!(client.recv_batch(2).unwrap(), vec![Response::Pong, Response::Pong]);
            // A blob-bearing prepared request works the same way.
            let v = SparseVector::new(vec![1], vec![1.0]);
            let sk = crate::sketch::fastgm::FastGm::new(32, 42).sketch(&v);
            let put = PreparedRequest::new(
                &Request::StorePutBin { data: codec::encode_sketch_bytes("p", 2, &sk) },
                true,
            );
            client.send_prepared(&put).unwrap();
            let Response::Ack { info } = &client.recv_batch(1).unwrap()[0] else {
                panic!("expected ack")
            };
            assert!(info.contains("installed"), "unexpected ack: {info}");
            // JSON-prepared bytes on a framed wire are refused cleanly.
            assert!(client.send_prepared(&PreparedRequest::new(&Request::Ping, false)).is_err());
            drop(client);
            server.stop();
            Arc::try_unwrap(coord).ok().expect("still referenced").shutdown();
        }

        #[test]
        fn recv_more_than_outstanding_is_an_error() {
            let (coord, server) = start_event(1);
            let mut client = Client::connect_framed(&server.addr.to_string()).unwrap();
            assert!(client.recv_batch(1).is_err());
            drop(client);
            server.stop();
            Arc::try_unwrap(coord).ok().expect("still referenced").shutdown();
        }
    }

    #[test]
    fn hello_and_sketch_fetch_roundtrip() {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig {
                k: 32,
                workers: 2,
                node_id: "unit-node".into(),
                ..Default::default()
            })
            .unwrap(),
        );
        let server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let hello = client.hello().unwrap();
        assert_eq!(hello.protocol, protocol::PROTOCOL_VERSION);
        assert_eq!(hello.node, "unit-node");
        assert_eq!(hello.epoch, 0);
        assert_eq!(hello.k, 32);
        assert_eq!(hello.algo, "fastgm");
        assert!(hello.algos.iter().any(|a| a == "fastgm"));
        // A stored sketch fetches back bit-identically through the codec.
        let v = SparseVector::new(vec![1, 2], vec![1.0, 0.5]);
        client.upsert("doc", v.clone()).unwrap();
        let fetched = client.sketch_fetch("doc", SketchSource::Store).unwrap();
        assert_eq!(fetched, crate::sketch::fastgm::FastGm::new(32, 42).sketch(&v));
        // Missing keys are clean errors on every source.
        for source in [SketchSource::Store, SketchSource::Registry, SketchSource::Stream] {
            assert!(client.sketch_fetch("ghost", source).is_err());
        }
        server.stop();
    }

    #[test]
    fn typed_store_helpers_roundtrip() {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig { k: 32, workers: 2, ..Default::default() })
                .unwrap(),
        );
        let server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let v = SparseVector::new(vec![1, 2], vec![1.0, 0.5]);
        assert!(client.upsert("a", v.clone()).unwrap().contains("upserted"));
        let hits = client.topk(v.clone(), 1).unwrap();
        assert_eq!(hits[0].0, "a");
        let stats = client.store_stats().unwrap();
        assert_eq!(stats.get("size").and_then(|x| x.as_f64()), Some(1.0));
        // The repair surface: key walk, LWW versioned writes, blob install.
        assert_eq!(client.store_keys(None, 10).unwrap(), vec![("a".to_string(), 1)]);
        assert!(client.upsert_versioned("a", v.clone(), 7).unwrap().contains("@v7"));
        assert!(client.upsert_versioned("a", v, 3).unwrap().contains("kept"));
        let (version, sk) = client.sketch_fetch_versioned("a", SketchSource::Store).unwrap();
        assert_eq!(version, 7);
        let blob = codec::encode_sketch_hex("a", 12, &sk);
        assert!(client.store_put(&blob).unwrap().contains("installed 'a' @v12"));
        assert_eq!(client.store_keys(Some("a"), 10).unwrap(), vec![]);
        assert!(client.delete("a").unwrap().contains("deleted"));
        // Server-side error replies surface as Err, not as a panic.
        assert!(client.restore("/no/such/file.fgms").is_err());
        server.stop();
    }
}
