//! Blocking TCP client for the JSON-lines protocol — used by the CLI
//! (`fastgm client` / `store` / `topk` / `snapshot`), the examples and the
//! load generators in `examples/serve_e2e.rs` and
//! `examples/similarity_serve.rs`. The typed helpers below unwrap the
//! expected response variant and turn server-side `error` replies into
//! `Err`, so callers don't re-match every response.

use super::protocol::{self, HelloInfo, Request, Response, SketchSource};
use crate::sketch::{codec, GumbelMaxSketch, SparseVector};
use crate::util::json::Value;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("cannot connect to '{addr}': {e}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    /// Bound how long any read OR write waits for the server (`None` =
    /// forever, the default). A timed-out operation errors out of
    /// `call`/`send_batch`/`recv_batch` possibly mid-line, so after a
    /// timeout the connection must be discarded, not reused — the cluster
    /// layer does exactly that (timeout ⇒ node marked down), turning a
    /// hung-but-connected node (even one with a full receive buffer that
    /// would block writes forever) into the same typed degradation as a
    /// dead one.
    pub fn set_io_timeout(&mut self, timeout: Option<std::time::Duration>) -> anyhow::Result<()> {
        // Socket-level options: the reader half is a clone of the same
        // socket, so setting them on the writer covers both directions.
        self.writer.set_read_timeout(timeout)?;
        self.writer.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Phase 1 of a split-phase exchange: write `reqs` as one buffer
    /// without reading anything. Pair with [`Client::recv_batch`]. The
    /// cluster fan-out uses this to put requests on EVERY node's wire
    /// before reading any reply, so per-node server work overlaps and a
    /// scatter costs ~max(RTT) instead of sum(RTT).
    pub fn send_batch(&mut self, reqs: &[Request]) -> anyhow::Result<()> {
        let mut buf = String::new();
        for r in reqs {
            buf.push_str(&protocol::encode_line(&r.to_json()));
        }
        self.writer.write_all(buf.as_bytes())?;
        Ok(())
    }

    /// Phase 2: read `n` in-order response lines.
    pub fn recv_batch(&mut self, n: usize) -> anyhow::Result<Vec<Response>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut reply = String::new();
            let got = self.reader.read_line(&mut reply)?;
            anyhow::ensure!(got > 0, "server closed the connection mid-batch");
            out.push(protocol::decode_response(&reply)?);
        }
        Ok(out)
    }

    /// Send one request and wait for its response line.
    pub fn call(&mut self, req: &Request) -> anyhow::Result<Response> {
        self.send_batch(std::slice::from_ref(req))?;
        Ok(self.recv_batch(1)?.pop().expect("recv_batch(1) yields one reply"))
    }

    /// Pipeline many requests, then collect all responses (cuts RTT for
    /// bulk ingestion).
    pub fn call_pipelined(&mut self, reqs: &[Request]) -> anyhow::Result<Vec<Response>> {
        self.send_batch(reqs)?;
        self.recv_batch(reqs.len())
    }

    /// Call and expect an `ack`; server-side errors become `Err`.
    fn call_ack(&mut self, req: &Request) -> anyhow::Result<String> {
        match self.call(req)? {
            Response::Ack { info } => Ok(info),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected ack, got {other:?}"),
        }
    }

    // -- typed keyed-store helpers ---------------------------------------

    /// Upsert `vector` into the keyed store under `key` (store-assigned
    /// next version).
    pub fn upsert(&mut self, key: &str, vector: SparseVector) -> anyhow::Result<String> {
        self.call_ack(&Request::Upsert { key: key.to_string(), vector, version: None })
    }

    /// Upsert at an explicit write version: installs iff strictly newer
    /// than the held copy (last-writer-wins), acks "kept" otherwise.
    pub fn upsert_versioned(
        &mut self,
        key: &str,
        vector: SparseVector,
        version: u64,
    ) -> anyhow::Result<String> {
        self.call_ack(&Request::Upsert { key: key.to_string(), vector, version: Some(version) })
    }

    /// One `(key, version)` page of the store's sorted key walk — pass the
    /// last key back as `after` to continue.
    pub fn store_keys(
        &mut self,
        after: Option<&str>,
        limit: usize,
    ) -> anyhow::Result<Vec<(String, u64)>> {
        let req = Request::StoreKeys { after: after.map(str::to_string), limit };
        match self.call(&req)? {
            Response::Keys { keys } => Ok(keys),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected keys, got {other:?}"),
        }
    }

    /// Install a codec blob (key + version inside) under last-writer-wins.
    pub fn store_put(&mut self, data: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::StorePut { data: data.to_string() })
    }

    /// Merge a codec blob into the named live stream state (§2.3 repair).
    pub fn stream_merge(&mut self, stream: &str, data: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::StreamMerge { stream: stream.to_string(), data: data.to_string() })
    }

    /// Delete `key` from the keyed store (idempotent).
    pub fn delete(&mut self, key: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::Delete { key: key.to_string() })
    }

    /// Top-`limit` store entries most similar to `vector`.
    pub fn topk(
        &mut self,
        vector: SparseVector,
        limit: usize,
    ) -> anyhow::Result<Vec<(String, f64)>> {
        match self.call(&Request::TopK { vector, limit })? {
            Response::TopK { hits } => Ok(hits),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected topk, got {other:?}"),
        }
    }

    /// Keyed-store statistics (size, shard occupancy, index shape).
    pub fn store_stats(&mut self) -> anyhow::Result<Value> {
        match self.call(&Request::StoreStats)? {
            Response::Stats { stats } => Ok(stats),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected stats, got {other:?}"),
        }
    }

    /// Freeze the server's keyed store to `path` (server-side file).
    pub fn snapshot(&mut self, path: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::Snapshot { path: path.to_string() })
    }

    /// Replace the server's keyed store from the snapshot at `path`.
    pub fn restore(&mut self, path: &str) -> anyhow::Result<String> {
        self.call_ack(&Request::Restore { path: path.to_string() })
    }

    // -- cluster handshake & gather helpers -------------------------------

    /// Version/identity handshake: protocol version, node id, state epoch
    /// and supported algorithms.
    pub fn hello(&mut self) -> anyhow::Result<HelloInfo> {
        match self.call(&Request::Hello)? {
            Response::Hello { info } => Ok(info),
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected hello, got {other:?}"),
        }
    }

    /// Fetch one sketch from `source` as a codec blob and decode it —
    /// checksum-verified, bit-identical to the server's registers.
    pub fn sketch_fetch(
        &mut self,
        name: &str,
        source: SketchSource,
    ) -> anyhow::Result<GumbelMaxSketch> {
        Ok(self.sketch_fetch_versioned(name, source)?.1)
    }

    /// [`Client::sketch_fetch`] keeping the blob's write version (store
    /// source; 0 for registry/stream sketches).
    pub fn sketch_fetch_versioned(
        &mut self,
        name: &str,
        source: SketchSource,
    ) -> anyhow::Result<(u64, GumbelMaxSketch)> {
        match self.call(&Request::SketchFetch { name: name.to_string(), source })? {
            Response::SketchBlob { name: got, data } => {
                let (key, version, sk) = codec::decode_sketch_hex(&data)?;
                anyhow::ensure!(
                    got == name && key == name,
                    "sketch_fetch for '{name}' answered with '{got}' (blob key '{key}')"
                );
                Ok((version, sk))
            }
            Response::Error { message } => anyhow::bail!("{message}"),
            other => anyhow::bail!("expected sketch_blob, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::Server;
    use crate::coordinator::service::{Coordinator, CoordinatorConfig};
    use crate::sketch::Sketcher;
    use std::sync::Arc;

    #[test]
    fn pipelined_requests_preserve_order() {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig { k: 32, workers: 2, ..Default::default() })
                .unwrap(),
        );
        let server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let reqs: Vec<Request> = (0..10u64)
            .map(|i| Request::Push { stream: "p".into(), items: vec![(i, 1.0)] })
            .collect();
        let resps = client.call_pipelined(&reqs).unwrap();
        assert_eq!(resps.len(), 10);
        for (i, r) in resps.iter().enumerate() {
            let Response::Ack { info } = r else { panic!("expected ack") };
            assert!(
                info.contains(&format!("processed {}", i + 1)),
                "response {i} out of order: {info}"
            );
        }
        server.stop();
    }

    #[test]
    fn connect_failure_is_clean_error() {
        assert!(Client::connect("127.0.0.1:1").is_err());
    }

    #[test]
    fn hello_and_sketch_fetch_roundtrip() {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig {
                k: 32,
                workers: 2,
                node_id: "unit-node".into(),
                ..Default::default()
            })
            .unwrap(),
        );
        let server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let hello = client.hello().unwrap();
        assert_eq!(hello.protocol, protocol::PROTOCOL_VERSION);
        assert_eq!(hello.node, "unit-node");
        assert_eq!(hello.epoch, 0);
        assert_eq!(hello.k, 32);
        assert_eq!(hello.algo, "fastgm");
        assert!(hello.algos.iter().any(|a| a == "fastgm"));
        // A stored sketch fetches back bit-identically through the codec.
        let v = SparseVector::new(vec![1, 2], vec![1.0, 0.5]);
        client.upsert("doc", v.clone()).unwrap();
        let fetched = client.sketch_fetch("doc", SketchSource::Store).unwrap();
        assert_eq!(fetched, crate::sketch::fastgm::FastGm::new(32, 42).sketch(&v));
        // Missing keys are clean errors on every source.
        for source in [SketchSource::Store, SketchSource::Registry, SketchSource::Stream] {
            assert!(client.sketch_fetch("ghost", source).is_err());
        }
        server.stop();
    }

    #[test]
    fn typed_store_helpers_roundtrip() {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig { k: 32, workers: 2, ..Default::default() })
                .unwrap(),
        );
        let server = Server::start(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let v = SparseVector::new(vec![1, 2], vec![1.0, 0.5]);
        assert!(client.upsert("a", v.clone()).unwrap().contains("upserted"));
        let hits = client.topk(v.clone(), 1).unwrap();
        assert_eq!(hits[0].0, "a");
        let stats = client.store_stats().unwrap();
        assert_eq!(stats.get("size").and_then(|x| x.as_f64()), Some(1.0));
        // The repair surface: key walk, LWW versioned writes, blob install.
        assert_eq!(client.store_keys(None, 10).unwrap(), vec![("a".to_string(), 1)]);
        assert!(client.upsert_versioned("a", v.clone(), 7).unwrap().contains("@v7"));
        assert!(client.upsert_versioned("a", v, 3).unwrap().contains("kept"));
        let (version, sk) = client.sketch_fetch_versioned("a", SketchSource::Store).unwrap();
        assert_eq!(version, 7);
        let blob = codec::encode_sketch_hex("a", 12, &sk);
        assert!(client.store_put(&blob).unwrap().contains("installed 'a' @v12"));
        assert_eq!(client.store_keys(Some("a"), 10).unwrap(), vec![]);
        assert!(client.delete("a").unwrap().contains("deleted"));
        // Server-side error replies surface as Err, not as a panic.
        assert!(client.restore("/no/such/file.fgms").is_err());
        server.stop();
    }
}
