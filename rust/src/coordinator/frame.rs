//! Length-prefixed binary frame codec — the multiplexed wire format the
//! event-driven transport speaks, next to (never instead of) the JSON
//! lines of [`super::protocol`].
//!
//! Frame layout, little-endian, with a trailing integrity checksum in the
//! style of [`crate::sketch::codec`]:
//!
//! ```text
//! magic 0xFB | version u8 | payload_len u32
//! payload:
//!   request id u64 | kind u8 (0 = request, 1 = response) | body
//! fnv1a64(header + payload) u64
//! ```
//!
//! * The magic byte `0xFB` can never open a JSON-lines request (those
//!   start with `{` = `0x7B`, or whitespace), so a server can dispatch on
//!   the FIRST byte of every message and serve both protocols on one
//!   port, even interleaved on one connection.
//! * The **request id** is client-assigned and echoed verbatim in the
//!   response frame — responses may complete out of order, and the id is
//!   what lets a multiplexing client (or a pipelined batch) match them
//!   back up without imposing FIFO on the server.
//! * The body is a compact tag-byte encoding of the same
//!   [`Request`]/[`Response`] enums the JSON protocol carries — identical
//!   semantics, zero text parsing, and codec blobs (`sketch_fetch`
//!   replies, `store_put`/`stream_merge` payloads) ride as **raw
//!   [`crate::sketch::codec`] bytes** instead of hex-in-JSON, halving
//!   their wire size.
//!
//! Decoding is strict, exactly like the snapshot codec: bad magic,
//! unknown version/kind/tag, out-of-range lengths, truncation inside any
//! field, payload bytes left over after the message, and checksum
//! mismatches are all clean `Err`s — never panics, never partial state.
//! [`decode_frame`] is incremental: on a prefix of a well-formed frame it
//! answers [`FrameStatus::Incomplete`] so a read loop can just keep
//! appending bytes and retrying.

use super::protocol::{check_weights, HelloInfo, QueryTarget, Request, Response, SketchSource};
use crate::sketch::codec::{self, Reader};
use crate::sketch::{GumbelMaxSketch, SparseVector};
use crate::util::hash::{fnv1a64, fnv1a64_chain};
use crate::util::json;

/// First byte of every binary frame. `0xFB` is an invalid first byte for
/// both JSON and UTF-8 text, so frame-vs-line auto-detection is exact.
pub const FRAME_MAGIC: u8 = 0xFB;
/// Frame layout version. Bumped on any layout change; decoders refuse
/// versions they don't know (no best-effort parsing of future frames).
pub const FRAME_VERSION: u8 = 1;
/// Bytes before the payload: magic, version, payload length.
pub const HEADER_LEN: usize = 6;
/// Trailing fnv1a64 checksum.
const TRAILER_LEN: usize = 8;
/// Payload floor: the request id and the kind byte.
const MIN_PAYLOAD: usize = 9;
/// Allocation guard — a corrupt length field must not ask the allocator
/// for gigabytes before the inevitable checksum/truncation error.
pub const MAX_PAYLOAD: usize = 1 << 26;

const KIND_REQUEST: u8 = 0;
const KIND_RESPONSE: u8 = 1;

/// Body tag of the `sketch_blob_bin` response — named (unlike the other
/// tags) because the zero-copy read path ([`FrameView::sketch_blob_bin`])
/// and the spliced write path must agree on it with the body codec.
const RESP_TAG_BLOB_BIN: u8 = 12;

/// A decoded frame body: the direction is part of the frame, so a server
/// can refuse response frames and a client request frames, loudly.
#[derive(Debug, Clone, PartialEq)]
pub enum FrameMsg {
    Request(Request),
    Response(Response),
}

/// Result of [`decode_frame`] on a (possibly partial) buffer front.
#[derive(Debug, Clone, PartialEq)]
pub enum FrameStatus {
    /// The buffer holds a prefix of a well-formed frame — read more bytes.
    Incomplete,
    /// One complete frame: `consumed` bytes of the buffer, carrying `msg`
    /// under client-assigned request id `id`.
    Frame { consumed: usize, id: u64, msg: FrameMsg },
}

/// Append one request frame to `out` (frames concatenate, so a pipelined
/// batch encodes into a single buffer → a single write).
pub fn encode_request_frame(id: u64, req: &Request, out: &mut Vec<u8>) {
    encode_frame(id, KIND_REQUEST, out, |b| encode_request_body(req, b));
}

/// Append one response frame to `out`, echoing the request's `id`.
pub fn encode_response_frame(id: u64, resp: &Response, out: &mut Vec<u8>) {
    encode_frame(id, KIND_RESPONSE, out, |b| encode_response_body(resp, b));
}

fn encode_frame(id: u64, kind: u8, out: &mut Vec<u8>, body: impl FnOnce(&mut Vec<u8>)) {
    let start = out.len();
    out.push(FRAME_MAGIC);
    out.push(FRAME_VERSION);
    codec::push_u32(out, 0); // payload_len, backpatched below
    codec::push_u64(out, id);
    out.push(kind);
    body(out);
    let payload_len = (out.len() - start - HEADER_LEN) as u32;
    out[start + 2..start + HEADER_LEN].copy_from_slice(&payload_len.to_le_bytes());
    let sum = fnv1a64(&out[start..]);
    codec::push_u64(out, sum);
}

/// Encode one request frame as buffers to be written back-to-back
/// (vectored). For the binary blob ops (`store_put_bin` /
/// `stream_merge_bin`) the already-encoded codec blob is **moved** into
/// its own span — `codec::encode_sketch_bytes` output is written once and
/// never re-buffered — with the frame checksum chained across the spans.
/// Every other request encodes into a single buffer, bit-identical to
/// [`encode_request_frame`] (so is the concatenation of the spans).
pub fn encode_request_frame_vectored(id: u64, req: Request) -> Vec<Vec<u8>> {
    match req {
        Request::StorePutBin { data } => splice_frame(id, KIND_REQUEST, data, |out| {
            out.push(25);
        }),
        Request::StreamMergeBin { stream, data } => {
            splice_frame(id, KIND_REQUEST, data, |out| {
                out.push(26);
                put_str(out, &stream);
            })
        }
        other => {
            let mut buf = Vec::new();
            encode_request_frame(id, &other, &mut buf);
            vec![buf]
        }
    }
}

/// Response-side twin of [`encode_request_frame_vectored`]: a
/// `sketch_blob_bin` reply splices its blob span verbatim; everything
/// else is a single buffer bit-identical to [`encode_response_frame`].
pub fn encode_response_frame_vectored(id: u64, resp: Response) -> Vec<Vec<u8>> {
    match resp {
        Response::SketchBlobBin { name, data } => {
            splice_frame(id, KIND_RESPONSE, data, |out| {
                out.push(RESP_TAG_BLOB_BIN);
                put_str(out, &name);
            })
        }
        other => {
            let mut buf = Vec::new();
            encode_response_frame(id, &other, &mut buf);
            vec![buf]
        }
    }
}

/// Build `[prefix, blob, trailer]`: the prefix holds header + id + kind +
/// the body head (tag and any scalar fields) + the blob's u32 length, the
/// blob span is the caller's buffer moved verbatim, and the trailer is
/// the fnv1a64 checksum folded incrementally across both prior spans —
/// byte-for-byte the frame [`encode_frame`] would have produced, without
/// ever copying the blob.
fn splice_frame(
    id: u64,
    kind: u8,
    blob: Vec<u8>,
    body_head: impl FnOnce(&mut Vec<u8>),
) -> Vec<Vec<u8>> {
    let mut prefix = Vec::with_capacity(HEADER_LEN + MIN_PAYLOAD + 64);
    prefix.push(FRAME_MAGIC);
    prefix.push(FRAME_VERSION);
    codec::push_u32(&mut prefix, 0); // payload_len, backpatched below
    codec::push_u64(&mut prefix, id);
    prefix.push(kind);
    body_head(&mut prefix);
    codec::push_u32(&mut prefix, blob.len() as u32);
    let payload_len = (prefix.len() - HEADER_LEN + blob.len()) as u32;
    prefix[2..HEADER_LEN].copy_from_slice(&payload_len.to_le_bytes());
    let sum = fnv1a64_chain(fnv1a64(&prefix), &blob);
    let mut trailer = Vec::with_capacity(TRAILER_LEN);
    codec::push_u64(&mut trailer, sum);
    vec![prefix, blob, trailer]
}

/// Envelope for an already-encoded request body: writing `prefix`, the
/// body bytes, then `trailer` back to back is bit-identical to
/// [`encode_request_frame`] — without re-encoding or copying the body.
/// This is the fan-out path: a replicated write or repair install
/// serializes its body ONCE and shares the bytes across every owner
/// connection; only this 14-byte prefix and 8-byte checksum trailer are
/// derived per frame (the request id is per-connection state).
pub fn request_frame_envelope(id: u64, body: &[u8]) -> (Vec<u8>, Vec<u8>) {
    let mut prefix = Vec::with_capacity(HEADER_LEN + MIN_PAYLOAD);
    prefix.push(FRAME_MAGIC);
    prefix.push(FRAME_VERSION);
    codec::push_u32(&mut prefix, (MIN_PAYLOAD + body.len()) as u32);
    codec::push_u64(&mut prefix, id);
    prefix.push(KIND_REQUEST);
    let sum = fnv1a64_chain(fnv1a64(&prefix), body);
    let mut trailer = Vec::with_capacity(TRAILER_LEN);
    codec::push_u64(&mut trailer, sum);
    (prefix, trailer)
}

/// Try to decode one frame off the front of `buf`. `Incomplete` means
/// "more bytes needed"; `Err` means the stream is corrupt (or not a frame
/// at all) and the connection should be torn down — framing cannot be
/// resynchronized once the length prefix is untrustworthy.
pub fn decode_frame(buf: &[u8]) -> anyhow::Result<FrameStatus> {
    match decode_frame_view(buf)? {
        FrameViewStatus::Incomplete => Ok(FrameStatus::Incomplete),
        FrameViewStatus::Frame(view) => Ok(FrameStatus::Frame {
            consumed: view.consumed,
            id: view.id,
            msg: view.message()?,
        }),
    }
}

/// One complete frame with its body **borrowed** from the caller's buffer:
/// header, length range and checksum are already validated, but the
/// message is not yet parsed. This is the zero-copy read path — a client
/// awaiting a `sketch_blob_bin` reply slices the codec blob straight out
/// of the connection's input buffer via [`FrameView::sketch_blob_bin`]
/// (registers sliced, not copied) instead of materializing an owned
/// [`Response`] first.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameView<'a> {
    /// Total frame bytes consumed off the buffer front.
    pub consumed: usize,
    /// Client-assigned request id (echoed verbatim in responses).
    pub id: u64,
    /// Direction: `true` for response frames (kind byte 1).
    pub is_response: bool,
    /// The tag-byte message body, borrowed from the input buffer.
    pub body: &'a [u8],
}

/// Result of [`decode_frame_view`] on a (possibly partial) buffer front.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FrameViewStatus<'a> {
    /// The buffer holds a prefix of a well-formed frame — read more bytes.
    Incomplete,
    /// One complete, checksum-verified frame borrowing the buffer.
    Frame(FrameView<'a>),
}

impl<'a> FrameView<'a> {
    /// Parse the borrowed body into an owned message — exactly what
    /// [`decode_frame`] returns, same strictness, same errors.
    pub fn message(&self) -> anyhow::Result<FrameMsg> {
        let mut r = Reader { bytes: self.body, pos: 0 };
        let msg = if self.is_response {
            FrameMsg::Response(read_response(&mut r)?)
        } else {
            FrameMsg::Request(read_request(&mut r)?)
        };
        anyhow::ensure!(
            r.remaining() == 0,
            "frame has {} trailing payload bytes after the message",
            r.remaining()
        );
        Ok(msg)
    }

    /// If this frame is a `sketch_blob_bin` response, return its name and
    /// the codec blob as a slice **borrowing the input buffer** — feed it
    /// to [`codec::decode_sketch_bytes`] directly, no intermediate copy.
    /// Any other frame answers `None` (fall back to [`Self::message`]).
    pub fn sketch_blob_bin(&self) -> anyhow::Result<Option<(String, &'a [u8])>> {
        if !self.is_response || self.body.first() != Some(&RESP_TAG_BLOB_BIN) {
            return Ok(None);
        }
        let mut r = Reader { bytes: &self.body[1..], pos: 0 };
        let name = get_str(&mut r)?;
        let blob = get_bytes(&mut r)?;
        anyhow::ensure!(
            r.remaining() == 0,
            "frame has {} trailing payload bytes after the blob",
            r.remaining()
        );
        Ok(Some((name, blob)))
    }
}

/// The borrowing half of [`decode_frame`]: validate the frame envelope
/// (magic, version, length range, checksum, kind byte) and hand back the
/// body as a slice of `buf` without parsing it. Same contract otherwise —
/// `Incomplete` wants more bytes, `Err` means tear the connection down.
pub fn decode_frame_view(buf: &[u8]) -> anyhow::Result<FrameViewStatus<'_>> {
    if buf.is_empty() {
        return Ok(FrameViewStatus::Incomplete);
    }
    anyhow::ensure!(
        buf[0] == FRAME_MAGIC,
        "not a binary frame (first byte 0x{:02x}, want 0x{FRAME_MAGIC:02x})",
        buf[0]
    );
    if buf.len() >= 2 {
        anyhow::ensure!(
            buf[1] == FRAME_VERSION,
            "unsupported frame version {} (this build speaks v{FRAME_VERSION})",
            buf[1]
        );
    }
    if buf.len() < HEADER_LEN {
        return Ok(FrameViewStatus::Incomplete);
    }
    let payload_len =
        u32::from_le_bytes(buf[2..HEADER_LEN].try_into().expect("4 bytes")) as usize;
    anyhow::ensure!(
        (MIN_PAYLOAD..=MAX_PAYLOAD).contains(&payload_len),
        "frame payload length {payload_len} out of range ({MIN_PAYLOAD}..={MAX_PAYLOAD})"
    );
    let total = HEADER_LEN + payload_len + TRAILER_LEN;
    if buf.len() < total {
        return Ok(FrameViewStatus::Incomplete);
    }
    let (checked, tail) = buf[..total].split_at(total - TRAILER_LEN);
    let want = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
    anyhow::ensure!(
        fnv1a64(checked) == want,
        "frame checksum mismatch (corrupt or torn stream)"
    );
    let mut r = Reader { bytes: &checked[HEADER_LEN..], pos: 0 };
    let id = r.u64()?;
    let is_response = match r.u8()? {
        KIND_REQUEST => false,
        KIND_RESPONSE => true,
        other => anyhow::bail!("unknown frame kind {other}"),
    };
    Ok(FrameViewStatus::Frame(FrameView {
        consumed: total,
        id,
        is_response,
        body: &checked[HEADER_LEN + MIN_PAYLOAD..],
    }))
}

/// Encode a request body alone (no frame header/checksum) — what the
/// frame-vs-JSON microbenches measure.
pub fn encode_request_body(req: &Request, out: &mut Vec<u8>) {
    match req {
        Request::Sketch { name, vector, algo } => {
            out.push(0);
            put_str(out, name);
            put_vector(out, vector);
            put_opt_str(out, algo.as_deref());
        }
        Request::SketchDense { name, weights } => {
            out.push(1);
            put_str(out, name);
            put_f64s(out, weights);
        }
        Request::GetSketch { name } => {
            out.push(2);
            put_str(out, name);
        }
        Request::Push { stream, items } => {
            out.push(3);
            put_str(out, stream);
            codec::push_u32(out, items.len() as u32);
            for &(id, w) in items {
                codec::push_u64(out, id);
                codec::push_u64(out, w.to_bits());
            }
        }
        Request::Cardinality { stream } => {
            out.push(4);
            put_str(out, stream);
        }
        Request::Jaccard { a, b } => {
            out.push(5);
            put_str(out, a);
            put_str(out, b);
        }
        Request::WeightedJaccard { a, b } => {
            out.push(6);
            put_str(out, a);
            put_str(out, b);
        }
        Request::Merge { names, out: dest } => {
            out.push(7);
            put_strs(out, names);
            put_str(out, dest);
        }
        Request::LshInsert { name } => {
            out.push(8);
            put_str(out, name);
        }
        Request::LshQuery { vector, limit } => {
            out.push(9);
            put_vector(out, vector);
            codec::push_u64(out, *limit as u64);
        }
        Request::Upsert { key, vector, version } => {
            out.push(10);
            put_str(out, key);
            put_vector(out, vector);
            match version {
                None => out.push(0),
                Some(v) => {
                    out.push(1);
                    codec::push_u64(out, *v);
                }
            }
        }
        Request::Delete { key } => {
            out.push(11);
            put_str(out, key);
        }
        Request::StoreKeys { after, limit } => {
            out.push(12);
            put_opt_str(out, after.as_deref());
            codec::push_u64(out, *limit as u64);
        }
        Request::StorePut { data } => {
            out.push(13);
            put_blob(out, data);
        }
        Request::StreamMerge { stream, data } => {
            out.push(14);
            put_str(out, stream);
            put_blob(out, data);
        }
        Request::TopK { vector, limit } => {
            out.push(15);
            put_vector(out, vector);
            codec::push_u64(out, *limit as u64);
        }
        Request::StoreStats => out.push(16),
        Request::Snapshot { path } => {
            out.push(17);
            put_str(out, path);
        }
        Request::Restore { path } => {
            out.push(18);
            put_str(out, path);
        }
        Request::Hello => out.push(19),
        Request::SketchFetch { name, source } => {
            out.push(20);
            put_str(out, name);
            out.push(source_tag(*source));
        }
        Request::Metrics => out.push(21),
        Request::Ping => out.push(22),
        Request::Sample { target, n, seed } => {
            out.push(23);
            put_target(out, target);
            codec::push_u64(out, *n as u64);
            codec::push_u64(out, *seed);
        }
        Request::Partition { target } => {
            out.push(24);
            put_target(out, target);
        }
        Request::StorePutBin { data } => {
            out.push(25);
            put_bytes(out, data);
        }
        Request::StreamMergeBin { stream, data } => {
            out.push(26);
            put_str(out, stream);
            put_bytes(out, data);
        }
        Request::SketchFetchBin { name, source } => {
            out.push(27);
            put_str(out, name);
            out.push(source_tag(*source));
        }
    }
}

/// Strict inverse of [`encode_request_body`].
pub fn decode_request_body(bytes: &[u8]) -> anyhow::Result<Request> {
    let mut r = Reader { bytes, pos: 0 };
    let req = read_request(&mut r)?;
    anyhow::ensure!(r.remaining() == 0, "{} trailing bytes after request", r.remaining());
    Ok(req)
}

fn read_request(r: &mut Reader) -> anyhow::Result<Request> {
    Ok(match r.u8()? {
        0 => Request::Sketch {
            name: get_str(r)?,
            vector: get_vector(r)?,
            algo: get_opt_str(r)?,
        },
        1 => Request::SketchDense { name: get_str(r)?, weights: get_f64s(r)? },
        2 => Request::GetSketch { name: get_str(r)? },
        3 => Request::Push {
            stream: get_str(r)?,
            items: {
                let n = r.u32()? as usize;
                anyhow::ensure!(r.remaining() >= 16 * n, "truncated push items (n={n})");
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    let id = r.u64()?;
                    let w = f64::from_bits(r.u64()?);
                    items.push((id, w));
                }
                items
            },
        },
        4 => Request::Cardinality { stream: get_str(r)? },
        5 => Request::Jaccard { a: get_str(r)?, b: get_str(r)? },
        6 => Request::WeightedJaccard { a: get_str(r)?, b: get_str(r)? },
        7 => Request::Merge { names: get_strs(r)?, out: get_str(r)? },
        8 => Request::LshInsert { name: get_str(r)? },
        9 => Request::LshQuery { vector: get_vector(r)?, limit: get_usize(r)? },
        10 => Request::Upsert {
            key: get_str(r)?,
            vector: get_vector(r)?,
            version: match r.u8()? {
                0 => None,
                1 => Some(r.u64()?),
                other => anyhow::bail!("bad option flag {other}"),
            },
        },
        11 => Request::Delete { key: get_str(r)? },
        12 => Request::StoreKeys { after: get_opt_str(r)?, limit: get_usize(r)? },
        13 => Request::StorePut { data: get_blob(r)? },
        14 => Request::StreamMerge { stream: get_str(r)?, data: get_blob(r)? },
        15 => Request::TopK { vector: get_vector(r)?, limit: get_usize(r)? },
        16 => Request::StoreStats,
        17 => Request::Snapshot { path: get_str(r)? },
        18 => Request::Restore { path: get_str(r)? },
        19 => Request::Hello,
        20 => Request::SketchFetch { name: get_str(r)?, source: source_from_tag(r.u8()?)? },
        21 => Request::Metrics,
        22 => Request::Ping,
        23 => Request::Sample {
            target: get_target(r)?,
            n: get_usize(r)?,
            seed: r.u64()?,
        },
        24 => Request::Partition { target: get_target(r)? },
        25 => Request::StorePutBin { data: get_bytes(r)?.to_vec() },
        26 => Request::StreamMergeBin { stream: get_str(r)?, data: get_bytes(r)?.to_vec() },
        27 => Request::SketchFetchBin {
            name: get_str(r)?,
            source: source_from_tag(r.u8()?)?,
        },
        other => anyhow::bail!("unknown request tag {other}"),
    })
}

/// Encode a response body alone (no frame header/checksum).
pub fn encode_response_body(resp: &Response, out: &mut Vec<u8>) {
    match resp {
        Response::Sketch { name, sketch } => {
            out.push(0);
            put_str(out, name);
            put_sketch(out, sketch);
        }
        Response::Ack { info } => {
            out.push(1);
            put_str(out, info);
        }
        Response::Estimate { value } => {
            out.push(2);
            codec::push_u64(out, value.to_bits());
        }
        Response::TopK { hits } => {
            out.push(3);
            codec::push_u32(out, hits.len() as u32);
            for (name, score) in hits {
                put_str(out, name);
                codec::push_u64(out, score.to_bits());
            }
        }
        // Metrics/stats snapshots are free-form JSON values; they ride as
        // compact JSON text inside the binary frame (cold ops — not worth
        // a binary schema of their own).
        Response::MetricsDump { snapshot } => {
            out.push(4);
            put_str(out, &snapshot.to_string());
        }
        Response::Stats { stats } => {
            out.push(5);
            put_str(out, &stats.to_string());
        }
        Response::Keys { keys } => {
            out.push(6);
            codec::push_u32(out, keys.len() as u32);
            for (key, version) in keys {
                put_str(out, key);
                codec::push_u64(out, *version);
            }
        }
        Response::Hello { info } => {
            out.push(7);
            codec::push_u64(out, info.protocol);
            put_str(out, &info.node);
            codec::push_u64(out, info.epoch);
            codec::push_u64(out, info.k as u64);
            codec::push_u64(out, info.seed);
            put_str(out, &info.algo);
            put_strs(out, &info.algos);
        }
        Response::SketchBlob { name, data } => {
            out.push(8);
            put_str(out, name);
            put_blob(out, data);
        }
        Response::Error { message } => {
            out.push(9);
            put_str(out, message);
        }
        Response::Pong => out.push(10),
        Response::Samples { ids } => {
            out.push(11);
            codec::push_u32(out, ids.len() as u32);
            for &id in ids {
                codec::push_u64(out, id);
            }
        }
        Response::SketchBlobBin { name, data } => {
            out.push(RESP_TAG_BLOB_BIN);
            put_str(out, name);
            put_bytes(out, data);
        }
    }
}

/// Strict inverse of [`encode_response_body`].
pub fn decode_response_body(bytes: &[u8]) -> anyhow::Result<Response> {
    let mut r = Reader { bytes, pos: 0 };
    let resp = read_response(&mut r)?;
    anyhow::ensure!(r.remaining() == 0, "{} trailing bytes after response", r.remaining());
    Ok(resp)
}

fn read_response(r: &mut Reader) -> anyhow::Result<Response> {
    Ok(match r.u8()? {
        0 => Response::Sketch { name: get_str(r)?, sketch: get_sketch(r)? },
        1 => Response::Ack { info: get_str(r)? },
        2 => Response::Estimate { value: f64::from_bits(r.u64()?) },
        3 => Response::TopK {
            hits: {
                let n = r.u32()? as usize;
                anyhow::ensure!(n <= r.remaining(), "truncated topk hits (n={n})");
                let mut hits = Vec::with_capacity(n);
                for _ in 0..n {
                    let name = get_str(r)?;
                    let score = f64::from_bits(r.u64()?);
                    hits.push((name, score));
                }
                hits
            },
        },
        4 => Response::MetricsDump { snapshot: json::parse(&get_str(r)?)? },
        5 => Response::Stats { stats: json::parse(&get_str(r)?)? },
        6 => Response::Keys {
            keys: {
                let n = r.u32()? as usize;
                anyhow::ensure!(n <= r.remaining(), "truncated keys page (n={n})");
                let mut keys = Vec::with_capacity(n);
                for _ in 0..n {
                    let key = get_str(r)?;
                    let version = r.u64()?;
                    keys.push((key, version));
                }
                keys
            },
        },
        7 => Response::Hello {
            info: HelloInfo {
                protocol: r.u64()?,
                node: get_str(r)?,
                epoch: r.u64()?,
                k: get_usize(r)?,
                seed: r.u64()?,
                algo: get_str(r)?,
                algos: get_strs(r)?,
            },
        },
        8 => Response::SketchBlob { name: get_str(r)?, data: get_blob(r)? },
        9 => Response::Error { message: get_str(r)? },
        10 => Response::Pong,
        11 => Response::Samples {
            ids: {
                let n = r.u32()? as usize;
                anyhow::ensure!(r.remaining() >= 8 * n, "truncated sample ids (n={n})");
                let mut ids = Vec::with_capacity(n);
                for _ in 0..n {
                    ids.push(r.u64()?);
                }
                ids
            },
        },
        RESP_TAG_BLOB_BIN => Response::SketchBlobBin {
            name: get_str(r)?,
            data: get_bytes(r)?.to_vec(),
        },
        other => anyhow::bail!("unknown response tag {other}"),
    })
}

// -- field primitives ------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) {
    codec::push_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn get_str(r: &mut Reader) -> anyhow::Result<String> {
    let n = r.u32()? as usize;
    anyhow::ensure!(n <= MAX_PAYLOAD, "string length {n} too large");
    Ok(std::str::from_utf8(r.take(n)?)
        .map_err(|e| anyhow::anyhow!("string field is not UTF-8: {e}"))?
        .to_string())
}

fn put_opt_str(out: &mut Vec<u8>, s: Option<&str>) {
    match s {
        None => out.push(0),
        Some(s) => {
            out.push(1);
            put_str(out, s);
        }
    }
}

fn get_opt_str(r: &mut Reader) -> anyhow::Result<Option<String>> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(get_str(r)?)),
        other => anyhow::bail!("bad option flag {other}"),
    }
}

fn put_strs(out: &mut Vec<u8>, ss: &[String]) {
    codec::push_u32(out, ss.len() as u32);
    for s in ss {
        put_str(out, s);
    }
}

fn get_strs(r: &mut Reader) -> anyhow::Result<Vec<String>> {
    let n = r.u32()? as usize;
    anyhow::ensure!(n <= r.remaining(), "truncated string list (n={n})");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(get_str(r)?);
    }
    Ok(out)
}

fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    codec::push_u32(out, xs.len() as u32);
    for &x in xs {
        codec::push_u64(out, x.to_bits());
    }
}

fn get_f64s(r: &mut Reader) -> anyhow::Result<Vec<f64>> {
    let n = r.u32()? as usize;
    anyhow::ensure!(r.remaining() >= 8 * n, "truncated f64 array (n={n})");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(f64::from_bits(r.u64()?));
    }
    Ok(out)
}

fn get_usize(r: &mut Reader) -> anyhow::Result<usize> {
    let v = r.u64()?;
    usize::try_from(v).map_err(|_| anyhow::anyhow!("value {v} overflows usize"))
}

fn put_vector(out: &mut Vec<u8>, v: &SparseVector) {
    codec::push_u32(out, v.ids.len() as u32);
    for &id in &v.ids {
        codec::push_u64(out, id);
    }
    for &w in &v.weights {
        codec::push_u64(out, w.to_bits());
    }
}

fn get_vector(r: &mut Reader) -> anyhow::Result<SparseVector> {
    let n = r.u32()? as usize;
    anyhow::ensure!(r.remaining() >= 16 * n, "truncated sparse vector (n={n})");
    let mut ids = Vec::with_capacity(n);
    for _ in 0..n {
        ids.push(r.u64()?);
    }
    let mut weights = Vec::with_capacity(n);
    for _ in 0..n {
        weights.push(f64::from_bits(r.u64()?));
    }
    // Same ingress guard as the JSON wire — raw f64 bits make NaN/inf
    // trivially expressible here, so the framed path must reject them too.
    check_weights(&weights)?;
    Ok(SparseVector::new(ids, weights))
}

/// Register arrays travel as raw bit patterns — bit-identical restore,
/// exactly like [`crate::sketch::codec`]'s snapshot entries.
fn put_sketch(out: &mut Vec<u8>, sk: &GumbelMaxSketch) {
    out.push(codec::family_tag(sk.family));
    codec::push_u64(out, sk.seed);
    codec::push_u64(out, sk.k() as u64);
    for &y in &sk.y {
        codec::push_u64(out, y.to_bits());
    }
    for &s in &sk.s {
        codec::push_u64(out, s);
    }
}

fn get_sketch(r: &mut Reader) -> anyhow::Result<GumbelMaxSketch> {
    let family = codec::family_from_tag(r.u8()?)?;
    let seed = r.u64()?;
    let k = r.u64()?;
    anyhow::ensure!(k <= codec::MAX_K, "register count {k} too large");
    anyhow::ensure!(r.remaining() as u64 >= 16 * k, "truncated register arrays (k={k})");
    let k = k as usize;
    let mut y = Vec::with_capacity(k);
    for j in 0..k {
        let v = f64::from_bits(r.u64()?);
        anyhow::ensure!(!v.is_nan(), "register y[{j}] is NaN");
        y.push(v);
    }
    let mut s = Vec::with_capacity(k);
    for _ in 0..k {
        s.push(r.u64()?);
    }
    Ok(GumbelMaxSketch { family, seed, y, s })
}

fn put_target(out: &mut Vec<u8>, t: &QueryTarget) {
    match t {
        QueryTarget::Keys(keys) => {
            out.push(0);
            put_strs(out, keys);
        }
        QueryTarget::Stream(s) => {
            out.push(1);
            put_str(out, s);
        }
    }
}

fn get_target(r: &mut Reader) -> anyhow::Result<QueryTarget> {
    Ok(match r.u8()? {
        0 => QueryTarget::Keys(get_strs(r)?),
        1 => QueryTarget::Stream(get_str(r)?),
        other => anyhow::bail!("unknown query target tag {other}"),
    })
}

fn source_tag(s: SketchSource) -> u8 {
    match s {
        SketchSource::Store => 0,
        SketchSource::Registry => 1,
        SketchSource::Stream => 2,
    }
}

fn source_from_tag(t: u8) -> anyhow::Result<SketchSource> {
    Ok(match t {
        0 => SketchSource::Store,
        1 => SketchSource::Registry,
        2 => SketchSource::Stream,
        other => anyhow::bail!("unknown sketch_fetch source tag {other}"),
    })
}

/// Codec-blob fields (`store_put`/`stream_merge` payloads, `sketch_blob`
/// replies) are hex strings on the JSON wire. On the binary wire the
/// common case — lowercase hex, which is exactly what
/// [`codec::encode_sketch_hex`] emits — ships as the raw decoded bytes
/// (flag 0, half the size); anything else ships as a literal string
/// (flag 1), so round-trips are byte-exact either way and a server-side
/// validation error for malformed hex surfaces identically on both wires.
fn put_blob(out: &mut Vec<u8>, data: &str) {
    if is_lower_hex(data) {
        out.push(0);
        let raw = codec::from_hex(data).expect("lowercase hex checked");
        codec::push_u32(out, raw.len() as u32);
        out.extend_from_slice(&raw);
    } else {
        out.push(1);
        put_str(out, data);
    }
}

fn get_blob(r: &mut Reader) -> anyhow::Result<String> {
    match r.u8()? {
        0 => {
            let n = r.u32()? as usize;
            anyhow::ensure!(n <= MAX_PAYLOAD, "blob length {n} too large");
            Ok(codec::to_hex(r.take(n)?))
        }
        1 => get_str(r),
        other => anyhow::bail!("bad blob flag {other}"),
    }
}

fn is_lower_hex(s: &str) -> bool {
    s.len() % 2 == 0 && s.bytes().all(|b| matches!(b, b'0'..=b'9' | b'a'..=b'f'))
}

/// Raw byte blob: u32 length + bytes. The binary blob ops' payload form —
/// no hex detection, no flag byte; the bytes ARE the codec blob.
fn put_bytes(out: &mut Vec<u8>, data: &[u8]) {
    codec::push_u32(out, data.len() as u32);
    out.extend_from_slice(data);
}

/// Borrowing inverse of [`put_bytes`] — the slice aliases the reader's
/// buffer, so the zero-copy paths never duplicate the blob. The length
/// guard rejects hostile prefixes before any allocation happens.
fn get_bytes<'a>(r: &mut Reader<'a>) -> anyhow::Result<&'a [u8]> {
    let n = r.u32()? as usize;
    anyhow::ensure!(n <= MAX_PAYLOAD, "byte blob length {n} too large");
    r.take(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::PROTOCOL_VERSION;
    use crate::sketch::{Family, Sketcher};
    use crate::util::json::Value;

    fn sample_vector() -> SparseVector {
        SparseVector::new(vec![1, 5, u64::MAX - 2], vec![0.5, 2.0, -0.0])
    }

    fn sample_sketch() -> GumbelMaxSketch {
        crate::sketch::fastgm::FastGm::new(8, 7).sketch(&sample_vector())
    }

    fn all_requests() -> Vec<Request> {
        let v = sample_vector();
        let hex = codec::encode_sketch_hex("a", 3, &sample_sketch());
        vec![
            Request::Sketch { name: "doc".into(), vector: v.clone(), algo: None },
            Request::Sketch {
                name: "doc".into(),
                vector: v.clone(),
                algo: Some("pminhash".into()),
            },
            Request::SketchDense { name: "d".into(), weights: vec![0.0, 1.5, -2.25] },
            Request::GetSketch { name: "doc".into() },
            Request::Push { stream: "s".into(), items: vec![(3, 0.5), (u64::MAX, 1.0)] },
            Request::Cardinality { stream: "s".into() },
            Request::Jaccard { a: "x".into(), b: "y".into() },
            Request::WeightedJaccard { a: "x".into(), b: "βeta".into() },
            Request::Merge { names: vec!["a".into(), "b".into()], out: "u".into() },
            Request::LshInsert { name: "doc".into() },
            Request::LshQuery { vector: v.clone(), limit: 10 },
            Request::Upsert { key: "doc".into(), vector: v.clone(), version: None },
            Request::Upsert {
                key: "doc".into(),
                vector: v.clone(),
                version: Some(u64::MAX - 5),
            },
            Request::Delete { key: "doc".into() },
            Request::StoreKeys { after: None, limit: 100 },
            Request::StoreKeys { after: Some("doc".into()), limit: 64 },
            Request::StorePut { data: hex.clone() },
            Request::StorePut { data: "NOT-HEX".into() },
            Request::StreamMerge { stream: "s".into(), data: hex },
            Request::TopK { vector: v, limit: 5 },
            Request::Sample { target: QueryTarget::key("doc"), n: 8, seed: 7 },
            Request::Sample {
                target: QueryTarget::Keys(vec!["doc".into(), "βeta".into()]),
                n: 3,
                seed: u64::MAX,
            },
            Request::Sample { target: QueryTarget::Stream("pkts".into()), n: 1, seed: 0 },
            Request::Partition { target: QueryTarget::Keys(vec!["a".into(), "b".into()]) },
            Request::Partition { target: QueryTarget::Stream("pkts".into()) },
            Request::StoreStats,
            Request::Snapshot { path: "/tmp/fgm.snap".into() },
            Request::Restore { path: "/tmp/fgm.snap".into() },
            Request::Hello,
            Request::SketchFetch { name: "doc".into(), source: SketchSource::Store },
            Request::SketchFetch { name: "doc".into(), source: SketchSource::Registry },
            Request::SketchFetch { name: "doc".into(), source: SketchSource::Stream },
            Request::Metrics,
            Request::Ping,
            Request::StorePutBin {
                data: codec::encode_sketch_bytes("a", 3, &sample_sketch()),
            },
            Request::StorePutBin { data: vec![] },
            Request::StreamMergeBin {
                stream: "s".into(),
                data: codec::encode_sketch_bytes("s", 0, &sample_sketch()),
            },
            Request::SketchFetchBin { name: "doc".into(), source: SketchSource::Store },
            Request::SketchFetchBin { name: "doc".into(), source: SketchSource::Stream },
        ]
    }

    fn all_responses() -> Vec<Response> {
        let mut sk = GumbelMaxSketch::empty(Family::Ordered, 7, 4);
        sk.y[2] = 0.125;
        sk.s[2] = u64::MAX - 1;
        vec![
            Response::Sketch { name: "doc".into(), sketch: sk.clone() },
            Response::Sketch { name: "live".into(), sketch: sample_sketch() },
            Response::Ack { info: "stored".into() },
            Response::Estimate { value: 3.5 },
            Response::Estimate { value: f64::INFINITY },
            Response::TopK { hits: vec![("a".into(), 0.9), ("βeta".into(), 0.5)] },
            Response::TopK { hits: vec![] },
            Response::MetricsDump {
                snapshot: Value::obj(vec![("counters", Value::obj(vec![]))]),
            },
            Response::Stats {
                stats: Value::obj(vec![("size", Value::num(3.0)), ("shards", Value::num(8.0))]),
            },
            // The extended store_stats shape: write generations plus the
            // nested read-path cache object ride inside the opaque JSON
            // payload, so the frame body codec needs no schema change.
            Response::Stats {
                stats: Value::obj(vec![
                    ("size", Value::num(2.0)),
                    ("generation", Value::num(9.0)),
                    ("delete_generation", Value::num(1.0)),
                    (
                        "cache",
                        Value::obj(vec![
                            ("enabled", Value::Bool(true)),
                            ("hits", Value::num(3.0)),
                            ("stale_drops", Value::num(1.0)),
                            ("bytes", Value::num(4096.0)),
                            ("max_bytes", Value::num(8388608.0)),
                        ]),
                    ),
                ]),
            },
            Response::Keys { keys: vec![("doc1".into(), 3), ("doc2".into(), u64::MAX - 1)] },
            Response::Keys { keys: vec![] },
            Response::Hello {
                info: HelloInfo {
                    protocol: PROTOCOL_VERSION,
                    node: "node-0".into(),
                    epoch: 2,
                    k: 256,
                    seed: u64::MAX,
                    algo: "fastgm".into(),
                    algos: vec!["fastgm".into(), "pminhash".into()],
                },
            },
            Response::SketchBlob {
                name: "doc".into(),
                data: codec::encode_sketch_hex("doc", 9, &sk),
            },
            Response::SketchBlob { name: "weird".into(), data: "UPPER-case".into() },
            Response::Error { message: "nope".into() },
            Response::Pong,
            Response::Samples { ids: vec![3, 17, 3, u64::MAX - 2] },
            Response::Samples { ids: vec![] },
            Response::SketchBlobBin {
                name: "doc".into(),
                data: codec::encode_sketch_bytes("doc", 9, &sk),
            },
            Response::SketchBlobBin { name: "empty".into(), data: vec![] },
        ]
    }

    #[test]
    fn every_request_roundtrips_through_a_frame() {
        for (i, req) in all_requests().into_iter().enumerate() {
            let id = 1 + (i as u64) * 7;
            let mut buf = Vec::new();
            encode_request_frame(id, &req, &mut buf);
            assert_eq!(buf[0], FRAME_MAGIC);
            let FrameStatus::Frame { consumed, id: got, msg } = decode_frame(&buf).unwrap()
            else {
                panic!("frame {i} incomplete")
            };
            assert_eq!(consumed, buf.len());
            assert_eq!(got, id);
            assert_eq!(msg, FrameMsg::Request(req));
        }
    }

    #[test]
    fn every_response_roundtrips_through_a_frame() {
        for (i, resp) in all_responses().into_iter().enumerate() {
            let id = u64::MAX - i as u64;
            let mut buf = Vec::new();
            encode_response_frame(id, &resp, &mut buf);
            let FrameStatus::Frame { consumed, id: got, msg } = decode_frame(&buf).unwrap()
            else {
                panic!("response frame {i} incomplete")
            };
            assert_eq!(consumed, buf.len());
            assert_eq!(got, id);
            assert_eq!(msg, FrameMsg::Response(resp));
        }
    }

    #[test]
    fn concatenated_frames_decode_in_order() {
        let reqs = all_requests();
        let mut buf = Vec::new();
        for (i, req) in reqs.iter().enumerate() {
            encode_request_frame(i as u64, req, &mut buf);
        }
        let mut off = 0;
        for (i, req) in reqs.iter().enumerate() {
            let FrameStatus::Frame { consumed, id, msg } = decode_frame(&buf[off..]).unwrap()
            else {
                panic!("frame {i} incomplete at offset {off}")
            };
            assert_eq!(id, i as u64);
            assert_eq!(msg, FrameMsg::Request(req.clone()));
            off += consumed;
        }
        assert_eq!(off, buf.len());
    }

    #[test]
    fn body_encodings_roundtrip_standalone() {
        for req in all_requests() {
            let mut body = Vec::new();
            encode_request_body(&req, &mut body);
            assert_eq!(decode_request_body(&body).unwrap(), req);
            // Trailing garbage after a complete message is rejected.
            body.push(0);
            assert!(decode_request_body(&body).is_err());
        }
        for resp in all_responses() {
            let mut body = Vec::new();
            encode_response_body(&resp, &mut body);
            assert_eq!(decode_response_body(&body).unwrap(), resp);
        }
    }

    #[test]
    fn lowercase_hex_blobs_ship_as_raw_bytes() {
        let hex = codec::encode_sketch_hex("doc", 1, &sample_sketch());
        let mut framed = Vec::new();
        encode_request_body(&Request::StorePut { data: hex.clone() }, &mut framed);
        // Roughly half the hex size: tag + blob flag + u32 len + raw bytes.
        assert!(
            framed.len() < hex.len() / 2 + 16,
            "blob not sent raw: {} bytes for {} hex chars",
            framed.len(),
            hex.len()
        );
        // Uppercase hex survives verbatim through the literal path.
        let upper = hex.to_uppercase();
        let mut body = Vec::new();
        encode_request_body(&Request::StorePut { data: upper.clone() }, &mut body);
        let Request::StorePut { data } = decode_request_body(&body).unwrap() else {
            panic!("wrong variant")
        };
        assert_eq!(data, upper);
    }

    #[test]
    fn sketch_registers_roundtrip_bit_identically() {
        let mut sk = GumbelMaxSketch::empty(Family::Direct, 3, 4);
        sk.y[1] = 0.125;
        sk.s[1] = u64::MAX - 1;
        let resp = Response::Sketch { name: "x".into(), sketch: sk.clone() };
        let mut buf = Vec::new();
        encode_response_frame(9, &resp, &mut buf);
        let FrameStatus::Frame { msg: FrameMsg::Response(Response::Sketch { sketch, .. }), .. } =
            decode_frame(&buf).unwrap()
        else {
            panic!("expected sketch response")
        };
        // Untouched registers (the +inf / EMPTY sentinels) survive exactly.
        assert!(sketch.y[0].is_infinite());
        assert_eq!(sketch, sk);
    }

    /// The spliced (vectored) encoders must be indistinguishable on the
    /// wire from the contiguous ones: concatenating the spans reproduces
    /// the frame byte for byte, and the blob span is the caller's buffer
    /// verbatim — written once, never re-buffered.
    #[test]
    fn vectored_encoders_are_bit_identical_and_do_not_copy_the_blob() {
        let blob = codec::encode_sketch_bytes("doc", 5, &sample_sketch());
        for req in [
            Request::StorePutBin { data: blob.clone() },
            Request::StreamMergeBin { stream: "s".into(), data: blob.clone() },
        ] {
            let mut contiguous = Vec::new();
            encode_request_frame(7, &req, &mut contiguous);
            let parts = encode_request_frame_vectored(7, req);
            assert_eq!(parts.len(), 3, "blob requests splice into three spans");
            assert_eq!(parts[1], blob, "middle span must be the blob verbatim");
            assert_eq!(parts.concat(), contiguous);
        }
        let resp = Response::SketchBlobBin { name: "doc".into(), data: blob.clone() };
        let mut contiguous = Vec::new();
        encode_response_frame(9, &resp, &mut contiguous);
        let parts = encode_response_frame_vectored(9, resp);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[1], blob);
        assert_eq!(parts.concat(), contiguous);
        // Non-blob messages fall back to one contiguous buffer.
        let mut ping = Vec::new();
        encode_request_frame(1, &Request::Ping, &mut ping);
        assert_eq!(encode_request_frame_vectored(1, Request::Ping), vec![ping.clone()]);
        let mut pong = Vec::new();
        encode_response_frame(1, &Response::Pong, &mut pong);
        assert_eq!(encode_response_frame_vectored(1, Response::Pong), vec![pong]);
    }

    /// `decode_frame_view` + `sketch_blob_bin` is the zero-copy read path:
    /// the returned blob slice must alias the input buffer (no copy), and
    /// the borrowed bytes must decode to the exact sketch that was sent.
    #[test]
    fn frame_view_borrows_the_blob_from_the_input_buffer() {
        let sk = sample_sketch();
        let blob = codec::encode_sketch_bytes("doc", 5, &sk);
        let mut buf = Vec::new();
        encode_response_frame(
            42,
            &Response::SketchBlobBin { name: "doc".into(), data: blob.clone() },
            &mut buf,
        );
        let FrameViewStatus::Frame(view) = decode_frame_view(&buf).unwrap() else {
            panic!("complete frame must decode")
        };
        assert_eq!((view.consumed, view.id, view.is_response), (buf.len(), 42, true));
        let (name, borrowed) = view.sketch_blob_bin().unwrap().expect("blob frame");
        assert_eq!(name, "doc");
        assert_eq!(borrowed, &blob[..]);
        // The slice aliases `buf` — sliced, not copied.
        let range = buf.as_ptr() as usize..buf.as_ptr() as usize + buf.len();
        assert!(range.contains(&(borrowed.as_ptr() as usize)), "blob was copied");
        let (key, version, back) = codec::decode_sketch_bytes(borrowed).unwrap();
        assert_eq!((key.as_str(), version), ("doc", 5));
        assert_eq!(back, sk);
        // Non-blob frames answer None; view.message() still parses them.
        let mut other = Vec::new();
        encode_response_frame(1, &Response::Pong, &mut other);
        let FrameViewStatus::Frame(view) = decode_frame_view(&other).unwrap() else {
            panic!("pong frame must decode")
        };
        assert_eq!(view.sketch_blob_bin().unwrap(), None);
        assert_eq!(view.message().unwrap(), FrameMsg::Response(Response::Pong));
        // Request frames never match the response-blob fast path.
        let mut req = Vec::new();
        encode_request_frame(1, &Request::StorePutBin { data: blob }, &mut req);
        let FrameViewStatus::Frame(view) = decode_frame_view(&req).unwrap() else {
            panic!("request frame must decode")
        };
        assert_eq!(view.sketch_blob_bin().unwrap(), None);
    }

    /// The fan-out envelope must reproduce `encode_request_frame` byte
    /// for byte around a shared body, for every request shape and id.
    #[test]
    fn request_frame_envelope_is_bit_identical_to_contiguous_encode() {
        for (i, req) in all_requests().into_iter().enumerate() {
            let id = (i as u64) * 31 + 5;
            let mut body = Vec::new();
            encode_request_body(&req, &mut body);
            let (prefix, trailer) = request_frame_envelope(id, &body);
            let mut spliced = prefix;
            spliced.extend_from_slice(&body);
            spliced.extend_from_slice(&trailer);
            let mut contiguous = Vec::new();
            encode_request_frame(id, &req, &mut contiguous);
            assert_eq!(spliced, contiguous, "request {i} envelope diverged");
        }
    }

    #[test]
    fn incomplete_prefixes_ask_for_more_bytes() {
        let mut buf = Vec::new();
        encode_request_frame(1, &Request::Ping, &mut buf);
        for len in 0..buf.len() {
            match decode_frame(&buf[..len]) {
                Ok(FrameStatus::Incomplete) => {}
                other => panic!("prefix {len}/{}: {other:?}", buf.len()),
            }
        }
        assert!(matches!(decode_frame(&buf).unwrap(), FrameStatus::Frame { .. }));
    }

    /// The binary wire carries raw f64 bits, so NaN/inf/negative weights
    /// are trivially expressible — the framed decode must apply the same
    /// ingress guard as the JSON path (they share `check_weights`).
    #[test]
    fn framed_vectors_reject_invalid_weights() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0] {
            let v = SparseVector { ids: vec![1, 2], weights: vec![0.5, bad] };
            let mut body = Vec::new();
            encode_request_body(&Request::TopK { vector: v, limit: 3 }, &mut body);
            let err = decode_request_body(&body).unwrap_err().to_string();
            assert!(err.contains("index 1"), "weight {bad}: {err}");
            assert!(err.contains("non-negative finite"), "weight {bad}: {err}");
        }
    }

    #[test]
    fn json_first_bytes_are_never_frames() {
        for lead in [b'{', b' ', b'\t', b'p', 0x00] {
            let err = decode_frame(&[lead, 1, 2, 3]).unwrap_err();
            assert!(err.to_string().contains("not a binary frame"), "{err}");
        }
    }

    #[test]
    fn version_kind_and_length_violations_are_clean_errors() {
        let mut buf = Vec::new();
        encode_request_frame(1, &Request::Ping, &mut buf);
        // Future frame version: refused as soon as the byte is seen.
        let mut wrong_version = buf.clone();
        wrong_version[1] = FRAME_VERSION + 1;
        let err = decode_frame(&wrong_version).unwrap_err();
        assert!(err.to_string().contains("frame version"), "{err}");
        // Oversized payload length: refused before any allocation.
        let mut huge = buf.clone();
        huge[2..6].copy_from_slice(&(u32::MAX).to_le_bytes());
        let err = decode_frame(&huge).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        // Unknown kind byte (checksum refreshed so framing is valid).
        let mut bad_kind = buf.clone();
        bad_kind[HEADER_LEN + 8] = 7;
        let n = bad_kind.len();
        let sum = fnv1a64(&bad_kind[..n - 8]);
        bad_kind[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = decode_frame(&bad_kind).unwrap_err();
        assert!(err.to_string().contains("frame kind"), "{err}");
    }
}
