//! TCP JSON-lines server: one accept loop, one thread per connection, each
//! line a [`protocol::Request`], each reply a single JSON line. The server
//! is pure transport — it decodes lines and hands typed requests to the
//! [`Coordinator`] (whose pool runs [`super::node::Node::execute`]); no
//! request logic lives here, so everything it serves is equally reachable
//! without a socket.
//!
//! Shutdown is cooperative AND fully joined: a flag plus a self-connection
//! unblock `accept`, per-connection read timeouts let idle connections
//! observe the flag, and [`Server::stop`] joins every live connection
//! thread — it can never return while a request is still being processed
//! or a response is mid-write.

use super::protocol::{self, Response};
use super::service::Coordinator;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// How often a blocked connection read/write re-checks the shutdown flag.
/// Bounds how long [`Server::stop`] waits on connections with no traffic.
const IDLE_POLL: Duration = Duration::from_millis(50);

/// After shutdown is signalled, how many more write polls a non-draining
/// client gets to accept an in-flight response before the connection is
/// dropped (`IDLE_POLL` each — ~2s total). Slow-but-alive clients are
/// never torn during normal operation: write timeouts just retry.
const SHUTDOWN_DRAIN_POLLS: u32 = 40;

/// How often the janitor thread reaps finished connection handles. A
/// burst of short-lived connections followed by quiet must not leave dead
/// `JoinHandle`s pinned until the next accept (or `stop()`).
const REAP_PERIOD: Duration = Duration::from_millis(100);

pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    janitor: Option<JoinHandle<()>>,
    /// Live per-connection threads, joined by [`Server::stop`]. Reaped on
    /// every accept AND periodically by the janitor, so the vector tracks
    /// open connections, not connection history.
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve.
    pub fn start(coordinator: Arc<Coordinator>, addr: &str) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind '{addr}': {e}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let flag = shutdown.clone();
        let conn_reg = conns.clone();
        let handle = std::thread::Builder::new()
            .name("fastgm-acceptor".into())
            .spawn(move || {
                log::info!("serving on {local}");
                for conn in listener.incoming() {
                    if flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            // The timeouts turn blocking reads/writes into
                            // periodic shutdown-flag checks — see
                            // read_line_shutdown_aware / write_all_
                            // shutdown_aware below.
                            let _ = stream.set_read_timeout(Some(IDLE_POLL));
                            let _ = stream.set_write_timeout(Some(IDLE_POLL));
                            let coord = coordinator.clone();
                            let cflag = flag.clone();
                            match std::thread::Builder::new()
                                .name("fastgm-conn".into())
                                .spawn(move || serve_connection(coord, stream, cflag))
                            {
                                Ok(h) => {
                                    let mut live = conn_reg
                                        .lock()
                                        .unwrap_or_else(|e| e.into_inner());
                                    live.retain(|c| !c.is_finished());
                                    live.push(h);
                                }
                                Err(e) => log::warn!("spawn connection thread: {e}"),
                            }
                        }
                        Err(e) => log::warn!("accept error: {e}"),
                    }
                }
                log::info!("acceptor stopped");
            })?;
        // Janitor: reap finished connection threads even when no new
        // connection ever arrives again.
        let jflag = shutdown.clone();
        let jconns = conns.clone();
        let janitor = std::thread::Builder::new()
            .name("fastgm-conn-janitor".into())
            .spawn(move || {
                while !jflag.load(Ordering::SeqCst) {
                    std::thread::sleep(REAP_PERIOD);
                    let mut live = jconns.lock().unwrap_or_else(|e| e.into_inner());
                    live.retain(|c| !c.is_finished());
                }
            })?;
        Ok(Server { addr: local, shutdown, handle: Some(handle), janitor: Some(janitor), conns })
    }

    /// Connection threads currently tracked (finished ones are reaped by
    /// the janitor within [`REAP_PERIOD`] even with no new accepts).
    pub fn live_connections(&self) -> usize {
        self.conns.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Stop accepting, then join the acceptor AND every live connection
    /// thread. In-flight requests finish and their responses are fully
    /// written before this returns, so callers can tear down the
    /// coordinator (or rebind the port) without racing a connection.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Unblock accept.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        if let Some(j) = self.janitor.take() {
            let _ = j.join();
        }
        // The acceptor is gone, so no new handles can appear: drain.
        let handles = std::mem::take(&mut *self.conns.lock().unwrap_or_else(|e| e.into_inner()));
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Retryable read/write errors: timeouts (how the shutdown flag gets
/// polled) and EINTR.
fn is_retryable(kind: ErrorKind) -> bool {
    matches!(kind, ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted)
}

/// Read one line's raw bytes, retrying timeouts until data or shutdown.
/// Deliberately byte-level (`read_until`, not `read_line`): `read_line`'s
/// UTF-8 guard DISCARDS everything a call appended when it returns an
/// error while the accumulated bytes end mid multi-byte character, so a
/// read timeout could silently eat part of a request. `read_until` keeps
/// partial reads in `buf` across retries — a slow writer is never torn.
/// Returns `None` on EOF, broken connection or shutdown.
fn read_line_shutdown_aware(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    shutdown: &AtomicBool,
) -> Option<()> {
    buf.clear();
    loop {
        match reader.read_until(b'\n', buf) {
            Ok(0) => return None, // EOF (any half line at EOF is dropped)
            Ok(_) => return Some(()),
            Err(e) if is_retryable(e.kind()) => {
                if shutdown.load(Ordering::SeqCst) {
                    return None;
                }
            }
            Err(_) => return None,
        }
    }
}

/// Write a whole response line, retrying timeouts so a slow-but-alive
/// client never receives a torn line (a pipelined client legitimately
/// stalls the reply direction while it is still writing requests). After
/// shutdown is signalled, a non-draining client gets a bounded grace
/// period and is then dropped. Returns `false` when the connection should
/// close.
fn write_all_shutdown_aware(
    writer: &mut TcpStream,
    mut buf: &[u8],
    shutdown: &AtomicBool,
) -> bool {
    let mut drain_polls = 0u32;
    while !buf.is_empty() {
        match writer.write(buf) {
            Ok(0) => return false,
            Ok(n) => buf = &buf[n..],
            Err(e) if is_retryable(e.kind()) => {
                if shutdown.load(Ordering::SeqCst) {
                    drain_polls += 1;
                    if drain_polls > SHUTDOWN_DRAIN_POLLS {
                        return false;
                    }
                }
            }
            Err(_) => return false,
        }
    }
    true
}

fn serve_connection(coord: Arc<Coordinator>, stream: TcpStream, shutdown: Arc<AtomicBool>) {
    use std::fmt::Write as _;
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    // One output buffer for the whole connection: every response after the
    // first reuses the allocation instead of building a fresh String per
    // line. The alloc/reuse split is surfaced as metrics so the win is
    // observable, not assumed.
    let mut out = String::new();
    while read_line_shutdown_aware(&mut reader, &mut buf, &shutdown).is_some() {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        // Strict UTF-8: a lossy conversion would silently mangle keys
        // (distinct invalid byte sequences collapse to U+FFFD and collide),
        // so invalid bytes are rejected as a bad request instead.
        let resp = match std::str::from_utf8(&buf) {
            Err(e) => Response::err(format!("bad request: invalid UTF-8: {e}")),
            Ok(line) => {
                if line.trim().is_empty() {
                    continue;
                }
                match protocol::decode_request(line) {
                    Ok(req) => coord.call(req),
                    Err(e) => Response::err(format!("bad request: {e}")),
                }
            }
        };
        let metrics = coord.node().metrics();
        if out.capacity() == 0 {
            metrics.incr("transport.obuf.alloc");
        } else {
            metrics.incr("transport.obuf.reuse");
        }
        out.clear();
        let _ = writeln!(out, "{}", resp.to_json());
        if !write_all_shutdown_aware(&mut writer, out.as_bytes(), &shutdown) {
            break;
        }
    }
    log::debug!("connection {peer} closed");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::client::Client;
    use crate::coordinator::protocol::Request;
    use crate::coordinator::service::CoordinatorConfig;
    use crate::sketch::SparseVector;

    fn start_server() -> (Server, Arc<Coordinator>) {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig { k: 64, workers: 2, ..Default::default() })
                .unwrap(),
        );
        let server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
        (server, coord)
    }

    #[test]
    fn ping_over_tcp() {
        let (server, _coord) = start_server();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
        server.stop();
    }

    #[test]
    fn full_flow_over_tcp() {
        let (server, _coord) = start_server();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let v = SparseVector::new(vec![1, 2, 3], vec![1.0, 2.0, 0.5]);
        let resp = client
            .call(&Request::Sketch { name: "doc".into(), vector: v.clone(), algo: None })
            .unwrap();
        assert!(matches!(resp, Response::Sketch { .. }));
        let resp = client
            .call(&Request::Jaccard { a: "doc".into(), b: "doc".into() })
            .unwrap();
        assert_eq!(resp, Response::Estimate { value: 1.0 });
        // Errors arrive as error responses, connection stays usable.
        let resp = client.call(&Request::GetSketch { name: "ghost".into() }).unwrap();
        assert!(matches!(resp, Response::Error { .. }));
        assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
        server.stop();
    }

    #[test]
    fn concurrent_clients() {
        let (server, _coord) = start_server();
        let addr = server.addr.to_string();
        let mut handles = Vec::new();
        for t in 0..4 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                for i in 0..20u64 {
                    let items = vec![(t * 1000 + i, 1.0)];
                    let resp = client
                        .call(&Request::Push { stream: format!("s{t}"), items })
                        .unwrap();
                    assert!(matches!(resp, Response::Ack { .. }));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut client = Client::connect(&addr).unwrap();
        let resp = client.call(&Request::Cardinality { stream: "s0".into() }).unwrap();
        assert!(matches!(resp, Response::Estimate { .. }));
        server.stop();
    }

    /// Regression (leaky shutdown): `stop()` used to detach per-connection
    /// threads, so it could return while a pipelined request was still
    /// being processed — and while the connection thread still held the
    /// coordinator. Now it joins: after `stop()` the test's Arc is the only
    /// coordinator reference left, and everything the server wrote is
    /// complete JSON lines (never a torn half-response).
    #[test]
    fn stop_joins_inflight_pipelined_connections() {
        let (server, coord) = start_server();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        let mut burst = String::new();
        for i in 0..64u64 {
            burst.push_str(&protocol::encode_line(
                &Request::Push { stream: "p".into(), items: vec![(i, 1.0)] }.to_json(),
            ));
        }
        stream.write_all(burst.as_bytes()).unwrap();
        // Stop while the server is (very likely) mid-pipeline.
        server.stop();
        assert_eq!(
            Arc::strong_count(&coord),
            1,
            "stop() returned while a connection thread still held the coordinator"
        );
        // Drain whatever was answered before shutdown: every line must parse.
        stream.shutdown(std::net::Shutdown::Write).ok();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        let mut replies = 0usize;
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {
                    protocol::decode_response(&line)
                        .unwrap_or_else(|e| panic!("torn response line {line:?}: {e}"));
                    replies += 1;
                }
            }
        }
        assert!(replies <= 64);
    }

    /// An idle (no traffic) connection must not block `stop()` forever —
    /// the read-timeout poll lets it observe the shutdown flag.
    #[test]
    fn stop_returns_with_an_idle_connection_open() {
        let (server, coord) = start_server();
        let _idle = TcpStream::connect(server.addr).unwrap();
        // Give the acceptor a beat to register the connection.
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
        server.stop();
        assert_eq!(Arc::strong_count(&coord), 1);
    }

    /// A request trickling in across read-timeout boundaries — split in
    /// the middle of a multi-byte UTF-8 character — must still be
    /// reassembled intact (`read_line`'s UTF-8 guard would have discarded
    /// the partial bytes; the byte-level reader keeps them).
    #[test]
    fn slow_writes_split_inside_utf8_are_not_torn() {
        let (server, _coord) = start_server();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        let line = "{\"op\":\"get_sketch\",\"name\":\"βeta\"}\n".as_bytes();
        // Split one byte into the two-byte 'β' (0xCE 0xB2).
        let split = line.iter().position(|&b| b == 0xCE).unwrap() + 1;
        stream.write_all(&line[..split]).unwrap();
        // Several read-timeout periods pass with the character half-sent.
        std::thread::sleep(std::time::Duration::from_millis(200));
        stream.write_all(&line[split..]).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let resp = protocol::decode_response(&reply).unwrap();
        // The name survived intact: a "no sketch named 'βeta'" error —
        // NOT a bad-request parse failure from dropped bytes.
        let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
        assert!(message.contains("βeta"), "request was torn: {message}");
        assert!(!message.contains("bad request"), "request was torn: {message}");
        server.stop();
    }

    /// Regression (handle leak): finished connection threads used to be
    /// reaped only on the NEXT accept, so a burst of short-lived clients
    /// followed by quiet left their dead `JoinHandle`s pinned until
    /// `stop()`. The janitor must shrink the registry with no new accept.
    #[test]
    fn finished_connections_are_reaped_without_a_new_accept() {
        let (server, _coord) = start_server();
        for _ in 0..5 {
            let mut client = Client::connect(&server.addr.to_string()).unwrap();
            assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
            drop(client);
        }
        // No further connections: only the janitor can reap now.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while server.live_connections() > 0 {
            assert!(
                std::time::Instant::now() < deadline,
                "janitor never reaped: {} handles still tracked",
                server.live_connections()
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        server.stop();
    }

    /// The per-connection output buffer is allocated once and reused for
    /// every subsequent response — observable via the obuf counters.
    #[test]
    fn output_buffer_is_reused_across_responses() {
        let (server, coord) = start_server();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        for _ in 0..8 {
            assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
        }
        let metrics = coord.node().metrics();
        assert_eq!(metrics.counter("transport.obuf.alloc"), 1);
        assert!(
            metrics.counter("transport.obuf.reuse") >= 7,
            "expected >=7 reuses, got {}",
            metrics.counter("transport.obuf.reuse")
        );
        // And they ride the metrics op like every other counter.
        let resp = client.call(&Request::Metrics).unwrap();
        let Response::MetricsDump { snapshot } = resp else { panic!("expected dump") };
        let counters = snapshot.get("counters").expect("counters");
        assert!(counters.get("transport.obuf.reuse").is_some());
        server.stop();
    }

    #[test]
    fn malformed_lines_get_error_responses() {
        let (server, _coord) = start_server();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream.write_all(b"this is not json\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = protocol::decode_response(&line).unwrap();
        assert!(matches!(resp, Response::Error { .. }));
        server.stop();
    }
}
