//! TCP JSON-lines server: one accept loop, one thread per connection, each
//! line a [`protocol::Request`], each reply a single JSON line. Shutdown is
//! cooperative: a flag plus a self-connection to unblock `accept`.

use super::protocol::{self, Response};
use super::service::Coordinator;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve.
    pub fn start(coordinator: Arc<Coordinator>, addr: &str) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind '{addr}': {e}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        let handle = std::thread::Builder::new()
            .name("fastgm-acceptor".into())
            .spawn(move || {
                log::info!("serving on {local}");
                for conn in listener.incoming() {
                    if flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let coord = coordinator.clone();
                            let cflag = flag.clone();
                            let _ = std::thread::Builder::new()
                                .name("fastgm-conn".into())
                                .spawn(move || serve_connection(coord, stream, cflag));
                        }
                        Err(e) => log::warn!("accept error: {e}"),
                    }
                }
                log::info!("acceptor stopped");
            })?;
        Ok(Server { addr: local, shutdown, handle: Some(handle) })
    }

    /// Stop accepting and join the acceptor (in-flight connections finish
    /// their current request and then see EOF behaviour from clients).
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Unblock accept.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_connection(coord: Arc<Coordinator>, stream: TcpStream, shutdown: Arc<AtomicBool>) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let resp = match protocol::decode_request(&line) {
            Ok(req) => coord.call(req),
            Err(e) => Response::err(format!("bad request: {e}")),
        };
        let out = protocol::encode_line(&resp.to_json());
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
    }
    log::debug!("connection {peer} closed");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::client::Client;
    use crate::coordinator::protocol::Request;
    use crate::coordinator::service::CoordinatorConfig;
    use crate::sketch::SparseVector;

    fn start_server() -> (Server, Arc<Coordinator>) {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig { k: 64, workers: 2, ..Default::default() })
                .unwrap(),
        );
        let server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
        (server, coord)
    }

    #[test]
    fn ping_over_tcp() {
        let (server, _coord) = start_server();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
        server.stop();
    }

    #[test]
    fn full_flow_over_tcp() {
        let (server, _coord) = start_server();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let v = SparseVector::new(vec![1, 2, 3], vec![1.0, 2.0, 0.5]);
        let resp = client
            .call(&Request::Sketch { name: "doc".into(), vector: v.clone(), algo: None })
            .unwrap();
        assert!(matches!(resp, Response::Sketch { .. }));
        let resp = client
            .call(&Request::Jaccard { a: "doc".into(), b: "doc".into() })
            .unwrap();
        assert_eq!(resp, Response::Estimate { value: 1.0 });
        // Errors arrive as error responses, connection stays usable.
        let resp = client.call(&Request::GetSketch { name: "ghost".into() }).unwrap();
        assert!(matches!(resp, Response::Error { .. }));
        assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
        server.stop();
    }

    #[test]
    fn concurrent_clients() {
        let (server, _coord) = start_server();
        let addr = server.addr.to_string();
        let mut handles = Vec::new();
        for t in 0..4 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                for i in 0..20u64 {
                    let items = vec![(t * 1000 + i, 1.0)];
                    let resp = client
                        .call(&Request::Push { stream: format!("s{t}"), items })
                        .unwrap();
                    assert!(matches!(resp, Response::Ack { .. }));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut client = Client::connect(&addr).unwrap();
        let resp = client.call(&Request::Cardinality { stream: "s0".into() }).unwrap();
        assert!(matches!(resp, Response::Estimate { .. }));
        server.stop();
    }

    #[test]
    fn malformed_lines_get_error_responses() {
        let (server, _coord) = start_server();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream.write_all(b"this is not json\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = protocol::decode_response(&line).unwrap();
        assert!(matches!(resp, Response::Error { .. }));
        server.stop();
    }
}
