//! Distributed-site sketch merging (§2.3): a balanced binary merge tree
//! over per-site sketches. Merging is associative/commutative, so the tree
//! shape only affects parallelism; the parallel variant splits across
//! threads for large fan-in (the central-site role in the paper's
//! weighted-cardinality setting — and the gather half of
//! [`super::cluster`]'s scatter-gather).
//!
//! Empty input is a [`MergeError::EmptyMerge`], not a panic: a cluster
//! gather over zero live sites is an expected failure mode and must degrade
//! into an error response, never crash the caller.

use crate::sketch::{GumbelMaxSketch, MergeError};

/// Sequential fold (small fan-in). Empty input is
/// [`MergeError::EmptyMerge`], straight from [`GumbelMaxSketch::merge_all`].
pub fn merge_sequential(sketches: &[GumbelMaxSketch]) -> Result<GumbelMaxSketch, MergeError> {
    GumbelMaxSketch::merge_all(sketches.iter())
}

/// Balanced-tree merge, splitting across `threads` for wide fan-in.
pub fn merge_tree(
    sketches: &[GumbelMaxSketch],
    threads: usize,
) -> Result<GumbelMaxSketch, MergeError> {
    if sketches.is_empty() {
        return Err(MergeError::EmptyMerge);
    }
    if sketches.len() < 4 || threads <= 1 {
        return merge_sequential(sketches);
    }
    let chunk = sketches.len().div_ceil(threads);
    let partials: Vec<Result<GumbelMaxSketch, MergeError>> = std::thread::scope(|scope| {
        let handles: Vec<_> = sketches
            .chunks(chunk)
            .map(|c| scope.spawn(move || merge_sequential(c)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("merge thread")).collect()
    });
    let partials: Result<Vec<GumbelMaxSketch>, MergeError> = partials.into_iter().collect();
    merge_sequential(&partials?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::lemiesz::LemieszSketch;
    use crate::estimate::cardinality::estimate_cardinality;

    fn site_sketch(k: usize, seed: u64, ids: std::ops::Range<u64>) -> GumbelMaxSketch {
        let mut s = LemieszSketch::new(k, seed);
        for id in ids {
            s.push(id, 1.0);
        }
        s.sketch()
    }

    #[test]
    fn tree_equals_sequential_equals_union() {
        let k = 128;
        let sites: Vec<GumbelMaxSketch> =
            (0..10).map(|i| site_sketch(k, 5, (i * 100)..(i * 100 + 150))).collect();
        let seq = merge_sequential(&sites).unwrap();
        let tree = merge_tree(&sites, 4).unwrap();
        assert_eq!(seq, tree);
        // Union set is 0..1050 (overlapping ranges), estimate tracks it.
        let est = estimate_cardinality(&tree);
        assert!((est - 1050.0).abs() / 1050.0 < 0.2, "est={est}");
    }

    #[test]
    fn merge_rejects_mixed_seeds() {
        let a = site_sketch(16, 1, 0..10);
        let b = site_sketch(16, 2, 0..10);
        assert!(merge_tree(&[a, b], 2).is_err());
    }

    #[test]
    fn single_site_is_identity() {
        let a = site_sketch(16, 1, 0..10);
        assert_eq!(merge_tree(std::slice::from_ref(&a), 8).unwrap(), a);
    }

    /// A gather over zero live sites is an error, not a crash (both
    /// entry points, every thread count).
    #[test]
    fn empty_merge_is_a_typed_error() {
        assert_eq!(merge_sequential(&[]).unwrap_err(), MergeError::EmptyMerge);
        for threads in [1, 2, 8] {
            assert_eq!(merge_tree(&[], threads).unwrap_err(), MergeError::EmptyMerge);
        }
    }
}
