//! The serving coordinator — Layer 3 of the stack, itself split into three
//! explicit serving layers:
//!
//! ```text
//!   ┌──────────────────────────────────────────────────────────────┐
//!   │ cluster   Partitioner · ClusterClient · LocalCluster harness │
//!   │           (rendezvous key routing, scatter-gather topk,      │
//!   │            §2.3 merged cardinality across sites)             │
//!   ├──────────────────────────────────────────────────────────────┤
//!   │ transport server (thread/conn JSON-lines) · event_server     │
//!   │           (poll loop: binary frames + JSON on one port) ·    │
//!   │           frame codec · client · worker pool · backpressure  │
//!   │           · batcher  — the Coordinator shell                 │
//!   ├──────────────────────────────────────────────────────────────┤
//!   │ node      Node::execute(Request) -> Response                 │
//!   │           registry · store · LSH · router · merger · metrics │
//!   └──────────────────────────────────────────────────────────────┘
//! ```
//!
//! * [`node`] — the transport-agnostic execution core: every op (sketch,
//!   estimate, store, snapshot, hello, fetch) behind one typed
//!   [`node::Node::execute`] API. Embed this for in-process serving.
//! * [`service`] — the [`service::Coordinator`]: a worker pool (per-worker
//!   bounded queues + reusable [`crate::sketch::SketchScratch`]) around a
//!   [`node::Node`].
//! * [`cluster`] — the fan-out layer: a rendezvous [`cluster::Partitioner`]
//!   mapping store keys to nodes, a [`cluster::ClusterClient`] that routes
//!   upserts, scatter-gathers `topk` and merges per-site sketches for
//!   cluster-wide cardinality (§2.3), and a [`cluster::LocalCluster`]
//!   process harness.
//! * [`protocol`] — JSON-lines wire requests/responses (incl. the `hello`
//!   handshake and the codec-blob `sketch_fetch` the gather path uses).
//! * [`frame`] — the length-prefixed binary frame codec: client-assigned
//!   request ids for out-of-order multiplexing, compact tag-byte bodies,
//!   checksummed strict decode in [`crate::sketch::codec`]'s idiom.
//! * [`router`] — the sparse/dense/stream routing decision, including the
//!   engine-registry `algo` plan ([`router::SketchPlan`]).
//! * [`worker`] — the CPU worker pool (round-robin dispatch).
//! * [`batcher`] — size/deadline dynamic batching for the accelerator.
//! * [`backpressure`] — per-worker bounded admission with shed-or-block
//!   policy and queue-depth gauges.
//! * [`registry`] — named sketch & stream state store.
//! * [`store`] — the keyed similarity-serving store: sharded key→sketch
//!   map with an incrementally maintained LSH index, top-k queries
//!   (band-probe or brute-scan, router's choice) and versioned binary
//!   snapshot/restore via [`crate::sketch::codec`].
//! * [`merger`] — distributed-site sketch merge (§2.3 mergeability; empty
//!   merges are typed errors, the zero-live-sites failure mode).
//! * [`cache`] — the versioned read-path cache: byte-bounded sharded LRU
//!   for merged key unions (tagged with per-key write versions — hits are
//!   bit-identical to fresh merges by construction) and top-k rankings
//!   (tagged with per-shard store generations); the cluster client reuses
//!   it for `(key, version)` gather blobs.
//! * [`metrics`] — counters + latency histograms, surfaced over the wire.
//! * [`server`] / [`client`] — blocking TCP transport (one thread per
//!   connection, JSON lines; the client also speaks framed mode).
//! * [`event_server`] — the event-driven transport (unix only): one
//!   `poll(2)` readiness thread serving many non-blocking connections,
//!   per-message protocol auto-detection (binary frames and JSON lines
//!   coexist on one port, even one connection), admission batching into
//!   the worker pool, and coalesced vectored writes.
//!
//! Python never appears here: the accelerator path executes AOT-compiled
//! HLO through [`crate::runtime`].

pub mod protocol;
pub mod frame;
pub mod metrics;
pub mod backpressure;
pub mod registry;
pub mod store;
pub mod cache;
pub mod router;
pub mod worker;
pub mod batcher;
pub mod merger;
pub mod node;
pub mod service;
pub mod server;
#[cfg(unix)]
pub mod event_server;
pub mod client;
pub mod cluster;
