//! The serving coordinator — Layer 3 of the stack.
//!
//! A request-path framework in the shape of a sketching analytics service:
//!
//! * [`protocol`] — JSON-lines wire requests/responses.
//! * [`service`] — the [`service::Coordinator`]: routes sparse vectors to
//!   CPU FastGM workers, dense batches to the AOT accelerator, streams to
//!   Stream-FastGM states; owns the sketch registry and LSH index.
//! * [`router`] — the sparse/dense/stream routing decision, including the
//!   engine-registry `algo` plan ([`router::SketchPlan`]).
//! * [`worker`] — the CPU worker pool: one bounded queue and one reusable
//!   [`crate::sketch::SketchScratch`] per worker (round-robin dispatch).
//! * [`batcher`] — size/deadline dynamic batching for the accelerator.
//! * [`backpressure`] — per-worker bounded admission with shed-or-block
//!   policy and queue-depth gauges.
//! * [`registry`] — named sketch & stream state store.
//! * [`store`] — the keyed similarity-serving store: sharded key→sketch
//!   map with an incrementally maintained LSH index, top-k queries
//!   (band-probe or brute-scan, router's choice) and versioned binary
//!   snapshot/restore via [`crate::sketch::codec`].
//! * [`merger`] — distributed-site sketch merge (§2.3 mergeability).
//! * [`metrics`] — counters + latency histograms, surfaced over the wire.
//! * [`server`] / [`client`] — TCP JSON-lines transport.
//!
//! Python never appears here: the accelerator path executes AOT-compiled
//! HLO through [`crate::runtime`].

pub mod protocol;
pub mod metrics;
pub mod backpressure;
pub mod registry;
pub mod store;
pub mod router;
pub mod worker;
pub mod batcher;
pub mod merger;
pub mod service;
pub mod server;
pub mod client;
