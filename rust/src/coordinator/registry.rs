//! Named state store: sketches (by name) and live Stream-FastGM states.
//! Shared across workers behind RwLocks; sketch computation happens outside
//! the lock — only the store/fetch is serialized.

use crate::sketch::stream_fastgm::StreamFastGm;
use crate::sketch::GumbelMaxSketch;
use std::collections::HashMap;
use std::sync::RwLock;

#[derive(Default)]
pub struct Registry {
    sketches: RwLock<HashMap<String, GumbelMaxSketch>>,
    streams: RwLock<HashMap<String, StreamFastGm>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn put_sketch(&self, name: &str, sk: GumbelMaxSketch) {
        self.sketches.write().unwrap().insert(name.to_string(), sk);
    }

    pub fn get_sketch(&self, name: &str) -> Option<GumbelMaxSketch> {
        self.sketches.read().unwrap().get(name).cloned()
    }

    pub fn sketch_count(&self) -> usize {
        self.sketches.read().unwrap().len()
    }

    /// Push items into a stream, creating it with (k, seed) on first touch.
    pub fn stream_push(&self, name: &str, k: usize, seed: u64, items: &[(u64, f64)]) -> u64 {
        let mut streams = self.streams.write().unwrap();
        let st = streams
            .entry(name.to_string())
            .or_insert_with(|| StreamFastGm::new(k, seed));
        for &(id, w) in items {
            st.push(id, w);
        }
        st.processed
    }

    /// Merge a peer's stream sketch into the named live stream state,
    /// creating it at `(k, seed)` on first touch — the anti-entropy repair
    /// op. Merging (never overwriting) is what §2.3 licenses: local
    /// history is kept, missed history is absorbed, and repeating the
    /// merge is a no-op. Incompatible sketches are refused untouched.
    pub fn stream_merge(
        &self,
        name: &str,
        k: usize,
        seed: u64,
        sk: &GumbelMaxSketch,
    ) -> Result<(), crate::sketch::MergeError> {
        // Validate against the serving (k, seed) BEFORE touching the map:
        // a refused merge must not leave an empty stream state behind.
        StreamFastGm::new(k, seed).merge_sketch(sk)?;
        let mut streams = self.streams.write().unwrap();
        let st = streams
            .entry(name.to_string())
            .or_insert_with(|| StreamFastGm::new(k, seed));
        st.merge_sketch(sk)
    }

    pub fn stream_sketch(&self, name: &str) -> Option<GumbelMaxSketch> {
        self.streams.read().unwrap().get(name).map(|s| s.sketch())
    }

    pub fn stream_count(&self) -> usize {
        self.streams.read().unwrap().len()
    }

    /// Run `f` over every stored (name, sketch) pair (read lock held).
    pub fn for_each_sketch(&self, mut f: impl FnMut(&str, &GumbelMaxSketch)) {
        for (n, s) in self.sketches.read().unwrap().iter() {
            f(n, s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::Family;

    #[test]
    fn sketch_store_roundtrip() {
        let r = Registry::new();
        assert!(r.get_sketch("x").is_none());
        r.put_sketch("x", GumbelMaxSketch::empty(Family::Ordered, 1, 4));
        assert_eq!(r.get_sketch("x").unwrap().k(), 4);
        assert_eq!(r.sketch_count(), 1);
    }

    #[test]
    fn stream_state_persists_across_pushes() {
        let r = Registry::new();
        let n1 = r.stream_push("s", 16, 7, &[(1, 0.5)]);
        let n2 = r.stream_push("s", 16, 7, &[(2, 1.0), (3, 0.25)]);
        assert_eq!(n1, 1);
        assert_eq!(n2, 3);
        assert_eq!(r.stream_count(), 1);
        let sk = r.stream_sketch("s").unwrap();
        assert!(sk.y.iter().any(|y| y.is_finite()));
    }

    #[test]
    fn stream_merge_absorbs_missed_history() {
        let r = Registry::new();
        r.stream_push("s", 16, 7, &[(1, 0.5), (2, 1.0)]);
        // A peer that also saw element 3.
        let peer = Registry::new();
        peer.stream_push("s", 16, 7, &[(2, 1.0), (3, 0.25)]);
        r.stream_merge("s", 16, 7, &peer.stream_sketch("s").unwrap()).unwrap();
        let full = Registry::new();
        full.stream_push("s", 16, 7, &[(1, 0.5), (2, 1.0), (3, 0.25)]);
        assert_eq!(r.stream_sketch("s"), full.stream_sketch("s"));
        // Merging into an absent stream creates it; a refused merge does
        // not (no empty stream left behind).
        let cold = Registry::new();
        cold.stream_merge("t", 16, 7, &peer.stream_sketch("s").unwrap()).unwrap();
        assert_eq!(cold.stream_sketch("t"), peer.stream_sketch("s"));
        assert!(cold.stream_merge("u", 16, 99, &peer.stream_sketch("s").unwrap()).is_err());
        assert_eq!(cold.stream_count(), 1, "refused merge must not create 'u'");
    }

    #[test]
    fn concurrent_pushes_do_not_lose_updates() {
        let r = std::sync::Arc::new(Registry::new());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    r.stream_push("shared", 32, 1, &[(t * 1000 + i, 1.0)]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // processed counts all pushes.
        let streams = r.streams.read().unwrap();
        assert_eq!(streams.get("shared").unwrap().processed, 400);
    }
}
