//! The scatter-gather cluster router.
//!
//! A [`ClusterClient`] holds one JSON-lines connection per node plus the
//! rendezvous [`Partitioner`] built from the node ids the `hello`
//! handshake reported. Reads and writes split by op:
//!
//! * **writes** (`upsert`, `delete`, stream `push`) go to the partition
//!   owner only — a dead owner is a typed [`ClusterError::NodeDown`], not
//!   a silent reroute (re-homing keys would desync the partitioner and
//!   make restarts ambiguous);
//! * **`topk`** scatters to every live node (split-phase: all requests on
//!   the wire before any reply is read), gathers the per-node LSH
//!   candidate sets, fetches each candidate's sketch from the node that
//!   reported it as a codec blob and re-ranks centrally with
//!   `estimate_jp` — the partition-then-reduce shape (per-partition
//!   candidates, central exact re-rank, global k). Dead nodes shrink
//!   coverage, never the answer.
//! * **cardinality** fetches every live node's stream sketch and
//!   `merge_tree`s them (§2.3): the merged sketch is bit-identical to
//!   sketching the concatenated stream, because stream pushes are
//!   partitioned by element id.
//!
//! Liveness is observed, not configured: the first I/O error on a node's
//! connection marks it down; [`ClusterClient::reconnect`] re-attaches
//! (e.g. after a restart-from-snapshot, on whatever address the node came
//! back on — identity is the node id, not the socket).

use super::partitioner::Partitioner;
use crate::coordinator::client::Client;
use crate::coordinator::merger::merge_tree;
use crate::coordinator::protocol::{HelloInfo, Request, Response, SketchSource, PROTOCOL_VERSION};
use crate::estimate::cardinality::estimate_cardinality;
use crate::estimate::jaccard::estimate_jp;
use crate::sketch::engine::{self, EngineParams};
use crate::sketch::{AlgorithmId, GumbelMaxSketch, Sketcher, SparseVector};
use std::collections::BTreeMap;

/// How long a gather waits on any single node read before treating the
/// node as down. Without this, a hung-but-connected node (silent
/// partition, stop-the-world pause) would wedge every gather forever —
/// only cleanly closed sockets would degrade. Generous: normal ops answer
/// in microseconds-to-milliseconds on a healthy node.
const NODE_IO_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(10);

/// Typed cluster-layer failures. Per-node faults carry the node identity
/// so callers can alert on the *site*, not just the operation.
#[derive(Debug, thiserror::Error)]
pub enum ClusterError {
    /// The node owning the touched partition is unreachable. Writes to its
    /// keys fail with this until it returns; gathers simply skip it.
    #[error("node '{node}' ({addr}) is down: {reason}")]
    NodeDown { node: String, addr: String, reason: String },
    /// Every node is down — there is nothing left to scatter to.
    #[error("no live nodes in the cluster")]
    NoLiveNodes,
    /// A live node answered with a protocol-level error.
    #[error("node '{node}' rejected the request: {message}")]
    Remote { node: String, message: String },
    /// The gather itself failed (merge/estimator error across sites).
    #[error("cluster gather failed: {0}")]
    Gather(String),
}

/// What a scatter-gather `topk` cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GatherStats {
    /// Cluster size (configured membership).
    pub nodes: usize,
    /// Nodes that *responded* to the scatter — including ones that
    /// answered with a protocol-level refusal (alive but contributing
    /// nothing). Only unreachable nodes are excluded.
    pub live: usize,
    /// Distinct candidates returned by the per-node probes.
    pub candidates: usize,
    /// Candidates whose sketches were fetched and centrally re-ranked.
    pub reranked: usize,
}

struct NodeSlot {
    addr: String,
    hello: HelloInfo,
    /// `None` = observed down (I/O error) until a `reconnect`.
    conn: Option<Client>,
}

/// The sketch config every member must serve (frozen at `connect`);
/// `reconnect` re-checks it so a node rejoining with a changed config is
/// refused exactly like it would have been at formation time.
#[derive(Debug, Clone, PartialEq)]
struct ClusterSketchConfig {
    k: usize,
    seed: u64,
    algo: String,
}

impl ClusterSketchConfig {
    fn matches(&self, h: &HelloInfo) -> bool {
        h.k == self.k && h.seed == self.seed && h.algo == self.algo
    }
}

pub struct ClusterClient {
    slots: Vec<NodeSlot>,
    partitioner: Partitioner,
    expect: ClusterSketchConfig,
    /// Central sketcher at the cluster's (algo, k, seed) — what queries
    /// and re-rank probes are sketched with. Bit-identical to every node's
    /// default sketch path.
    sketcher: Box<dyn Sketcher>,
}

impl ClusterClient {
    /// Connect to every node, handshake, and verify the cluster is
    /// coherent: same protocol version, same `(k, seed)`, same default
    /// algorithm (an EXP-register one — the re-rank needs `estimate_jp`),
    /// distinct node ids.
    ///
    /// All nodes must be reachable to *form* the client: membership
    /// identity (the node ids the partitioner hashes on) comes from the
    /// handshake itself, so a dead node would leave the keyspace
    /// unroutable. Once formed, any member may die and the client degrades
    /// per-op — which means degraded reads belong to long-lived clients;
    /// a fresh client (e.g. a CLI invocation) cannot form against a
    /// cluster with a member down.
    pub fn connect(addrs: &[String]) -> anyhow::Result<ClusterClient> {
        anyhow::ensure!(!addrs.is_empty(), "cluster needs at least one node address");
        let mut slots = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let mut conn = Client::connect(addr)?;
            conn.set_io_timeout(Some(NODE_IO_TIMEOUT))?;
            let hello = conn
                .hello()
                .map_err(|e| anyhow::anyhow!("hello to '{addr}' failed: {e}"))?;
            anyhow::ensure!(
                hello.protocol == PROTOCOL_VERSION,
                "node '{}' ({addr}) speaks protocol v{}, this client v{PROTOCOL_VERSION}",
                hello.node,
                hello.protocol,
            );
            slots.push(NodeSlot { addr: addr.clone(), hello, conn: Some(conn) });
        }
        let first = &slots[0].hello;
        for s in &slots[1..] {
            let h = &s.hello;
            anyhow::ensure!(
                h.k == first.k && h.seed == first.seed && h.algo == first.algo,
                "cluster config mismatch: node '{}' serves (k={}, seed={}, algo={}) but \
                 node '{}' serves (k={}, seed={}, algo={})",
                first.node,
                first.k,
                first.seed,
                first.algo,
                h.node,
                h.k,
                h.seed,
                h.algo,
            );
        }
        let algo = AlgorithmId::from_name(&first.algo)?;
        anyhow::ensure!(
            algo.family().has_exponential_registers(),
            "cluster default algo '{}' has no J_P estimator — scatter-gather topk \
             cannot re-rank (use an ordered/direct-family default)",
            first.algo,
        );
        let sketcher = engine::build(algo, EngineParams::new(first.k, first.seed));
        let expect = ClusterSketchConfig {
            k: first.k,
            seed: first.seed,
            algo: first.algo.clone(),
        };
        let node_ids: Vec<String> = slots.iter().map(|s| s.hello.node.clone()).collect();
        let partitioner = Partitioner::new(&node_ids)?;
        Ok(ClusterClient { slots, partitioner, expect, sketcher })
    }

    pub fn nodes(&self) -> usize {
        self.slots.len()
    }

    pub fn live_nodes(&self) -> usize {
        self.slots.iter().filter(|s| s.conn.is_some()).count()
    }

    pub fn node_id(&self, i: usize) -> &str {
        &self.slots[i].hello.node
    }

    pub fn addr(&self, i: usize) -> &str {
        &self.slots[i].addr
    }

    /// The node index owning `key` (stable; dead nodes keep ownership).
    pub fn owner(&self, key: &str) -> usize {
        self.partitioner.owner(key)
    }

    /// Last handshake each node answered (epoch shows snapshot restores).
    pub fn hello(&self, i: usize) -> &HelloInfo {
        &self.slots[i].hello
    }

    /// Re-attach node `i` on `addr` (it may have come back on a different
    /// port). The node must present the same id — a different identity on
    /// the same slot would silently re-partition the keyspace — AND the
    /// same protocol/sketch config the cluster was formed with: a node
    /// rejoining after a config change must be refused here exactly like
    /// [`ClusterClient::connect`] would have refused it, not discovered
    /// query-by-query as gather errors.
    pub fn reconnect(&mut self, i: usize, addr: &str) -> anyhow::Result<()> {
        let mut conn = Client::connect(addr)?;
        conn.set_io_timeout(Some(NODE_IO_TIMEOUT))?;
        let hello = conn.hello()?;
        anyhow::ensure!(
            hello.node == self.slots[i].hello.node,
            "slot {i} expects node '{}' but '{addr}' answered as '{}'",
            self.slots[i].hello.node,
            hello.node,
        );
        anyhow::ensure!(
            hello.protocol == PROTOCOL_VERSION,
            "node '{}' rejoined speaking protocol v{}, this client v{PROTOCOL_VERSION}",
            hello.node,
            hello.protocol,
        );
        anyhow::ensure!(
            self.expect.matches(&hello),
            "node '{}' rejoined with (k={}, seed={}, algo={}) but the cluster was formed \
             with (k={}, seed={}, algo={})",
            hello.node,
            hello.k,
            hello.seed,
            hello.algo,
            self.expect.k,
            self.expect.seed,
            self.expect.algo,
        );
        self.slots[i] = NodeSlot { addr: addr.to_string(), hello, conn: Some(conn) };
        Ok(())
    }

    /// The typed down-error for slot `i` (does not change liveness).
    fn down_err(&self, i: usize, reason: &str) -> ClusterError {
        ClusterError::NodeDown {
            node: self.slots[i].hello.node.clone(),
            addr: self.slots[i].addr.clone(),
            reason: reason.to_string(),
        }
    }

    /// Mark slot `i` down after an observed I/O failure.
    fn mark_down(&mut self, i: usize, reason: &str) -> ClusterError {
        self.slots[i].conn = None;
        self.down_err(i, reason)
    }

    /// Phase 1: write `reqs` to node `i` without reading replies. I/O
    /// failure marks the node down. All slot traffic funnels through
    /// this + [`Self::slot_recv`], so down-marking lives in one place.
    fn slot_send(&mut self, i: usize, reqs: &[Request]) -> Result<(), ClusterError> {
        if self.slots[i].conn.is_none() {
            return Err(self.down_err(i, "previously observed down"));
        }
        let sent = self.slots[i].conn.as_mut().expect("checked live above").send_batch(reqs);
        sent.map_err(|e| self.mark_down(i, &e.to_string()))
    }

    /// Phase 2: read `n` in-order replies from node `i`. I/O failure (or
    /// a connection closed mid-batch) marks the node down.
    fn slot_recv(&mut self, i: usize, n: usize) -> Result<Vec<Response>, ClusterError> {
        if self.slots[i].conn.is_none() {
            return Err(self.down_err(i, "previously observed down"));
        }
        let resps = self.slots[i].conn.as_mut().expect("checked live above").recv_batch(n);
        resps.map_err(|e| self.mark_down(i, &e.to_string()))
    }

    /// One synchronous call on node `i` (send + recv).
    fn slot_call(&mut self, i: usize, req: &Request) -> Result<Response, ClusterError> {
        self.slot_send(i, std::slice::from_ref(req))?;
        Ok(self.slot_recv(i, 1)?.pop().expect("slot_recv(1) yields one reply"))
    }

    fn remote_err(&self, i: usize, message: String) -> ClusterError {
        ClusterError::Remote { node: self.slots[i].hello.node.clone(), message }
    }

    /// Unwrap the `ack` every write-path op expects from node `i`;
    /// protocol-level refusals become [`ClusterError::Remote`].
    fn expect_ack(&self, i: usize, resp: Response) -> Result<String, ClusterError> {
        match resp {
            Response::Ack { info } => Ok(info),
            Response::Error { message } => Err(self.remote_err(i, message)),
            other => Err(self.remote_err(i, format!("expected ack, got {other:?}"))),
        }
    }

    /// Upsert `key` on its owning node. Dead owner ⇒ typed error (the
    /// write's partition is down; re-homing would desync the partitioner).
    pub fn upsert(&mut self, key: &str, vector: SparseVector) -> Result<String, ClusterError> {
        let i = self.partitioner.owner(key);
        let resp = self.slot_call(i, &Request::Upsert { key: key.to_string(), vector })?;
        self.expect_ack(i, resp)
    }

    /// Delete `key` on its owning node (idempotent there).
    pub fn delete(&mut self, key: &str) -> Result<String, ClusterError> {
        let i = self.partitioner.owner(key);
        let resp = self.slot_call(i, &Request::Delete { key: key.to_string() })?;
        self.expect_ack(i, resp)
    }

    /// Scatter-gather top-k: per-node candidates, central exact re-rank.
    ///
    /// 1. scatter `topk(vector, limit)` to every live node — the request
    ///    goes onto EVERY wire before any reply is read, so the per-node
    ///    probe work overlaps and the scatter costs ~max(RTT), not the
    ///    sum; each node answers from its own partition (LSH band probe
    ///    or scan, its router's call), and the global top-k is always
    ///    contained in the union of the per-partition top-k's;
    /// 2. fetch the distinct candidates' sketches as checksummed codec
    ///    blobs (`sketch_fetch`), one pipelined batch per *reporting*
    ///    node — the one place each candidate is guaranteed to exist,
    ///    even if ownership has drifted (membership change, mis-homed
    ///    restore);
    /// 3. re-rank everything centrally with `estimate_jp` against a query
    ///    sketch computed here at the shared `(algo, k, seed)` — the same
    ///    deterministic scores every node computes, so the gather ranks
    ///    exactly like a single node holding the union store would. The
    ///    nodes' own scores are deliberately NOT trusted: the central
    ///    estimator is the authority (a stale, buggy or differently-built
    ///    node can report candidates but never distort the ranking), at
    ///    the cost of transferring one codec blob per candidate;
    /// 4. sort (score desc, key asc — the store's tie rule) and truncate.
    ///
    /// Nodes that die mid-gather only shrink coverage. Zero responding
    /// nodes is [`ClusterError::NoLiveNodes`].
    pub fn topk(
        &mut self,
        vector: &SparseVector,
        limit: usize,
    ) -> Result<(Vec<(String, f64)>, GatherStats), ClusterError> {
        let query = self.sketcher.sketch(vector);
        // Scatter phase 1: the same request onto every live wire.
        let req = Request::TopK { vector: vector.clone(), limit };
        let mut awaiting: Vec<usize> = Vec::new();
        for i in 0..self.slots.len() {
            match self.slot_send(i, std::slice::from_ref(&req)) {
                Ok(()) => awaiting.push(i),
                Err(ClusterError::NodeDown { node, reason, .. }) => {
                    log::warn!("topk scatter: node '{node}' down ({reason}), degrading");
                }
                Err(e) => return Err(e),
            }
        }
        // Scatter phase 2: collect replies. Candidates remember which
        // node reported them (BTreeMap keeps the gather deterministic) —
        // dedup across nodes keeps a mid-rebalance store overlap correct.
        let mut candidates: BTreeMap<String, usize> = BTreeMap::new();
        let mut live = 0usize;
        for i in awaiting {
            match self.slot_recv(i, 1) {
                Ok(mut resps) => {
                    // The node answered: it is live even if it refused
                    // (e.g. mid-restore config mismatch) — only
                    // unreachable nodes are excluded from `live`, so an
                    // all-refusing-but-healthy cluster is a degraded
                    // answer, never a spurious NoLiveNodes.
                    live += 1;
                    match resps.pop().expect("slot_recv(1) yields one reply") {
                        Response::TopK { hits } => {
                            for (name, _) in hits {
                                candidates.entry(name).or_insert(i);
                            }
                        }
                        Response::Error { message } => log::warn!(
                            "topk scatter: node '{}' rejected: {message}",
                            self.slots[i].hello.node
                        ),
                        other => log::warn!(
                            "topk scatter: node '{}' answered {other:?}",
                            self.slots[i].hello.node
                        ),
                    }
                }
                Err(ClusterError::NodeDown { node, reason, .. }) => {
                    log::warn!("topk scatter: node '{node}' down ({reason}), degrading");
                }
                Err(e) => return Err(e),
            }
        }
        if live == 0 {
            return Err(ClusterError::NoLiveNodes);
        }
        // Gather: fetch + central re-rank, split-phase again. Candidates
        // are grouped by the node that REPORTED them and fetched as one
        // pipelined batch per node (all batches written before any reply
        // is read), so the gather costs ~one overlapped round-trip. A
        // candidate whose node died between scatter and fetch (or which
        // was deleted meanwhile) is skipped, not an error.
        let n_candidates = candidates.len();
        let mut by_reporter: Vec<Vec<String>> = vec![Vec::new(); self.slots.len()];
        for (name, reporter) in candidates {
            by_reporter[reporter].push(name);
        }
        let mut fetching: Vec<(usize, Vec<String>)> = Vec::new();
        for (i, names) in by_reporter.into_iter().enumerate() {
            if names.is_empty() {
                continue;
            }
            let reqs: Vec<Request> = names
                .iter()
                .map(|name| Request::SketchFetch {
                    name: name.clone(),
                    source: SketchSource::Store,
                })
                .collect();
            match self.slot_send(i, &reqs) {
                Ok(()) => fetching.push((i, names)),
                Err(ClusterError::NodeDown { node, .. }) => {
                    log::warn!(
                        "gather: node '{node}' holding {} candidates died mid-gather",
                        names.len()
                    );
                }
                Err(e) => return Err(e),
            }
        }
        let mut scored: Vec<(String, f64)> = Vec::with_capacity(n_candidates);
        for (i, names) in fetching {
            let resps = match self.slot_recv(i, names.len()) {
                Ok(resps) => resps,
                Err(ClusterError::NodeDown { node, .. }) => {
                    log::warn!(
                        "gather: node '{node}' holding {} candidates died mid-gather",
                        names.len()
                    );
                    continue;
                }
                Err(e) => return Err(e),
            };
            for (name, resp) in names.into_iter().zip(resps) {
                let sk = match resp {
                    Response::SketchBlob { name: got, data } => {
                        match crate::sketch::codec::decode_sketch_hex(&data) {
                            // The central re-rank is the trust boundary:
                            // a blob answering for the wrong key must be
                            // a loud error, never scored under `name`.
                            Ok((key, sk)) if got == name && key == name => sk,
                            Ok((key, _)) => {
                                return Err(ClusterError::Gather(format!(
                                    "candidate '{name}': node '{}' answered with '{got}' \
                                     (blob key '{key}')",
                                    self.slots[i].hello.node
                                )))
                            }
                            Err(e) => {
                                return Err(ClusterError::Gather(format!(
                                    "candidate '{name}': corrupt sketch blob: {e}"
                                )))
                            }
                        }
                    }
                    Response::Error { message } => {
                        log::debug!("gather: candidate '{name}' gone: {message}");
                        continue;
                    }
                    other => {
                        return Err(ClusterError::Gather(format!(
                            "candidate '{name}': expected sketch_blob, got {other:?}"
                        )))
                    }
                };
                let score = estimate_jp(&query, &sk)
                    .map_err(|e| ClusterError::Gather(format!("candidate '{name}': {e}")))?;
                scored.push((name, score));
            }
        }
        let reranked = scored.len();
        scored.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).expect("estimates are never NaN").then(a.0.cmp(&b.0))
        });
        scored.truncate(limit);
        Ok((
            scored,
            GatherStats {
                nodes: self.slots.len(),
                live,
                candidates: n_candidates,
                reranked,
            },
        ))
    }

    /// Push stream items, partitioned by element id so every element lives
    /// on exactly one site (the §2.3 disjoint-support case). Returns the
    /// number of items routed. Any dead owner fails the whole push —
    /// silently dropping a partition would bias the cardinality estimate.
    /// Owners already known down are refused before anything is sent; a
    /// push that fails mid-way is safe to RETRY VERBATIM once the owner
    /// returns: Stream-FastGM element races are deterministic per
    /// `(seed, id)`, so re-pushing the same `(id, weight)` items is
    /// idempotent, never double-counted.
    pub fn push(&mut self, stream: &str, items: &[(u64, f64)]) -> Result<usize, ClusterError> {
        let mut parts: Vec<Vec<(u64, f64)>> = vec![Vec::new(); self.slots.len()];
        for &(id, w) in items {
            parts[self.partitioner.owner_of_id(id)].push((id, w));
        }
        for (i, part) in parts.iter().enumerate() {
            if !part.is_empty() && self.slots[i].conn.is_none() {
                return Err(self.down_err(i, "previously observed down"));
            }
        }
        for (i, part) in parts.into_iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            let resp =
                self.slot_call(i, &Request::Push { stream: stream.to_string(), items: part })?;
            self.expect_ack(i, resp)?;
        }
        Ok(items.len())
    }

    /// The cluster-wide sketch of `stream`: every live site's stream sketch
    /// fetched as a codec blob and merged (§2.3). Sites that never saw the
    /// stream contribute nothing (they are still live); dead sites degrade
    /// coverage (logged). Zero *responding* sites is
    /// [`ClusterError::NoLiveNodes`]; responding sites but zero holders of
    /// the stream is a [`ClusterError::Gather`] naming the stream — a
    /// typo'd stream on a healthy cluster must not read as an outage.
    pub fn merged_stream_sketch(&mut self, stream: &str) -> Result<GumbelMaxSketch, ClusterError> {
        // Split-phase like `topk`: the fetch goes onto every live wire
        // before any (potentially large) sketch blob is read back, so the
        // per-site encoding work overlaps.
        let req = Request::SketchFetch { name: stream.to_string(), source: SketchSource::Stream };
        let mut awaiting: Vec<usize> = Vec::new();
        for i in 0..self.slots.len() {
            match self.slot_send(i, std::slice::from_ref(&req)) {
                Ok(()) => awaiting.push(i),
                Err(ClusterError::NodeDown { node, .. }) => {
                    log::warn!("cardinality gather: node '{node}' down, degrading");
                }
                Err(e) => return Err(e),
            }
        }
        let mut sketches = Vec::with_capacity(awaiting.len());
        let mut responded = 0usize;
        for i in awaiting {
            match self.slot_recv(i, 1) {
                Ok(mut resps) => match resps.pop().expect("slot_recv(1) yields one reply") {
                    Response::SketchBlob { data, .. } => {
                        responded += 1;
                        let (_, sk) = crate::sketch::codec::decode_sketch_hex(&data)
                            .map_err(|e| ClusterError::Gather(format!("site sketch: {e}")))?;
                        sketches.push(sk);
                    }
                    Response::Error { message } => {
                        // This site holds no partition of the stream.
                        responded += 1;
                        log::debug!(
                            "cardinality gather: node '{}' has no '{stream}': {message}",
                            self.slots[i].hello.node
                        );
                    }
                    other => {
                        return Err(ClusterError::Gather(format!(
                            "expected sketch_blob, got {other:?}"
                        )))
                    }
                },
                Err(ClusterError::NodeDown { node, .. }) => {
                    log::warn!("cardinality gather: node '{node}' down, degrading");
                }
                Err(e) => return Err(e),
            }
        }
        if sketches.is_empty() {
            return Err(if responded == 0 {
                ClusterError::NoLiveNodes
            } else {
                ClusterError::Gather(format!(
                    "stream '{stream}' not found on any of the {responded} responding nodes"
                ))
            });
        }
        merge_tree(&sketches, 4).map_err(|e| ClusterError::Gather(e.to_string()))
    }

    /// Cluster-wide weighted cardinality of `stream` via the merged sketch.
    pub fn cardinality(&mut self, stream: &str) -> Result<f64, ClusterError> {
        Ok(estimate_cardinality(&self.merged_stream_sketch(stream)?))
    }

    /// Per-node `(node id, store size)` from `store_stats`, skipping dead
    /// nodes — the CLI's occupancy report.
    pub fn store_sizes(&mut self) -> Vec<(String, Option<f64>)> {
        (0..self.slots.len())
            .map(|i| {
                let id = self.slots[i].hello.node.clone();
                let size = match self.slot_call(i, &Request::StoreStats) {
                    Ok(Response::Stats { stats }) => {
                        stats.get("size").and_then(|v| v.as_f64())
                    }
                    _ => None,
                };
                (id, size)
            })
            .collect()
    }

    /// Snapshot node `i`'s store to a node-local `path`.
    pub fn snapshot_node(&mut self, i: usize, path: &str) -> Result<String, ClusterError> {
        let resp = self.slot_call(i, &Request::Snapshot { path: path.to_string() })?;
        self.expect_ack(i, resp)
    }

    /// Restore node `i`'s store from a node-local `path` (bumps its epoch;
    /// refresh with [`ClusterClient::reconnect`] to observe it).
    pub fn restore_node(&mut self, i: usize, path: &str) -> Result<String, ClusterError> {
        let resp = self.slot_call(i, &Request::Restore { path: path.to_string() })?;
        self.expect_ack(i, resp)
    }
}
