//! The scatter-gather cluster router, replication-aware.
//!
//! A [`ClusterClient`] holds one JSON-lines connection per node plus the
//! rendezvous [`Partitioner`] built from the node ids the `hello`
//! handshake reported, and a [`ReplicaConfig`] choosing the replication
//! factor R and write quorum W. Reads and writes split by op:
//!
//! * **writes** (`upsert`, `delete`, stream `push`) fan out to all R
//!   owners of each key / element partition. W acks make the write a
//!   success; fewer are a typed [`ClusterError::QuorumLost`] naming the
//!   down nodes (at R=1 the degenerate single-owner failure stays the
//!   classic [`ClusterError::NodeDown`]). Replicas converge because
//!   store writes carry monotonic per-key versions and stream pushes are
//!   idempotent per `(seed, id)` — re-sending is always safe;
//! * **`topk`** scatters to every live node (split-phase: all requests on
//!   the wire before any reply is read), gathers the per-node LSH
//!   candidate sets, fetches each candidate's versioned codec blob from
//!   EVERY node that reported it and keeps the **highest-version** copy
//!   (a mid-rebalance or mid-repair overlap can leave replicas briefly
//!   disagreeing — the version, not node order, decides), fails over to
//!   the remaining replica owners for candidates whose reporters died
//!   mid-gather, and re-ranks centrally with `estimate_jp`. With R ≥ 2 a
//!   single dead node is invisible to reads;
//! * **cardinality** fetches every live node's stream sketch and
//!   `merge_tree`s them (§2.3): merging is idempotent, so replicated
//!   pushes cost nothing at read time — and when a replica is down, its
//!   peers' sketches already cover every partition, keeping the merged
//!   sketch (and the estimate) bit-identical to the healthy cluster's.
//!
//! [`ClusterClient::repair`] is the anti-entropy path: it walks every
//! live node's `(key, version)` pages via `store_keys`, diffs each key's
//! replica set, streams the highest-version codec blob onto stale/cold
//! owners (`store_put`, last-writer-wins), and converges stream states by
//! fetching, merging and `stream_merge`-ing per-site sketches — §2.3
//! makes the merge lossless and idempotent, so repair can run any time,
//! repeatedly, against live traffic.
//!
//! Liveness is observed, not configured: the first I/O error on a node's
//! connection marks it down; [`ClusterClient::reconnect`] re-attaches
//! (e.g. after a restart-from-snapshot, on whatever address the node came
//! back on — identity is the node id, not the socket).
//!
//! With [`ReplicaConfig::cache_bytes`] > 0 the client keeps a
//! `(key, version)`-keyed **gather-blob cache**: every codec blob a gather
//! decodes is remembered under its store version, and subsequent
//! `topk`/`sample`/`partition` gathers first walk the live nodes'
//! `(key, version)` pages (`store_keys` — the same read-only walk `repair`
//! phase 1 performs) and skip re-fetching any key whose version has not
//! advanced. Versioned blobs are immutable under the repair-on-rejoin rule
//! (README §Replication: version-only diffing is already what `repair` and
//! the LWW gather trust), so a version match is a register match and the
//! warm gather stays bit-identical to the cold one. At `cache_bytes == 0`
//! (the default) the client behaves exactly as before: every gather
//! re-fetches every blob.
//!
//! With [`ReplicaConfig::framed`] set, every blob the client moves —
//! gather fetches, cache fills, repair installs, stream-merge convergence
//! — rides the binary frame ops (`sketch_fetch_bin`, `store_put_bin`,
//! `stream_merge_bin`): raw [`crate::sketch::codec`] bytes in the frame
//! body, no hex expansion, no JSON escaping, decoding to bit-identical
//! registers. JSON-lines clusters keep the hex ops verbatim, so mixed and
//! pre-binary deployments interoperate unchanged. Fan-out writes
//! (`quorum_write`, repair installs, stream convergence) serialize their
//! request ONCE and share the bytes across all R owners.

use super::partitioner::Partitioner;
use crate::coordinator::cache::{ByteLruCache, CacheStats, Digest};
use crate::coordinator::client::{Client, PreparedRequest};
use crate::coordinator::merger::merge_tree;
use crate::coordinator::protocol::{
    HelloInfo, QueryTarget, Request, Response, SketchSource, PROTOCOL_VERSION,
};
use crate::estimate::cardinality::estimate_cardinality;
use crate::estimate::jaccard::{estimate_jp, estimate_jp_batch};
use crate::estimate::sample;
use crate::sketch::codec;
use crate::sketch::engine::{self, EngineParams};
use crate::sketch::{AlgorithmId, GumbelMaxSketch, Sketcher, SparseVector};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Default for [`ReplicaConfig::io_timeout`]: how long a gather waits on
/// any single node read before treating the node as down. Without a
/// timeout, a hung-but-connected node (silent partition, stop-the-world
/// pause) would wedge every gather forever — only cleanly closed sockets
/// would degrade. Generous: normal ops answer in microseconds-to-
/// milliseconds on a healthy node.
pub const DEFAULT_NODE_IO_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(10);

/// Page size of the `store_keys` walk `repair` performs per node.
const REPAIR_PAGE: usize = 512;

/// Replication shape of a cluster client: every key/element partition is
/// owned by the top-`replication` nodes of its HRW ranking, and a write
/// needs `write_quorum` owner acks to succeed. Also carries per-node
/// transport knobs: the I/O timeout that bounds how long a hung node can
/// stall a gather, and whether node connections upgrade to the binary
/// framed protocol after the (always JSON) `hello` handshake.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaConfig {
    pub replication: usize,
    pub write_quorum: usize,
    /// Per-node read/write timeout; an expiry marks the node down. Tune
    /// down for fast failover in tests/latency-sensitive callers, up for
    /// WAN links. [`DEFAULT_NODE_IO_TIMEOUT`] by default.
    pub io_timeout: std::time::Duration,
    /// Upgrade node connections to binary frames after the handshake.
    /// Requires every node to serve the event-driven transport (the
    /// thread-per-connection JSON server does not speak frames).
    pub framed: bool,
    /// Byte budget of the client-side `(key, version)` gather-blob cache.
    /// 0 (the default) disables it: every gather re-fetches every blob,
    /// exactly the pre-cache behavior. With a budget, gathers first diff
    /// versions via `store_keys` pages and only pull keys that changed.
    pub cache_bytes: usize,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        ReplicaConfig {
            replication: 1,
            write_quorum: 1,
            io_timeout: DEFAULT_NODE_IO_TIMEOUT,
            framed: false,
            cache_bytes: 0,
        }
    }
}

/// Typed cluster-layer failures. Per-node faults carry the node identity
/// so callers can alert on the *site*, not just the operation.
#[derive(Debug, thiserror::Error)]
pub enum ClusterError {
    /// The single node owning the touched partition is unreachable (the
    /// R=1 degenerate case). Writes to its keys fail with this until it
    /// returns; gathers simply skip it.
    #[error("node '{node}' ({addr}) is down: {reason}")]
    NodeDown { node: String, addr: String, reason: String },
    /// A replicated write reached fewer than W of its R owners. Names the
    /// owners that are down so the operator knows which sites to heal.
    #[error(
        "write quorum lost for {target}: {acked}/{want} owner acks (replication {replication}); \
         down: {down:?}"
    )]
    QuorumLost {
        target: String,
        want: usize,
        acked: usize,
        replication: usize,
        down: Vec<String>,
    },
    /// Every node is down — there is nothing left to scatter to.
    #[error("no live nodes in the cluster")]
    NoLiveNodes,
    /// A live node answered with a protocol-level error.
    #[error("node '{node}' rejected the request: {message}")]
    Remote { node: String, message: String },
    /// The gather itself failed (merge/estimator error across sites).
    #[error("cluster gather failed: {0}")]
    Gather(String),
}

/// What a scatter-gather `topk` cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GatherStats {
    /// Cluster size (configured membership).
    pub nodes: usize,
    /// Nodes that *responded* to the scatter — including ones that
    /// answered with a protocol-level refusal (alive but contributing
    /// nothing). Only unreachable nodes are excluded.
    pub live: usize,
    /// Distinct candidates returned by the per-node probes.
    pub candidates: usize,
    /// Candidates whose sketches were fetched and centrally re-ranked.
    pub reranked: usize,
}

/// What an anti-entropy [`ClusterClient::repair`] did.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RepairReport {
    /// Distinct store keys seen across the live nodes' key walks.
    pub keys_scanned: usize,
    /// `(key, owner)` installs streamed (stale or missing replica healed).
    pub keys_healed: usize,
    /// Keys left untouched because their best-version source died (or
    /// vanished) mid-repair — rerun once the cluster settles.
    pub keys_skipped: usize,
    /// Stream-merge acks applied across nodes and streams.
    pub stream_merges: usize,
}

struct NodeSlot {
    addr: String,
    hello: HelloInfo,
    /// `None` = observed down (I/O error) until a `reconnect`.
    conn: Option<Client>,
}

/// A blob-bearing reply normalized across the hex (`sketch_blob`) and
/// binary (`sketch_blob_bin`) response shapes — gathers handle both so a
/// framed cluster and a JSON cluster run the same decision logic.
enum BlobReply {
    /// A decodable blob: the name the node answered for, plus the decoded
    /// `(key, version, sketch)` the blob itself carries.
    Blob { got: String, key: String, version: u64, sk: GumbelMaxSketch },
    /// A protocol-level `error` reply (key/stream not held there).
    Missing(String),
    /// A blob that failed to decode.
    Corrupt(String),
    /// Any other response shape.
    Unexpected(Response),
}

/// The sketch config every member must serve (frozen at `connect`);
/// `reconnect` re-checks it so a node rejoining with a changed config is
/// refused exactly like it would have been at formation time.
#[derive(Debug, Clone, PartialEq)]
struct ClusterSketchConfig {
    k: usize,
    seed: u64,
    algo: String,
}

impl ClusterSketchConfig {
    fn matches(&self, h: &HelloInfo) -> bool {
        h.k == self.k && h.seed == self.seed && h.algo == self.algo
    }
}

pub struct ClusterClient {
    slots: Vec<NodeSlot>,
    partitioner: Partitioner,
    repl: ReplicaConfig,
    expect: ClusterSketchConfig,
    /// Central sketcher at the cluster's (algo, k, seed) — what queries
    /// and re-rank probes are sketched with. Bit-identical to every node's
    /// default sketch path.
    sketcher: Box<dyn Sketcher>,
    /// `(key, version)` gather-blob cache (digest of the key → Arc'd
    /// `(version, sketch)`); `None` when `cache_bytes == 0`. Entries are
    /// only served after a `store_keys` version walk proves the key has
    /// not advanced past the cached version.
    gather_cache: Option<ByteLruCache<Arc<(u64, GumbelMaxSketch)>>>,
}

impl ClusterClient {
    /// [`ClusterClient::connect_with`] at the default R=1, W=1 (the
    /// unreplicated PR-4 topology: one owner per key).
    pub fn connect(addrs: &[String]) -> anyhow::Result<ClusterClient> {
        ClusterClient::connect_with(addrs, ReplicaConfig::default())
    }

    /// Connect to every node, handshake, and verify the cluster is
    /// coherent: same protocol version, same `(k, seed)`, same default
    /// algorithm (an EXP-register one — the re-rank needs `estimate_jp`),
    /// distinct node ids, and a replication shape the membership can
    /// carry (`1 <= W <= R <= nodes`).
    ///
    /// All nodes must be reachable to *form* the client: membership
    /// identity (the node ids the partitioner hashes on) comes from the
    /// handshake itself, so a dead node would leave the keyspace
    /// unroutable. Once formed, any member may die and the client degrades
    /// per-op — which means degraded reads belong to long-lived clients;
    /// a fresh client (e.g. a CLI invocation) cannot form against a
    /// cluster with a member down.
    pub fn connect_with(addrs: &[String], repl: ReplicaConfig) -> anyhow::Result<ClusterClient> {
        anyhow::ensure!(!addrs.is_empty(), "cluster needs at least one node address");
        anyhow::ensure!(
            repl.replication >= 1 && repl.replication <= addrs.len(),
            "replication {} needs 1..={} (the cluster size)",
            repl.replication,
            addrs.len(),
        );
        anyhow::ensure!(
            repl.write_quorum >= 1 && repl.write_quorum <= repl.replication,
            "write quorum {} needs 1..={} (the replication factor)",
            repl.write_quorum,
            repl.replication,
        );
        let mut slots = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let mut conn = Client::connect(addr)?;
            conn.set_io_timeout(Some(repl.io_timeout))?;
            let hello = conn
                .hello()
                .map_err(|e| anyhow::anyhow!("hello to '{addr}' failed: {e}"))?;
            anyhow::ensure!(
                hello.protocol == PROTOCOL_VERSION,
                "node '{}' ({addr}) speaks protocol v{}, this client v{PROTOCOL_VERSION}",
                hello.node,
                hello.protocol,
            );
            if repl.framed {
                conn.set_framed(true)?;
            }
            slots.push(NodeSlot { addr: addr.clone(), hello, conn: Some(conn) });
        }
        let first = &slots[0].hello;
        for s in &slots[1..] {
            let h = &s.hello;
            anyhow::ensure!(
                h.k == first.k && h.seed == first.seed && h.algo == first.algo,
                "cluster config mismatch: node '{}' serves (k={}, seed={}, algo={}) but \
                 node '{}' serves (k={}, seed={}, algo={})",
                first.node,
                first.k,
                first.seed,
                first.algo,
                h.node,
                h.k,
                h.seed,
                h.algo,
            );
        }
        let algo = AlgorithmId::from_name(&first.algo)?;
        anyhow::ensure!(
            algo.family().has_exponential_registers(),
            "cluster default algo '{}' has no J_P estimator — scatter-gather topk \
             cannot re-rank (use an ordered/direct-family default)",
            first.algo,
        );
        let sketcher = engine::build(algo, EngineParams::new(first.k, first.seed));
        let expect = ClusterSketchConfig {
            k: first.k,
            seed: first.seed,
            algo: first.algo.clone(),
        };
        let node_ids: Vec<String> = slots.iter().map(|s| s.hello.node.clone()).collect();
        let partitioner = Partitioner::new(&node_ids)?;
        let gather_cache = (repl.cache_bytes > 0).then(|| ByteLruCache::new(repl.cache_bytes, 4));
        Ok(ClusterClient { slots, partitioner, repl, expect, sketcher, gather_cache })
    }

    pub fn nodes(&self) -> usize {
        self.slots.len()
    }

    pub fn live_nodes(&self) -> usize {
        self.slots.iter().filter(|s| s.conn.is_some()).count()
    }

    pub fn node_id(&self, i: usize) -> &str {
        &self.slots[i].hello.node
    }

    pub fn addr(&self, i: usize) -> &str {
        &self.slots[i].addr
    }

    pub fn replication(&self) -> ReplicaConfig {
        self.repl
    }

    /// Adjust the write quorum of this client (still `1..=R`). Lowering W
    /// is how an operator keeps writes available while an R=2 replica set
    /// has a member down; repair reconverges the replicas afterwards.
    pub fn set_write_quorum(&mut self, w: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            w >= 1 && w <= self.repl.replication,
            "write quorum {w} needs 1..={} (the replication factor)",
            self.repl.replication,
        );
        self.repl.write_quorum = w;
        Ok(())
    }

    /// The primary owner of `key` (stable; dead nodes keep ownership).
    pub fn owner(&self, key: &str) -> usize {
        self.partitioner.owner(key)
    }

    /// The full replica set of `key` at this client's replication factor
    /// (HRW top-R: prefix-stable in R, standby-promoting on node loss).
    pub fn owners(&self, key: &str) -> Vec<usize> {
        self.partitioner.owners(key, self.repl.replication)
    }

    /// Last handshake each node answered (epoch shows snapshot restores).
    pub fn hello(&self, i: usize) -> &HelloInfo {
        &self.slots[i].hello
    }

    /// Re-attach node `i` on `addr` (it may have come back on a different
    /// port). The node must present the same id — a different identity on
    /// the same slot would silently re-partition the keyspace — AND the
    /// same protocol/sketch config the cluster was formed with: a node
    /// rejoining after a config change must be refused here exactly like
    /// [`ClusterClient::connect`] would have refused it, not discovered
    /// query-by-query as gather errors.
    pub fn reconnect(&mut self, i: usize, addr: &str) -> anyhow::Result<()> {
        let mut conn = Client::connect(addr)?;
        conn.set_io_timeout(Some(self.repl.io_timeout))?;
        let hello = conn.hello()?;
        anyhow::ensure!(
            hello.node == self.slots[i].hello.node,
            "slot {i} expects node '{}' but '{addr}' answered as '{}'",
            self.slots[i].hello.node,
            hello.node,
        );
        anyhow::ensure!(
            hello.protocol == PROTOCOL_VERSION,
            "node '{}' rejoined speaking protocol v{}, this client v{PROTOCOL_VERSION}",
            hello.node,
            hello.protocol,
        );
        anyhow::ensure!(
            self.expect.matches(&hello),
            "node '{}' rejoined with (k={}, seed={}, algo={}) but the cluster was formed \
             with (k={}, seed={}, algo={})",
            hello.node,
            hello.k,
            hello.seed,
            hello.algo,
            self.expect.k,
            self.expect.seed,
            self.expect.algo,
        );
        if self.repl.framed {
            conn.set_framed(true)?;
        }
        self.slots[i] = NodeSlot { addr: addr.to_string(), hello, conn: Some(conn) };
        // A rejoining node may have been restored from a snapshot, which
        // can move key versions *backwards* — a regression the forward-only
        // (key, version) validation cannot see. Drop the gather cache
        // wholesale; it refills on the next warm gather.
        if let Some(cache) = &self.gather_cache {
            cache.clear();
        }
        Ok(())
    }

    fn is_live(&self, i: usize) -> bool {
        self.slots[i].conn.is_some()
    }

    /// The typed down-error for slot `i` (does not change liveness).
    fn down_err(&self, i: usize, reason: &str) -> ClusterError {
        ClusterError::NodeDown {
            node: self.slots[i].hello.node.clone(),
            addr: self.slots[i].addr.clone(),
            reason: reason.to_string(),
        }
    }

    /// Mark slot `i` down after an observed I/O failure.
    fn mark_down(&mut self, i: usize, reason: &str) -> ClusterError {
        self.slots[i].conn = None;
        self.down_err(i, reason)
    }

    /// Phase 1: write `reqs` to node `i` without reading replies. I/O
    /// failure marks the node down. All slot traffic funnels through
    /// this + [`Self::slot_recv`], so down-marking lives in one place.
    fn slot_send(&mut self, i: usize, reqs: &[Request]) -> Result<(), ClusterError> {
        if self.slots[i].conn.is_none() {
            return Err(self.down_err(i, "previously observed down"));
        }
        let sent = self.slots[i].conn.as_mut().expect("checked live above").send_batch(reqs);
        sent.map_err(|e| self.mark_down(i, &e.to_string()))
    }

    /// Phase 2: read `n` in-order replies from node `i`. I/O failure (or
    /// a connection closed mid-batch) marks the node down.
    fn slot_recv(&mut self, i: usize, n: usize) -> Result<Vec<Response>, ClusterError> {
        if self.slots[i].conn.is_none() {
            return Err(self.down_err(i, "previously observed down"));
        }
        let resps = self.slots[i].conn.as_mut().expect("checked live above").recv_batch(n);
        resps.map_err(|e| self.mark_down(i, &e.to_string()))
    }

    /// [`Self::slot_send`] for a [`PreparedRequest`] — the fan-out form:
    /// one serialization shared across every owner the caller sends to.
    fn slot_send_prepared(&mut self, i: usize, p: &PreparedRequest) -> Result<(), ClusterError> {
        if self.slots[i].conn.is_none() {
            return Err(self.down_err(i, "previously observed down"));
        }
        let sent = self.slots[i].conn.as_mut().expect("checked live above").send_prepared(p);
        sent.map_err(|e| self.mark_down(i, &e.to_string()))
    }

    /// One synchronous call on node `i` (send + recv).
    fn slot_call(&mut self, i: usize, req: &Request) -> Result<Response, ClusterError> {
        self.slot_send(i, std::slice::from_ref(req))?;
        Ok(self.slot_recv(i, 1)?.pop().expect("slot_recv(1) yields one reply"))
    }

    /// The blob-fetch request for this client's wire: raw codec bytes over
    /// frames (`sketch_fetch_bin` — no hex, half the wire size), hex-in-
    /// JSON over line connections, so mixed and pre-binary peers keep
    /// speaking the exact protocol they always did. Both forms decode to
    /// bit-identical registers, which is what keeps every gather result
    /// independent of the transport.
    fn fetch_req(&self, name: &str, source: SketchSource) -> Request {
        if self.repl.framed {
            Request::SketchFetchBin { name: name.to_string(), source }
        } else {
            Request::SketchFetch { name: name.to_string(), source }
        }
    }

    /// Normalize either blob-response shape; each call site maps the arms
    /// back to its own (unchanged) error wording.
    fn unpack_blob(resp: Response) -> BlobReply {
        match resp {
            Response::SketchBlob { name: got, data } => match codec::decode_sketch_hex(&data) {
                Ok((key, version, sk)) => BlobReply::Blob { got, key, version, sk },
                Err(e) => BlobReply::Corrupt(e.to_string()),
            },
            Response::SketchBlobBin { name: got, data } => {
                match codec::decode_sketch_bytes(&data) {
                    Ok((key, version, sk)) => BlobReply::Blob { got, key, version, sk },
                    Err(e) => BlobReply::Corrupt(e.to_string()),
                }
            }
            Response::Error { message } => BlobReply::Missing(message),
            other => BlobReply::Unexpected(other),
        }
    }

    fn remote_err(&self, i: usize, message: String) -> ClusterError {
        ClusterError::Remote { node: self.slots[i].hello.node.clone(), message }
    }

    /// Unwrap the `ack` every write-path op expects from node `i`;
    /// protocol-level refusals become [`ClusterError::Remote`].
    fn expect_ack(&self, i: usize, resp: Response) -> Result<String, ClusterError> {
        match resp {
            Response::Ack { info } => Ok(info),
            Response::Error { message } => Err(self.remote_err(i, message)),
            other => Err(self.remote_err(i, format!("expected ack, got {other:?}"))),
        }
    }

    /// Fan a keyed write out to all R owners and demand W acks.
    ///
    /// Split-phase: the request goes onto every live owner's wire before
    /// any ack is read, so replicas write in parallel. The replicas stay
    /// convergent without coordination because every store mutation is
    /// version-ordered (LWW) and re-sendable; an under-quorum write may
    /// still have landed on some owners — retrying it verbatim (or
    /// running `repair`) is always safe.
    ///
    /// Failure typing: at R=1 a dead owner keeps the classic
    /// [`ClusterError::NodeDown`]; at R>1 missing quorum is
    /// [`ClusterError::QuorumLost`] naming the down owners. A protocol-
    /// level refusal (the cluster rejecting the write, e.g. an oversized
    /// key) surfaces as [`ClusterError::Remote`], never as a quorum loss.
    fn quorum_write(&mut self, key: &str, req: &Request) -> Result<String, ClusterError> {
        let owners = self.partitioner.owners(key, self.repl.replication);
        let want = self.repl.write_quorum;
        // Serialize ONCE, fan the bytes out: every owner receives the same
        // wire payload without R separate re-encodes of the same request
        // (framed connections share the body; only the id-bearing envelope
        // is derived per owner).
        let prepared = PreparedRequest::new(req, self.repl.framed);
        let mut awaiting: Vec<usize> = Vec::new();
        let mut down: Vec<String> = Vec::new();
        for &o in &owners {
            match self.slot_send_prepared(o, &prepared) {
                Ok(()) => awaiting.push(o),
                Err(ClusterError::NodeDown { node, .. }) => down.push(node),
                Err(e) => return Err(e),
            }
        }
        let mut acks: Vec<String> = Vec::new();
        let mut refusal: Option<ClusterError> = None;
        for o in awaiting {
            match self.slot_recv(o, 1) {
                Ok(mut resps) => {
                    match self.expect_ack(o, resps.pop().expect("one reply")) {
                        Ok(info) => acks.push(info),
                        Err(e) => refusal = Some(e),
                    }
                }
                Err(ClusterError::NodeDown { node, .. }) => down.push(node),
                Err(e) => return Err(e),
            }
        }
        if acks.len() >= want {
            let info = acks.swap_remove(0);
            return Ok(if owners.len() > 1 {
                format!("{info} ({}/{} replicas)", acks.len() + 1, owners.len())
            } else {
                info
            });
        }
        if let Some(e) = refusal {
            return Err(e); // the cluster refused the write; not an outage
        }
        if owners.len() == 1 {
            return Err(self.down_err(owners[0], "previously observed down"));
        }
        Err(ClusterError::QuorumLost {
            target: format!("key '{key}'"),
            want,
            acked: acks.len(),
            replication: owners.len(),
            down,
        })
    }

    /// Upsert `key` on its replica set (store-assigned versions stay in
    /// step across replicas because every owner sees the same write
    /// sequence; divergence from downtime is what `repair` heals).
    pub fn upsert(&mut self, key: &str, vector: SparseVector) -> Result<String, ClusterError> {
        let req = Request::Upsert { key: key.to_string(), vector, version: None };
        self.quorum_write(key, &req)
    }

    /// Delete `key` on its replica set (idempotent per owner). Note that
    /// deletes leave no tombstone: a replica that misses one can
    /// resurrect the key at a later `repair` (README §Replication).
    pub fn delete(&mut self, key: &str) -> Result<String, ClusterError> {
        let req = Request::Delete { key: key.to_string() };
        self.quorum_write(key, &req)
    }

    /// Scatter-gather top-k: per-node candidates, central exact re-rank.
    ///
    /// 1. scatter `topk(vector, limit)` to every live node — the request
    ///    goes onto EVERY wire before any reply is read, so the per-node
    ///    probe work overlaps and the scatter costs ~max(RTT), not the
    ///    sum; each node answers from its own partition (LSH band probe
    ///    or scan, its router's call), and the global top-k is always
    ///    contained in the union of the per-partition top-k's;
    /// 2. fetch the distinct candidates' versioned sketches as checksummed
    ///    codec blobs (`sketch_fetch`), one pipelined batch per
    ///    *reporting* node. A candidate reported by several replicas is
    ///    fetched from all of them and the **highest-version** blob wins —
    ///    replica order never decides, so a mid-rebalance/mid-repair
    ///    overlap where replicas briefly disagree resolves to the last
    ///    write. Candidates whose reporters died mid-gather fail over to
    ///    the rest of their replica set (the owners that hold the key but
    ///    did not surface it);
    /// 3. re-rank everything centrally with `estimate_jp` against a query
    ///    sketch computed here at the shared `(algo, k, seed)` — the same
    ///    deterministic scores every node computes, so the gather ranks
    ///    exactly like a single node holding the union store would. The
    ///    nodes' own scores are deliberately NOT trusted: the central
    ///    estimator is the authority (a stale, buggy or differently-built
    ///    node can report candidates but never distort the ranking), at
    ///    the cost of transferring one codec blob per candidate copy;
    /// 4. sort (score desc, key asc — the store's tie rule) and truncate.
    ///
    /// Nodes that die mid-gather only shrink coverage — and with R ≥ 2
    /// they do not even do that, because every partition has a surviving
    /// replica. Zero responding nodes is [`ClusterError::NoLiveNodes`].
    ///
    /// With [`ReplicaConfig::cache_bytes`] > 0, step 2 first diffs the
    /// candidates against a `store_keys` version walk and serves cached
    /// blobs for every candidate whose version has not advanced — only
    /// changed keys are re-fetched, and the ranking stays bit-identical
    /// because a version match pins the registers.
    pub fn topk(
        &mut self,
        vector: &SparseVector,
        limit: usize,
    ) -> Result<(Vec<(String, f64)>, GatherStats), ClusterError> {
        let query = self.sketcher.sketch(vector);
        // Scatter phase 1: the same request onto every live wire.
        let req = Request::TopK { vector: vector.clone(), limit };
        let mut awaiting: Vec<usize> = Vec::new();
        for i in 0..self.slots.len() {
            match self.slot_send(i, std::slice::from_ref(&req)) {
                Ok(()) => awaiting.push(i),
                Err(ClusterError::NodeDown { node, reason, .. }) => {
                    log::warn!("topk scatter: node '{node}' down ({reason}), degrading");
                }
                Err(e) => return Err(e),
            }
        }
        // Scatter phase 2: collect replies. Candidates remember every
        // node that reported them (BTreeMap keeps the gather
        // deterministic) — the fetch phase uses ALL reporters so replica
        // disagreements resolve by version, not by reply order.
        let mut candidates: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut live = 0usize;
        for i in awaiting {
            match self.slot_recv(i, 1) {
                Ok(mut resps) => {
                    // The node answered: it is live even if it refused
                    // (e.g. mid-restore config mismatch) — only
                    // unreachable nodes are excluded from `live`, so an
                    // all-refusing-but-healthy cluster is a degraded
                    // answer, never a spurious NoLiveNodes.
                    live += 1;
                    match resps.pop().expect("slot_recv(1) yields one reply") {
                        Response::TopK { hits } => {
                            for (name, _) in hits {
                                candidates.entry(name).or_default().push(i);
                            }
                        }
                        Response::Error { message } => log::warn!(
                            "topk scatter: node '{}' rejected: {message}",
                            self.slots[i].hello.node
                        ),
                        other => log::warn!(
                            "topk scatter: node '{}' answered {other:?}",
                            self.slots[i].hello.node
                        ),
                    }
                }
                Err(ClusterError::NodeDown { node, reason, .. }) => {
                    log::warn!("topk scatter: node '{node}' down ({reason}), degrading");
                }
                Err(e) => return Err(e),
            }
        }
        if live == 0 {
            return Err(ClusterError::NoLiveNodes);
        }
        let n_candidates = candidates.len();
        // Cached-gather probe: one version walk, then every candidate
        // whose cached blob still matches the cluster's highest version
        // goes straight into `best` — its replica fetches are skipped
        // below. Misses and version advances fall through to the fetch
        // path unchanged, so a warm gather is bit-identical to a cold one.
        let mut best: BTreeMap<String, (u64, GumbelMaxSketch)> = BTreeMap::new();
        let mut cached_names: std::collections::BTreeSet<String> =
            std::collections::BTreeSet::new();
        if self.gather_cache.is_some() && n_candidates > 0 {
            let view = self.version_view()?;
            let names: Vec<String> = candidates.keys().cloned().collect();
            for name in names {
                if let Some(&ver) = view.get(&name) {
                    if let Some(sk) = self.cached_blob(&name, ver) {
                        cached_names.insert(name.clone());
                        best.insert(name, (ver, sk));
                    }
                }
            }
        }
        // Gather: fetch + central re-rank, split-phase again. Fetches are
        // grouped by reporting node and pipelined (all batches written
        // before any reply is read), so the gather costs ~one overlapped
        // round-trip even though replicated candidates are fetched R
        // times. A candidate whose node died between scatter and fetch
        // (or which was deleted meanwhile) is retried on its remaining
        // replica owners before being skipped.
        let mut by_node: Vec<Vec<String>> = vec![Vec::new(); self.slots.len()];
        for (name, reporters) in &candidates {
            if cached_names.contains(name) {
                continue;
            }
            for &i in reporters {
                by_node[i].push(name.clone());
            }
        }
        let mut fetching: Vec<(usize, Vec<String>)> = Vec::new();
        for (i, names) in by_node.into_iter().enumerate() {
            if names.is_empty() {
                continue;
            }
            let reqs: Vec<Request> = names
                .iter()
                .map(|name| self.fetch_req(name, SketchSource::Store))
                .collect();
            match self.slot_send(i, &reqs) {
                Ok(()) => fetching.push((i, names)),
                Err(ClusterError::NodeDown { node, .. }) => {
                    log::warn!(
                        "gather: node '{node}' holding {} candidate copies died mid-gather",
                        names.len()
                    );
                }
                Err(e) => return Err(e),
            }
        }
        // Highest-version copy per candidate; ties keep the first-decoded
        // copy (slot order). Replicas that followed the repair-on-rejoin
        // rule hold identical registers at equal versions; replicas that
        // skipped it can diverge at the same version (README
        // §Replication), in which case this tie-break is arbitrary but
        // deterministic.
        for (i, names) in fetching {
            let resps = match self.slot_recv(i, names.len()) {
                Ok(resps) => resps,
                Err(ClusterError::NodeDown { node, .. }) => {
                    log::warn!(
                        "gather: node '{node}' holding {} candidate copies died mid-gather",
                        names.len()
                    );
                    continue;
                }
                Err(e) => return Err(e),
            };
            for (name, resp) in names.into_iter().zip(resps) {
                match Self::unpack_blob(resp) {
                    // The central re-rank is the trust boundary: a blob
                    // answering for the wrong key must be a loud error,
                    // never scored under `name`.
                    BlobReply::Blob { got, key, version, sk }
                        if got == name && key == name =>
                    {
                        let held = best.get(&name).map(|(v, _)| *v);
                        if !held.is_some_and(|h| h >= version) {
                            best.insert(name, (version, sk));
                        }
                    }
                    BlobReply::Blob { got, key, .. } => {
                        return Err(ClusterError::Gather(format!(
                            "candidate '{name}': node '{}' answered with '{got}' \
                             (blob key '{key}')",
                            self.slots[i].hello.node
                        )))
                    }
                    BlobReply::Corrupt(e) => {
                        return Err(ClusterError::Gather(format!(
                            "candidate '{name}': corrupt sketch blob: {e}"
                        )))
                    }
                    BlobReply::Missing(message) => {
                        log::debug!("gather: candidate '{name}' gone on one replica: {message}");
                    }
                    BlobReply::Unexpected(other) => {
                        return Err(ClusterError::Gather(format!(
                            "candidate '{name}': expected sketch_blob, got {other:?}"
                        )))
                    }
                }
            }
        }
        // Failover pass: candidates none of whose reporters delivered a
        // blob (reporter died mid-gather, or raced a delete) are tried on
        // the rest of their replica set — any owner holds the key even if
        // its own probe did not surface it. Rare path, so sequential.
        let missing: Vec<(String, Vec<usize>)> = candidates
            .iter()
            .filter(|(name, _)| !best.contains_key(*name))
            .map(|(name, reporters)| (name.clone(), reporters.clone()))
            .collect();
        for (name, reporters) in missing {
            for o in self.partitioner.owners(&name, self.repl.replication) {
                if reporters.contains(&o) || !self.is_live(o) {
                    continue;
                }
                let req = self.fetch_req(&name, SketchSource::Store);
                match self.slot_call(o, &req) {
                    Ok(resp) => match Self::unpack_blob(resp) {
                        BlobReply::Blob { got, key, version, sk }
                            if got == name && key == name =>
                        {
                            best.insert(name.clone(), (version, sk));
                            break;
                        }
                        BlobReply::Blob { .. } | BlobReply::Corrupt(_) => {
                            return Err(ClusterError::Gather(format!(
                                "candidate '{name}': corrupt failover blob from '{}'",
                                self.slots[o].hello.node
                            )))
                        }
                        // Not held here either; try the next owner.
                        BlobReply::Missing(_) | BlobReply::Unexpected(_) => {}
                    },
                    Err(ClusterError::NodeDown { .. }) => {}
                    Err(e) => return Err(e),
                }
            }
            if !best.contains_key(&name) {
                log::warn!("gather: candidate '{name}' unreachable on every replica, skipped");
            }
        }
        // Remember every freshly fetched winner under its (key, version)
        // identity so the next gather can skip re-pulling it while the
        // version holds.
        for (name, (version, sk)) in &best {
            if !cached_names.contains(name) {
                self.remember_blob(name, *version, sk);
            }
        }
        // Central re-rank of every winning copy in one batched pass (the
        // per-pair error semantics are preserved by `estimate_jp_batch`:
        // the first incompatible candidate aborts with the same message
        // the old per-candidate loop produced).
        let mut scored: Vec<(String, f64)> =
            estimate_jp_batch(&query, best.iter().map(|(name, (_, sk))| (name.clone(), sk)))
                .map_err(|e| {
                    let name = best
                        .iter()
                        .find(|(_, (_, sk))| estimate_jp(&query, sk).is_err())
                        .map(|(name, _)| name.as_str())
                        .unwrap_or("?");
                    ClusterError::Gather(format!("candidate '{name}': {e}"))
                })?;
        let reranked = scored.len();
        scored.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).expect("estimates are never NaN").then(a.0.cmp(&b.0))
        });
        scored.truncate(limit);
        Ok((
            scored,
            GatherStats {
                nodes: self.slots.len(),
                live,
                candidates: n_candidates,
                reranked,
            },
        ))
    }

    /// Push stream items, partitioned by element id onto each element's R
    /// owners — every element lives on `replication` sites, so any
    /// covering subset of replicas reconstructs the full stream sketch
    /// (§2.3: replays are idempotent, merges are lossless). Per owner-set
    /// quorum: a partition written to at least W of its R owners counts
    /// as success; fewer is [`ClusterError::QuorumLost`] (at R=1: the
    /// classic [`ClusterError::NodeDown`]) — and a push that fails
    /// mid-way is always safe to RETRY VERBATIM, because Stream-FastGM
    /// element races are deterministic per `(seed, id)`: re-pushing the
    /// same `(id, weight)` items is idempotent, never double-counted.
    /// Returns the number of items routed.
    pub fn push(&mut self, stream: &str, items: &[(u64, f64)]) -> Result<usize, ClusterError> {
        let r = self.repl.replication;
        let want = self.repl.write_quorum;
        // Per-node batches plus the distinct owner sets they came from
        // (quorum is judged per owner set — the granularity at which a
        // partition can lose replicas).
        let mut parts: Vec<Vec<(u64, f64)>> = vec![Vec::new(); self.slots.len()];
        let mut owner_sets: std::collections::BTreeSet<Vec<usize>> =
            std::collections::BTreeSet::new();
        for &(id, w) in items {
            let owners = self.partitioner.owners_of_id(id, r);
            for &o in &owners {
                parts[o].push((id, w));
            }
            owner_sets.insert(owners);
        }
        // Pre-check: every owner set must already have a live quorum —
        // refuse before sending anything rather than landing a partial
        // partition (retry-verbatim keeps even that safe, but failing
        // fast names the problem site immediately).
        for owners in &owner_sets {
            let live = owners.iter().filter(|&&o| self.is_live(o)).count();
            if live < want {
                return Err(self.push_quorum_err(stream, owners, live));
            }
        }
        // Split-phase: every live owner's batch on the wire, then acks.
        let mut awaiting: Vec<usize> = Vec::new();
        for (i, part) in parts.iter().enumerate() {
            if part.is_empty() || !self.is_live(i) {
                continue;
            }
            let req = Request::Push { stream: stream.to_string(), items: part.clone() };
            match self.slot_send(i, std::slice::from_ref(&req)) {
                Ok(()) => awaiting.push(i),
                Err(ClusterError::NodeDown { .. }) => {}
                Err(e) => return Err(e),
            }
        }
        let mut acked: Vec<bool> = vec![false; self.slots.len()];
        for i in awaiting {
            match self.slot_recv(i, 1) {
                Ok(mut resps) => {
                    self.expect_ack(i, resps.pop().expect("one reply"))?;
                    acked[i] = true;
                }
                Err(ClusterError::NodeDown { .. }) => {}
                Err(e) => return Err(e),
            }
        }
        // Post-check: did every owner set keep its quorum through the
        // send? (A node can die mid-push.)
        for owners in &owner_sets {
            let got = owners.iter().filter(|&&o| acked[o]).count();
            if got < want {
                return Err(self.push_quorum_err(stream, owners, got));
            }
        }
        Ok(items.len())
    }

    /// The typed under-quorum error for a push partition (R=1 keeps the
    /// degenerate NodeDown shape).
    fn push_quorum_err(&self, stream: &str, owners: &[usize], acked: usize) -> ClusterError {
        if owners.len() == 1 {
            return self.down_err(owners[0], "previously observed down");
        }
        ClusterError::QuorumLost {
            target: format!("stream '{stream}'"),
            want: self.repl.write_quorum,
            acked,
            replication: owners.len(),
            down: owners
                .iter()
                .filter(|&&o| !self.is_live(o))
                .map(|&o| self.slots[o].hello.node.clone())
                .collect(),
        }
    }

    /// The cluster-wide sketch of `stream`: every live site's stream sketch
    /// fetched as a codec blob and merged (§2.3). Replication makes this
    /// failure-transparent: pushes land on R sites per partition, merging
    /// duplicates is idempotent, and with any single node down the
    /// surviving replicas still cover every partition — the merged sketch
    /// is bit-identical to the healthy cluster's. Sites that never saw
    /// the stream contribute nothing (they are still live); zero
    /// *responding* sites is [`ClusterError::NoLiveNodes`]; responding
    /// sites but zero holders of the stream is a [`ClusterError::Gather`]
    /// naming the stream — a typo'd stream on a healthy cluster must not
    /// read as an outage.
    pub fn merged_stream_sketch(&mut self, stream: &str) -> Result<GumbelMaxSketch, ClusterError> {
        // Split-phase like `topk`: the fetch goes onto every live wire
        // before any (potentially large) sketch blob is read back, so the
        // per-site encoding work overlaps.
        let req = self.fetch_req(stream, SketchSource::Stream);
        let mut awaiting: Vec<usize> = Vec::new();
        for i in 0..self.slots.len() {
            match self.slot_send(i, std::slice::from_ref(&req)) {
                Ok(()) => awaiting.push(i),
                Err(ClusterError::NodeDown { node, .. }) => {
                    log::warn!("cardinality gather: node '{node}' down, degrading");
                }
                Err(e) => return Err(e),
            }
        }
        let mut sketches = Vec::with_capacity(awaiting.len());
        let mut responded = 0usize;
        for i in awaiting {
            match self.slot_recv(i, 1) {
                Ok(mut resps) => {
                    let resp = resps.pop().expect("slot_recv(1) yields one reply");
                    match Self::unpack_blob(resp) {
                        BlobReply::Blob { sk, .. } => {
                            responded += 1;
                            sketches.push(sk);
                        }
                        BlobReply::Corrupt(e) => {
                            return Err(ClusterError::Gather(format!("site sketch: {e}")))
                        }
                        BlobReply::Missing(message) => {
                            // This site holds no partition of the stream.
                            responded += 1;
                            log::debug!(
                                "cardinality gather: node '{}' has no '{stream}': {message}",
                                self.slots[i].hello.node
                            );
                        }
                        BlobReply::Unexpected(other) => {
                            return Err(ClusterError::Gather(format!(
                                "expected sketch_blob, got {other:?}"
                            )))
                        }
                    }
                }
                Err(ClusterError::NodeDown { node, .. }) => {
                    log::warn!("cardinality gather: node '{node}' down, degrading");
                }
                Err(e) => return Err(e),
            }
        }
        if sketches.is_empty() {
            return Err(if responded == 0 {
                ClusterError::NoLiveNodes
            } else {
                ClusterError::Gather(format!(
                    "stream '{stream}' not found on any of the {responded} responding nodes"
                ))
            });
        }
        merge_tree(&sketches, 4).map_err(|e| ClusterError::Gather(e.to_string()))
    }

    /// Cluster-wide weighted cardinality of `stream` via the merged sketch.
    pub fn cardinality(&mut self, stream: &str) -> Result<f64, ClusterError> {
        Ok(estimate_cardinality(&self.merged_stream_sketch(stream)?))
    }

    /// Read `key`'s `(version, sketch)` from its replica set: every live
    /// owner is asked and the **highest-version** copy wins — the same
    /// LWW rule the `topk` gather applies, so a mid-repair stale replica
    /// can never answer for the key (HRW-order-first-wins could). Dead
    /// owners only shrink coverage. `Ok(None)` means no live owner holds
    /// the key; [`ClusterError::NoLiveNodes`] means no owner was
    /// reachable at all. Drives `fastgm cluster get`.
    pub fn fetch_key(
        &mut self,
        key: &str,
    ) -> Result<Option<(u64, GumbelMaxSketch)>, ClusterError> {
        let mut reachable = 0usize;
        let mut best: Option<(u64, GumbelMaxSketch)> = None;
        for o in self.partitioner.owners(key, self.repl.replication) {
            let req = self.fetch_req(key, SketchSource::Store);
            match self.slot_call(o, &req) {
                Ok(resp) => match Self::unpack_blob(resp) {
                    BlobReply::Blob { got, key: k, version, sk } if got == key && k == key => {
                        reachable += 1;
                        if !best.as_ref().is_some_and(|(held, _)| *held >= version) {
                            best = Some((version, sk));
                        }
                    }
                    BlobReply::Blob { .. } | BlobReply::Corrupt(_) => {
                        return Err(ClusterError::Gather(format!(
                            "key '{key}': corrupt blob from '{}'",
                            self.slots[o].hello.node
                        )))
                    }
                    BlobReply::Missing(_) => reachable += 1, // live, not holding it
                    BlobReply::Unexpected(other) => {
                        return Err(ClusterError::Gather(format!(
                            "key '{key}': expected sketch_blob, got {other:?}"
                        )))
                    }
                },
                Err(ClusterError::NodeDown { node, .. }) => {
                    log::warn!("fetch '{key}': owner '{node}' down, failing over");
                }
                Err(e) => return Err(e),
            }
        }
        if reachable == 0 {
            return Err(ClusterError::NoLiveNodes);
        }
        Ok(best)
    }

    /// Live counters of the `(key, version)` gather-blob cache; `None`
    /// when the cache is disabled (`cache_bytes == 0`).
    pub fn gather_cache_stats(&self) -> Option<CacheStats> {
        self.gather_cache.as_ref().map(|c| c.stats())
    }

    fn blob_digest(key: &str) -> u64 {
        let mut d = Digest::new();
        d.str(key);
        d.finish()
    }

    /// Probe the gather cache for `key` at exactly `version` (any other
    /// cached version is a stale drop — versions only move forward).
    fn cached_blob(&self, key: &str, version: u64) -> Option<GumbelMaxSketch> {
        let cache = self.gather_cache.as_ref()?;
        cache
            .get_validated(Self::blob_digest(key), |e| e.0 == version)
            .map(|e| e.1.clone())
    }

    /// Remember a decoded gather blob under its `(key, version)` identity.
    fn remember_blob(&self, key: &str, version: u64, sk: &GumbelMaxSketch) {
        if let Some(cache) = &self.gather_cache {
            let cost = key.len() + sk.k() * 16 + 64;
            cache.insert(Self::blob_digest(key), Arc::new((version, sk.clone())), cost);
        }
    }

    /// `key → highest version across live nodes`: the read-only
    /// `store_keys` page walk (repair phase 1) the cached gathers diff
    /// against. Key pages are tiny next to register blobs (`k × 16` bytes
    /// each), which is the whole trade: one cheap walk decides which
    /// expensive fetches can be skipped. Dead nodes shrink the view —
    /// exactly like they shrink a gather.
    fn version_view(&mut self) -> Result<BTreeMap<String, u64>, ClusterError> {
        let mut view: BTreeMap<String, u64> = BTreeMap::new();
        let mut live = 0usize;
        for i in 0..self.slots.len() {
            if !self.is_live(i) {
                continue;
            }
            match self.walk_node_keys(i) {
                Ok(map) => {
                    live += 1;
                    for (key, version) in map {
                        let held = view.get(&key).copied();
                        if !held.is_some_and(|h| h >= version) {
                            view.insert(key, version);
                        }
                    }
                }
                Err(ClusterError::NodeDown { node, .. }) => {
                    log::warn!("gather cache: node '{node}' died during its version walk");
                }
                Err(e) => return Err(e),
            }
        }
        if live == 0 {
            return Err(ClusterError::NoLiveNodes);
        }
        Ok(view)
    }

    /// Resolve a query target to one cluster-wide merged sketch. Key
    /// targets fetch each key from its replica set via
    /// [`ClusterClient::fetch_key`] — highest-version copy wins, and a
    /// key whose primary owner is down **fails over** to the next live
    /// owner instead of erroring — then union-merge centrally (§2.3, so
    /// the merge is bit-identical to a single store holding every key).
    /// Stream targets reuse the replicated stream gather.
    ///
    /// With the gather cache on, one [`ClusterClient::version_view`] walk
    /// runs first and keys whose cached blob still matches the cluster's
    /// highest version skip their replica-set fetch entirely; the merged
    /// result is bit-identical either way because a version match pins the
    /// registers. Stream targets are never cached (stream sketches have no
    /// version to validate against).
    fn target_sketch(&mut self, target: &QueryTarget) -> Result<GumbelMaxSketch, ClusterError> {
        match target {
            QueryTarget::Keys(keys) => {
                if keys.is_empty() {
                    return Err(ClusterError::Gather(
                        "sample/partition needs at least one key".to_string(),
                    ));
                }
                let view = if self.gather_cache.is_some() {
                    Some(self.version_view()?)
                } else {
                    None
                };
                let mut acc: Option<GumbelMaxSketch> = None;
                for key in keys {
                    let cached = view
                        .as_ref()
                        .and_then(|v| v.get(key))
                        .and_then(|&ver| self.cached_blob(key, ver));
                    let sk = match cached {
                        Some(sk) => sk,
                        None => {
                            let (version, sk) = self.fetch_key(key)?.ok_or_else(|| {
                                ClusterError::Gather(format!(
                                    "no store entry '{key}' on any live owner"
                                ))
                            })?;
                            self.remember_blob(key, version, &sk);
                            sk
                        }
                    };
                    match &mut acc {
                        None => acc = Some(sk),
                        Some(a) => a
                            .merge_in_place(&sk)
                            .map_err(|e| ClusterError::Gather(e.to_string()))?,
                    }
                }
                Ok(acc.expect("non-empty keys imply an accumulator"))
            }
            QueryTarget::Stream(stream) => self.merged_stream_sketch(stream),
        }
    }

    /// Draw `n` element ids ∝ weight from the target's cluster-wide
    /// sketch. The draw happens centrally on the merged registers with
    /// [`crate::estimate::sample::sample_n`], so the same
    /// `(state, target, n, seed)` yields the same ids as a single node
    /// holding the union — replica failover (or which owner happened to
    /// answer) can never change the sample.
    pub fn sample(
        &mut self,
        target: &QueryTarget,
        n: usize,
        seed: u64,
    ) -> Result<Vec<u64>, ClusterError> {
        let sk = self.target_sketch(target)?;
        sample::sample_n(&sk, n, seed).map_err(|e| ClusterError::Gather(e.to_string()))
    }

    /// Estimate the target's cluster-wide partition function (total
    /// weight `Z = Σ w_i`) from the merged registers.
    pub fn partition(&mut self, target: &QueryTarget) -> Result<f64, ClusterError> {
        let sk = self.target_sketch(target)?;
        sample::total_weight(&sk).map_err(|e| ClusterError::Gather(e.to_string()))
    }

    /// Page node `i`'s whole `(key, version)` map through `store_keys`.
    fn walk_node_keys(&mut self, i: usize) -> Result<BTreeMap<String, u64>, ClusterError> {
        let mut map = BTreeMap::new();
        let mut after: Option<String> = None;
        loop {
            let req = Request::StoreKeys { after: after.clone(), limit: REPAIR_PAGE };
            let page = match self.slot_call(i, &req)? {
                Response::Keys { keys } => keys,
                Response::Error { message } => return Err(self.remote_err(i, message)),
                other => {
                    return Err(self.remote_err(i, format!("expected keys, got {other:?}")))
                }
            };
            let n = page.len();
            if let Some((last, _)) = page.last() {
                after = Some(last.clone());
            }
            map.extend(page);
            if n < REPAIR_PAGE {
                return Ok(map);
            }
        }
    }

    /// Anti-entropy repair: converge every key's replica set to its
    /// highest version, and every named stream to the merged (§2.3) union
    /// of its per-site sketches.
    ///
    /// 1. walk each live node's `(key, version)` pages (`store_keys`);
    /// 2. per key: find the best version and its holder; stream the
    ///    holder's codec blob (`sketch_fetch`) onto every live owner that
    ///    is missing the key or behind on version (`store_put`,
    ///    last-writer-wins — concurrent writes that land mid-repair are
    ///    never clobbered, because a newer version refuses the stale
    ///    blob);
    /// 3. per stream in `streams`: fetch every live site's stream sketch,
    ///    `merge_tree` them, and `stream_merge` the union back into every
    ///    live node. Merging (never overwriting) is what §2.3 licenses:
    ///    each node keeps its own pushes and absorbs the ones it missed,
    ///    so after repair all replicas hold bit-identical registers and
    ///    the op is idempotent — running repair twice is a no-op.
    ///
    /// Dead nodes are skipped (heal them after `reconnect`); a best-copy
    /// holder dying mid-repair skips that key (`keys_skipped`) rather
    /// than failing the whole pass. Keys found on non-owner nodes (e.g.
    /// ownership drift after a membership change) are used as version
    /// *sources* but never deleted — repair only adds state.
    pub fn repair(&mut self, streams: &[String]) -> Result<RepairReport, ClusterError> {
        let mut report = RepairReport::default();
        // Phase 1: every live node's key→version map.
        let mut maps: Vec<Option<BTreeMap<String, u64>>> = Vec::with_capacity(self.slots.len());
        for i in 0..self.slots.len() {
            if !self.is_live(i) {
                maps.push(None);
                continue;
            }
            match self.walk_node_keys(i) {
                Ok(m) => maps.push(Some(m)),
                Err(ClusterError::NodeDown { node, .. }) => {
                    log::warn!("repair: node '{node}' died during its key walk, skipping it");
                    maps.push(None);
                }
                Err(e) => return Err(e),
            }
        }
        if maps.iter().all(|m| m.is_none()) {
            return Err(ClusterError::NoLiveNodes);
        }
        // Phase 2: per key, best version + holder (lowest slot on ties).
        // Version-only diffing means equal-version divergence — possible
        // when a rejoined node was NOT repaired before the next outage —
        // is invisible here; see README §Replication for the
        // repair-on-rejoin rule that keeps that state unreachable.
        let mut best: BTreeMap<String, (u64, usize)> = BTreeMap::new();
        for (i, map) in maps.iter().enumerate() {
            let Some(map) = map else { continue };
            for (key, &version) in map {
                let held = best.get(key).map(|&(v, _)| v);
                if !held.is_some_and(|h| h >= version) {
                    best.insert(key.clone(), (version, i));
                }
            }
        }
        report.keys_scanned = best.len();
        for (key, (version, holder)) in best {
            // Which owners need healing?
            let stale: Vec<usize> = self
                .partitioner
                .owners(&key, self.repl.replication)
                .into_iter()
                .filter(|&o| {
                    maps[o].as_ref().is_some_and(|m| {
                        m.get(&key).copied().unwrap_or(0) < version || !m.contains_key(&key)
                    })
                })
                .collect();
            if stale.is_empty() {
                continue;
            }
            // One fetch from the holder, then install on every stale
            // owner. The blob carries (key, version) — `store_put`'s LWW
            // check makes a concurrent newer write win over this repair.
            // The install request is serialized ONCE per key and the same
            // wire bytes fan out to every stale owner (previously each
            // owner re-encoded the identical blob); on the framed wire the
            // blob additionally rides as raw codec bytes end to end.
            let req = self.fetch_req(&key, SketchSource::Store);
            let put = match self.slot_call(holder, &req) {
                Ok(Response::SketchBlob { name: got, data }) if got == key => {
                    PreparedRequest::new(&Request::StorePut { data }, self.repl.framed)
                }
                Ok(Response::SketchBlobBin { name: got, data }) if got == key => {
                    PreparedRequest::new(&Request::StorePutBin { data }, self.repl.framed)
                }
                Ok(_) | Err(ClusterError::NodeDown { .. }) => {
                    // Holder died or no longer has the key (raced a
                    // delete): skip, a rerun converges whatever remains.
                    report.keys_skipped += 1;
                    continue;
                }
                Err(e) => return Err(e),
            };
            // Split-phase install: the blob goes onto every stale owner's
            // wire before any ack is read, so replicas heal in parallel
            // (per-holder fetch batching is a known follow-up; installs
            // dominate at R>2, fetches at R=2).
            let mut installing: Vec<usize> = Vec::new();
            for o in stale {
                match self.slot_send_prepared(o, &put) {
                    Ok(()) => installing.push(o),
                    Err(ClusterError::NodeDown { node, .. }) => {
                        log::warn!("repair: node '{node}' died mid-heal of '{key}'");
                    }
                    Err(e) => return Err(e),
                }
            }
            for o in installing {
                match self.slot_recv(o, 1) {
                    Ok(mut resps) => {
                        self.expect_ack(o, resps.pop().expect("one reply"))?;
                        report.keys_healed += 1;
                    }
                    Err(ClusterError::NodeDown { node, .. }) => {
                        log::warn!("repair: node '{node}' died mid-heal of '{key}'");
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        // Phase 3: stream convergence.
        for stream in streams {
            let merged = match self.merged_stream_sketch(stream) {
                Ok(sk) => sk,
                Err(ClusterError::Gather(msg)) => {
                    // Stream unknown everywhere: nothing to converge.
                    log::warn!("repair: {msg}");
                    continue;
                }
                Err(e) => return Err(e),
            };
            // The merged union is encoded ONCE — raw codec bytes on the
            // framed wire, hex on JSON — and the same serialized request
            // fans out to every live node.
            let req = if self.repl.framed {
                Request::StreamMergeBin {
                    stream: stream.clone(),
                    data: codec::encode_sketch_bytes(stream, 0, &merged),
                }
            } else {
                Request::StreamMerge {
                    stream: stream.clone(),
                    data: codec::encode_sketch_hex(stream, 0, &merged),
                }
            };
            let put = PreparedRequest::new(&req, self.repl.framed);
            for i in 0..self.slots.len() {
                if !self.is_live(i) {
                    continue;
                }
                let sent = self.slot_send_prepared(i, &put);
                match sent.and_then(|()| self.slot_recv(i, 1)) {
                    Ok(mut resps) => {
                        let resp = resps.pop().expect("slot_recv(1) yields one reply");
                        self.expect_ack(i, resp)?;
                        report.stream_merges += 1;
                    }
                    Err(ClusterError::NodeDown { node, .. }) => {
                        log::warn!("repair: node '{node}' died mid stream-merge of '{stream}'");
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        Ok(report)
    }

    /// Per-node `(node id, store size)` from `store_stats`, skipping dead
    /// nodes — the CLI's occupancy report. With replication R, sizes sum
    /// to ~R× the number of distinct keys.
    pub fn store_sizes(&mut self) -> Vec<(String, Option<f64>)> {
        (0..self.slots.len())
            .map(|i| {
                let id = self.slots[i].hello.node.clone();
                let size = match self.slot_call(i, &Request::StoreStats) {
                    Ok(Response::Stats { stats }) => {
                        stats.get("size").and_then(|v| v.as_f64())
                    }
                    _ => None,
                };
                (id, size)
            })
            .collect()
    }

    /// Snapshot node `i`'s store to a node-local `path`.
    pub fn snapshot_node(&mut self, i: usize, path: &str) -> Result<String, ClusterError> {
        let resp = self.slot_call(i, &Request::Snapshot { path: path.to_string() })?;
        self.expect_ack(i, resp)
    }

    /// Restore node `i`'s store from a node-local `path` (bumps its epoch;
    /// refresh with [`ClusterClient::reconnect`] to observe it). Clears
    /// the gather cache: a restore can move key versions backwards, which
    /// the forward-only `(key, version)` validation cannot detect. (A
    /// restore driven by a *different* client leaves this one's cache
    /// exposed to the same regression until its next `reconnect` — the
    /// version-only trust `repair` already documents.)
    pub fn restore_node(&mut self, i: usize, path: &str) -> Result<String, ClusterError> {
        let resp = self.slot_call(i, &Request::Restore { path: path.to_string() })?;
        let ack = self.expect_ack(i, resp)?;
        if let Some(cache) = &self.gather_cache {
            cache.clear();
        }
        Ok(ack)
    }

    /// Node `i`'s current `(key, version)` map — the convergence witness
    /// the acceptance tests (and curious operators) read after a repair.
    pub fn node_keys(&mut self, i: usize) -> Result<BTreeMap<String, u64>, ClusterError> {
        if !self.is_live(i) {
            return Err(self.down_err(i, "previously observed down"));
        }
        self.walk_node_keys(i)
    }
}
