//! Rendezvous (highest-random-weight) partitioning of keys onto nodes.
//!
//! Every key is owned by the node whose `(node, key)` hash is largest.
//! Unlike modulo partitioning, membership changes are minimal: removing a
//! node only remaps the keys that node owned, and adding one steals an
//! ~`1/(n+1)` fraction from everyone — no ring maintenance, no
//! virtual-node bookkeeping, deterministic from the node-id list alone
//! (every client that knows the same ids computes the same owners).

use crate::util::hash::{mix2, token_id};

#[derive(Debug, Clone)]
pub struct Partitioner {
    /// `token_id` of each node id, in cluster order.
    node_tokens: Vec<u64>,
}

impl Partitioner {
    /// Build from the cluster's node ids (order defines the index space).
    /// Duplicate ids would make ownership ambiguous and are rejected.
    pub fn new(node_ids: &[String]) -> anyhow::Result<Partitioner> {
        anyhow::ensure!(!node_ids.is_empty(), "partitioner needs at least one node");
        let node_tokens: Vec<u64> = node_ids.iter().map(|id| token_id(id)).collect();
        for (i, id) in node_ids.iter().enumerate() {
            anyhow::ensure!(
                !node_ids[..i].contains(id),
                "duplicate node id '{id}' in the cluster"
            );
        }
        Ok(Partitioner { node_tokens })
    }

    pub fn nodes(&self) -> usize {
        self.node_tokens.len()
    }

    /// Owning node index for a store key.
    pub fn owner(&self, key: &str) -> usize {
        self.owner_of_id(token_id(key))
    }

    /// Owning node index for a stream element id. Routing streams by
    /// element id keeps every occurrence of an element on one site, which
    /// is exactly the disjoint-support case of §2.3: the per-site stream
    /// sketches merge bit-identically to the sketch of the whole stream.
    pub fn owner_of_id(&self, id: u64) -> usize {
        let mut best = 0usize;
        let mut best_w = u64::MIN;
        for (i, &tok) in self.node_tokens.iter().enumerate() {
            let w = mix2(tok, id);
            // Strict '>' keeps the lowest index on (astronomically rare)
            // ties, so every client breaks them identically.
            if i == 0 || w > best_w {
                best = i;
                best_w = w;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("node-{i}")).collect()
    }

    #[test]
    fn ownership_is_deterministic_and_total() {
        let p = Partitioner::new(&ids(3)).unwrap();
        let q = Partitioner::new(&ids(3)).unwrap();
        for i in 0..500 {
            let key = format!("doc{i}");
            let o = p.owner(&key);
            assert!(o < 3);
            assert_eq!(o, q.owner(&key), "owners must agree across clients");
            assert_eq!(o, p.owner(&key), "owner must be stable");
        }
    }

    #[test]
    fn keys_spread_over_every_node() {
        let p = Partitioner::new(&ids(4)).unwrap();
        let mut counts = [0usize; 4];
        for i in 0..2000 {
            counts[p.owner(&format!("doc{i:04}"))] += 1;
        }
        // Rendezvous over 4 nodes: expect ~500 each; very loose bounds so
        // the test only catches broken hashing, not statistical noise.
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 250 && c < 750, "node {i} owns {c}/2000 keys: {counts:?}");
        }
    }

    /// HRW's minimal-disruption property: dropping one node remaps only the
    /// keys that node owned; everything else keeps its owner (by node id).
    #[test]
    fn removing_a_node_only_remaps_its_keys() {
        let all = ids(4);
        let p4 = Partitioner::new(&all).unwrap();
        let survivors: Vec<String> =
            all.iter().filter(|id| *id != "node-2").cloned().collect();
        let p3 = Partitioner::new(&survivors).unwrap();
        for i in 0..1000 {
            let key = format!("doc{i:04}");
            let before = &all[p4.owner(&key)];
            let after = &survivors[p3.owner(&key)];
            if before != "node-2" {
                assert_eq!(before, after, "'{key}' moved needlessly");
            } else {
                assert_ne!(after, "node-2");
            }
        }
    }

    #[test]
    fn stream_ids_partition_like_keys() {
        let p = Partitioner::new(&ids(3)).unwrap();
        for id in 0..1000u64 {
            let o = p.owner_of_id(id);
            assert!(o < 3);
            assert_eq!(o, p.owner_of_id(id));
        }
    }

    #[test]
    fn rejects_empty_and_duplicate_node_sets() {
        assert!(Partitioner::new(&[]).is_err());
        assert!(Partitioner::new(&["a".into(), "b".into(), "a".into()]).is_err());
        assert_eq!(Partitioner::new(&ids(1)).unwrap().owner("anything"), 0);
    }
}
