//! Rendezvous (highest-random-weight) partitioning of keys onto nodes,
//! generalized to **replica sets**: the R owners of a key are the R nodes
//! with the largest `(node, key)` hashes — the prefix of the key's full
//! HRW ranking.
//!
//! Unlike modulo partitioning, membership changes are minimal: removing a
//! node only remaps the keys that node owned, and adding one steals an
//! ~`1/(n+1)` fraction from everyone — no ring maintenance, no
//! virtual-node bookkeeping, deterministic from the node-id list alone
//! (every client that knows the same ids computes the same owners).
//!
//! Replica sets inherit both properties *for free* from the ranking view:
//!
//! * **prefix stability** — `owners(key, r)` is literally the first `r`
//!   entries of one fixed ranking, so raising R only *appends* owners
//!   (no existing replica ever moves), and `owner()` is `owners(_, 1)`;
//! * **standby promotion** — removing a node deletes it from every
//!   ranking it appears in without reordering the survivors, so a key
//!   only changes its replica set if the removed node was in it, and the
//!   only change is its standby (the old rank-R+1 node) stepping in.
//!
//! Node-id strings are hashed exactly once, at construction; every
//! `owner`/`owners` call afterwards only mixes the precomputed per-node
//! digest with the key hash (`benches/perf_probe.rs` tracks this as
//! `cluster.owner_ns` next to a rehash-per-call baseline).

use crate::util::hash::{mix2, token_id};

#[derive(Debug, Clone)]
pub struct Partitioner {
    /// Precomputed 64-bit digest (`token_id`) of each node id, in cluster
    /// order — the only thing `owners_of_id` ever touches per call.
    node_tokens: Vec<u64>,
}

impl Partitioner {
    /// Build from the cluster's node ids (order defines the index space).
    /// Duplicate ids would make ownership ambiguous and are rejected.
    pub fn new(node_ids: &[String]) -> anyhow::Result<Partitioner> {
        anyhow::ensure!(!node_ids.is_empty(), "partitioner needs at least one node");
        let node_tokens: Vec<u64> = node_ids.iter().map(|id| token_id(id)).collect();
        for (i, id) in node_ids.iter().enumerate() {
            anyhow::ensure!(
                !node_ids[..i].contains(id),
                "duplicate node id '{id}' in the cluster"
            );
        }
        Ok(Partitioner { node_tokens })
    }

    pub fn nodes(&self) -> usize {
        self.node_tokens.len()
    }

    /// Primary owner of a store key (`owners(key, 1)[0]`).
    pub fn owner(&self, key: &str) -> usize {
        self.owner_of_id(token_id(key))
    }

    /// Primary owner for a stream element id.
    pub fn owner_of_id(&self, id: u64) -> usize {
        let mut best = 0usize;
        let mut best_w = u64::MIN;
        for (i, &tok) in self.node_tokens.iter().enumerate() {
            let w = mix2(tok, id);
            // Strict '>' keeps the lowest index on (astronomically rare)
            // ties, so every client breaks them identically.
            if i == 0 || w > best_w {
                best = i;
                best_w = w;
            }
        }
        best
    }

    /// The replica set of a store key: the top-`r` node indices of the
    /// key's HRW ranking (weight desc, index asc on ties). `r` is clamped
    /// to the cluster size; `r == 0` is rejected as a caller bug.
    pub fn owners(&self, key: &str, r: usize) -> Vec<usize> {
        self.owners_of_id(token_id(key), r)
    }

    /// Replica set for a stream element id. Routing streams by element id
    /// keeps every occurrence of an element on the same `r` sites, which
    /// is exactly the §2.3 merge-friendly layout: per-site stream sketches
    /// of any covering subset of replicas merge bit-identically to the
    /// sketch of the whole stream (re-occurrences are idempotent).
    pub fn owners_of_id(&self, id: u64, r: usize) -> Vec<usize> {
        assert!(r >= 1, "replica sets need at least one owner");
        let r = r.min(self.node_tokens.len());
        // Insertion-sorted top-r: n and r are both small (cluster sizes,
        // replication factors), so this beats sorting the full ranking.
        let mut top: Vec<(u64, usize)> = Vec::with_capacity(r + 1);
        for (i, &tok) in self.node_tokens.iter().enumerate() {
            let w = mix2(tok, id);
            // `>=` places an equal weight AFTER the ones already kept:
            // indices ascend during the scan, so ties rank index-asc —
            // the same deterministic order every client computes.
            let pos = top.partition_point(|&(tw, _)| tw >= w);
            if pos < r {
                top.insert(pos, (w, i));
                top.truncate(r);
            }
        }
        top.into_iter().map(|(_, i)| i).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("node-{i}")).collect()
    }

    #[test]
    fn ownership_is_deterministic_and_total() {
        let p = Partitioner::new(&ids(3)).unwrap();
        let q = Partitioner::new(&ids(3)).unwrap();
        for i in 0..500 {
            let key = format!("doc{i}");
            let o = p.owner(&key);
            assert!(o < 3);
            assert_eq!(o, q.owner(&key), "owners must agree across clients");
            assert_eq!(o, p.owner(&key), "owner must be stable");
        }
    }

    #[test]
    fn keys_spread_over_every_node() {
        let p = Partitioner::new(&ids(4)).unwrap();
        let mut counts = [0usize; 4];
        for i in 0..2000 {
            counts[p.owner(&format!("doc{i:04}"))] += 1;
        }
        // Rendezvous over 4 nodes: expect ~500 each; very loose bounds so
        // the test only catches broken hashing, not statistical noise.
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 250 && c < 750, "node {i} owns {c}/2000 keys: {counts:?}");
        }
    }

    /// HRW's minimal-disruption property: dropping one node remaps only the
    /// keys that node owned; everything else keeps its owner (by node id).
    #[test]
    fn removing_a_node_only_remaps_its_keys() {
        let all = ids(4);
        let p4 = Partitioner::new(&all).unwrap();
        let survivors: Vec<String> =
            all.iter().filter(|id| *id != "node-2").cloned().collect();
        let p3 = Partitioner::new(&survivors).unwrap();
        for i in 0..1000 {
            let key = format!("doc{i:04}");
            let before = &all[p4.owner(&key)];
            let after = &survivors[p3.owner(&key)];
            if before != "node-2" {
                assert_eq!(before, after, "'{key}' moved needlessly");
            } else {
                assert_ne!(after, "node-2");
            }
        }
    }

    #[test]
    fn stream_ids_partition_like_keys() {
        let p = Partitioner::new(&ids(3)).unwrap();
        for id in 0..1000u64 {
            let o = p.owner_of_id(id);
            assert!(o < 3);
            assert_eq!(o, p.owner_of_id(id));
        }
    }

    #[test]
    fn rejects_empty_and_duplicate_node_sets() {
        assert!(Partitioner::new(&[]).is_err());
        assert!(Partitioner::new(&["a".into(), "b".into(), "a".into()]).is_err());
        assert_eq!(Partitioner::new(&ids(1)).unwrap().owner("anything"), 0);
    }

    #[test]
    fn replica_sets_are_distinct_and_led_by_the_owner() {
        let p = Partitioner::new(&ids(5)).unwrap();
        for i in 0..500 {
            let key = format!("doc{i}");
            for r in 1..=5 {
                let owners = p.owners(&key, r);
                assert_eq!(owners.len(), r);
                let mut uniq = owners.clone();
                uniq.sort_unstable();
                uniq.dedup();
                assert_eq!(uniq.len(), r, "'{key}' r={r}: duplicate owners {owners:?}");
                assert_eq!(owners[0], p.owner(&key), "rank 1 must be the primary");
            }
        }
    }

    #[test]
    fn replica_sets_are_prefix_stable_in_r() {
        let p = Partitioner::new(&ids(5)).unwrap();
        for i in 0..500 {
            let key = format!("doc{i}");
            let full = p.owners(&key, 5);
            for r in 1..5 {
                assert_eq!(
                    p.owners(&key, r),
                    full[..r],
                    "'{key}': owners({r}) is not a prefix of owners(5)"
                );
            }
        }
    }

    /// Removing a node from the membership only promotes its standby into
    /// the replica sets it was part of — survivors never reshuffle.
    #[test]
    fn removing_a_node_only_promotes_its_standby() {
        const R: usize = 2;
        let all = ids(4);
        let p4 = Partitioner::new(&all).unwrap();
        let survivors: Vec<String> =
            all.iter().filter(|id| *id != "node-1").cloned().collect();
        let p3 = Partitioner::new(&survivors).unwrap();
        for i in 0..1000 {
            let key = format!("doc{i:04}");
            let before: Vec<&String> = p4.owners(&key, R).into_iter().map(|o| &all[o]).collect();
            let after: Vec<&String> =
                p3.owners(&key, R).into_iter().map(|o| &survivors[o]).collect();
            if !before.contains(&&"node-1".to_string()) {
                assert_eq!(before, after, "'{key}' reshuffled without cause");
            } else {
                // The new set is the old rank-(R+1) ranking minus node-1,
                // order preserved: survivors keep their ranks, the standby
                // fills the vacated slot.
                let want: Vec<&String> = p4
                    .owners(&key, R + 1)
                    .into_iter()
                    .map(|o| &all[o])
                    .filter(|id| *id != "node-1")
                    .collect();
                assert_eq!(after, want[..R], "'{key}' promoted the wrong standby");
            }
        }
    }

    #[test]
    fn owners_clamps_r_to_the_cluster() {
        let p = Partitioner::new(&ids(2)).unwrap();
        assert_eq!(p.owners("x", 9).len(), 2);
    }
}
