//! In-process cluster harness: N real nodes (coordinator + TCP server)
//! on loopback, each with its own worker pool, store and snapshot files —
//! real sockets, real protocol, one process. Drives `fastgm cluster
//! serve`, `examples/cluster_serve.rs` and the acceptance tests.

use crate::coordinator::server::Server;
use crate::coordinator::service::{Coordinator, CoordinatorConfig};
use std::sync::Arc;

/// Which transport every node of a [`LocalCluster`] serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NodeTransport {
    /// Thread-per-connection JSON lines (the portable default).
    #[default]
    Json,
    /// The event-driven transport (unix only): binary frames and JSON
    /// lines on one port — what a framed `ClusterClient`
    /// (`ReplicaConfig::framed`) requires its nodes to speak.
    #[cfg(unix)]
    Event,
}

/// A running node's server handle, one variant per transport.
enum NodeServer {
    Json(Server),
    #[cfg(unix)]
    Event(crate::coordinator::event_server::EventServer),
}

impl NodeServer {
    fn addr(&self) -> String {
        match self {
            NodeServer::Json(s) => s.addr.to_string(),
            #[cfg(unix)]
            NodeServer::Event(s) => s.addr.to_string(),
        }
    }

    fn stop(self) {
        match self {
            NodeServer::Json(s) => s.stop(),
            #[cfg(unix)]
            NodeServer::Event(s) => s.stop(),
        }
    }
}

struct LocalNode {
    cfg: CoordinatorConfig,
    addr: String,
    /// `None` after [`LocalCluster::kill`].
    running: Option<(NodeServer, Arc<Coordinator>)>,
}

pub struct LocalCluster {
    nodes: Vec<LocalNode>,
    transport: NodeTransport,
}

impl LocalCluster {
    /// Start `n` nodes on ephemeral loopback ports. Each node gets
    /// `base`'s config with a unique, stable id `"<base id>-<i>"` — the
    /// identity the partitioner keys on.
    pub fn start(n: usize, base: &CoordinatorConfig) -> anyhow::Result<LocalCluster> {
        let addrs = vec!["127.0.0.1:0".to_string(); n];
        LocalCluster::start_on(&addrs, base)
    }

    /// [`LocalCluster::start`] on the event-driven transport: every node
    /// serves binary frames next to JSON lines, so framed cluster clients
    /// (and the binary blob data plane) can form against it. Kill/restart
    /// cycles keep the transport.
    #[cfg(unix)]
    pub fn start_event(n: usize, base: &CoordinatorConfig) -> anyhow::Result<LocalCluster> {
        let addrs = vec!["127.0.0.1:0".to_string(); n];
        LocalCluster::start_with(&addrs, base, NodeTransport::Event)
    }

    /// Start one node per bind address (the CLI's fixed-port path).
    pub fn start_on(addrs: &[String], base: &CoordinatorConfig) -> anyhow::Result<LocalCluster> {
        LocalCluster::start_with(addrs, base, NodeTransport::Json)
    }

    /// Start one node per bind address on the chosen transport.
    pub fn start_with(
        addrs: &[String],
        base: &CoordinatorConfig,
        transport: NodeTransport,
    ) -> anyhow::Result<LocalCluster> {
        anyhow::ensure!(!addrs.is_empty(), "cluster needs at least one node");
        let mut nodes = Vec::with_capacity(addrs.len());
        for (i, bind) in addrs.iter().enumerate() {
            let cfg = CoordinatorConfig {
                node_id: format!("{}-{i}", base.node_id),
                ..base.clone()
            };
            let (server, coordinator) = spawn(&cfg, bind, transport)?;
            nodes.push(LocalNode {
                cfg,
                addr: server.addr(),
                running: Some((server, coordinator)),
            });
        }
        Ok(LocalCluster { nodes, transport })
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Current serve addresses, cluster order (a restarted node may have
    /// moved to a fresh ephemeral port).
    pub fn addrs(&self) -> Vec<String> {
        self.nodes.iter().map(|n| n.addr.clone()).collect()
    }

    pub fn addr(&self, i: usize) -> &str {
        &self.nodes[i].addr
    }

    pub fn node_id(&self, i: usize) -> &str {
        &self.nodes[i].cfg.node_id
    }

    pub fn is_up(&self, i: usize) -> bool {
        self.nodes[i].running.is_some()
    }

    /// Stop node `i` completely: the server joins every connection thread,
    /// then the coordinator (pool + node core) is torn down. Its partition
    /// goes dark; the rest of the cluster keeps serving.
    pub fn kill(&mut self, i: usize) {
        if let Some((server, coordinator)) = self.nodes[i].running.take() {
            server.stop();
            match Arc::try_unwrap(coordinator) {
                Ok(c) => c.shutdown(),
                Err(_) => log::warn!(
                    "node '{}' still referenced after stop",
                    self.nodes[i].cfg.node_id
                ),
            }
        }
    }

    /// Bring node `i` back **cold** (same id and config, empty store) on a
    /// fresh ephemeral port — rebinding the old port would race the
    /// kernel's TIME_WAIT connections. State comes back via snapshot
    /// `restore`; identity (the node id) is what the cluster keys on.
    pub fn restart(&mut self, i: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.nodes[i].running.is_none(), "node {i} is already running");
        let (server, coordinator) = spawn(&self.nodes[i].cfg, "127.0.0.1:0", self.transport)?;
        self.nodes[i].addr = server.addr();
        self.nodes[i].running = Some((server, coordinator));
        Ok(())
    }

    /// Tear the whole cluster down (joins everything).
    pub fn stop(mut self) {
        for i in 0..self.nodes.len() {
            self.kill(i);
        }
    }
}

fn spawn(
    cfg: &CoordinatorConfig,
    bind: &str,
    transport: NodeTransport,
) -> anyhow::Result<(NodeServer, Arc<Coordinator>)> {
    let coordinator = Arc::new(Coordinator::new(cfg.clone())?);
    let server = match transport {
        NodeTransport::Json => NodeServer::Json(Server::start(coordinator.clone(), bind)?),
        #[cfg(unix)]
        NodeTransport::Event => NodeServer::Event(
            crate::coordinator::event_server::EventServer::start(coordinator.clone(), bind)?,
        ),
    };
    Ok((server, coordinator))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::client::Client;
    use crate::coordinator::protocol::Request;

    fn base() -> CoordinatorConfig {
        CoordinatorConfig { k: 32, workers: 1, node_id: "t".into(), ..Default::default() }
    }

    #[test]
    fn nodes_get_distinct_ids_and_addresses() {
        let cluster = LocalCluster::start(3, &base()).unwrap();
        assert_eq!(cluster.len(), 3);
        let addrs = cluster.addrs();
        let mut uniq = addrs.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 3, "addresses must be distinct: {addrs:?}");
        for i in 0..3 {
            assert_eq!(cluster.node_id(i), format!("t-{i}"));
            let mut c = Client::connect(cluster.addr(i)).unwrap();
            let hello = c.hello().unwrap();
            assert_eq!(hello.node, format!("t-{i}"));
        }
        cluster.stop();
    }

    /// Event-transport clusters serve frames on every node, and a
    /// kill/restart cycle keeps the transport.
    #[cfg(unix)]
    #[test]
    fn event_transport_cluster_speaks_frames_across_restarts() {
        let mut cluster = LocalCluster::start_event(2, &base()).unwrap();
        for i in 0..2 {
            let mut c = Client::connect_framed(cluster.addr(i)).unwrap();
            assert!(c.is_framed());
            assert_eq!(c.hello().unwrap().node, format!("t-{i}"));
        }
        cluster.kill(1);
        cluster.restart(1).unwrap();
        let mut c = Client::connect_framed(cluster.addr(1)).unwrap();
        assert_eq!(c.hello().unwrap().node, "t-1");
        cluster.stop();
    }

    #[test]
    fn kill_and_restart_cycle() {
        let mut cluster = LocalCluster::start(2, &base()).unwrap();
        let old_addr = cluster.addr(1).to_string();
        cluster.kill(1);
        assert!(!cluster.is_up(1));
        assert!(cluster.is_up(0), "killing one node must not touch the others");
        assert!(Client::connect(&old_addr).is_err(), "dead node still accepting");
        // Double-kill is a no-op.
        cluster.kill(1);
        cluster.restart(1).unwrap();
        assert!(cluster.is_up(1));
        // Same identity, cold state, possibly new port.
        let mut c = Client::connect(cluster.addr(1)).unwrap();
        let hello = c.hello().unwrap();
        assert_eq!(hello.node, "t-1");
        assert_eq!(hello.epoch, 0, "restart is cold until a restore");
        assert!(matches!(
            c.call(&Request::Ping).unwrap(),
            crate::coordinator::protocol::Response::Pong
        ));
        assert!(cluster.restart(1).is_err(), "restarting a live node must fail");
        cluster.stop();
    }
}
