//! In-process cluster harness: N real nodes (coordinator + TCP server)
//! on loopback, each with its own worker pool, store and snapshot files —
//! real sockets, real protocol, one process. Drives `fastgm cluster
//! serve`, `examples/cluster_serve.rs` and the acceptance tests.

use crate::coordinator::server::Server;
use crate::coordinator::service::{Coordinator, CoordinatorConfig};
use std::sync::Arc;

struct LocalNode {
    cfg: CoordinatorConfig,
    addr: String,
    /// `None` after [`LocalCluster::kill`].
    running: Option<(Server, Arc<Coordinator>)>,
}

pub struct LocalCluster {
    nodes: Vec<LocalNode>,
}

impl LocalCluster {
    /// Start `n` nodes on ephemeral loopback ports. Each node gets
    /// `base`'s config with a unique, stable id `"<base id>-<i>"` — the
    /// identity the partitioner keys on.
    pub fn start(n: usize, base: &CoordinatorConfig) -> anyhow::Result<LocalCluster> {
        let addrs = vec!["127.0.0.1:0".to_string(); n];
        LocalCluster::start_on(&addrs, base)
    }

    /// Start one node per bind address (the CLI's fixed-port path).
    pub fn start_on(addrs: &[String], base: &CoordinatorConfig) -> anyhow::Result<LocalCluster> {
        anyhow::ensure!(!addrs.is_empty(), "cluster needs at least one node");
        let mut nodes = Vec::with_capacity(addrs.len());
        for (i, bind) in addrs.iter().enumerate() {
            let cfg = CoordinatorConfig {
                node_id: format!("{}-{i}", base.node_id),
                ..base.clone()
            };
            let (server, coordinator) = spawn(&cfg, bind)?;
            nodes.push(LocalNode {
                cfg,
                addr: server.addr.to_string(),
                running: Some((server, coordinator)),
            });
        }
        Ok(LocalCluster { nodes })
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Current serve addresses, cluster order (a restarted node may have
    /// moved to a fresh ephemeral port).
    pub fn addrs(&self) -> Vec<String> {
        self.nodes.iter().map(|n| n.addr.clone()).collect()
    }

    pub fn addr(&self, i: usize) -> &str {
        &self.nodes[i].addr
    }

    pub fn node_id(&self, i: usize) -> &str {
        &self.nodes[i].cfg.node_id
    }

    pub fn is_up(&self, i: usize) -> bool {
        self.nodes[i].running.is_some()
    }

    /// Stop node `i` completely: the server joins every connection thread,
    /// then the coordinator (pool + node core) is torn down. Its partition
    /// goes dark; the rest of the cluster keeps serving.
    pub fn kill(&mut self, i: usize) {
        if let Some((server, coordinator)) = self.nodes[i].running.take() {
            server.stop();
            match Arc::try_unwrap(coordinator) {
                Ok(c) => c.shutdown(),
                Err(_) => log::warn!(
                    "node '{}' still referenced after stop",
                    self.nodes[i].cfg.node_id
                ),
            }
        }
    }

    /// Bring node `i` back **cold** (same id and config, empty store) on a
    /// fresh ephemeral port — rebinding the old port would race the
    /// kernel's TIME_WAIT connections. State comes back via snapshot
    /// `restore`; identity (the node id) is what the cluster keys on.
    pub fn restart(&mut self, i: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.nodes[i].running.is_none(), "node {i} is already running");
        let (server, coordinator) = spawn(&self.nodes[i].cfg, "127.0.0.1:0")?;
        self.nodes[i].addr = server.addr.to_string();
        self.nodes[i].running = Some((server, coordinator));
        Ok(())
    }

    /// Tear the whole cluster down (joins everything).
    pub fn stop(mut self) {
        for i in 0..self.nodes.len() {
            self.kill(i);
        }
    }
}

fn spawn(cfg: &CoordinatorConfig, bind: &str) -> anyhow::Result<(Server, Arc<Coordinator>)> {
    let coordinator = Arc::new(Coordinator::new(cfg.clone())?);
    let server = Server::start(coordinator.clone(), bind)?;
    Ok((server, coordinator))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::client::Client;
    use crate::coordinator::protocol::Request;

    fn base() -> CoordinatorConfig {
        CoordinatorConfig { k: 32, workers: 1, node_id: "t".into(), ..Default::default() }
    }

    #[test]
    fn nodes_get_distinct_ids_and_addresses() {
        let cluster = LocalCluster::start(3, &base()).unwrap();
        assert_eq!(cluster.len(), 3);
        let addrs = cluster.addrs();
        let mut uniq = addrs.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 3, "addresses must be distinct: {addrs:?}");
        for i in 0..3 {
            assert_eq!(cluster.node_id(i), format!("t-{i}"));
            let mut c = Client::connect(cluster.addr(i)).unwrap();
            let hello = c.hello().unwrap();
            assert_eq!(hello.node, format!("t-{i}"));
        }
        cluster.stop();
    }

    #[test]
    fn kill_and_restart_cycle() {
        let mut cluster = LocalCluster::start(2, &base()).unwrap();
        let old_addr = cluster.addr(1).to_string();
        cluster.kill(1);
        assert!(!cluster.is_up(1));
        assert!(cluster.is_up(0), "killing one node must not touch the others");
        assert!(Client::connect(&old_addr).is_err(), "dead node still accepting");
        // Double-kill is a no-op.
        cluster.kill(1);
        cluster.restart(1).unwrap();
        assert!(cluster.is_up(1));
        // Same identity, cold state, possibly new port.
        let mut c = Client::connect(cluster.addr(1)).unwrap();
        let hello = c.hello().unwrap();
        assert_eq!(hello.node, "t-1");
        assert_eq!(hello.epoch, 0, "restart is cold until a restore");
        assert!(matches!(
            c.call(&Request::Ping).unwrap(),
            crate::coordinator::protocol::Response::Pong
        ));
        assert!(cluster.restart(1).is_err(), "restarting a live node must fail");
        cluster.stop();
    }
}
