//! Cluster-sharded serving: the fan-out layer on top of the node core.
//!
//! The paper's §2.3 mergeability is what makes Gumbel-Max sketches
//! distributable: per-site sketches merge register-wise into exactly the
//! sketch of the union, bit for bit. This module turns that property into
//! a serving topology — the many-sites/central-estimator deployment of
//! Qi et al. (WWW'20) and the partition-then-reduce retrieval of Mussmann
//! et al. (2017):
//!
//! * [`Partitioner`] — rendezvous (highest-random-weight) hashing from
//!   store keys / stream element ids to node indices. Stable under node-set
//!   changes: removing one node only remaps the keys it owned.
//! * [`ClusterClient`] — the scatter-gather router. Routes `upsert`/
//!   `delete` to the owning node, fans `topk` out to every live node
//!   (per-node LSH candidates → central `estimate_jp` re-rank over
//!   codec-fetched sketches → global k), partitions stream pushes by
//!   element id, and computes cluster-wide weighted cardinality by
//!   `merge_tree`-ing per-site stream sketches fetched through
//!   [`crate::sketch::codec`].
//! * [`LocalCluster`] — an in-process harness spawning N real TCP nodes on
//!   loopback (the `fastgm cluster serve` CLI, `examples/cluster_serve.rs`
//!   and the acceptance tests all drive it).
//!
//! Failure domains: every node is its own. A dead node degrades `topk`
//! coverage (its partition's candidates vanish, the gather still answers)
//! and fails *writes to its partition* with a typed
//! [`ClusterError::NodeDown`] — it never wedges or panics the gather, and
//! a gather over zero live nodes is [`ClusterError::NoLiveNodes`], backed
//! by [`crate::sketch::MergeError::EmptyMerge`] at the merge layer.

mod client;
mod harness;
mod partitioner;

pub use client::{ClusterClient, ClusterError, GatherStats};
pub use harness::LocalCluster;
pub use partitioner::Partitioner;
