//! Replicated cluster serving: the fan-out layer on top of the node core.
//!
//! The paper's §2.3 mergeability is what makes Gumbel-Max sketches
//! distributable AND replicable: per-site sketches merge register-wise
//! into exactly the sketch of the union, bit for bit, and replays are
//! idempotent — so replicas converge by merge with no coordination. This
//! module turns that property into a serving topology — the many-sites/
//! central-estimator deployment of Qi et al. (WWW'20) and the
//! partition-then-reduce retrieval of Mussmann et al. (2017), hardened
//! with HRW replica sets:
//!
//! * [`Partitioner`] — rendezvous (highest-random-weight) hashing from
//!   store keys / stream element ids to **replica sets** (`owners(key,
//!   r)`: the top-R of each key's HRW ranking — prefix-stable in R,
//!   minimal-disruption under node-set changes: removing one node only
//!   promotes each affected key's standby).
//! * [`ClusterClient`] — the replication-aware scatter-gather router.
//!   Fans `upsert`/`delete`/stream `push` out to all R owners under a
//!   configurable write quorum W ([`ReplicaConfig`]; under-quorum writes
//!   are a typed [`ClusterError::QuorumLost`] naming the down nodes),
//!   answers `topk` by per-node LSH candidates → highest-**version**
//!   codec blob per candidate → central `estimate_jp` re-rank → global k
//!   (with failover to surviving replicas), computes cluster-wide
//!   weighted cardinality by `merge_tree`-ing per-site stream sketches,
//!   and heals diverged replicas with [`ClusterClient::repair`] — the
//!   anti-entropy walk (`store_keys` version diff → `store_put` blob
//!   streaming → `stream_merge` union merges).
//! * [`LocalCluster`] — an in-process harness spawning N real TCP nodes on
//!   loopback (the `fastgm cluster serve` CLI, the examples and the
//!   acceptance tests all drive it).
//!
//! Failure domains: every node is its own. At R ≥ 2, W = 1, one dead
//! node is **invisible**: reads and writes keep their exact healthy-
//! cluster answers (every partition has a live replica, and §2.3 merges
//! make replicated stream coverage bit-identical). At R = 1 a dead node
//! degrades `topk` coverage and fails writes to its partition with a
//! typed [`ClusterError::NodeDown`] — it never wedges or panics the
//! gather, and a gather over zero live nodes is
//! [`ClusterError::NoLiveNodes`], backed by
//! [`crate::sketch::MergeError::EmptyMerge`] at the merge layer.

mod client;
mod harness;
mod partitioner;

pub use client::{ClusterClient, ClusterError, GatherStats, RepairReport, ReplicaConfig};
pub use harness::{LocalCluster, NodeTransport};
pub use partitioner::Partitioner;
